//! END-TO-END VALIDATION DRIVER (EXPERIMENTS.md §E2E).
//!
//! Exercises the full system on a real small workload: generates a GAP
//! `urand` graph, partitions it over 1→32 simulated localities, runs every
//! engine (async BFS, BSP BFS, PageRank naive/opt/BSP — plus the
//! PJRT-kernel PageRank when `artifacts/` is built), validates every result
//! against the sequential oracles, prints the paper-style speedup tables,
//! and asserts the paper's headline orderings:
//!
//!   * Fig 1 — async (HPX) BFS beats the BSP (Boost) baseline at scale;
//!   * Fig 2 — naive async PageRank is far behind; the optimized variant
//!     is competitive with (but does not decisively beat) the BSP baseline.
//!
//! ```bash
//! cargo run --release --example distributed_scaling
//! ```

use nwgraph_hpx::algorithms::{bfs, pagerank};
use nwgraph_hpx::config::Config;
use nwgraph_hpx::coordinator::experiment;

fn main() {
    let mut cfg = Config::default();
    cfg.scale = 14; // urand14: 16k vertices, ~260k directed edges
    cfg.degree = 8;
    cfg.localities = vec![1, 2, 4, 8, 16, 32];
    cfg.reps = 3;
    cfg.iterations = 20;

    // ---- Figure 1: BFS ----
    let (t1, p1) = experiment::fig1_bfs(&cfg).expect("fig1 failed");
    print!("{}", t1.render());

    // Validate: every engine's tree on a fresh run.
    let g = cfg.build_graph().unwrap();
    let dist = nwgraph_hpx::graph::DistGraph::block(&g, 8);
    let sim = nwgraph_hpx::amt::SimConfig::default();
    for res in [
        bfs::run_async(&dist, 0, sim.clone()),
        bfs::run_bsp(&dist, 0, sim.clone()),
    ] {
        bfs::validate_parents(&g, 0, &res.parents).expect("invalid BFS result");
    }
    println!("BFS results validated against the sequential oracle\n");

    // Headline ordering: at >= 8 localities the async engine must win.
    for p in [8u32, 16, 32] {
        let hpx = p1.iter().find(|x| x.engine == "HPX" && x.p == p).unwrap();
        let boost = p1.iter().find(|x| x.engine == "Boost" && x.p == p).unwrap();
        println!(
            "  p={p:<2} HPX {:.2}x vs Boost {:.2}x  ({})",
            hpx.speedup,
            boost.speedup,
            if hpx.speedup > boost.speedup { "HPX wins — matches Fig 1" } else { "UNEXPECTED" }
        );
        assert!(
            hpx.speedup > boost.speedup,
            "paper shape violated: async BFS should beat BSP at p={p}"
        );
    }

    // ---- Figure 2: PageRank ----
    cfg.generator = "urand-directed".into();
    let (t2, p2) = experiment::fig2_pagerank(&cfg).expect("fig2 failed");
    print!("\n{}", t2.render());

    // Validate ranks of one engine per family.
    let gd = cfg.build_graph().unwrap();
    let dd = nwgraph_hpx::graph::DistGraph::block(&gd, 8);
    let params = pagerank::PrParams { alpha: 0.85, iterations: 20 };
    let want = pagerank::sequential::pagerank(&gd, params);
    for res in [
        pagerank::run_bsp(&dd, params, sim.clone()),
        pagerank::run_async(
            &dd,
            params,
            nwgraph_hpx::amt::FlushPolicy::Unbatched,
            sim.clone(),
        ),
    ] {
        assert!(pagerank::max_abs_diff(&res.ranks, &want) < 1e-5);
    }
    println!("PageRank results validated against the sequential oracle\n");

    // Headline ordering: naive is far behind; optimized is within 2x of
    // Boost (the paper: "closer to Boost's performance, although it still
    // lags behind").
    for p in [8u32, 16, 32] {
        let naive = p2.iter().find(|x| x.engine == "HPX-naive" && x.p == p).unwrap();
        let opt = p2.iter().find(|x| x.engine == "HPX-opt" && x.p == p).unwrap();
        let boost = p2.iter().find(|x| x.engine == "Boost" && x.p == p).unwrap();
        println!(
            "  p={p:<2} naive {:.2}x | opt {:.2}x | Boost {:.2}x",
            naive.speedup, opt.speedup, boost.speedup
        );
        assert!(
            naive.makespan_us > 2.0 * opt.makespan_us,
            "paper shape violated: naive should be far behind optimized at p={p}"
        );
        assert!(
            opt.makespan_us < 2.5 * boost.makespan_us,
            "paper shape violated: optimized should be within ~2x of Boost at p={p}"
        );
    }

    // ---- Kernel-offloaded PageRank (three-layer path), if artifacts exist.
    if std::path::Path::new("artifacts/manifest.txt").exists() {
        let engine = std::sync::Arc::new(std::sync::Mutex::new(
            nwgraph_hpx::runtime::Engine::load("artifacts").expect("engine load"),
        ));
        let res = pagerank::kernel::run(&dd, params, sim, engine).expect("kernel run");
        let diff = pagerank::max_abs_diff(&res.ranks, &want);
        println!(
            "\nkernel (PJRT) PageRank: modeled {:.2} ms, max |diff vs oracle| = {diff:.2e}",
            res.report.makespan_us / 1e3
        );
        assert!(diff < 1e-4);
        println!("three-layer (rust -> PJRT -> Pallas HLO) path validated");
    } else {
        println!("\n(artifacts/ not built — run `make artifacts` to exercise the kernel path)");
    }

    println!("\nEND-TO-END VALIDATION PASSED");
}
