//! Quickstart: build a graph, partition it, run distributed BFS and
//! PageRank, validate both against the sequential oracles.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use nwgraph_hpx::algorithms::{bfs, pagerank, pagerank::PrParams};
use nwgraph_hpx::amt::{FlushPolicy, NetConfig, SimConfig};
use nwgraph_hpx::graph::{generators, DistGraph};

fn main() {
    // 1. Generate a GAP-style uniform random graph: 2^12 vertices, ~8 avg
    //    degree (the paper's `urand` family, laptop scale).
    let g = generators::urand(12, 8, 42);
    println!("graph: urand12 — n={} m={}", g.n(), g.m());

    // 2. Partition over 8 simulated localities (1-D blocks, like
    //    hpx::partitioned_vector).
    let dist = DistGraph::block(&g, 8);

    // 3. Asynchronous HPX-style BFS from vertex 0.
    let sim = SimConfig { net: NetConfig::default(), ..SimConfig::default() };
    let res = bfs::run_async(&dist, 0, sim.clone());
    let reached = res.parents.iter().filter(|&&p| p >= 0).count();
    println!(
        "async BFS: reached {reached}/{} vertices, modeled time {:.2} ms, {} messages",
        g.n(),
        res.report.makespan_us / 1e3,
        res.report.net.messages
    );
    bfs::validate_parents(&g, 0, &res.parents).expect("BFS tree invalid");
    println!("async BFS: parent tree validated against the sequential oracle");

    // 4. BSP baseline for comparison (distributed-BGL style).
    let bsp = bfs::run_bsp(&dist, 0, sim.clone());
    println!(
        "BSP BFS:   modeled time {:.2} ms, {} barriers",
        bsp.report.makespan_us / 1e3,
        bsp.report.barriers
    );

    // 5. Distributed PageRank (optimized async variant) vs oracle.
    let gd = generators::urand_directed(12, 8, 43);
    let dd = DistGraph::block(&gd, 8);
    let params = PrParams { alpha: 0.85, iterations: 20 };
    let pr = pagerank::run_async(&dd, params, FlushPolicy::Items(1024), sim);
    let want = pagerank::sequential::pagerank(&gd, params);
    let diff = pagerank::max_abs_diff(&pr.ranks, &want);
    println!(
        "PageRank:  20 iters, modeled time {:.2} ms, max |diff vs oracle| = {diff:.2e}",
        pr.report.makespan_us / 1e3
    );
    assert!(diff < 1e-5);
    println!("PageRank:  validated against the sequential oracle");
}
