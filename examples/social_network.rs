//! Social-network analytics: influencer ranking + community structure on a
//! skewed (RMAT/Kronecker) graph — the workload class the paper's intro
//! motivates (social networks, recommendation systems).
//!
//! ```bash
//! cargo run --release --example social_network
//! ```

use nwgraph_hpx::algorithms::{cc, pagerank, pagerank::PrParams, triangle};
use nwgraph_hpx::amt::SimConfig;
use nwgraph_hpx::graph::{degree, generators, DistGraph, Partition1D};

fn main() {
    // Graph500-parameterized Kronecker graph: heavy-tailed degrees, like a
    // real follower graph.
    let g = generators::kron(13, 8, 7);
    let degs = degree::out_degrees(&g);
    let stats = degree::degree_stats(&degs);
    println!(
        "social graph: kron13 — n={} m={} | degree min={} median={} max={}",
        g.n(),
        g.m(),
        stats.min,
        stats.median,
        stats.max
    );

    // Skewed graphs punish naive block partitions; use the edge-balanced
    // cut (DESIGN.md ablation) for even shard sizes.
    let part = Partition1D::edge_balanced(&g, 16);
    println!(
        "partition: 16 localities, edge imbalance {:.2} (block would be {:.2})",
        part.edge_imbalance(&g),
        Partition1D::block(g.n(), 16).edge_imbalance(&g)
    );
    let dist = DistGraph::build(&g, &part);
    let sim = SimConfig::default();

    // Influencers: distributed PageRank, top 10.
    let pr = pagerank::run_bsp(&dist, PrParams { alpha: 0.85, iterations: 25 }, sim.clone());
    let mut ranked: Vec<(usize, f32)> = pr.ranks.iter().cloned().enumerate().collect();
    ranked.sort_by(|a, b| b.1.total_cmp(&a.1));
    println!("\ntop-10 influencers (vertex, rank, degree):");
    for (v, r) in ranked.iter().take(10) {
        println!("  v{v:<6} rank={r:.5} deg={}", degs[*v]);
    }
    println!(
        "pagerank: modeled {:.2} ms over 16 localities",
        pr.report.makespan_us / 1e3
    );

    // Communities: connected components.
    let comps = cc::run(&dist, sim.clone());
    let n_comp = cc::component_count(&comps.labels);
    let mut sizes = std::collections::HashMap::new();
    for &l in &comps.labels {
        *sizes.entry(l).or_insert(0usize) += 1;
    }
    let giant = sizes.values().max().copied().unwrap_or(0);
    println!(
        "\ncommunities: {n_comp} components, giant component {giant}/{} ({:.1}%)",
        g.n(),
        100.0 * giant as f64 / g.n() as f64
    );

    // Cohesion: triangle count (clustering signal).
    let tri = triangle::run(&dist, sim);
    println!(
        "triangles: {} (modeled {:.2} ms distributed)",
        tri.triangles,
        tri.report.makespan_us / 1e3
    );
    assert_eq!(tri.triangles, triangle::count_sequential(&g));
    println!("triangle count validated against sequential oracle");
}
