//! Web-crawl reachability: BFS-based frontier analysis on a uniform random
//! graph, comparing all three distributed BFS engines plus weighted SSSP
//! (latency-weighted crawl cost).
//!
//! ```bash
//! cargo run --release --example web_crawl_bfs
//! ```

use nwgraph_hpx::algorithms::{bfs, sssp};
use nwgraph_hpx::amt::SimConfig;
use nwgraph_hpx::graph::{generators, DistGraph};

fn main() {
    let g = generators::urand(13, 8, 99);
    let dist = DistGraph::block(&g, 8);
    let sim = SimConfig::default();
    let root = 0;

    println!("crawl graph: urand13 — n={} m={}", g.n(), g.m());

    // Frontier profile from the level-synchronous engine (true BFS levels).
    let res = bfs::run_bsp(&dist, root, sim.clone());
    let levels = bfs::tree_levels(root, &res.parents);
    let max_lvl = levels.iter().cloned().max().unwrap_or(0);
    println!("\nfrontier profile (the irregular workload of paper §4.1):");
    for lvl in 0..=max_lvl {
        let count = levels.iter().filter(|&&l| l == lvl).count();
        let bar = "#".repeat((count * 60 / g.n()).max(usize::from(count > 0)));
        println!("  level {lvl:>2}: {count:>7} {bar}");
    }
    let unreached = levels.iter().filter(|&&l| l < 0).count();
    println!("  unreachable: {unreached}");

    // Engine comparison on the same traversal.
    println!("\nengine comparison (8 localities):");
    let hpx_sim = SimConfig {
        aggregate_sends: true,
        coalesce_window_us: 5.0,
        ..SimConfig::default()
    };
    let a = bfs::run_async(&dist, root, hpx_sim);
    let b = bfs::run_bsp(&dist, root, sim.clone());
    let (d, td, bu) = bfs::direction_opt::run_with_params(&dist, root, sim.clone(), 14.0, 24.0);
    for (name, r) in [("async (HPX)", &a), ("level-sync (BGL)", &b), ("direction-opt", &d)] {
        println!(
            "  {name:<18} {:>9.2} ms  msgs={:<8} envs={:<6} barriers={}",
            r.report.makespan_us / 1e3,
            r.report.net.messages,
            r.report.net.envelopes,
            r.report.barriers
        );
    }
    println!("  direction-opt rounds: {td} top-down, {bu} bottom-up");
    for r in [&a, &b, &d] {
        bfs::validate_parents(&g, root, &r.parents).expect("invalid BFS tree");
    }

    // Latency-weighted crawl: SSSP with random per-link latencies (the
    // SSSP engines read weights from the shards, so the distributed graph
    // is rebuilt from the weighted Csr).
    let gw = generators::with_random_weights(&g, 5.0, 150.0, 7);
    let distw = DistGraph::block(&gw, 8);
    let s = sssp::run_async(&gw, &distw, root, sim);
    let reachable: Vec<f32> = s.dist.iter().cloned().filter(|d| d.is_finite()).collect();
    let mean = reachable.iter().sum::<f32>() / reachable.len() as f32;
    let max = reachable.iter().cloned().fold(0.0f32, f32::max);
    println!(
        "\nlatency-weighted crawl (SSSP): mean cost {mean:.1}, max {max:.1}, \
         modeled {:.2} ms",
        s.report.makespan_us / 1e3
    );
    let want = sssp::dijkstra(&gw, root);
    assert!(s
        .dist
        .iter()
        .zip(&want)
        .all(|(a, b)| (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-3));
    println!("SSSP validated against Dijkstra oracle");
}
