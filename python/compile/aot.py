"""AOT: lower the L2 jax functions to HLO *text* artifacts for the rust runtime.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``: jax
>= 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).  The text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Each (kind, n_global, n_rows, max_deg) config becomes one self-contained
module ``artifacts/<kind>_g<G>_r<R>_d<D>.hlo.txt``; ``artifacts/manifest.txt``
lists them all and is the rust side's discovery point
(``runtime::artifact::Manifest``).

Run as ``python -m compile.aot --out-dir ../artifacts`` (the Makefile does).
"""

from __future__ import annotations

import argparse
import os
import sys

from jax._src.lib import xla_client as xc

from compile import model

# Shape registry — every config the rust coordinator may request.  The
# kernel-offload path pads a shard to the smallest covering config; the
# plain rust path has no shape constraint.  Keep this list in sync with
# rust/src/runtime/artifact.rs expectations (parsed from the manifest, so
# adding configs here is enough).
PAGERANK_CONFIGS = [
    # (n_global, n_rows, max_deg)
    (1024, 1024, 16),
    (4096, 4096, 32),
    (4096, 2048, 32),
    (4096, 1024, 32),
    (16384, 16384, 32),
    (16384, 8192, 32),
    (16384, 4096, 32),
    (16384, 2048, 32),
    (65536, 65536, 32),
    (65536, 32768, 32),
    (65536, 16384, 32),
    (65536, 8192, 32),
]

BFS_CONFIGS = [
    (1024, 1024, 16),
    (4096, 4096, 32),
    (4096, 2048, 32),
    (4096, 1024, 32),
    (16384, 16384, 32),
    (16384, 8192, 32),
    (16384, 4096, 32),
    (16384, 2048, 32),
]


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = [
        "# kind n_global n_rows max_deg tile_rows file",
    ]

    def one(kind, lower_fn, g, r, d):
        tile = model._pick_tile_rows(r)
        name = f"{kind}_g{g}_r{r}_d{d}.hlo.txt"
        path = os.path.join(out_dir, name)
        text = to_hlo_text(lower_fn(g, r, d))
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"{kind} {g} {r} {d} {tile} {name}")
        print(f"  {name}: {len(text)} chars", flush=True)

    print("lowering pagerank configs...", flush=True)
    for g, r, d in PAGERANK_CONFIGS:
        one("pagerank", model.lower_pagerank, g, r, d)
    print("lowering bfs configs...", flush=True)
    for g, r, d in BFS_CONFIGS:
        one("bfs", model.lower_bfs, g, r, d)

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {len(manifest_lines) - 1} artifacts to {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="artifact output directory")
    args = ap.parse_args()
    emit(args.out_dir)


if __name__ == "__main__":
    sys.exit(main())
