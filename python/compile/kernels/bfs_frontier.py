"""L1 Pallas kernel: bitmap frontier expansion for level-synchronous BFS.

The BSP/PBGL-style baseline expands a whole frontier level at once.  Per
locality and per level the work is: for every owned vertex ``u`` not yet
visited, check whether any in-neighbor is in the current frontier; if so,
``u`` joins the next frontier and records one frontier neighbor as parent.

With the shard in the same masked-ELL layout as the PageRank kernel this is
a gather + masked-reduce over the slot axis:

    hit[i, j]  = frontier[cols[i, j]] * mask[i, j]
    next[i]    = (max_j hit[i, j] > 0) && !visited[i]
    parent[i]  = cols[i, argmax_j hit[i, j]]        (only valid when next[i])

Everything is carried as f32/i32 bitmaps so a single HLO module covers the
level step.  interpret=True for CPU-PJRT executability (see pagerank_ell).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_ROWS = 1024


def _frontier_kernel(frontier_ref, visited_ref, cols_ref, mask_ref,
                     next_ref, parent_ref):
    frontier = frontier_ref[...]        # (n_global,) f32 bitmap
    visited = visited_ref[...]          # (tile_rows,) f32 bitmap
    cols = cols_ref[...]                # (tile_rows, max_deg) i32
    mask = mask_ref[...]                # (tile_rows, max_deg) f32
    hit = frontier[cols] * mask         # (tile_rows, max_deg)
    any_hit = jnp.max(hit, axis=1)      # > 0 iff some frontier in-neighbor
    nxt = jnp.where(any_hit > 0.0, 1.0, 0.0) * (1.0 - visited)
    # Parent = the column of the first maximal hit; -1 when not discovered.
    best = jnp.argmax(hit, axis=1)
    parent = jnp.take_along_axis(cols, best[:, None], axis=1)[:, 0]
    parent_ref[...] = jnp.where(nxt > 0.0, parent, -1).astype(jnp.int32)
    next_ref[...] = nxt


@functools.partial(jax.jit, static_argnames=("tile_rows",))
def frontier_expand(frontier, visited, cols, mask, *,
                    tile_rows=DEFAULT_TILE_ROWS):
    """One BFS level for one shard.

    Args:
      frontier: f32[n_global] current-frontier bitmap (global index space).
      visited:  f32[n_rows] visited bitmap for the owned vertices.
      cols:     i32[n_rows, max_deg] in-neighbor ELL columns (global ids).
      mask:     f32[n_rows, max_deg] slot validity.
      tile_rows: grid tile height; must divide n_rows.

    Returns:
      (next_frontier: f32[n_rows], parent: i32[n_rows]) — next-frontier
      bitmap over owned vertices and the discovered parent (-1 when the
      vertex was not discovered at this level).
    """
    n_rows, max_deg = cols.shape
    if n_rows % tile_rows != 0:
        raise ValueError(f"n_rows={n_rows} not divisible by tile_rows={tile_rows}")
    n_global = frontier.shape[0]
    grid = (n_rows // tile_rows,)
    return pl.pallas_call(
        _frontier_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n_rows,), jnp.float32),
            jax.ShapeDtypeStruct((n_rows,), jnp.int32),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_global,), lambda i: (0,)),
            pl.BlockSpec((tile_rows,), lambda i: (i,)),
            pl.BlockSpec((tile_rows, max_deg), lambda i: (i, 0)),
            pl.BlockSpec((tile_rows, max_deg), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((tile_rows,), lambda i: (i,)),
            pl.BlockSpec((tile_rows,), lambda i: (i,)),
        ),
        interpret=True,
    )(frontier, visited, cols, mask)
