"""L1 Pallas kernel: masked-ELL gather-accumulate for PageRank.

The per-locality hot loop of distributed PageRank is the rank-update phase
(paper §4.2): after contributions ``contrib[v] = rank[v] / out_deg[v]`` have
been exchanged, each locality computes, for every owned vertex ``u``,

    z[u] = sum_{v in N_in(u)} contrib[v]

i.e. an SpMV with the transposed local adjacency shard.  For static HLO
shapes the shard is stored in ELLPACK form: every row-tile has a fixed
``max_deg`` slot count, padded entries carry ``mask == 0`` and point at
column 0.

The kernel is blocked over row tiles: one grid step loads one
``(TILE_ROWS, MAX_DEG)`` tile of column indices + mask into VMEM together
with the full contribution vector slice, gathers, masks, and reduces along
the slot axis.  On a real TPU this schedule keeps the index tile + gathered
values VMEM-resident (BlockSpec below expresses exactly that HBM->VMEM
movement); the multiply-accumulate maps onto the VPU.  ``interpret=True``
is mandatory here: the CPU PJRT client cannot execute Mosaic custom-calls,
and interpret mode lowers to plain HLO that round-trips through the rust
runtime (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile geometry.  TILE_ROWS is sized so that
#   cols tile  (TILE_ROWS * MAX_DEG * 4 B)
# + mask tile  (TILE_ROWS * MAX_DEG * 4 B)
# + gathered   (TILE_ROWS * MAX_DEG * 4 B)
# stays well under ~16 MiB VMEM even at MAX_DEG=64 (3 MiB at 4096x64).
DEFAULT_TILE_ROWS = 1024


def _ell_gather_kernel(contrib_ref, cols_ref, mask_ref, z_ref):
    """One row-tile: z[i] = sum_j contrib[cols[i, j]] * mask[i, j]."""
    contrib = contrib_ref[...]          # (n_global,) f32, VMEM-resident slice
    cols = cols_ref[...]                # (tile_rows, max_deg) i32
    mask = mask_ref[...]                # (tile_rows, max_deg) f32 in {0, 1}
    gathered = contrib[cols]            # advanced indexing == gather
    z_ref[...] = jnp.sum(gathered * mask, axis=1)


@functools.partial(jax.jit, static_argnames=("tile_rows",))
def ell_gather(contrib, cols, mask, *, tile_rows=DEFAULT_TILE_ROWS):
    """Masked ELL SpMV: z = (A_ell @ contrib) with A given as (cols, mask).

    Args:
      contrib: f32[n_global] global contribution vector (zero-padded).
      cols:    i32[n_rows, max_deg] column indices, padded slots -> 0.
      mask:    f32[n_rows, max_deg] 1.0 for real slots, 0.0 for padding.
      tile_rows: grid tile height; must divide n_rows.

    Returns:
      f32[n_rows] accumulated in-neighbor contributions.
    """
    n_rows, max_deg = cols.shape
    if n_rows % tile_rows != 0:
        raise ValueError(f"n_rows={n_rows} not divisible by tile_rows={tile_rows}")
    n_global = contrib.shape[0]
    grid = (n_rows // tile_rows,)
    return pl.pallas_call(
        _ell_gather_kernel,
        out_shape=jax.ShapeDtypeStruct((n_rows,), jnp.float32),
        grid=grid,
        in_specs=[
            # Contribution vector: whole thing every grid step (the gather
            # may touch any global vertex).
            pl.BlockSpec((n_global,), lambda i: (0,)),
            pl.BlockSpec((tile_rows, max_deg), lambda i: (i, 0)),
            pl.BlockSpec((tile_rows, max_deg), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_rows,), lambda i: (i,)),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(contrib, cols, mask)


def _rank_update_kernel(z_ref, old_ref, base_ref, alpha_ref, new_ref, delta_ref):
    """rank_new = base + alpha * z;  delta = sum |rank_new - rank_old|."""
    z = z_ref[...]
    old = old_ref[...]
    base = base_ref[0]
    alpha = alpha_ref[0]
    new = base + alpha * z
    new_ref[...] = new
    delta_ref[0] = jnp.sum(jnp.abs(new - old))


@jax.jit
def rank_update(z, rank_old, base, alpha):
    """Damped rank update + L1 convergence delta for one shard.

    Args:
      z:        f32[n_rows] in-contribution sums (from :func:`ell_gather`).
      rank_old: f32[n_rows] previous ranks for the owned vertices.
      base:     f32[1] teleport term (1 - alpha) / n_total, broadcast.
      alpha:    f32[1] damping factor.

    Returns:
      (rank_new: f32[n_rows], delta: f32[1]) — delta is the shard-local L1
      difference used for the distributed convergence test (paper §4.2,
      "Error Computation").
    """
    n_rows = z.shape[0]
    return pl.pallas_call(
        _rank_update_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n_rows,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ),
        interpret=True,
    )(z, rank_old, base, alpha)
