"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: straightforward jnp formulations with
no Pallas, no tiling, no grid.  pytest (python/tests/) asserts allclose
between each kernel and its oracle over hypothesis-generated shapes/seeds,
and the rust integration tests validate the AOT artifacts against vectors
produced from these same functions.
"""

from __future__ import annotations

import jax.numpy as jnp


def ell_gather_ref(contrib, cols, mask):
    """z[i] = sum_j contrib[cols[i, j]] * mask[i, j] (masked ELL SpMV)."""
    return jnp.sum(contrib[cols] * mask, axis=1)


def rank_update_ref(z, rank_old, base, alpha):
    """Damped update + shard L1 delta (paper §4.2 phases 2 and 3)."""
    new = base[0] + alpha[0] * z
    delta = jnp.sum(jnp.abs(new - rank_old))
    return new, jnp.reshape(delta, (1,))


def frontier_expand_ref(frontier, visited, cols, mask):
    """Reference bitmap BFS level expansion (see bfs_frontier)."""
    hit = frontier[cols] * mask
    any_hit = jnp.max(hit, axis=1)
    nxt = jnp.where(any_hit > 0.0, 1.0, 0.0) * (1.0 - visited)
    best = jnp.argmax(hit, axis=1)
    parent = jnp.take_along_axis(cols, best[:, None], axis=1)[:, 0]
    parent = jnp.where(nxt > 0.0, parent, -1).astype(jnp.int32)
    return nxt, parent


def pagerank_full_ref(out_adj, alpha, iters):
    """Dense textbook PageRank used by model-level tests.

    Args:
      out_adj: f32[n, n] adjacency, out_adj[u, v] = 1 iff edge u -> v.
      alpha: damping factor.
      iters: power-iteration count.

    Returns f32[n] ranks after `iters` iterations; vertices with zero
    out-degree contribute nothing (matching the distributed implementation,
    which divides by max(out_deg, 1)).
    """
    n = out_adj.shape[0]
    out_deg = jnp.maximum(jnp.sum(out_adj, axis=1), 1.0)
    rank = jnp.full((n,), 1.0 / n, dtype=jnp.float32)
    base = (1.0 - alpha) / n
    for _ in range(iters):
        contrib = rank / out_deg
        z = out_adj.T @ contrib
        rank = base + alpha * z
    return rank
