"""L2: JAX compute graph for the per-locality phases, calling the L1 kernels.

Distributed PageRank and level-synchronous BFS both decompose into
(a) a *coordination* layer — routing contributions / frontier updates between
localities, owned by the rust L3 — and (b) a *local compute* phase over the
locality's shard, which is what gets AOT-lowered here.  Each function below
is a pure jax function over statically-shaped arrays; ``aot.py`` lowers a
small registry of shapes to HLO text that the rust runtime loads via PJRT.

Shard layout contract (shared with rust `graph::distributed`):
  * the shard's in-adjacency is masked ELL: ``cols: i32[n_rows, max_deg]``
    global column ids (padding -> 0), ``mask: f32[n_rows, max_deg]``;
  * ``n_rows`` is the padded owned-vertex count, ``n_global`` the padded
    global vertex count; both fixed per artifact;
  * bitmaps/ranks are f32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import bfs_frontier, pagerank_ell


def pagerank_step(contrib, rank_old, cols, mask, row_map, base, alpha,
                  *, tile_rows=None):
    """One full local rank-update: gather + row-fold + damped update + delta.

    This fuses the paper's three per-iteration phases (§4.2) for the local
    shard into a single HLO module: contribution accumulation over the
    in-ELL, rank update ``rank = base + alpha * z``, and the shard-local
    error term.  The cross-locality contribution exchange happens before
    this in rust.

    ``row_map`` handles *virtual-row splitting*: shard rows wider than
    ``max_deg`` are split across several ELL rows (rust
    ``graph::distributed::Shard::in_ell``); the scatter-add below folds the
    per-virtual-row partial sums back onto owned rows.  Padding virtual
    rows carry ``mask == 0`` (so ``z_virt == 0``) and may map anywhere.
    Padding *owned* rows must arrive with ``rank_old == base`` so they
    contribute nothing to the delta.

    Returns (rank_new: f32[n_rows], delta: f32[1]).
    """
    kw = {}
    if tile_rows is not None:
        kw["tile_rows"] = tile_rows
    z_virt = pagerank_ell.ell_gather(contrib, cols, mask, **kw)
    z = jnp.zeros_like(z_virt).at[row_map].add(z_virt)
    return pagerank_ell.rank_update(z, rank_old, base, alpha)


def bfs_level(frontier, visited, cols, mask, *, tile_rows=None):
    """One local BFS level expansion (see kernels/bfs_frontier.py).

    Returns (next_frontier: f32[n_rows], parent: i32[n_rows]).
    """
    kw = {}
    if tile_rows is not None:
        kw["tile_rows"] = tile_rows
    return bfs_frontier.frontier_expand(frontier, visited, cols, mask, **kw)


def _pick_tile_rows(n_rows):
    """Largest power-of-two tile <= n_rows, capped at the default."""
    t = 1
    while t * 2 <= n_rows and t * 2 <= pagerank_ell.DEFAULT_TILE_ROWS:
        t *= 2
    return t


def lower_pagerank(n_global, n_rows, max_deg):
    """jax.jit(...).lower(...) for a (n_global, n_rows, max_deg) config."""
    tile = _pick_tile_rows(n_rows)

    def fn(contrib, rank_old, cols, mask, row_map, base, alpha):
        return pagerank_step(contrib, rank_old, cols, mask, row_map, base,
                             alpha, tile_rows=tile)

    return jax.jit(fn).lower(
        jax.ShapeDtypeStruct((n_global,), jnp.float32),
        jax.ShapeDtypeStruct((n_rows,), jnp.float32),
        jax.ShapeDtypeStruct((n_rows, max_deg), jnp.int32),
        jax.ShapeDtypeStruct((n_rows, max_deg), jnp.float32),
        jax.ShapeDtypeStruct((n_rows,), jnp.int32),
        jax.ShapeDtypeStruct((1,), jnp.float32),
        jax.ShapeDtypeStruct((1,), jnp.float32),
    )


def lower_bfs(n_global, n_rows, max_deg):
    """jax.jit(...).lower(...) for the BFS level step."""
    tile = _pick_tile_rows(n_rows)

    def fn(frontier, visited, cols, mask):
        return bfs_level(frontier, visited, cols, mask, tile_rows=tile)

    return jax.jit(fn).lower(
        jax.ShapeDtypeStruct((n_global,), jnp.float32),
        jax.ShapeDtypeStruct((n_rows,), jnp.float32),
        jax.ShapeDtypeStruct((n_rows, max_deg), jnp.int32),
        jax.ShapeDtypeStruct((n_rows, max_deg), jnp.float32),
    )
