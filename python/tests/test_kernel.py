"""Kernel-vs-reference correctness: the CORE signal for the L1 layer.

hypothesis sweeps shapes/seeds/densities; every property asserts
allclose between the Pallas kernel (interpret=True) and the pure-jnp
oracle in kernels/ref.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bfs_frontier, pagerank_ell, ref

# Keep shapes in the small regime: interpret-mode Pallas is slow, and the
# tiling logic is exercised as soon as n_rows > tile_rows.
TILE = 8


def _case(seed, n_global, n_tiles, max_deg, density):
    rng = np.random.default_rng(seed)
    n_rows = TILE * n_tiles
    contrib = rng.random(n_global, dtype=np.float32)
    cols = rng.integers(0, n_global, (n_rows, max_deg)).astype(np.int32)
    mask = (rng.random((n_rows, max_deg)) < density).astype(np.float32)
    return contrib, cols, mask


shape_strategy = st.tuples(
    st.integers(0, 2**31 - 1),       # seed
    st.sampled_from([8, 32, 100, 257]),  # n_global (incl. non-powers of two)
    st.integers(1, 4),               # n_tiles
    st.integers(1, 9),               # max_deg
    st.sampled_from([0.0, 0.3, 1.0]),  # mask density (incl. all-padding)
)


class TestEllGather:
    @settings(max_examples=25, deadline=None)
    @given(shape_strategy)
    def test_matches_ref(self, params):
        seed, n_global, n_tiles, max_deg, density = params
        contrib, cols, mask = _case(seed, n_global, n_tiles, max_deg, density)
        got = pagerank_ell.ell_gather(
            jnp.asarray(contrib), jnp.asarray(cols), jnp.asarray(mask),
            tile_rows=TILE)
        want = ref.ell_gather_ref(
            jnp.asarray(contrib), jnp.asarray(cols), jnp.asarray(mask))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)

    def test_all_padding_is_zero(self):
        contrib, cols, mask = _case(1, 64, 2, 4, 0.0)
        got = pagerank_ell.ell_gather(
            jnp.asarray(contrib), jnp.asarray(cols), jnp.asarray(mask),
            tile_rows=TILE)
        np.testing.assert_array_equal(np.asarray(got), 0.0)

    def test_single_full_row(self):
        # Row gathering every vertex once == sum(contrib).
        n = 16
        contrib = np.arange(n, dtype=np.float32)
        cols = np.tile(np.arange(n, dtype=np.int32), (TILE, 1))
        mask = np.ones((TILE, n), dtype=np.float32)
        got = pagerank_ell.ell_gather(
            jnp.asarray(contrib), jnp.asarray(cols), jnp.asarray(mask),
            tile_rows=TILE)
        np.testing.assert_allclose(np.asarray(got), contrib.sum() * np.ones(TILE))

    def test_rejects_non_divisible_rows(self):
        contrib, cols, mask = _case(0, 32, 1, 4, 1.0)
        with pytest.raises(ValueError, match="not divisible"):
            pagerank_ell.ell_gather(
                jnp.asarray(contrib), jnp.asarray(cols[:-1]),
                jnp.asarray(mask[:-1]), tile_rows=TILE)


class TestRankUpdate:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 5),
           st.floats(0.0, 1.0), st.floats(0.0, 1.0))
    def test_matches_ref(self, seed, n_tiles, base, alpha):
        rng = np.random.default_rng(seed)
        n = TILE * n_tiles
        z = rng.random(n, dtype=np.float32)
        old = rng.random(n, dtype=np.float32)
        b = jnp.asarray([base], dtype=jnp.float32)
        a = jnp.asarray([alpha], dtype=jnp.float32)
        new, delta = pagerank_ell.rank_update(jnp.asarray(z), jnp.asarray(old), b, a)
        new_r, delta_r = ref.rank_update_ref(jnp.asarray(z), jnp.asarray(old), b, a)
        np.testing.assert_allclose(np.asarray(new), np.asarray(new_r), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(delta), np.asarray(delta_r),
                                   rtol=1e-4, atol=1e-6)

    def test_zero_alpha_gives_base(self):
        z = np.ones(TILE, dtype=np.float32) * 7.0
        old = np.zeros(TILE, dtype=np.float32)
        new, delta = pagerank_ell.rank_update(
            jnp.asarray(z), jnp.asarray(old),
            jnp.asarray([0.25], jnp.float32), jnp.asarray([0.0], jnp.float32))
        np.testing.assert_allclose(np.asarray(new), 0.25)
        np.testing.assert_allclose(np.asarray(delta), 0.25 * TILE, rtol=1e-6)


class TestFrontierExpand:
    @settings(max_examples=25, deadline=None)
    @given(shape_strategy, st.sampled_from([0.0, 0.2, 1.0]),
           st.sampled_from([0.0, 0.5, 1.0]))
    def test_matches_ref(self, params, frontier_density, visited_density):
        seed, n_global, n_tiles, max_deg, density = params
        contrib, cols, mask = _case(seed, n_global, n_tiles, max_deg, density)
        rng = np.random.default_rng(seed ^ 0xABCDEF)
        n_rows = cols.shape[0]
        frontier = (rng.random(n_global) < frontier_density).astype(np.float32)
        visited = (rng.random(n_rows) < visited_density).astype(np.float32)
        got_f, got_p = bfs_frontier.frontier_expand(
            jnp.asarray(frontier), jnp.asarray(visited),
            jnp.asarray(cols), jnp.asarray(mask), tile_rows=TILE)
        want_f, want_p = ref.frontier_expand_ref(
            jnp.asarray(frontier), jnp.asarray(visited),
            jnp.asarray(cols), jnp.asarray(mask))
        np.testing.assert_array_equal(np.asarray(got_f), np.asarray(want_f))
        np.testing.assert_array_equal(np.asarray(got_p), np.asarray(want_p))

    def test_empty_frontier_discovers_nothing(self):
        _, cols, mask = _case(3, 64, 2, 4, 1.0)
        frontier = np.zeros(64, dtype=np.float32)
        visited = np.zeros(cols.shape[0], dtype=np.float32)
        nf, par = bfs_frontier.frontier_expand(
            jnp.asarray(frontier), jnp.asarray(visited),
            jnp.asarray(cols), jnp.asarray(mask), tile_rows=TILE)
        np.testing.assert_array_equal(np.asarray(nf), 0.0)
        np.testing.assert_array_equal(np.asarray(par), -1)

    def test_visited_never_rediscovered(self):
        _, cols, mask = _case(4, 64, 2, 4, 1.0)
        frontier = np.ones(64, dtype=np.float32)
        visited = np.ones(cols.shape[0], dtype=np.float32)
        nf, par = bfs_frontier.frontier_expand(
            jnp.asarray(frontier), jnp.asarray(visited),
            jnp.asarray(cols), jnp.asarray(mask), tile_rows=TILE)
        np.testing.assert_array_equal(np.asarray(nf), 0.0)
        np.testing.assert_array_equal(np.asarray(par), -1)

    def test_parent_is_a_frontier_neighbor(self):
        rng = np.random.default_rng(5)
        contrib, cols, mask = _case(5, 64, 2, 6, 0.7)
        frontier = (rng.random(64) < 0.4).astype(np.float32)
        visited = np.zeros(cols.shape[0], dtype=np.float32)
        nf, par = bfs_frontier.frontier_expand(
            jnp.asarray(frontier), jnp.asarray(visited),
            jnp.asarray(cols), jnp.asarray(mask), tile_rows=TILE)
        nf, par = np.asarray(nf), np.asarray(par)
        for i in range(cols.shape[0]):
            if nf[i] > 0:
                assert par[i] >= 0
                assert frontier[par[i]] == 1.0
                # parent must be one of i's masked in-neighbors
                slots = cols[i][mask[i] > 0]
                assert par[i] in slots
            else:
                assert par[i] == -1
