"""L2 model-level tests: pagerank_step / bfs_level against dense references.

These exercise the composed modules exactly as they are AOT-lowered — same
functions, same shard layout — on small random graphs, checking that
iterating the shard-local step reproduces textbook PageRank and BFS.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

TILE = 8


def _random_graph(seed, n, p):
    """Random digraph adjacency (no self loops)."""
    rng = np.random.default_rng(seed)
    adj = (rng.random((n, n)) < p).astype(np.float32)
    np.fill_diagonal(adj, 0.0)
    return adj


def _to_ell(in_adj, max_deg):
    """Dense in-adjacency rows -> masked ELL (cols, mask)."""
    n = in_adj.shape[0]
    cols = np.zeros((n, max_deg), dtype=np.int32)
    mask = np.zeros((n, max_deg), dtype=np.float32)
    for u in range(n):
        nbrs = np.nonzero(in_adj[u])[0]
        assert len(nbrs) <= max_deg, "test graph exceeds ELL width"
        cols[u, :len(nbrs)] = nbrs
        mask[u, :len(nbrs)] = 1.0
    return cols, mask


class TestPagerankStep:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.sampled_from([0.1, 0.3]))
    def test_iterated_step_matches_dense_pagerank(self, seed, p):
        n, alpha, iters = 16, 0.85, 12
        out_adj = _random_graph(seed, n, p)
        in_adj = out_adj.T                       # in-neighbors of u
        cols, mask = _to_ell(in_adj, max_deg=n)
        out_deg = np.maximum(out_adj.sum(axis=1), 1.0).astype(np.float32)

        rank = np.full(n, 1.0 / n, dtype=np.float32)
        base = jnp.asarray([(1.0 - alpha) / n], jnp.float32)
        a = jnp.asarray([alpha], jnp.float32)
        row_map = jnp.arange(n, dtype=jnp.int32)  # no splitting
        for _ in range(iters):
            contrib = (rank / out_deg).astype(np.float32)
            new, _delta = model.pagerank_step(
                jnp.asarray(contrib), jnp.asarray(rank),
                jnp.asarray(cols), jnp.asarray(mask), row_map, base, a,
                tile_rows=TILE)
            rank = np.asarray(new)

        want = np.asarray(ref.pagerank_full_ref(jnp.asarray(out_adj), alpha, iters))
        np.testing.assert_allclose(rank, want, rtol=1e-4, atol=1e-6)

    def test_delta_reaches_zero_at_fixpoint(self):
        n, alpha = 16, 0.85
        out_adj = _random_graph(7, n, 0.3)
        cols, mask = _to_ell(out_adj.T, max_deg=n)
        out_deg = np.maximum(out_adj.sum(axis=1), 1.0).astype(np.float32)
        rank = np.full(n, 1.0 / n, dtype=np.float32)
        base = jnp.asarray([(1.0 - alpha) / n], jnp.float32)
        a = jnp.asarray([alpha], jnp.float32)
        row_map = jnp.arange(n, dtype=jnp.int32)
        deltas = []
        for _ in range(60):
            contrib = (rank / out_deg).astype(np.float32)
            new, delta = model.pagerank_step(
                jnp.asarray(contrib), jnp.asarray(rank),
                jnp.asarray(cols), jnp.asarray(mask), row_map, base, a,
                tile_rows=TILE)
            rank = np.asarray(new)
            deltas.append(float(np.asarray(delta)[0]))
        assert deltas[-1] < 1e-6
        assert deltas[-1] < deltas[0]


class TestRowSplitting:
    def _split_ell(self, in_adj, max_deg, pad_rows):
        """Dense in-adjacency -> split masked ELL + row_map (mirrors rust
        Shard::in_ell)."""
        n = in_adj.shape[0]
        cols, mask, row_map = [], [], []
        for u in range(n):
            nbrs = np.nonzero(in_adj[u])[0]
            chunks = max(1, -(-len(nbrs) // max_deg))
            for c in range(chunks):
                row_map.append(u)
                chunk = nbrs[c * max_deg:(c + 1) * max_deg]
                row = np.zeros(max_deg, dtype=np.int32)
                m = np.zeros(max_deg, dtype=np.float32)
                row[:len(chunk)] = chunk
                m[:len(chunk)] = 1.0
                cols.append(row)
                mask.append(m)
        while len(row_map) < pad_rows:
            row_map.append(0)
            cols.append(np.zeros(max_deg, dtype=np.int32))
            mask.append(np.zeros(max_deg, dtype=np.float32))
        assert len(row_map) <= pad_rows
        return (np.stack(cols), np.stack(mask),
                np.asarray(row_map, dtype=np.int32))

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_split_rows_fold_to_same_ranks(self, seed):
        n, alpha = 16, 0.85
        out_adj = _random_graph(seed, n, 0.5)  # wide rows force splitting
        in_adj = out_adj.T
        out_deg = np.maximum(out_adj.sum(axis=1), 1.0).astype(np.float32)
        rank = np.full(n, 1.0 / n, dtype=np.float32)
        contrib = (rank / out_deg).astype(np.float32)
        base = jnp.asarray([(1.0 - alpha) / n], jnp.float32)
        a = jnp.asarray([alpha], jnp.float32)

        # Unsplit reference (max_deg = n).
        cols_f, mask_f = _to_ell(in_adj, max_deg=n)
        new_full, delta_full = model.pagerank_step(
            jnp.asarray(contrib), jnp.asarray(rank),
            jnp.asarray(cols_f), jnp.asarray(mask_f),
            jnp.arange(n, dtype=jnp.int32), base, a, tile_rows=TILE)

        # Split at max_deg=4, padded rows; rank_old padding = base so the
        # delta ignores padding rows (layout contract with rust).
        pad_rows = 8 * ((3 * n) // 8 + 1)
        cols_s, mask_s, row_map = self._split_ell(in_adj, 4, pad_rows)
        rank_pad = np.full(pad_rows, float(base[0]), dtype=np.float32)
        rank_pad[:n] = rank
        new_s, delta_s = model.pagerank_step(
            jnp.asarray(contrib), jnp.asarray(rank_pad),
            jnp.asarray(cols_s), jnp.asarray(mask_s),
            jnp.asarray(row_map), base, a, tile_rows=8)
        np.testing.assert_allclose(np.asarray(new_s)[:n], np.asarray(new_full),
                                   rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(np.asarray(delta_s), np.asarray(delta_full),
                                   rtol=1e-4, atol=1e-6)


class TestBfsLevel:
    def _dense_bfs(self, adj, root):
        """Level-synchronous reference distances."""
        n = adj.shape[0]
        dist = np.full(n, -1)
        dist[root] = 0
        frontier = {root}
        lvl = 0
        while frontier:
            nxt = set()
            for u in frontier:
                for v in np.nonzero(adj[u])[0]:
                    if dist[v] == -1:
                        dist[v] = lvl + 1
                        nxt.add(v)
            frontier = nxt
            lvl += 1
        return dist

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.sampled_from([0.05, 0.15, 0.4]))
    def test_iterated_level_matches_dense_bfs(self, seed, p):
        n, root = 16, 0
        adj = _random_graph(seed, n, p)
        # in-ELL: row u lists vertices v with edge v -> u
        cols, mask = _to_ell(adj.T, max_deg=n)

        frontier = np.zeros(n, dtype=np.float32)
        frontier[root] = 1.0
        visited = frontier.copy()
        dist = np.full(n, -1)
        dist[root] = 0
        lvl = 0
        while frontier.any() and lvl <= n:
            nf, par = model.bfs_level(
                jnp.asarray(frontier), jnp.asarray(visited),
                jnp.asarray(cols), jnp.asarray(mask), tile_rows=TILE)
            nf = np.asarray(nf)
            par = np.asarray(par)
            lvl += 1
            newly = nf > 0
            dist[newly] = lvl
            # parents must be frontier members with a real edge parent->child
            for v in np.nonzero(newly)[0]:
                assert frontier[par[v]] == 1.0
                assert adj[par[v], v] == 1.0
            visited = np.clip(visited + nf, 0.0, 1.0)
            frontier = nf
        np.testing.assert_array_equal(dist, self._dense_bfs(adj, root))
