//! Bench: DESIGN.md ablations.
//!
//! * **A1** — HPX parcel aggregation in async BFS (on/off): quantifies why
//!   coalescing is load-bearing for fine-grained asynchrony.
//! * **A2** — executor chunking policies on the PageRank update loop,
//!   including the paper §6 `adaptive_core_chunk_size`.
//! * **A3** — partition policy: block vs edge-balanced cuts on a skewed
//!   kron graph (load imbalance, paper §2).
//! * **A4** — `amt::aggregate` flush policies on asynchronous PageRank:
//!   the naive-vs-aggregated axis (envelope counts, fold factor, accuracy)
//!   on both a uniform and a skewed (RMAT) graph.
//! * **A5** — delta-stepping SSSP: Δ sweep × flush policy (Δ=∞ ≡
//!   Bellman-Ford, Δ→0 ≡ Dijkstra-like) with relaxation counters, against
//!   async label-correcting and BSP reference rows, on a uniform and a
//!   skewed (RMAT) graph.
//! * **A6** — partition scheme × algorithm on the skewed kron10 graph at
//!   8 localities: block vs edge-balanced vs hash vs 2-D vertex cut, with
//!   vertex/edge imbalance and replication-factor columns. The vertex cut
//!   must reach lower edge imbalance than block (the tentpole acceptance
//!   criterion) at the price of replication traffic.
//! * **A7** — adaptive coalescing on kron10 at 8 localities: the static
//!   break-even `adaptive` policy vs the latency-observing self-tuner vs
//!   `time:US` flush windows, × {block, vertex_cut} × {bfs-async,
//!   pagerank-async, sssp-delta}, with per-slot-space observed-latency
//!   columns. The acceptance pin (`LatencyAdaptive` envelopes ≤ static
//!   `Adaptive` on the vertex cut) lives in `tests/engine_props.rs`.
//! * **A8** — query serving on kron10 at 8 localities: landmark oracle ×
//!   hot-source LRU cache × wave batch width over {sim, threads}, every
//!   answer set validated against sequential Dijkstra (hits and waves may
//!   move, answers may not). Columns: hits, waves, qps, p50/p99 latency.
//! * **A9** — memory-limit scale sweep: streamed kron ingestion (the
//!   whole-graph CSR is never materialized) × {plain, compressed} shard
//!   storage × {block, vertex_cut} at 8 localities, reporting bytes/edge,
//!   per-locality peak builder bytes, build time, and bfs/pagerank/sssp
//!   MTEPS, with compressed-vs-plain answer parity asserted per cell.
//!   `BENCH_LARGE=1` extends the sweep to kron18.
//! * **A10** — incremental re-convergence on kron10 at 8 localities:
//!   seeded edge-update batches (0.1% / 1% / 10% of m, half inserts) ×
//!   {block, vertex_cut} × {sim, threads}, SSSP re-converged from the
//!   previous fixpoint vs a from-scratch run on the updated graph. Every
//!   cell is validated against Dijkstra on the updated graph; batches
//!   ≤ 1% must strictly beat the full recompute on relaxations and
//!   envelopes under the deterministic sim substrate.
//!
//! `cargo bench --bench ablations`

use nwgraph_hpx::algorithms::bfs;
use nwgraph_hpx::amt::{FlushPolicy, SimConfig};
use nwgraph_hpx::config::Config;
use nwgraph_hpx::coordinator::{experiment, report::Table};
use nwgraph_hpx::graph::{generators, DistGraph, Partition1D};

fn main() {
    let reps: u32 = std::env::var("BENCH_REPS").ok().and_then(|s| s.parse().ok()).unwrap_or(3);

    let mut cfg = Config::default();
    cfg.scale = 13;
    cfg.degree = 8;
    cfg.reps = reps;
    cfg.localities = vec![2, 4, 8, 16, 32];
    print!("{}", experiment::ablation_aggregation(&cfg).expect("A1 failed").render());

    cfg.iterations = 20;
    cfg.generator = "urand-directed".into();
    print!("{}", experiment::ablation_adaptive_chunk(&cfg).expect("A2 failed").render());

    // A3: block vs edge-balanced partitions on a skewed graph.
    let g = generators::kron(13, 8, 3);
    let mut t = Table::new(
        "Ablation A3 — partition policy on kron13 (async BFS)",
        &["nodes", "block time", "balanced time", "block edge-imb", "balanced edge-imb"],
    );
    for p in [4u32, 8, 16, 32] {
        let block = Partition1D::block(g.n(), p);
        let bal = Partition1D::edge_balanced(&g, p);
        let mut best = [f64::INFINITY; 2];
        for _ in 0..reps {
            for (i, part) in [(0, &block), (1, &bal)] {
                let dist = DistGraph::build(&g, part);
                // App-level combiners off: A3 isolates the partition axis
                // under the pre-existing runtime-coalescing config.
                let r = bfs::run_async_with(
                    &dist,
                    0,
                    FlushPolicy::Unbatched,
                    SimConfig { aggregate_sends: true, coalesce_window_us: 5.0, ..SimConfig::default() },
                );
                best[i] = best[i].min(r.report.makespan_us);
            }
        }
        t.row(vec![
            p.to_string(),
            format!("{:.2}ms", best[0] / 1e3),
            format!("{:.2}ms", best[1] / 1e3),
            format!("{:.2}", block.edge_imbalance(&g)),
            format!("{:.2}", bal.edge_imbalance(&g)),
        ]);
    }
    print!("{}", t.render());

    // A4: flush policies on uniform and skewed PageRank traffic.
    let mut cfg4 = Config::default();
    cfg4.scale = 13;
    cfg4.degree = 8;
    cfg4.reps = reps;
    cfg4.iterations = 20;
    cfg4.localities = vec![8];
    cfg4.generator = "urand-directed".into();
    print!("{}", experiment::ablation_flush_policy(&cfg4).expect("A4 failed").render());
    cfg4.generator = "kron".into();
    print!("{}", experiment::ablation_flush_policy(&cfg4).expect("A4 failed").render());

    // A5: delta-stepping delta x flush-policy sweep on uniform and skewed
    // weighted graphs (weights are attached inside the experiment).
    let mut cfg5 = Config::default();
    cfg5.scale = 12;
    cfg5.degree = 8;
    cfg5.reps = reps;
    cfg5.localities = vec![8];
    print!("{}", experiment::ablation_delta_stepping(&cfg5).expect("A5 failed").render());
    cfg5.generator = "kron".into();
    print!("{}", experiment::ablation_delta_stepping(&cfg5).expect("A5 failed").render());

    // A6: partition scheme x algorithm on kron10 at 8 localities — the
    // acceptance point for the pluggable partition layer.
    let mut cfg6 = Config::default();
    cfg6.scale = 10;
    cfg6.degree = 8;
    cfg6.reps = reps;
    cfg6.iterations = 10;
    cfg6.localities = vec![8];
    cfg6.generator = "kron".into();
    print!("{}", experiment::ablation_partition_schemes(&cfg6).expect("A6 failed").render());

    // A7: adaptive coalescing on kron10 at 8 localities — the acceptance
    // point for the latency-observing flush layer (same graph shape as
    // the release-mode envelope pin in tests/engine_props.rs).
    print!("{}", experiment::ablation_adaptive_coalescing(&cfg6).expect("A7 failed").render());

    // A8: query serving on the same kron10 shape — the acceptance point
    // for the serve layer (oracle/cache hits > 0, waves < queries, on
    // both substrates).
    print!("{}", experiment::ablation_query_serving(&cfg6).expect("A8 failed").render());

    // A9: memory-limit scale sweep — the acceptance point for compressed
    // shard storage and streaming ingestion (BENCH_LARGE=1 adds kron18).
    let large = std::env::var("BENCH_LARGE").map(|v| v == "1").unwrap_or(false);
    print!("{}", experiment::ablation_scale_sweep(&cfg6, large).expect("A9 failed").render());

    // A10: incremental re-convergence on the same kron10 shape — the
    // acceptance point for the dynamic-graph subsystem (incremental
    // strictly cheaper than full recompute for small batches).
    print!("{}", experiment::ablation_incremental(&cfg6).expect("A10 failed").render());
}
