//! Bench: §6 extension algorithms (SSSP, connected components, triangle
//! counting) across locality counts — the "systematic benchmark suite"
//! the paper's future work calls for.
//!
//! `cargo bench --bench extensions`

use nwgraph_hpx::config::Config;
use nwgraph_hpx::coordinator::experiment;

fn main() {
    let mut cfg = Config::default();
    cfg.scale = 13;
    cfg.degree = 8;
    cfg.localities = vec![1, 2, 4, 8, 16, 32];
    print!("{}", experiment::extensions(&cfg).expect("extensions failed").render());

    // Also on a skewed graph.
    cfg.generator = "kron".into();
    print!("{}", experiment::extensions(&cfg).expect("extensions failed").render());
}
