//! Bench: regenerate the paper's **Figure 1** — distributed BFS speedup
//! (HPX async vs Boost/BSP) over locality count, on GAP `urand` graphs.
//!
//! `cargo bench --bench fig1_bfs` (criterion is unavailable offline; this
//! is a plain harness printing the paper-style table per graph size).
//! Override the scales with `BENCH_SCALES=12,14` and reps with
//! `BENCH_REPS=n`.

use nwgraph_hpx::config::Config;
use nwgraph_hpx::coordinator::experiment;

fn main() {
    let scales: Vec<u32> = std::env::var("BENCH_SCALES")
        .map(|s| s.split(',').map(|x| x.trim().parse().unwrap()).collect())
        .unwrap_or_else(|_| vec![12, 14, 16]);
    let reps: u32 = std::env::var("BENCH_REPS").ok().and_then(|s| s.parse().ok()).unwrap_or(3);

    for scale in scales {
        let mut cfg = Config::default();
        cfg.scale = scale;
        cfg.degree = 8;
        cfg.reps = reps;
        cfg.localities = vec![1, 2, 4, 8, 16, 32];
        let (table, points) = experiment::fig1_bfs(&cfg).expect("fig1 failed");
        print!("{}", table.render());
        // Shape summary: where does HPX overtake Boost?
        let crossover = cfg.localities.iter().find(|&&p| {
            let h = points.iter().find(|x| x.engine == "HPX" && x.p == p).unwrap();
            let b = points.iter().find(|x| x.engine == "Boost" && x.p == p).unwrap();
            h.speedup > b.speedup
        });
        match crossover {
            Some(p) => println!("HPX overtakes Boost at p={p} (paper: HPX ahead at scale)\n"),
            None => println!("HPX never overtakes Boost — check the cost model\n"),
        }
    }
}
