//! Bench: regenerate the paper's **Figure 2** — distributed PageRank
//! (HPX naive / HPX optimized / Boost BSP) over locality count.
//!
//! `cargo bench --bench fig2_pagerank`. Overrides: `BENCH_SCALES`,
//! `BENCH_REPS`.

use nwgraph_hpx::config::Config;
use nwgraph_hpx::coordinator::experiment;

fn main() {
    let scales: Vec<u32> = std::env::var("BENCH_SCALES")
        .map(|s| s.split(',').map(|x| x.trim().parse().unwrap()).collect())
        .unwrap_or_else(|_| vec![12, 14]);
    let reps: u32 = std::env::var("BENCH_REPS").ok().and_then(|s| s.parse().ok()).unwrap_or(3);

    for scale in scales {
        let mut cfg = Config::default();
        cfg.scale = scale;
        cfg.degree = 8;
        cfg.generator = "urand-directed".into();
        cfg.reps = reps;
        cfg.iterations = 20;
        cfg.localities = vec![1, 2, 4, 8, 16, 32];
        let (table, points) = experiment::fig2_pagerank(&cfg).expect("fig2 failed");
        print!("{}", table.render());
        // Shape summary at the largest locality count.
        let p = *cfg.localities.last().unwrap();
        let get = |e: &str| points.iter().find(|x| x.engine == e && x.p == p).unwrap().makespan_us;
        let (naive, opt, boost) = (get("HPX-naive"), get("HPX-opt"), get("Boost"));
        println!(
            "at p={p}: naive/boost = {:.1}x, opt/boost = {:.2}x \
             (paper: naive far behind, optimized close but still behind)\n",
            naive / boost,
            opt / boost
        );
    }
}
