//! Asynchronous distributed BFS — the paper's Listing 1.2, on the shared
//! [`amt::aggregate`](crate::amt::aggregate) combiner layer, over any
//! [`PartitionScheme`](crate::graph::partition::PartitionScheme).
//!
//! The message-driven form of `bfs_2`: discovering a remote vertex issues
//! an asynchronous remote action (`hpx::async(bfs_2, dst, ...)`) on its
//! owner; locally-owned discoveries are expanded immediately from a local
//! wavefront. Remote visits are folded into per-destination combiners
//! (min-by-level, keyed by the destination's dense master index from the
//! shard's ghost table) and flushed by the configured [`FlushPolicy`] —
//! the naive one-action-per-edge path survives as
//! [`FlushPolicy::Unbatched`]. There are **no global barriers**:
//! termination is network quiescence, which the discrete-event engine
//! detects exactly (the paper relies on `hpx::wait_all` over the recursive
//! future tree for the same effect).
//!
//! Visits are *level correcting*: a proposal with a smaller level
//! overwrites the previous parent, so at quiescence every reached vertex
//! carries its true BFS distance — the final tree is a shortest-path tree
//! regardless of message arrival order, aggregation, or partition scheme.
//!
//! Under a vertex cut the local wavefront runs over the whole local row
//! space (owned rows *and* mirror rows): an improvement at a ghost row
//! notifies the vertex's master through the master-bound combiner, and a
//! master improvement is scattered to every mirror of the vertex through
//! a second, mirror-bound combiner so the remotely homed edges expand too
//! (gather-apply-scatter). 1-D schemes have no mirrors and both extra
//! paths are dead code.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Arc;

use crate::amt::aggregate::{Aggregator, Batch, FlushPolicy};
use crate::amt::sim::{Actor, Ctx, LocalityId, Message, SimConfig, SimRuntime};
use crate::amt::AtomicLongVector;
use crate::graph::{DistGraph, Shard, VertexId};

use super::BfsResult;

/// Async BFS wire format: combiner batches toward masters (visit
/// proposals) or toward mirrors (level scatter).
#[derive(Debug, Clone)]
pub enum BfsMsg {
    /// `(master index, (parent, level))` — at most the best per vertex.
    ToMaster(Batch<(VertexId, u32)>),
    /// `(ghost slot, level)` — master's improved level for a mirror.
    ToMirror(Batch<u32>),
}

/// Per-item wire size toward masters: vertex + parent + level.
const ITEM_BYTES: usize = 12;

/// Per-item wire size toward mirrors: ghost slot + level.
const MIRROR_ITEM_BYTES: usize = 8;

impl Message for BfsMsg {
    fn wire_bytes(&self) -> usize {
        match self {
            BfsMsg::ToMaster(b) => b.wire_bytes(),
            BfsMsg::ToMirror(b) => b.wire_bytes(),
        }
    }

    fn item_count(&self) -> usize {
        match self {
            BfsMsg::ToMaster(b) => b.len(),
            BfsMsg::ToMirror(b) => b.len(),
        }
    }
}

/// Keep the proposal with the smaller level (ties: first wins).
fn min_level(acc: &mut (VertexId, u32), new: (VertexId, u32)) {
    if new.1 < acc.1 {
        *acc = new;
    }
}

fn min_u32(acc: &mut u32, new: u32) {
    if new < *acc {
        *acc = new;
    }
}

/// Per-locality actor state.
pub struct AsyncBfsActor {
    shard: Arc<Shard>,
    parents: AtomicLongVector,
    root: VertexId,
    /// Tentative BFS level of every local row — owned rows are
    /// authoritative, ghost rows cache the best level seen/sent
    /// (`u32::MAX` = unvisited). The ghost cache doubles as the
    /// send-dedup that keeps the correcting flood finite.
    level: Vec<u32>,
    /// Master-bound visit combiner (shared aggregation subsystem).
    pub agg: Aggregator<(VertexId, u32)>,
    /// Mirror-bound level-scatter combiner (idle under 1-D schemes).
    pub mirror_agg: Aggregator<u32>,
    /// Reusable wavefront heap.
    heap: BinaryHeap<Reverse<(u32, usize, VertexId)>>,
}

impl AsyncBfsActor {
    /// Drain the wavefront heap: cascade improvements through the local
    /// row space in level order (a per-locality BFS wavefront that keeps
    /// the label-correcting flood from re-expanding whole subtrees).
    fn relax(&mut self, ctx: &mut Ctx<BfsMsg>) {
        let n_owned = self.shard.n_local();
        while let Some(Reverse((lvl, row, parent))) = self.heap.pop() {
            if lvl >= self.level[row] {
                continue;
            }
            self.level[row] = lvl;
            if row < n_owned {
                // Correcting store: the smallest level seen so far wins, so
                // the final parent array encodes a shortest-path tree.
                self.parents.store(self.shard.owned_ids[row] as usize, parent as i64);
                for &(dst, gi) in self.shard.mirrors(row) {
                    if let Some(b) = self.mirror_agg.accumulate(dst, gi, lvl) {
                        ctx.send(dst, BfsMsg::ToMirror(b));
                    }
                }
            } else {
                let gi = row - n_owned;
                let dst = self.shard.ghost_owner[gi];
                let idx = self.shard.ghost_master_index[gi];
                if let Some(b) = self.agg.accumulate(dst, idx, (parent, lvl)) {
                    ctx.send(dst, BfsMsg::ToMaster(b));
                }
            }
            let gu = self.shard.global_of(row);
            let nl = lvl + 1;
            for &t in self.shard.row_neighbors_local(row) {
                if nl < self.level[t as usize] {
                    self.heap.push(Reverse((nl, t as usize, gu)));
                }
            }
        }
    }

    /// Ship whatever the policies left buffered; called at handler end so
    /// quiescence can never strand pending visits.
    fn drain(&mut self, ctx: &mut Ctx<BfsMsg>) {
        for (dst, batch) in self.agg.drain() {
            ctx.send(dst, BfsMsg::ToMaster(batch));
        }
        for (dst, batch) in self.mirror_agg.drain() {
            ctx.send(dst, BfsMsg::ToMirror(batch));
        }
    }
}

impl Actor for AsyncBfsActor {
    type Msg = BfsMsg;

    fn on_start(&mut self, ctx: &mut Ctx<BfsMsg>) {
        if let Ok(r) = self.shard.owned_ids.binary_search(&self.root) {
            let root = self.root;
            self.heap.push(Reverse((0, r, root)));
            self.relax(ctx);
            self.drain(ctx);
        }
    }

    fn on_message(&mut self, ctx: &mut Ctx<BfsMsg>, _from: LocalityId, msg: BfsMsg) {
        let n_owned = self.shard.n_local();
        match msg {
            BfsMsg::ToMaster(b) => {
                for (idx, (parent, lvl)) in b.items {
                    self.heap.push(Reverse((lvl, idx as usize, parent)));
                }
            }
            BfsMsg::ToMirror(b) => {
                // The value came *from* the master: install it directly
                // (no echo back) and expand the locally homed edges.
                for (gi, lvl) in b.items {
                    let row = n_owned + gi as usize;
                    if lvl < self.level[row] {
                        self.level[row] = lvl;
                        let gu = self.shard.global_of(row);
                        for &t in self.shard.row_neighbors_local(row) {
                            if lvl + 1 < self.level[t as usize] {
                                self.heap.push(Reverse((lvl + 1, t as usize, gu)));
                            }
                        }
                    }
                }
            }
        }
        self.relax(ctx);
        self.drain(ctx);
    }
}

/// Run asynchronous distributed BFS over `dist` from `root` with the
/// default [`FlushPolicy::Adaptive`] aggregation.
pub fn run(dist: &DistGraph, root: VertexId, cfg: SimConfig) -> BfsResult {
    run_with_policy(dist, root, FlushPolicy::Adaptive, cfg)
}

/// Run asynchronous distributed BFS with an explicit flush policy.
pub fn run_with_policy(
    dist: &DistGraph,
    root: VertexId,
    policy: FlushPolicy,
    cfg: SimConfig,
) -> BfsResult {
    let parents = AtomicLongVector::new(dist.n(), dist.p(), -1);
    let actors: Vec<AsyncBfsActor> = dist
        .shards
        .iter()
        .map(|s| AsyncBfsActor {
            shard: Arc::new(s.clone()),
            parents: parents.clone(),
            root,
            level: vec![u32::MAX; s.n_rows()],
            agg: Aggregator::new(
                dist.owned_counts(),
                s.locality,
                policy,
                &cfg.net,
                ITEM_BYTES,
                min_level,
            ),
            mirror_agg: Aggregator::new(
                dist.ghost_counts(),
                s.locality,
                policy,
                &cfg.net,
                MIRROR_ITEM_BYTES,
                min_u32,
            ),
            heap: BinaryHeap::new(),
        })
        .collect();
    let (actors, mut report) = SimRuntime::new(cfg).run(actors);
    for a in &actors {
        report.agg.merge(a.agg.stats());
        report.agg.merge(a.mirror_agg.stats());
    }
    report.partition = dist.partition_stats();
    BfsResult { parents: parents.to_vec(), report }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::bfs::{sequential, tree_levels, validate_parents};
    use crate::amt::NetConfig;
    use crate::graph::{generators, PartitionKind};

    fn det() -> SimConfig {
        SimConfig::deterministic(NetConfig::default())
    }

    fn check(g: &crate::graph::Csr, p: u32, root: VertexId) {
        let dist = DistGraph::block(g, p);
        let res = run(&dist, root, SimConfig::deterministic(NetConfig::default()));
        validate_parents(g, root, &res.parents).unwrap();
        // Level-correcting BFS converges to true distances at quiescence.
        let lv = tree_levels(root, &res.parents);
        let want = sequential::distances(g, root);
        assert_eq!(lv, want);
    }

    #[test]
    fn matches_oracle_on_random_graphs() {
        for (scale, p) in [(6u32, 1u32), (6, 2), (6, 4), (7, 8)] {
            let g = generators::urand(scale, 4, scale as u64 + p as u64);
            check(&g, p, 0);
        }
    }

    #[test]
    fn works_on_skewed_graphs() {
        let g = generators::kron(7, 6, 9);
        check(&g, 4, 0);
    }

    #[test]
    fn works_when_root_not_on_locality_zero() {
        let g = generators::urand(6, 4, 11);
        check(&g, 4, (g.n() - 1) as VertexId);
    }

    #[test]
    fn true_levels_under_every_partition_scheme() {
        // The tentpole property: the same graph yields the same BFS levels
        // under block, edge-balanced, hash, and vertex-cut partitions.
        let g = generators::kron(7, 6, 19);
        let want = sequential::distances(&g, 0);
        for kind in PartitionKind::all() {
            for p in [1u32, 3, 8] {
                let dist = DistGraph::build_with(&g, kind.build(&g, p));
                let res = run(&dist, 0, det());
                validate_parents(&g, 0, &res.parents).unwrap();
                assert_eq!(tree_levels(0, &res.parents), want, "{kind:?} p={p}");
            }
        }
    }

    #[test]
    fn vertex_cut_report_carries_replication() {
        let g = generators::kron(7, 8, 5);
        let dist = DistGraph::build_with(&g, PartitionKind::VertexCut.build(&g, 4));
        assert!(dist.has_mirrors());
        let res = run(&dist, 0, det());
        validate_parents(&g, 0, &res.parents).unwrap();
        assert!(res.report.partition.replication_factor > 1.0);
        assert!(res.report.partition.vertex_imbalance >= 1.0);
        assert!(res.report.partition.edge_imbalance >= 1.0);
    }

    #[test]
    fn disconnected_graph_terminates() {
        let mut el = crate::graph::EdgeList::new(10);
        el.push(0, 1);
        el.push(1, 0);
        let g = crate::graph::Csr::from_edge_list(&el);
        let dist = DistGraph::block(&g, 3);
        let res = run(&dist, 0, SimConfig::deterministic(NetConfig::default()));
        assert_eq!(res.parents[1], 0);
        assert!(res.parents[2..].iter().all(|&p| p == -1));
    }

    #[test]
    fn no_barriers_in_async_bfs() {
        let g = generators::urand(7, 4, 13);
        let dist = DistGraph::block(&g, 4);
        let res = run(&dist, 0, SimConfig::deterministic(NetConfig::default()));
        assert_eq!(res.report.barriers, 0);
    }

    #[test]
    fn every_flush_policy_yields_true_levels() {
        let g = generators::urand(7, 4, 15);
        let dist = DistGraph::block(&g, 4);
        let want = sequential::distances(&g, 0);
        for policy in [
            FlushPolicy::Unbatched,
            FlushPolicy::Items(4),
            FlushPolicy::Adaptive,
            FlushPolicy::Manual,
        ] {
            let res = run_with_policy(&dist, 0, policy, det());
            validate_parents(&g, 0, &res.parents).unwrap();
            assert_eq!(tree_levels(0, &res.parents), want, "{policy:?}");
        }
    }

    #[test]
    fn aggregation_reduces_envelopes_vs_unbatched() {
        let g = generators::urand(8, 8, 17);
        let dist = DistGraph::block(&g, 4);
        let naive = run_with_policy(&dist, 0, FlushPolicy::Unbatched, det());
        let agg = run_with_policy(&dist, 0, FlushPolicy::Adaptive, det());
        assert!(agg.report.net.envelopes < naive.report.net.envelopes);
        assert_eq!(agg.report.agg.envelopes, agg.report.net.envelopes);
    }
}
