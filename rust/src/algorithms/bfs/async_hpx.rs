//! Asynchronous distributed BFS — the paper's Listing 1.2, on the shared
//! [`amt::aggregate`](crate::amt::aggregate) combiner layer.
//!
//! The message-driven form of `bfs_2`: discovering a remote vertex issues
//! an asynchronous remote action (`hpx::async(bfs_2, dst, ...)`) on its
//! owner; locally-owned discoveries are expanded immediately from a local
//! wavefront. Remote visits are folded into per-destination combiners
//! (min-by-level) and flushed by the configured [`FlushPolicy`] — the
//! naive one-action-per-edge path survives as
//! [`FlushPolicy::Unbatched`]. There are **no global barriers**:
//! termination is network quiescence, which the discrete-event engine
//! detects exactly (the paper relies on `hpx::wait_all` over the recursive
//! future tree for the same effect).
//!
//! Unlike the seed's first-touch-CAS variant, visits are *level
//! correcting*: a proposal with a smaller level overwrites the previous
//! parent, so at quiescence every reached vertex carries its true BFS
//! distance — the final tree is a shortest-path tree regardless of message
//! arrival order or aggregation, which is what lets the property suite
//! assert `async == BSP == sequential` on levels, not just reachability.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Arc;

use crate::amt::aggregate::{Aggregator, Batch, FlushPolicy};
use crate::amt::sim::{Actor, Ctx, LocalityId, Message, SimConfig, SimRuntime};
use crate::amt::AtomicLongVector;
use crate::graph::{DistGraph, Shard, VertexId};

use super::BfsResult;

/// A flushed combiner of `Visit` actions: `(vertex, (parent, level))`,
/// at most one (the best) per destination vertex.
#[derive(Debug, Clone)]
pub struct VisitBatch(pub Batch<(VertexId, u32)>);

/// Per-item wire size: vertex + parent + level.
const ITEM_BYTES: usize = 12;

impl Message for VisitBatch {
    fn wire_bytes(&self) -> usize {
        self.0.wire_bytes()
    }

    fn item_count(&self) -> usize {
        self.0.len()
    }
}

/// Keep the proposal with the smaller level (ties: first wins).
fn min_level(acc: &mut (VertexId, u32), new: (VertexId, u32)) {
    if new.1 < acc.1 {
        *acc = new;
    }
}

/// Per-locality actor state.
pub struct AsyncBfsActor {
    shard: Arc<Shard>,
    dist: Arc<DistGraph>,
    parents: AtomicLongVector,
    root: VertexId,
    /// Tentative BFS level of each owned vertex (`u32::MAX` = unvisited).
    level: Vec<u32>,
    /// Best level already *sent* per remote vertex — legitimate local
    /// knowledge (our own send history) that prunes the correcting flood.
    best_sent: Vec<u32>,
    /// Remote-visit combiner (shared aggregation subsystem).
    pub agg: Aggregator<(VertexId, u32)>,
}

impl AsyncBfsActor {
    /// Cascade a winning visit through the local shard in level order — a
    /// per-locality BFS wavefront that keeps the label-correcting flood
    /// from re-expanding whole subtrees.
    fn relax_from(&mut self, ctx: &mut Ctx<VisitBatch>, v: VertexId, parent: VertexId, lvl: u32) {
        let here = ctx.locality();
        let start = self.shard.range.start;
        let mut heap: BinaryHeap<Reverse<(u32, VertexId, VertexId)>> = BinaryHeap::new();
        heap.push(Reverse((lvl, v, parent)));
        while let Some(Reverse((lu, u, pu))) = heap.pop() {
            let iu = u as usize - start;
            if lu >= self.level[iu] {
                continue;
            }
            self.level[iu] = lu;
            // Correcting store: the smallest level seen so far wins, so the
            // final parent array encodes a shortest-path tree.
            self.parents.store(u as usize, pu as i64);
            let nl = lu + 1;
            for &w in self.shard.out_neighbors(iu) {
                let dst = self.dist.owner(w);
                if dst == here {
                    if nl < self.level[w as usize - start] {
                        heap.push(Reverse((nl, w, u)));
                    }
                } else if nl < self.best_sent[w as usize] {
                    self.best_sent[w as usize] = nl;
                    if let Some(batch) = self.agg.accumulate(dst, w, (u, nl)) {
                        ctx.send(dst, VisitBatch(batch));
                    }
                }
            }
        }
    }

    /// Ship whatever the policy left buffered; called at handler end so
    /// quiescence can never strand pending visits.
    fn drain(&mut self, ctx: &mut Ctx<VisitBatch>) {
        for (dst, batch) in self.agg.drain() {
            ctx.send(dst, VisitBatch(batch));
        }
    }
}

impl Actor for AsyncBfsActor {
    type Msg = VisitBatch;

    fn on_start(&mut self, ctx: &mut Ctx<VisitBatch>) {
        if self.dist.owner(self.root) == ctx.locality() {
            let root = self.root;
            self.relax_from(ctx, root, root, 0);
            self.drain(ctx);
        }
    }

    fn on_message(&mut self, ctx: &mut Ctx<VisitBatch>, _from: LocalityId, msg: VisitBatch) {
        for (v, (parent, lvl)) in msg.0.items {
            self.relax_from(ctx, v, parent, lvl);
        }
        self.drain(ctx);
    }
}

/// Run asynchronous distributed BFS over `dist` from `root` with the
/// default [`FlushPolicy::Adaptive`] aggregation.
pub fn run(dist: &DistGraph, root: VertexId, cfg: SimConfig) -> BfsResult {
    run_with_policy(dist, root, FlushPolicy::Adaptive, cfg)
}

/// Run asynchronous distributed BFS with an explicit flush policy.
pub fn run_with_policy(
    dist: &DistGraph,
    root: VertexId,
    policy: FlushPolicy,
    cfg: SimConfig,
) -> BfsResult {
    let dist = Arc::new(dist.clone());
    let parents = AtomicLongVector::new(dist.n(), dist.p(), -1);
    let ranges = dist.partition.ranges();
    let actors: Vec<AsyncBfsActor> = dist
        .shards
        .iter()
        .map(|s| AsyncBfsActor {
            shard: Arc::new(s.clone()),
            dist: Arc::clone(&dist),
            parents: parents.clone(),
            root,
            level: vec![u32::MAX; s.n_local()],
            best_sent: vec![u32::MAX; dist.n()],
            agg: Aggregator::new(&ranges, s.locality, policy, &cfg.net, ITEM_BYTES, min_level),
        })
        .collect();
    let (actors, mut report) = SimRuntime::new(cfg).run(actors);
    for a in &actors {
        report.agg.merge(a.agg.stats());
    }
    BfsResult { parents: parents.to_vec(), report }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::bfs::{sequential, tree_levels, validate_parents};
    use crate::amt::NetConfig;
    use crate::graph::generators;

    fn det() -> SimConfig {
        SimConfig::deterministic(NetConfig::default())
    }

    fn check(g: &crate::graph::Csr, p: u32, root: VertexId) {
        let dist = DistGraph::block(g, p);
        let res = run(&dist, root, SimConfig::deterministic(NetConfig::default()));
        validate_parents(g, root, &res.parents).unwrap();
        // Level-correcting BFS converges to true distances at quiescence.
        let lv = tree_levels(root, &res.parents);
        let want = sequential::distances(g, root);
        assert_eq!(lv, want);
    }

    #[test]
    fn matches_oracle_on_random_graphs() {
        for (scale, p) in [(6u32, 1u32), (6, 2), (6, 4), (7, 8)] {
            let g = generators::urand(scale, 4, scale as u64 + p as u64);
            check(&g, p, 0);
        }
    }

    #[test]
    fn works_on_skewed_graphs() {
        let g = generators::kron(7, 6, 9);
        check(&g, 4, 0);
    }

    #[test]
    fn works_when_root_not_on_locality_zero() {
        let g = generators::urand(6, 4, 11);
        check(&g, 4, (g.n() - 1) as VertexId);
    }

    #[test]
    fn disconnected_graph_terminates() {
        let mut el = crate::graph::EdgeList::new(10);
        el.push(0, 1);
        el.push(1, 0);
        let g = crate::graph::Csr::from_edge_list(&el);
        let dist = DistGraph::block(&g, 3);
        let res = run(&dist, 0, SimConfig::deterministic(NetConfig::default()));
        assert_eq!(res.parents[1], 0);
        assert!(res.parents[2..].iter().all(|&p| p == -1));
    }

    #[test]
    fn no_barriers_in_async_bfs() {
        let g = generators::urand(7, 4, 13);
        let dist = DistGraph::block(&g, 4);
        let res = run(&dist, 0, SimConfig::deterministic(NetConfig::default()));
        assert_eq!(res.report.barriers, 0);
    }

    #[test]
    fn every_flush_policy_yields_true_levels() {
        let g = generators::urand(7, 4, 15);
        let dist = DistGraph::block(&g, 4);
        let want = sequential::distances(&g, 0);
        for policy in [
            FlushPolicy::Unbatched,
            FlushPolicy::Items(4),
            FlushPolicy::Adaptive,
            FlushPolicy::Manual,
        ] {
            let res = run_with_policy(&dist, 0, policy, det());
            validate_parents(&g, 0, &res.parents).unwrap();
            assert_eq!(tree_levels(0, &res.parents), want, "{policy:?}");
        }
    }

    #[test]
    fn aggregation_reduces_envelopes_vs_unbatched() {
        let g = generators::urand(8, 8, 17);
        let dist = DistGraph::block(&g, 4);
        let naive = run_with_policy(&dist, 0, FlushPolicy::Unbatched, det());
        let agg = run_with_policy(&dist, 0, FlushPolicy::Adaptive, det());
        assert!(agg.report.net.envelopes < naive.report.net.envelopes);
        assert_eq!(agg.report.agg.envelopes, agg.report.net.envelopes);
    }
}
