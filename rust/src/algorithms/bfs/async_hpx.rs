//! Asynchronous distributed BFS — the paper's Listing 1.2.
//!
//! The message-driven form of `bfs_2`: discovering a remote vertex issues
//! an asynchronous remote action (`hpx::async(bfs_2, dst, ...)`) on its
//! owner; locally-owned discoveries are expanded immediately from a local
//! queue. Parent updates go through the atomic `set_parent` CAS on the
//! shared partitioned parent vector. There are **no global barriers**:
//! termination is network quiescence, which the discrete-event engine
//! detects exactly (the paper relies on `hpx::wait_all` over the recursive
//! future tree for the same effect).

use std::sync::Arc;

use crate::amt::sim::{Actor, Ctx, LocalityId, Message, SimConfig, SimRuntime};
use crate::amt::AtomicLongVector;
use crate::graph::{DistGraph, Shard, VertexId};

use super::BfsResult;

/// A `Visit(v, parent, level)` remote action.
#[derive(Debug, Clone)]
pub struct Visit {
    /// Vertex to visit (owned by the receiving locality).
    pub v: VertexId,
    /// Proposed parent.
    pub parent: VertexId,
    /// Tree level of `v` if this visit wins.
    pub level: u32,
}

impl Message for Visit {
    fn wire_bytes(&self) -> usize {
        12 // v + parent + level
    }
}

/// Per-locality actor state.
pub struct AsyncBfsActor {
    shard: Arc<Shard>,
    dist: Arc<DistGraph>,
    parents: AtomicLongVector,
    root: VertexId,
    /// Local duplicate-suppression filter: remote vertices this locality
    /// has already issued a `Visit` for. This is knowledge a real locality
    /// legitimately has (its own send history) — unlike the remote parent
    /// array, which only the owner may read.
    sent: Vec<u64>,
}

impl AsyncBfsActor {
    fn already_sent(&mut self, v: VertexId) -> bool {
        let (w, b) = (v as usize / 64, v as usize % 64);
        let hit = self.sent[w] & (1 << b) != 0;
        self.sent[w] |= 1 << b;
        hit
    }
}

impl AsyncBfsActor {
    /// The paper's `set_parent`: atomic first-touch via compare-exchange.
    fn set_parent(&self, v: VertexId, parent: VertexId) -> bool {
        self.parents.cas(v as usize, -1, parent as i64)
    }

    /// Expand the local queue seeded by a winning visit (the inner loop of
    /// Listing 1.2: local discoveries stay in `q1`/`q2`, remote ones become
    /// async actions).
    fn expand_from(&mut self, ctx: &mut Ctx<Visit>, v: VertexId, level: u32) {
        let here = ctx.locality();
        let shard = Arc::clone(&self.shard);
        let mut queue: Vec<(VertexId, u32)> = vec![(v, level)];
        while let Some((u, lvl)) = queue.pop() {
            let lu = shard.local_index(u);
            for &w in shard.out_neighbors(lu) {
                let dst = self.dist.owner(w);
                if dst == here {
                    if self.set_parent(w, u) {
                        queue.push((w, lvl + 1));
                    }
                } else if !self.already_sent(w) {
                    // Remote: async action on the owner, which performs the
                    // atomic set_parent (CAS races are resolved there).
                    ctx.send(dst, Visit { v: w, parent: u, level: lvl + 1 });
                }
            }
        }
    }
}

impl Actor for AsyncBfsActor {
    type Msg = Visit;

    fn on_start(&mut self, ctx: &mut Ctx<Visit>) {
        if self.dist.owner(self.root) == ctx.locality() {
            let root = self.root;
            if self.set_parent(root, root) {
                self.expand_from(ctx, root, 0);
            }
        }
    }

    fn on_message(&mut self, ctx: &mut Ctx<Visit>, _from: LocalityId, msg: Visit) {
        if self.set_parent(msg.v, msg.parent) {
            self.expand_from(ctx, msg.v, msg.level);
        }
    }
}

/// Run asynchronous distributed BFS over `dist` from `root`.
pub fn run(dist: &DistGraph, root: VertexId, cfg: SimConfig) -> BfsResult {
    let dist = Arc::new(dist.clone());
    let parents = AtomicLongVector::new(dist.n(), dist.p(), -1);
    let actors: Vec<AsyncBfsActor> = dist
        .shards
        .iter()
        .map(|s| AsyncBfsActor {
            shard: Arc::new(s.clone()),
            dist: Arc::clone(&dist),
            parents: parents.clone(),
            root,
            sent: vec![0u64; dist.n().div_ceil(64)],
        })
        .collect();
    let (_, report) = SimRuntime::new(cfg).run(actors);
    BfsResult { parents: parents.to_vec(), report }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::bfs::{sequential, validate_parents};
    use crate::amt::NetConfig;
    use crate::graph::generators;

    fn check(g: &crate::graph::Csr, p: u32, root: VertexId) {
        let dist = DistGraph::block(g, p);
        let res = run(&dist, root, SimConfig::deterministic(NetConfig::default()));
        validate_parents(g, root, &res.parents).unwrap();
        // Reachable set must match the sequential oracle.
        let seq = sequential::bfs(g, root);
        for v in 0..g.n() {
            assert_eq!(res.parents[v] >= 0, seq[v] >= 0, "vertex {v}");
        }
    }

    #[test]
    fn matches_oracle_on_random_graphs() {
        for (scale, p) in [(6u32, 1u32), (6, 2), (6, 4), (7, 8)] {
            let g = generators::urand(scale, 4, scale as u64 + p as u64);
            check(&g, p, 0);
        }
    }

    #[test]
    fn works_on_skewed_graphs() {
        let g = generators::kron(7, 6, 9);
        check(&g, 4, 0);
    }

    #[test]
    fn works_when_root_not_on_locality_zero() {
        let g = generators::urand(6, 4, 11);
        check(&g, 4, (g.n() - 1) as VertexId);
    }

    #[test]
    fn disconnected_graph_terminates() {
        let mut el = crate::graph::EdgeList::new(10);
        el.push(0, 1);
        el.push(1, 0);
        let g = crate::graph::Csr::from_edge_list(&el);
        let dist = DistGraph::block(&g, 3);
        let res = run(&dist, 0, SimConfig::deterministic(NetConfig::default()));
        assert_eq!(res.parents[1], 0);
        assert!(res.parents[2..].iter().all(|&p| p == -1));
    }

    #[test]
    fn no_barriers_in_async_bfs() {
        let g = generators::urand(7, 4, 13);
        let dist = DistGraph::block(&g, 4);
        let res = run(&dist, 0, SimConfig::deterministic(NetConfig::default()));
        assert_eq!(res.report.barriers, 0);
    }
}
