//! Direction-optimizing distributed BFS (Beamer-style), as a BSP extension.
//!
//! The paper's future work (§6) calls for broader algorithm coverage and
//! runtime adaptivity; direction optimization is the classic example for
//! BFS. Top-down supersteps behave like the BSP engine ([`super::run_bsp`]);
//! when the frontier becomes edge-heavy (`m_frontier > m_unvisited / alpha`)
//! the traversal switches to bottom-up supersteps, where every locality
//! scans its *unvisited* vertices against a replicated frontier bitmap —
//! eliminating per-discovery remote traffic at the price of an extra
//! bitmap-allgather barrier per switch/round. It switches back when the
//! frontier shrinks below `n / beta`.
//!
//! Works with any mirror-free
//! [`PartitionScheme`](crate::graph::partition::PartitionScheme) —
//! block, edge-balanced, or
//! hash — since top-down needs whole rows at the owner and bottom-up
//! needs whole in-rows. Vertex-cut graphs are rejected; use
//! [`super::run_async`] or [`super::run_bsp`] there.

use std::sync::Arc;

use crate::amt::sim::{Actor, Ctx, LocalityId, Message, SimConfig};
use crate::amt::AtomicLongVector;
use crate::graph::{DistGraph, Shard, VertexId};

use super::BfsResult;

/// Beamer's alpha (top-down -> bottom-up threshold).
pub const DEFAULT_ALPHA: f64 = 14.0;
/// Beamer's beta (bottom-up -> top-down threshold).
pub const DEFAULT_BETA: f64 = 24.0;

/// Messages for the direction-optimizing traversal.
#[derive(Debug, Clone)]
pub enum DirMsg {
    /// Batched top-down remote discoveries `(vertex, parent)`.
    Visits(Vec<(VertexId, VertexId)>),
    /// Per-round stats for the coordinator's direction decision.
    Stats {
        /// Discoveries + sends this round.
        activity: u64,
        /// |next frontier| on this locality.
        frontier_vertices: u64,
        /// Sum of out-degrees over the next frontier.
        frontier_edges: u64,
        /// Sum of out-degrees over still-unvisited owned vertices.
        unvisited_edges: u64,
    },
    /// Coordinator verdict: continue? bottom-up next round?
    Decision {
        /// Keep traversing?
        go: bool,
        /// Use a bottom-up superstep next?
        bottom_up: bool,
    },
    /// Frontier-bitmap allgather fragment for bottom-up rounds. Wire size
    /// models a compressed bitmap slice (n/8/P bytes), which is how real
    /// implementations ship it.
    Bitmap {
        /// Frontier vertex ids on the sending locality.
        ids: Vec<VertexId>,
        /// Modeled wire size (bitmap slice).
        bitmap_bytes: usize,
    },
}

impl Message for DirMsg {
    fn wire_bytes(&self) -> usize {
        match self {
            DirMsg::Visits(v) => 8 * v.len(),
            DirMsg::Stats { .. } => 32,
            DirMsg::Decision { .. } => 2,
            DirMsg::Bitmap { bitmap_bytes, .. } => *bitmap_bytes,
        }
    }

    fn item_count(&self) -> usize {
        match self {
            DirMsg::Visits(v) => v.len(),
            // A bitmap is applied with word-level ops, not per-vertex.
            _ => 1,
        }
    }
}

#[derive(PartialEq, Clone, Copy, Debug)]
enum Phase {
    AfterExpand,
    AwaitDecision,
    AfterBitmap,
}

/// Per-locality direction-optimizing BFS state.
pub struct DirOptBfsActor {
    shard: Arc<Shard>,
    dist: Arc<DistGraph>,
    parents: AtomicLongVector,
    root: VertexId,
    alpha: f64,
    beta: f64,
    /// Current frontier as owned local rows (O(1) degree/adjacency
    /// access; global ids are rebuilt only for the bitmap allgather).
    frontier: Vec<u32>,
    inbox: Vec<(VertexId, VertexId)>,
    visited: Vec<bool>, // owned vertices, local index
    global_frontier_bitmap: Vec<u64>,
    // coordinator (locality 0) reduction state
    stats_seen: u32,
    act_sum: u64,
    fv_sum: u64,
    fe_sum: u64,
    ue_sum: u64,
    decision_go: bool,
    decision_bottom_up: bool,
    bottom_up_now: bool,
    phase: Phase,
    /// Bottom-up supersteps taken (reporting).
    pub bu_rounds: u32,
    /// Top-down supersteps taken (reporting).
    pub td_rounds: u32,
}

impl DirOptBfsActor {
    fn set_parent(&self, v: VertexId, parent: VertexId) -> bool {
        self.parents.cas(v as usize, -1, parent as i64)
    }

    /// Mark a remotely discovered owned vertex visited; returns its row.
    fn mark_visited(&mut self, v: VertexId) -> u32 {
        let l = self.shard.local_index(v);
        self.visited[l] = true;
        l as u32
    }

    fn send_stats(&mut self, ctx: &mut Ctx<DirMsg>, activity: u64) {
        let fv = self.frontier.len() as u64;
        let fe: u64 = self
            .frontier
            .iter()
            .map(|&r| self.shard.out_degree[r as usize] as u64)
            .sum();
        let ue: u64 = (0..self.shard.n_local())
            .filter(|&l| !self.visited[l])
            .map(|l| self.shard.out_degree[l] as u64)
            .sum();
        ctx.send(0, DirMsg::Stats {
            activity,
            frontier_vertices: fv,
            frontier_edges: fe,
            unvisited_edges: ue,
        });
        self.phase = Phase::AfterExpand;
        ctx.request_barrier();
    }

    /// Top-down superstep (same as the level-synchronous baseline).
    fn expand_top_down(&mut self, ctx: &mut Ctx<DirMsg>) {
        self.td_rounds += 1;
        let p = ctx.n_localities() as usize;
        let mut next: Vec<u32> = Vec::new();
        let mut outgoing: Vec<Vec<(VertexId, VertexId)>> = vec![Vec::new(); p];
        let mut activity: u64 = 0;
        let frontier = std::mem::take(&mut self.frontier);
        let shard = Arc::clone(&self.shard);
        let n_owned = shard.n_local();
        for &lu in &frontier {
            let u = shard.owned_ids[lu as usize];
            for t in shard.row_locals(lu as usize) {
                let t = t as usize;
                if t < n_owned {
                    if self.set_parent(shard.owned_ids[t], u) {
                        self.visited[t] = true;
                        next.push(t as u32);
                        activity += 1;
                    }
                } else {
                    let gi = t - n_owned;
                    outgoing[shard.ghost_owner[gi] as usize]
                        .push((shard.ghost_global_ids[gi], u));
                    activity += 1;
                }
            }
        }
        for (dst, batch) in outgoing.into_iter().enumerate() {
            if !batch.is_empty() {
                ctx.send(dst as LocalityId, DirMsg::Visits(batch));
            }
        }
        self.frontier = next;
        self.send_stats(ctx, activity);
    }

    /// Bottom-up superstep: scan unvisited owned vertices against the
    /// replicated frontier bitmap; discoveries are purely local.
    fn expand_bottom_up(&mut self, ctx: &mut Ctx<DirMsg>) {
        self.bu_rounds += 1;
        let mut next: Vec<u32> = Vec::new();
        let mut activity: u64 = 0;
        for l in 0..self.shard.n_local() {
            if self.visited[l] {
                continue;
            }
            let v = self.shard.global_id(l);
            for u in self.shard.in_neighbors_iter(l) {
                let (w, b) = (u as usize / 64, u as usize % 64);
                if self.global_frontier_bitmap[w] & (1 << b) != 0 {
                    if self.set_parent(v, u) {
                        self.visited[l] = true;
                        next.push(l as u32);
                        activity += 1;
                    }
                    break;
                }
            }
        }
        self.frontier = next;
        self.send_stats(ctx, activity);
    }

    fn broadcast_bitmap(&mut self, ctx: &mut Ctx<DirMsg>) {
        let n = self.dist.n();
        let p = ctx.n_localities();
        let slice_bytes = n.div_ceil(8).div_ceil(p as usize).max(1);
        let ids: Vec<VertexId> =
            self.frontier.iter().map(|&r| self.shard.owned_ids[r as usize]).collect();
        for l in 0..p {
            if l != ctx.locality() {
                ctx.send(l, DirMsg::Bitmap { ids: ids.clone(), bitmap_bytes: slice_bytes });
            }
        }
        // Own frontier goes straight into the bitmap.
        self.global_frontier_bitmap = vec![0u64; n.div_ceil(64)];
        for &v in &ids {
            self.global_frontier_bitmap[v as usize / 64] |= 1 << (v as usize % 64);
        }
        self.phase = Phase::AfterBitmap;
        ctx.request_barrier();
    }
}

impl Actor for DirOptBfsActor {
    type Msg = DirMsg;

    fn on_start(&mut self, ctx: &mut Ctx<DirMsg>) {
        if self.shard.owned_ids.binary_search(&self.root).is_ok()
            && self.set_parent(self.root, self.root)
        {
            let r = self.mark_visited(self.root);
            self.frontier.push(r);
        }
        self.expand_top_down(ctx);
    }

    fn on_message(&mut self, _ctx: &mut Ctx<DirMsg>, _from: LocalityId, msg: DirMsg) {
        match msg {
            DirMsg::Visits(batch) => self.inbox.extend(batch),
            DirMsg::Stats { activity, frontier_vertices, frontier_edges, unvisited_edges } => {
                self.stats_seen += 1;
                self.act_sum += activity;
                self.fv_sum += frontier_vertices;
                self.fe_sum += frontier_edges;
                self.ue_sum += unvisited_edges;
            }
            DirMsg::Decision { go, bottom_up } => {
                self.decision_go = go;
                self.decision_bottom_up = bottom_up;
            }
            DirMsg::Bitmap { ids, .. } => {
                for v in ids {
                    self.global_frontier_bitmap[v as usize / 64] |= 1 << (v as usize % 64);
                }
            }
        }
    }

    fn on_barrier(&mut self, ctx: &mut Ctx<DirMsg>, _epoch: u64) {
        match self.phase {
            Phase::AfterExpand => {
                // Fold top-down remote discoveries (no-op after bottom-up).
                let inbox = std::mem::take(&mut self.inbox);
                for (v, parent) in inbox {
                    if self.set_parent(v, parent) {
                        let r = self.mark_visited(v);
                        self.frontier.push(r);
                    }
                }
                if ctx.locality() == 0 {
                    debug_assert_eq!(self.stats_seen, ctx.n_localities());
                    let go = self.act_sum > 0;
                    // Beamer heuristic on global counts.
                    let bottom_up = if !self.bottom_up_now {
                        (self.fe_sum as f64) > (self.ue_sum as f64) / self.alpha
                    } else {
                        (self.fv_sum as f64) >= (self.dist.n() as f64) / self.beta
                    };
                    self.act_sum = 0;
                    self.fv_sum = 0;
                    self.fe_sum = 0;
                    self.ue_sum = 0;
                    self.stats_seen = 0;
                    for l in 0..ctx.n_localities() {
                        ctx.send(l, DirMsg::Decision { go, bottom_up });
                    }
                }
                self.phase = Phase::AwaitDecision;
                ctx.request_barrier();
            }
            Phase::AwaitDecision => {
                if !self.decision_go {
                    return; // quiesce
                }
                self.bottom_up_now = self.decision_bottom_up;
                if self.bottom_up_now {
                    self.broadcast_bitmap(ctx);
                } else {
                    self.expand_top_down(ctx);
                }
            }
            Phase::AfterBitmap => {
                self.expand_bottom_up(ctx);
            }
        }
    }
}

/// Run direction-optimizing BSP BFS; returns the result plus
/// `(top_down_rounds, bottom_up_rounds)`.
pub fn run_with_params(
    dist: &DistGraph,
    root: VertexId,
    cfg: SimConfig,
    alpha: f64,
    beta: f64,
) -> (BfsResult, u32, u32) {
    // Coordinator callers reject this combination gracefully up front;
    // the re-check here turns direct library misuse into a clear panic
    // instead of silently wrong traversals over unexpanded mirror rows.
    if let Err(e) = crate::engine::require_mirror_free(dist, "direction-optimizing BFS") {
        panic!("{e}");
    }
    let dist = Arc::new(dist.clone());
    let parents = AtomicLongVector::new(dist.n(), dist.p(), -1);
    let actors: Vec<DirOptBfsActor> = dist
        .shards
        .iter()
        .map(|s| DirOptBfsActor {
            shard: Arc::new(s.clone()),
            dist: Arc::clone(&dist),
            parents: parents.clone(),
            root,
            alpha,
            beta,
            frontier: Vec::new(),
            inbox: Vec::new(),
            visited: vec![false; s.n_local()],
            global_frontier_bitmap: vec![0u64; dist.n().div_ceil(64)],
            stats_seen: 0,
            act_sum: 0,
            fv_sum: 0,
            fe_sum: 0,
            ue_sum: 0,
            decision_go: false,
            decision_bottom_up: false,
            bottom_up_now: false,
            phase: Phase::AfterExpand,
            bu_rounds: 0,
            td_rounds: 0,
        })
        .collect();
    let (actors, mut report) = crate::amt::run_actors(&cfg, actors);
    report.partition = dist.partition_stats();
    report.mem = dist.mem_stats();
    let td = actors.iter().map(|a| a.td_rounds).max().unwrap_or(0);
    let bu = actors.iter().map(|a| a.bu_rounds).max().unwrap_or(0);
    (BfsResult { parents: parents.to_vec(), report }, td, bu)
}

/// Run with the standard Beamer parameters.
pub fn run(dist: &DistGraph, root: VertexId, cfg: SimConfig) -> BfsResult {
    run_with_params(dist, root, cfg, DEFAULT_ALPHA, DEFAULT_BETA).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::bfs::{sequential, validate_parents};
    use crate::amt::NetConfig;
    use crate::graph::generators;

    #[test]
    fn matches_oracle_reachability() {
        for (scale, p) in [(6u32, 2u32), (7, 4), (8, 8)] {
            let g = generators::urand(scale, 8, 500 + scale as u64 + p as u64);
            let dist = DistGraph::block(&g, p);
            let res = run(&dist, 0, SimConfig::deterministic(NetConfig::default()));
            validate_parents(&g, 0, &res.parents).unwrap();
            let seq = sequential::bfs(&g, 0);
            for v in 0..g.n() {
                assert_eq!(res.parents[v] >= 0, seq[v] >= 0, "vertex {v}");
            }
        }
    }

    #[test]
    fn dense_graph_triggers_bottom_up() {
        // urand with degree 16 has a huge middle frontier.
        let g = generators::urand(9, 16, 77);
        let dist = DistGraph::block(&g, 4);
        let (res, td, bu) =
            run_with_params(&dist, 0, SimConfig::deterministic(NetConfig::default()), 14.0, 24.0);
        validate_parents(&g, 0, &res.parents).unwrap();
        assert!(bu >= 1, "expected bottom-up rounds on a dense graph (td={td} bu={bu})");
    }

    #[test]
    fn forced_top_down_equals_level_sync_semantics() {
        // Beamer switches TD->BU when m_frontier > m_unvisited / alpha, so
        // alpha -> 0 makes the threshold infinite and disables bottom-up.
        let g = generators::kron(7, 6, 31);
        let dist = DistGraph::block(&g, 4);
        let (res, _, bu) = run_with_params(
            &dist,
            0,
            SimConfig::deterministic(NetConfig::default()),
            0.0,
            24.0,
        );
        assert_eq!(bu, 0);
        validate_parents(&g, 0, &res.parents).unwrap();
    }
}
