//! Level-synchronous BSP BFS — the distributed-BGL (PBGL) baseline.
//!
//! PBGL's BFS processes the frontier in supersteps: every locality expands
//! its local frontier slice, remote discoveries are buffered into
//! per-destination combiners and shipped as batched messages, and a global
//! barrier separates levels. Termination is a count reduction (here: an
//! activity count sent to locality 0, which broadcasts the verdict), so
//! each level costs **two global barriers** — the synchronization overhead
//! the paper's asynchronous variant eliminates (Fig. 1 discussion).

use std::sync::Arc;

use crate::amt::sim::{Actor, Ctx, LocalityId, Message, SimConfig, SimRuntime};
use crate::amt::AtomicLongVector;
use crate::graph::{DistGraph, Shard, VertexId};

use super::BfsResult;

/// BSP BFS messages.
#[derive(Debug, Clone)]
pub enum BspMsg {
    /// Batched remote discoveries: `(vertex, parent)` pairs.
    Visits(Vec<(VertexId, VertexId)>),
    /// Superstep activity count, reduced at locality 0.
    Count(u64),
    /// Locality 0's verdict: keep going?
    Continue(bool),
}

impl Message for BspMsg {
    fn wire_bytes(&self) -> usize {
        match self {
            BspMsg::Visits(v) => 8 * v.len(),
            BspMsg::Count(_) => 8,
            BspMsg::Continue(_) => 1,
        }
    }

    fn item_count(&self) -> usize {
        // PBGL's distributed queue marshals each discovery individually;
        // batching amortizes envelopes, not per-vertex work.
        match self {
            BspMsg::Visits(v) => v.len(),
            _ => 1,
        }
    }
}

#[derive(PartialEq)]
enum Phase {
    AfterExpand,
    AwaitDecision,
}

/// Per-locality BSP BFS state.
pub struct BspBfsActor {
    shard: Arc<Shard>,
    dist: Arc<DistGraph>,
    parents: AtomicLongVector,
    root: VertexId,
    frontier: Vec<VertexId>,
    inbox: Vec<(VertexId, VertexId)>,
    counts_seen: u32,
    counts_sum: u64,
    continue_flag: bool,
    phase: Phase,
    /// Levels completed (for reporting).
    pub levels: u32,
}

impl BspBfsActor {
    fn set_parent(&self, v: VertexId, parent: VertexId) -> bool {
        self.parents.cas(v as usize, -1, parent as i64)
    }

    /// Expand the current frontier one level: local discoveries feed the
    /// next frontier directly; remote ones go to per-destination combiners
    /// shipped as one batched message per destination (PBGL's buffering).
    fn expand_and_report(&mut self, ctx: &mut Ctx<BspMsg>) {
        let here = ctx.locality();
        let p = ctx.n_localities();
        let mut next: Vec<VertexId> = Vec::new();
        let mut outgoing: Vec<Vec<(VertexId, VertexId)>> = vec![Vec::new(); p as usize];
        let mut activity: u64 = 0;
        let frontier = std::mem::take(&mut self.frontier);
        for &u in &frontier {
            let lu = self.shard.local_index(u);
            for &w in self.shard.out_neighbors(lu) {
                let dst = self.dist.owner(w);
                if dst == here {
                    if self.set_parent(w, u) {
                        next.push(w);
                        activity += 1;
                    }
                } else {
                    outgoing[dst as usize].push((w, u));
                    activity += 1;
                }
            }
        }
        for (dst, batch) in outgoing.into_iter().enumerate() {
            if !batch.is_empty() {
                ctx.send(dst as LocalityId, BspMsg::Visits(batch));
            }
        }
        self.frontier = next;
        ctx.send(0, BspMsg::Count(activity));
        self.phase = Phase::AfterExpand;
        ctx.request_barrier();
    }
}

impl Actor for BspBfsActor {
    type Msg = BspMsg;

    fn on_start(&mut self, ctx: &mut Ctx<BspMsg>) {
        if self.dist.owner(self.root) == ctx.locality() && self.set_parent(self.root, self.root)
        {
            self.frontier.push(self.root);
        }
        self.expand_and_report(ctx);
    }

    fn on_message(&mut self, _ctx: &mut Ctx<BspMsg>, _from: LocalityId, msg: BspMsg) {
        match msg {
            BspMsg::Visits(batch) => self.inbox.extend(batch),
            BspMsg::Count(c) => {
                self.counts_seen += 1;
                self.counts_sum += c;
            }
            BspMsg::Continue(b) => self.continue_flag = b,
        }
    }

    fn on_barrier(&mut self, ctx: &mut Ctx<BspMsg>, _epoch: u64) {
        match self.phase {
            Phase::AfterExpand => {
                // Fold remote discoveries into the next frontier.
                let inbox = std::mem::take(&mut self.inbox);
                for (v, parent) in inbox {
                    if self.set_parent(v, parent) {
                        self.frontier.push(v);
                    }
                }
                if ctx.locality() == 0 {
                    debug_assert_eq!(self.counts_seen, ctx.n_localities());
                    let go = self.counts_sum > 0;
                    self.counts_sum = 0;
                    self.counts_seen = 0;
                    for l in 0..ctx.n_localities() {
                        ctx.send(l, BspMsg::Continue(go));
                    }
                }
                self.phase = Phase::AwaitDecision;
                ctx.request_barrier();
            }
            Phase::AwaitDecision => {
                if self.continue_flag {
                    self.levels += 1;
                    self.expand_and_report(ctx);
                }
                // else: quiesce — no sends, no barrier request.
            }
        }
    }
}

/// Run level-synchronous BSP BFS over `dist` from `root`.
pub fn run(dist: &DistGraph, root: VertexId, cfg: SimConfig) -> BfsResult {
    let dist = Arc::new(dist.clone());
    let parents = AtomicLongVector::new(dist.n(), dist.p(), -1);
    let actors: Vec<BspBfsActor> = dist
        .shards
        .iter()
        .map(|s| BspBfsActor {
            shard: Arc::new(s.clone()),
            dist: Arc::clone(&dist),
            parents: parents.clone(),
            root,
            frontier: Vec::new(),
            inbox: Vec::new(),
            counts_seen: 0,
            counts_sum: 0,
            continue_flag: false,
            phase: Phase::AfterExpand,
            levels: 0,
        })
        .collect();
    let (_, report) = SimRuntime::new(cfg).run(actors);
    BfsResult { parents: parents.to_vec(), report }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::bfs::{sequential, tree_levels, validate_parents};
    use crate::amt::NetConfig;
    use crate::graph::generators;

    fn check(g: &crate::graph::Csr, p: u32, root: VertexId) -> BfsResult {
        let dist = DistGraph::block(g, p);
        let res = run(&dist, root, SimConfig::deterministic(NetConfig::default()));
        validate_parents(g, root, &res.parents).unwrap();
        res
    }

    #[test]
    fn matches_oracle_reachability() {
        for (scale, p) in [(6u32, 1u32), (6, 3), (7, 4), (7, 8)] {
            let g = generators::urand(scale, 4, 100 + scale as u64 + p as u64);
            let res = check(&g, p, 0);
            let seq = sequential::bfs(&g, 0);
            for v in 0..g.n() {
                assert_eq!(res.parents[v] >= 0, seq[v] >= 0, "vertex {v}");
            }
        }
    }

    #[test]
    fn level_sync_trees_are_minimal_depth() {
        // Unlike async BFS, level-synchronous BFS produces true BFS levels.
        let g = generators::kron(8, 6, 21);
        let res = check(&g, 4, 0);
        let lv = tree_levels(0, &res.parents);
        let d = sequential::distances(&g, 0);
        assert_eq!(lv, d);
    }

    #[test]
    fn barrier_count_is_two_per_level() {
        let g = generators::path(9); // 8 levels from vertex 0
        let dist = DistGraph::block(&g, 3);
        let res = run(&dist, 0, SimConfig::deterministic(NetConfig::default()));
        // levels+1 rounds (last round discovers nothing), 2 barriers each.
        assert_eq!(res.report.barriers, 2 * (8 + 1));
    }

    #[test]
    fn empty_graph_single_vertex() {
        let g = generators::path(1);
        let res = check(&g, 1, 0);
        assert_eq!(res.parents, vec![0]);
    }
}
