//! Level-synchronous BSP BFS — the distributed-BGL (PBGL) baseline.
//!
//! PBGL's BFS processes the frontier in supersteps: every locality expands
//! its local frontier slice, remote discoveries are buffered into
//! per-destination combiners and shipped as batched messages, and a global
//! barrier separates levels. Termination is a count reduction (here: an
//! activity count sent to locality 0, which broadcasts the verdict), so
//! each level costs **two global barriers** — the synchronization overhead
//! the paper's asynchronous variant eliminates (Fig. 1 discussion).
//!
//! Partitioning is scheme-generic. Under a vertex cut, a frontier vertex's
//! row is split across localities: when the master expands it, it also
//! sends a [`BspMsg::MirrorExpand`] naming the destination's ghost slots,
//! and the mirror expands the remotely homed edges *immediately in the
//! message handler* — the runtime's barrier waits for network quiescence,
//! so the cascade completes inside the same superstep and levels stay
//! minimal. 1-D schemes never take this path.

use std::sync::Arc;

use crate::amt::sim::{Actor, Ctx, LocalityId, Message, SimConfig, SimRuntime};
use crate::amt::AtomicLongVector;
use crate::graph::{DistGraph, Shard, VertexId};

use super::BfsResult;

/// BSP BFS messages.
#[derive(Debug, Clone)]
pub enum BspMsg {
    /// Batched remote discoveries: `(destination master index, parent)`.
    Visits(Vec<(u32, VertexId)>),
    /// Ghost slots at the destination whose vertex the master is expanding
    /// this superstep — the mirror expands its share of the row now.
    MirrorExpand(Vec<u32>),
    /// Superstep activity count, reduced at locality 0.
    Count(u64),
    /// Locality 0's verdict: keep going?
    Continue(bool),
}

impl Message for BspMsg {
    fn wire_bytes(&self) -> usize {
        match self {
            BspMsg::Visits(v) => 8 * v.len(),
            BspMsg::MirrorExpand(v) => 4 * v.len(),
            BspMsg::Count(_) => 8,
            BspMsg::Continue(_) => 1,
        }
    }

    fn item_count(&self) -> usize {
        // PBGL's distributed queue marshals each discovery individually;
        // batching amortizes envelopes, not per-vertex work.
        match self {
            BspMsg::Visits(v) => v.len(),
            BspMsg::MirrorExpand(v) => v.len(),
            _ => 1,
        }
    }
}

#[derive(PartialEq)]
enum Phase {
    AfterExpand,
    AwaitDecision,
}

/// Per-locality BSP BFS state.
pub struct BspBfsActor {
    shard: Arc<Shard>,
    parents: AtomicLongVector,
    root: VertexId,
    /// Next-superstep frontier as local rows (owned rows only; mirror
    /// expansion happens eagerly on message receipt).
    frontier: Vec<u32>,
    inbox: Vec<(u32, VertexId)>,
    counts_seen: u32,
    counts_sum: u64,
    continue_flag: bool,
    phase: Phase,
    /// Levels completed (for reporting).
    pub levels: u32,
}

impl BspBfsActor {
    fn set_parent(&self, v: VertexId, parent: VertexId) -> bool {
        self.parents.cas(v as usize, -1, parent as i64)
    }

    /// Expand the locally homed edges of one local row (owned frontier row
    /// or mirror row being cascaded). Local discoveries feed the next
    /// frontier; remote ones go to the per-destination `outgoing` buffers.
    fn expand_row(
        &mut self,
        row: usize,
        outgoing: &mut [Vec<(u32, VertexId)>],
        activity: &mut u64,
    ) {
        let n_owned = self.shard.n_local();
        let u = self.shard.global_of(row);
        let shard = Arc::clone(&self.shard);
        for &t in shard.row_neighbors_local(row) {
            let t = t as usize;
            if t < n_owned {
                if self.set_parent(shard.owned_ids[t], u) {
                    self.frontier.push(t as u32);
                    *activity += 1;
                }
            } else {
                let gi = t - n_owned;
                let dst = shard.ghost_owner[gi] as usize;
                outgoing[dst].push((shard.ghost_master_index[gi], u));
                *activity += 1;
            }
        }
    }

    /// Expand the current frontier one level: local discoveries feed the
    /// next frontier directly; remote ones go to per-destination combiners
    /// shipped as one batched message per destination (PBGL's buffering).
    /// Frontier vertices with mirrors ask their mirrors to expand too.
    fn expand_and_report(&mut self, ctx: &mut Ctx<BspMsg>) {
        let p = ctx.n_localities() as usize;
        let mut outgoing: Vec<Vec<(u32, VertexId)>> = vec![Vec::new(); p];
        let mut mirror_out: Vec<Vec<u32>> = vec![Vec::new(); p];
        let mut activity: u64 = 0;
        let frontier = std::mem::take(&mut self.frontier);
        for &row in &frontier {
            for &(dst, gi) in self.shard.mirrors(row as usize) {
                mirror_out[dst as usize].push(gi);
                activity += 1;
            }
            self.expand_row(row as usize, &mut outgoing, &mut activity);
        }
        for (dst, batch) in mirror_out.into_iter().enumerate() {
            if !batch.is_empty() {
                ctx.send(dst as LocalityId, BspMsg::MirrorExpand(batch));
            }
        }
        for (dst, batch) in outgoing.into_iter().enumerate() {
            if !batch.is_empty() {
                ctx.send(dst as LocalityId, BspMsg::Visits(batch));
            }
        }
        ctx.send(0, BspMsg::Count(activity));
        self.phase = Phase::AfterExpand;
        ctx.request_barrier();
    }
}

impl Actor for BspBfsActor {
    type Msg = BspMsg;

    fn on_start(&mut self, ctx: &mut Ctx<BspMsg>) {
        if let Ok(r) = self.shard.owned_ids.binary_search(&self.root) {
            if self.set_parent(self.root, self.root) {
                self.frontier.push(r as u32);
            }
        }
        self.expand_and_report(ctx);
    }

    fn on_message(&mut self, ctx: &mut Ctx<BspMsg>, _from: LocalityId, msg: BspMsg) {
        match msg {
            BspMsg::Visits(batch) => self.inbox.extend(batch),
            BspMsg::MirrorExpand(slots) => {
                // Cascade inside the same superstep: discoveries here join
                // the *next* frontier (level L+1), remote proposals reach
                // their masters' inboxes before the barrier fires.
                let p = ctx.n_localities() as usize;
                let n_owned = self.shard.n_local();
                let mut outgoing: Vec<Vec<(u32, VertexId)>> = vec![Vec::new(); p];
                let mut cascade_activity = 0u64;
                for gi in slots {
                    self.expand_row(n_owned + gi as usize, &mut outgoing, &mut cascade_activity);
                }
                for (dst, batch) in outgoing.into_iter().enumerate() {
                    if !batch.is_empty() {
                        ctx.send(dst as LocalityId, BspMsg::Visits(batch));
                    }
                }
                // The master already counted the scatter itself, which
                // guarantees the next superstep runs; cascade discoveries
                // are expanded there and counted then.
            }
            BspMsg::Count(c) => {
                self.counts_seen += 1;
                self.counts_sum += c;
            }
            BspMsg::Continue(b) => self.continue_flag = b,
        }
    }

    fn on_barrier(&mut self, ctx: &mut Ctx<BspMsg>, _epoch: u64) {
        match self.phase {
            Phase::AfterExpand => {
                // Fold remote discoveries into the next frontier.
                let inbox = std::mem::take(&mut self.inbox);
                for (idx, parent) in inbox {
                    if self.set_parent(self.shard.owned_ids[idx as usize], parent) {
                        self.frontier.push(idx);
                    }
                }
                if ctx.locality() == 0 {
                    debug_assert_eq!(self.counts_seen, ctx.n_localities());
                    let go = self.counts_sum > 0;
                    self.counts_sum = 0;
                    self.counts_seen = 0;
                    for l in 0..ctx.n_localities() {
                        ctx.send(l, BspMsg::Continue(go));
                    }
                }
                self.phase = Phase::AwaitDecision;
                ctx.request_barrier();
            }
            Phase::AwaitDecision => {
                if self.continue_flag {
                    self.levels += 1;
                    self.expand_and_report(ctx);
                }
                // else: quiesce — no sends, no barrier request.
            }
        }
    }
}

/// Run level-synchronous BSP BFS over `dist` from `root`.
pub fn run(dist: &DistGraph, root: VertexId, cfg: SimConfig) -> BfsResult {
    let parents = AtomicLongVector::new(dist.n(), dist.p(), -1);
    let actors: Vec<BspBfsActor> = dist
        .shards
        .iter()
        .map(|s| BspBfsActor {
            shard: Arc::new(s.clone()),
            parents: parents.clone(),
            root,
            frontier: Vec::new(),
            inbox: Vec::new(),
            counts_seen: 0,
            counts_sum: 0,
            continue_flag: false,
            phase: Phase::AfterExpand,
            levels: 0,
        })
        .collect();
    let (_, mut report) = SimRuntime::new(cfg).run(actors);
    report.partition = dist.partition_stats();
    BfsResult { parents: parents.to_vec(), report }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::bfs::{sequential, tree_levels, validate_parents};
    use crate::amt::NetConfig;
    use crate::graph::{generators, PartitionKind};

    fn check(g: &crate::graph::Csr, p: u32, root: VertexId) -> BfsResult {
        let dist = DistGraph::block(g, p);
        let res = run(&dist, root, SimConfig::deterministic(NetConfig::default()));
        validate_parents(g, root, &res.parents).unwrap();
        res
    }

    #[test]
    fn matches_oracle_reachability() {
        for (scale, p) in [(6u32, 1u32), (6, 3), (7, 4), (7, 8)] {
            let g = generators::urand(scale, 4, 100 + scale as u64 + p as u64);
            let res = check(&g, p, 0);
            let seq = sequential::bfs(&g, 0);
            for v in 0..g.n() {
                assert_eq!(res.parents[v] >= 0, seq[v] >= 0, "vertex {v}");
            }
        }
    }

    #[test]
    fn level_sync_trees_are_minimal_depth() {
        // Unlike CAS-based async BFS, level-synchronous BFS produces true
        // BFS levels.
        let g = generators::kron(8, 6, 21);
        let res = check(&g, 4, 0);
        let lv = tree_levels(0, &res.parents);
        let d = sequential::distances(&g, 0);
        assert_eq!(lv, d);
    }

    #[test]
    fn minimal_levels_under_every_partition_scheme() {
        // The same-superstep mirror cascade keeps level synchrony exact
        // even when rows are split by a vertex cut.
        let g = generators::kron(7, 6, 33);
        let d = sequential::distances(&g, 0);
        for kind in PartitionKind::all() {
            for p in [2u32, 4, 8] {
                let dist = DistGraph::build_with(&g, kind.build(&g, p));
                let res = run(&dist, 0, SimConfig::deterministic(NetConfig::default()));
                validate_parents(&g, 0, &res.parents).unwrap();
                assert_eq!(tree_levels(0, &res.parents), d, "{kind:?} p={p}");
            }
        }
    }

    #[test]
    fn barrier_count_is_two_per_level() {
        let g = generators::path(9); // 8 levels from vertex 0
        let dist = DistGraph::block(&g, 3);
        let res = run(&dist, 0, SimConfig::deterministic(NetConfig::default()));
        // levels+1 rounds (last round discovers nothing), 2 barriers each.
        assert_eq!(res.report.barriers, 2 * (8 + 1));
    }

    #[test]
    fn empty_graph_single_vertex() {
        let g = generators::path(1);
        let res = check(&g, 1, 0);
        assert_eq!(res.parents, vec![0]);
    }
}
