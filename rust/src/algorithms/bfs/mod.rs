//! Breadth-First Search: sequential oracle, asynchronous HPX-style
//! distributed version (paper Listing 1.2), level-synchronous BSP baseline
//! (distributed BGL stand-in), and a direction-optimizing extension.

pub mod async_hpx;
pub mod direction_opt;
pub mod level_sync;
pub mod sequential;

use crate::amt::SimReport;
use crate::graph::{Csr, VertexId};

/// Result of a distributed BFS run.
#[derive(Debug)]
pub struct BfsResult {
    /// `parents[v]` = BFS-tree parent of `v`, `parents[root] == root`,
    /// `-1` for unreachable vertices.
    pub parents: Vec<i64>,
    /// Timing/traffic report from the simulated runtime.
    pub report: SimReport,
}

/// Validate a parent array against the graph, GAP-benchmark style:
///
/// 1. the root is its own parent;
/// 2. exactly the vertices reachable from `root` have parents;
/// 3. every tree edge `(parents[v], v)` exists in the graph;
/// 4. walking parents from any reached vertex terminates at the root
///    (tree, no cycles);
/// 5. tree levels are consistent with true BFS distances: a vertex at
///    true distance `d` has a parent at true distance `>= d - 1`
///    (asynchronous BFS may produce non-minimal trees, which the paper's
///    CAS-based `set_parent` permits; minimality is NOT required).
pub fn validate_parents(g: &Csr, root: VertexId, parents: &[i64]) -> Result<(), String> {
    let n = g.n();
    if parents.len() != n {
        return Err(format!("parents length {} != n {}", parents.len(), n));
    }
    if parents[root as usize] != root as i64 {
        return Err(format!("root parent is {}, not itself", parents[root as usize]));
    }
    let dist = sequential::distances(g, root);
    for v in 0..n {
        let reached = parents[v] >= 0;
        let reachable = dist[v] >= 0;
        if reached != reachable {
            return Err(format!(
                "vertex {v}: parent={} but true distance={}",
                parents[v], dist[v]
            ));
        }
        if reached && v != root as usize {
            let p = parents[v] as VertexId;
            if !g.has_edge(p, v as VertexId) {
                return Err(format!("tree edge {p}->{v} not in graph"));
            }
        }
    }
    // Walk up from every reached vertex; path lengths bounded by n.
    for v in 0..n {
        if parents[v] < 0 {
            continue;
        }
        let mut cur = v;
        let mut steps = 0usize;
        while cur != root as usize {
            cur = parents[cur] as usize;
            steps += 1;
            if steps > n {
                return Err(format!("cycle in parent chain starting at {v}"));
            }
        }
    }
    Ok(())
}

/// Derive per-vertex tree levels from a parent array (-1 = unreachable).
pub fn tree_levels(root: VertexId, parents: &[i64]) -> Vec<i64> {
    let n = parents.len();
    let mut levels = vec![-1i64; n];
    levels[root as usize] = 0;
    for v in 0..n {
        if parents[v] < 0 || levels[v] >= 0 {
            continue;
        }
        // Walk up until a labelled ancestor, then unwind.
        let mut chain = vec![v];
        let mut cur = parents[v] as usize;
        while levels[cur] < 0 {
            chain.push(cur);
            cur = parents[cur] as usize;
        }
        let mut lvl = levels[cur];
        for &u in chain.iter().rev() {
            lvl += 1;
            levels[u] = lvl;
        }
    }
    levels
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn validate_accepts_sequential_tree() {
        let g = generators::urand(7, 4, 8);
        let parents = sequential::bfs(&g, 0);
        validate_parents(&g, 0, &parents).unwrap();
    }

    #[test]
    fn validate_rejects_fake_edge() {
        let g = generators::path(4);
        // claim 3's parent is 0 (no edge 0-3)
        let parents = vec![0i64, 0, 1, 0];
        assert!(validate_parents(&g, 0, &parents).is_err());
    }

    #[test]
    fn validate_rejects_unreachable_marked_reached() {
        let mut el = crate::graph::EdgeList::new(3);
        el.push(0, 1);
        let g = Csr::from_edge_list(&el);
        let parents = vec![0i64, 0, 1]; // 2 is not reachable
        assert!(validate_parents(&g, 0, &parents).is_err());
    }

    #[test]
    fn validate_rejects_cycle() {
        let g = generators::cycle(4);
        // 1 and 2 point at each other
        let parents = vec![0i64, 2, 1, 0];
        assert!(validate_parents(&g, 0, &parents).is_err());
    }

    #[test]
    fn tree_levels_on_path() {
        let parents = vec![0i64, 0, 1, 2];
        assert_eq!(tree_levels(0, &parents), vec![0, 1, 2, 3]);
    }
}
