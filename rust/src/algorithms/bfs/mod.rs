//! Breadth-First Search: sequential oracle, the [`BfsProgram`] vertex
//! program (run on the generic [`engine`](crate::engine) loops —
//! asynchronous label-correcting or BSP level-by-level), and a
//! direction-optimizing extension kept as an explicitly specialized
//! engine.

pub mod direction_opt;
pub mod program;
pub mod sequential;

pub use program::{BfsProgram, BfsState};

use crate::amt::{FlushPolicy, SimConfig, SimReport};
use crate::engine;
use crate::graph::{Csr, DistGraph, VertexId};

/// Result of a distributed BFS run.
#[derive(Debug)]
pub struct BfsResult {
    /// `parents[v]` = BFS-tree parent of `v`, `parents[root] == root`,
    /// `-1` for unreachable vertices.
    pub parents: Vec<i64>,
    /// Timing/traffic report from the simulated runtime.
    pub report: SimReport,
}

fn to_result(run: engine::ProgramRun<BfsState>) -> BfsResult {
    BfsResult { parents: run.states.iter().map(|s| s.parent).collect(), report: run.report }
}

/// Asynchronous HPX-style BFS (label-correcting wavefront, no barriers)
/// with the default [`FlushPolicy::Adaptive`] aggregation.
pub fn run_async(dist: &DistGraph, root: VertexId, cfg: SimConfig) -> BfsResult {
    run_async_with(dist, root, FlushPolicy::Adaptive, cfg)
}

/// Asynchronous BFS with an explicit combiner flush policy.
pub fn run_async_with(
    dist: &DistGraph,
    root: VertexId,
    policy: FlushPolicy,
    cfg: SimConfig,
) -> BfsResult {
    to_result(engine::run_async(BfsProgram { root }, dist, policy, cfg))
}

/// Level-synchronous BSP BFS — the distributed-BGL (PBGL) baseline:
/// superstep frontier expansion with an activity-count termination
/// reduction (two global barriers per level).
pub fn run_bsp(dist: &DistGraph, root: VertexId, cfg: SimConfig) -> BfsResult {
    to_result(engine::run_bsp(BfsProgram { root }, dist, cfg))
}

/// Validate a parent array against the graph, GAP-benchmark style:
///
/// 1. the root is its own parent;
/// 2. exactly the vertices reachable from `root` have parents;
/// 3. every tree edge `(parents[v], v)` exists in the graph;
/// 4. walking parents from any reached vertex terminates at the root
///    (tree, no cycles);
/// 5. tree levels are consistent with true BFS distances: a vertex at
///    true distance `d` has a parent at true distance `>= d - 1`
///    (asynchronous BFS may produce non-minimal trees mid-flight, which
///    the paper's CAS-based `set_parent` permits; minimality is NOT
///    required by this check, though both engines converge to it).
pub fn validate_parents(g: &Csr, root: VertexId, parents: &[i64]) -> Result<(), String> {
    let n = g.n();
    if parents.len() != n {
        return Err(format!("parents length {} != n {}", parents.len(), n));
    }
    if parents[root as usize] != root as i64 {
        return Err(format!("root parent is {}, not itself", parents[root as usize]));
    }
    let dist = sequential::distances(g, root);
    for v in 0..n {
        let reached = parents[v] >= 0;
        let reachable = dist[v] >= 0;
        if reached != reachable {
            return Err(format!(
                "vertex {v}: parent={} but true distance={}",
                parents[v], dist[v]
            ));
        }
        if reached && v != root as usize {
            let p = parents[v] as VertexId;
            if !g.has_edge(p, v as VertexId) {
                return Err(format!("tree edge {p}->{v} not in graph"));
            }
        }
    }
    // Walk up from every reached vertex; path lengths bounded by n.
    for v in 0..n {
        if parents[v] < 0 {
            continue;
        }
        let mut cur = v;
        let mut steps = 0usize;
        while cur != root as usize {
            cur = parents[cur] as usize;
            steps += 1;
            if steps > n {
                return Err(format!("cycle in parent chain starting at {v}"));
            }
        }
    }
    Ok(())
}

/// Derive per-vertex tree levels from a parent array (-1 = unreachable).
pub fn tree_levels(root: VertexId, parents: &[i64]) -> Vec<i64> {
    let n = parents.len();
    let mut levels = vec![-1i64; n];
    levels[root as usize] = 0;
    for v in 0..n {
        if parents[v] < 0 || levels[v] >= 0 {
            continue;
        }
        // Walk up until a labelled ancestor, then unwind.
        let mut chain = vec![v];
        let mut cur = parents[v] as usize;
        while levels[cur] < 0 {
            chain.push(cur);
            cur = parents[cur] as usize;
        }
        let mut lvl = levels[cur];
        for &u in chain.iter().rev() {
            lvl += 1;
            levels[u] = lvl;
        }
    }
    levels
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amt::NetConfig;
    use crate::graph::{generators, PartitionKind};

    fn det() -> SimConfig {
        SimConfig::deterministic(NetConfig::default())
    }

    #[test]
    fn validate_accepts_sequential_tree() {
        let g = generators::urand(7, 4, 8);
        let parents = sequential::bfs(&g, 0);
        validate_parents(&g, 0, &parents).unwrap();
    }

    #[test]
    fn validate_rejects_fake_edge() {
        let g = generators::path(4);
        // claim 3's parent is 0 (no edge 0-3)
        let parents = vec![0i64, 0, 1, 0];
        assert!(validate_parents(&g, 0, &parents).is_err());
    }

    #[test]
    fn validate_rejects_unreachable_marked_reached() {
        let mut el = crate::graph::EdgeList::new(3);
        el.push(0, 1);
        let g = Csr::from_edge_list(&el);
        let parents = vec![0i64, 0, 1]; // 2 is not reachable
        assert!(validate_parents(&g, 0, &parents).is_err());
    }

    #[test]
    fn validate_rejects_cycle() {
        let g = generators::cycle(4);
        // 1 and 2 point at each other
        let parents = vec![0i64, 2, 1, 0];
        assert!(validate_parents(&g, 0, &parents).is_err());
    }

    #[test]
    fn tree_levels_on_path() {
        let parents = vec![0i64, 0, 1, 2];
        assert_eq!(tree_levels(0, &parents), vec![0, 1, 2, 3]);
    }

    #[test]
    fn both_engines_reach_true_levels_on_random_graphs() {
        for (scale, p) in [(6u32, 1u32), (6, 2), (6, 4), (7, 8)] {
            let g = generators::urand(scale, 4, scale as u64 + p as u64);
            let want = sequential::distances(&g, 0);
            let dist = DistGraph::block(&g, p);
            for res in [run_async(&dist, 0, det()), run_bsp(&dist, 0, det())] {
                validate_parents(&g, 0, &res.parents).unwrap();
                assert_eq!(tree_levels(0, &res.parents), want, "p={p}");
            }
        }
    }

    #[test]
    fn works_when_root_not_on_locality_zero() {
        let g = generators::urand(6, 4, 11);
        let root = (g.n() - 1) as VertexId;
        let want = sequential::distances(&g, root);
        let dist = DistGraph::block(&g, 4);
        for res in [run_async(&dist, root, det()), run_bsp(&dist, root, det())] {
            validate_parents(&g, root, &res.parents).unwrap();
            assert_eq!(tree_levels(root, &res.parents), want);
        }
    }

    #[test]
    fn true_levels_under_every_partition_scheme() {
        let g = generators::kron(7, 6, 19);
        let want = sequential::distances(&g, 0);
        for kind in PartitionKind::all() {
            for p in [1u32, 3, 8] {
                let dist = DistGraph::build_with(&g, kind.build(&g, p));
                for (name, res) in [
                    ("async", run_async(&dist, 0, det())),
                    ("bsp", run_bsp(&dist, 0, det())),
                ] {
                    validate_parents(&g, 0, &res.parents).unwrap();
                    assert_eq!(tree_levels(0, &res.parents), want, "{name} {kind:?} p={p}");
                }
            }
        }
    }

    #[test]
    fn vertex_cut_report_carries_replication() {
        let g = generators::kron(7, 8, 5);
        let dist = DistGraph::build_with(&g, PartitionKind::VertexCut.build(&g, 4));
        assert!(dist.has_mirrors());
        let res = run_async(&dist, 0, det());
        validate_parents(&g, 0, &res.parents).unwrap();
        assert!(res.report.partition.replication_factor > 1.0);
        assert!(res.report.partition.vertex_imbalance >= 1.0);
        assert!(res.report.partition.edge_imbalance >= 1.0);
    }

    #[test]
    fn disconnected_graph_terminates() {
        let mut el = crate::graph::EdgeList::new(10);
        el.push(0, 1);
        el.push(1, 0);
        let g = Csr::from_edge_list(&el);
        let dist = DistGraph::block(&g, 3);
        for res in [run_async(&dist, 0, det()), run_bsp(&dist, 0, det())] {
            assert_eq!(res.parents[1], 0);
            assert!(res.parents[2..].iter().all(|&p| p == -1));
        }
    }

    #[test]
    fn no_barriers_in_async_bfs() {
        let g = generators::urand(7, 4, 13);
        let dist = DistGraph::block(&g, 4);
        let res = run_async(&dist, 0, det());
        assert_eq!(res.report.barriers, 0);
    }

    #[test]
    fn bsp_barrier_count_is_two_per_level() {
        let g = generators::path(9); // 8 levels from vertex 0
        let dist = DistGraph::block(&g, 3);
        let res = run_bsp(&dist, 0, det());
        // levels+1 rounds (last round discovers nothing), 2 barriers each.
        assert_eq!(res.report.barriers, 2 * (8 + 1));
    }

    #[test]
    fn every_flush_policy_yields_true_levels() {
        let g = generators::urand(7, 4, 15);
        let dist = DistGraph::block(&g, 4);
        let want = sequential::distances(&g, 0);
        for policy in [
            FlushPolicy::Unbatched,
            FlushPolicy::Items(4),
            FlushPolicy::Adaptive,
            FlushPolicy::Manual,
        ] {
            let res = run_async_with(&dist, 0, policy, det());
            validate_parents(&g, 0, &res.parents).unwrap();
            assert_eq!(tree_levels(0, &res.parents), want, "{policy:?}");
        }
    }

    #[test]
    fn aggregation_reduces_envelopes_vs_unbatched() {
        let g = generators::urand(8, 8, 17);
        let dist = DistGraph::block(&g, 4);
        let naive = run_async_with(&dist, 0, FlushPolicy::Unbatched, det());
        let agg = run_async_with(&dist, 0, FlushPolicy::Adaptive, det());
        assert!(agg.report.net.envelopes < naive.report.net.envelopes);
        assert_eq!(agg.report.agg.envelopes, agg.report.net.envelopes);
    }

    #[test]
    fn bsp_empty_graph_single_vertex() {
        let g = generators::path(1);
        let res = run_bsp(&DistGraph::block(&g, 1), 0, det());
        assert_eq!(res.parents, vec![0]);
    }
}
