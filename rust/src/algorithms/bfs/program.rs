//! BFS as a [`VertexProgram`] — the paper's Listing 1.2 reduced to its
//! algorithmic hooks; every execution concern (wavefronts, supersteps,
//! mirror routing, termination) lives in [`engine`](crate::engine).
//!
//! The program is *level correcting*: messages are `(parent, level)`
//! proposals folded by min-level, so at convergence every reached vertex
//! carries its true BFS distance — the final tree is a shortest-path tree
//! regardless of engine, message order, aggregation, or partition scheme.

use crate::engine::{Mode, ProgramInfo, VertexProgram};
use crate::graph::VertexId;

/// Level-correcting BFS from a root vertex.
#[derive(Debug, Clone)]
pub struct BfsProgram {
    /// Root vertex.
    pub root: VertexId,
}

/// Per-row BFS state.
#[derive(Debug, Clone)]
pub struct BfsState {
    /// Tentative BFS level (`u32::MAX` = unvisited).
    pub level: u32,
    /// Discovering neighbor (`-1` = unreached).
    pub parent: i64,
}

impl VertexProgram for BfsProgram {
    type State = BfsState;
    /// `(parent, proposed level)`.
    type Msg = (VertexId, u32);

    fn info(&self) -> ProgramInfo {
        ProgramInfo {
            name: "bfs",
            mode: Mode::Converge,
            needs_weights: false,
            ordered: false,
            item_bytes: 12, // vertex + parent + level
        }
    }

    fn init(&self, _v: VertexId, _out_degree: u32) -> BfsState {
        BfsState { level: u32::MAX, parent: -1 }
    }

    fn seed(&self, v: VertexId) -> Option<Self::Msg> {
        (v == self.root).then_some((self.root, 0))
    }

    fn combine(acc: &mut Self::Msg, new: Self::Msg) {
        if new.1 < acc.1 {
            *acc = new;
        }
    }

    fn beats(&self, msg: &Self::Msg, state: &BfsState) -> bool {
        msg.1 < state.level
    }

    fn apply(&self, state: &mut BfsState, msg: Self::Msg) -> bool {
        if msg.1 < state.level {
            state.level = msg.1;
            state.parent = msg.0 as i64;
            true
        } else {
            false
        }
    }

    fn signal(&self, state: &BfsState) -> Self::Msg {
        // Only ever read from reached rows, whose parent is set.
        (state.parent.max(0) as VertexId, state.level)
    }

    fn along_edge(&self, u: VertexId, sig: &Self::Msg, _w: f32) -> Self::Msg {
        (u, sig.1 + 1)
    }

    fn priority(&self, msg: &Self::Msg) -> f32 {
        msg.1 as f32
    }

    /// A level is derived through `src -> dst` when it is one deeper than
    /// the source's — which covers the actual tree parent and every
    /// equally good alternative (over-taint is harmless).
    fn depends_on_edge(&self, src: &BfsState, dst: &BfsState, _w: f32) -> bool {
        src.level != u32::MAX && dst.level == src.level.saturating_add(1)
    }

    /// Unvisited rows must never re-emit: `along_edge` on a `u32::MAX`
    /// level would overflow.
    fn can_emit(&self, state: &BfsState) -> bool {
        state.level != u32::MAX
    }
}
