//! Sequential BFS — the paper's Listing 1.1, the correctness oracle and the
//! "fastest sequential implementation" that Figure 1 normalizes against.

use std::collections::VecDeque;

use crate::graph::{Csr, VertexId};

/// Naïve generic sequential BFS (Listing 1.1): returns the parent array,
/// `parents[root] == root`, `-1` for unreachable.
pub fn bfs(g: &Csr, root: VertexId) -> Vec<i64> {
    let mut parents = vec![-1i64; g.n()];
    if g.n() == 0 {
        return parents;
    }
    parents[root as usize] = root as i64;
    let mut frontier = VecDeque::new();
    frontier.push_back(root);
    while let Some(u) = frontier.pop_front() {
        for &v in g.neighbors(u) {
            if parents[v as usize] == -1 {
                parents[v as usize] = u as i64;
                frontier.push_back(v);
            }
        }
    }
    parents
}

/// BFS distances from `root` (`-1` unreachable).
pub fn distances(g: &Csr, root: VertexId) -> Vec<i64> {
    let mut dist = vec![-1i64; g.n()];
    if g.n() == 0 {
        return dist;
    }
    dist[root as usize] = 0;
    let mut frontier = VecDeque::new();
    frontier.push_back(root);
    while let Some(u) = frontier.pop_front() {
        for &v in g.neighbors(u) {
            if dist[v as usize] == -1 {
                dist[v as usize] = dist[u as usize] + 1;
                frontier.push_back(v);
            }
        }
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn path_distances() {
        let g = generators::path(6);
        assert_eq!(distances(&g, 0), vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(bfs(&g, 0), vec![0, 0, 1, 2, 3, 4]);
    }

    #[test]
    fn star_is_one_hop() {
        let g = generators::star(5);
        assert_eq!(distances(&g, 0), vec![0, 1, 1, 1, 1]);
        assert_eq!(distances(&g, 3), vec![1, 2, 2, 0, 2]);
    }

    #[test]
    fn disconnected_vertices_stay_unreached() {
        let mut el = crate::graph::EdgeList::new(4);
        el.push(0, 1);
        el.push(1, 0);
        let g = crate::graph::Csr::from_edge_list(&el);
        let d = distances(&g, 0);
        assert_eq!(d, vec![0, 1, -1, -1]);
        let p = bfs(&g, 0);
        assert_eq!(p[2], -1);
        assert_eq!(p[3], -1);
    }

    #[test]
    fn parents_are_one_level_up() {
        let g = generators::kron(8, 8, 1);
        let p = bfs(&g, 0);
        let d = distances(&g, 0);
        for v in 0..g.n() {
            if p[v] >= 0 && v != 0 {
                assert_eq!(d[v], d[p[v] as usize] + 1, "v={v}");
            }
        }
    }
}
