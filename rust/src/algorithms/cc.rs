//! Connected components — §6 future-work extension.
//!
//! Sequential oracle: union-find. Distributed: min-label propagation in
//! BSP supersteps (each vertex adopts the smallest label seen) — the
//! standard Shiloach-Vishkin-flavored formulation frameworks like Pregel
//! ship. Remote label updates route through the shared
//! [`amt::aggregate`](crate::amt::aggregate) combiner (fold = min over
//! labels, keyed by the destination's master index, drained once per
//! superstep), so at most one update per destination vertex hits the wire
//! each round.
//!
//! Scheme-generic: under a vertex cut every mirror row starts active (its
//! locally homed edges must propagate the initial labels), and a master
//! whose label improves scatters the new label to its mirrors through a
//! second Manual-policy combiner; the mirror re-activates the row for the
//! next superstep. Monotone min-folding makes the extra rounds converge
//! to the same fixpoint as the 1-D layout.

use std::sync::Arc;

use crate::amt::aggregate::{Aggregator, Batch, FlushPolicy};
use crate::amt::sim::{Actor, Ctx, LocalityId, Message, SimConfig, SimRuntime};
use crate::amt::SimReport;
use crate::graph::{Csr, DistGraph, Shard, VertexId};

/// Per-item wire size: vertex id + label.
const ITEM_BYTES: usize = 8;

/// Keep the smaller component label.
fn min_label(acc: &mut VertexId, label: VertexId) {
    if label < *acc {
        *acc = label;
    }
}

/// Result of a distributed CC run.
#[derive(Debug)]
pub struct CcResult {
    /// Component label per vertex (smallest vertex id in the component).
    pub labels: Vec<VertexId>,
    /// Runtime report.
    pub report: SimReport,
}

/// Sequential union-find oracle; labels are canonical minimum vertex ids.
pub fn union_find(g: &Csr) -> Vec<VertexId> {
    let n = g.n();
    let mut parent: Vec<u32> = (0..n as u32).collect();
    fn find(parent: &mut [u32], x: u32) -> u32 {
        let mut r = x;
        while parent[r as usize] != r {
            r = parent[r as usize];
        }
        let mut c = x;
        while parent[c as usize] != r {
            let next = parent[c as usize];
            parent[c as usize] = r;
            c = next;
        }
        r
    }
    for u in 0..n as VertexId {
        for &v in g.neighbors(u) {
            let (ru, rv) = (find(&mut parent, u), find(&mut parent, v));
            if ru != rv {
                // union by smaller id to get canonical min labels
                if ru < rv {
                    parent[rv as usize] = ru;
                } else {
                    parent[ru as usize] = rv;
                }
            }
        }
    }
    (0..n as u32).map(|v| find(&mut parent, v)).collect()
}

/// Number of distinct components in a label vector.
pub fn component_count(labels: &[VertexId]) -> usize {
    let mut sorted = labels.to_vec();
    sorted.sort_unstable();
    sorted.dedup();
    sorted.len()
}

/// Label-propagation messages.
#[derive(Debug, Clone)]
pub enum CcMsg {
    /// Batched label updates toward masters: `(master index, min label)`.
    Labels(Batch<VertexId>),
    /// Batched label scatter toward mirrors: `(ghost slot, label)`.
    MirrorLabels(Batch<VertexId>),
    /// Activity reduction.
    Count(u64),
    /// Coordinator verdict.
    Continue(bool),
}

impl Message for CcMsg {
    fn wire_bytes(&self) -> usize {
        match self {
            CcMsg::Labels(b) => b.wire_bytes(),
            CcMsg::MirrorLabels(b) => b.wire_bytes(),
            CcMsg::Count(_) => 8,
            CcMsg::Continue(_) => 1,
        }
    }

    fn item_count(&self) -> usize {
        match self {
            CcMsg::Labels(b) => b.len(),
            CcMsg::MirrorLabels(b) => b.len(),
            _ => 1,
        }
    }
}

#[derive(PartialEq)]
enum Phase {
    AfterPropagate,
    AwaitDecision,
}

struct CcActor {
    shard: Arc<Shard>,
    /// Label per local row: owned rows authoritative, ghost rows cached.
    labels: Vec<VertexId>,
    active: Vec<u32>, // local rows queued for the next propagate round
    in_active: Vec<bool>,
    inbox: Vec<(u32, VertexId)>,
    counts_sum: u64,
    /// Activity earned outside a propagate round (scatter queued at the
    /// barrier), folded into the next Count so termination can't outrun
    /// pending mirror work.
    pending_activity: u64,
    continue_flag: bool,
    phase: Phase,
    /// Superstep combiner toward masters: folded min labels, drained once
    /// per round.
    agg: Aggregator<VertexId>,
    /// Superstep combiner toward mirrors (label scatter).
    mirror_agg: Aggregator<VertexId>,
}

impl CcActor {
    fn activate(&mut self, row: usize) {
        if !self.in_active[row] {
            self.in_active[row] = true;
            self.active.push(row as u32);
        }
    }

    /// Apply `label` to the owned `row`; on improvement, queue the row and
    /// scatter the new label to its mirrors. Returns whether it improved.
    fn improve_owned(&mut self, row: usize, label: VertexId) -> bool {
        if label >= self.labels[row] {
            return false;
        }
        self.labels[row] = label;
        self.activate(row);
        let shard = Arc::clone(&self.shard);
        for &(dst, gi) in shard.mirrors(row) {
            // Manual policy: accumulate never auto-flushes.
            let flushed = self.mirror_agg.accumulate(dst, gi, label);
            debug_assert!(flushed.is_none());
        }
        true
    }

    fn propagate(&mut self, ctx: &mut Ctx<CcMsg>) {
        let n_owned = self.shard.n_local();
        let mut activity = self.pending_activity;
        self.pending_activity = 0;
        let active = std::mem::take(&mut self.active);
        for &row in &active {
            self.in_active[row as usize] = false;
        }
        for &row in &active {
            let label = self.labels[row as usize];
            let shard = Arc::clone(&self.shard);
            for &t in shard.row_neighbors_local(row as usize) {
                let t = t as usize;
                if t < n_owned {
                    if self.improve_owned(t, label) {
                        activity += 1;
                    }
                } else {
                    let gi = t - n_owned;
                    // Manual policy: accumulate never auto-flushes.
                    let flushed = self.agg.accumulate(
                        shard.ghost_owner[gi],
                        shard.ghost_master_index[gi],
                        label,
                    );
                    debug_assert!(flushed.is_none());
                    activity += 1;
                }
            }
        }
        for (dst, batch) in self.agg.drain() {
            ctx.send(dst, CcMsg::Labels(batch));
        }
        for (dst, batch) in self.mirror_agg.drain() {
            ctx.send(dst, CcMsg::MirrorLabels(batch));
            activity += 1;
        }
        ctx.send(0, CcMsg::Count(activity));
        self.phase = Phase::AfterPropagate;
        ctx.request_barrier();
    }
}

impl Actor for CcActor {
    type Msg = CcMsg;

    fn on_start(&mut self, ctx: &mut Ctx<CcMsg>) {
        // Every owned row starts active with its own id as label; mirror
        // rows start active too, so remotely homed edges propagate the
        // initial labels (their labels are the cached ghost ids).
        self.in_active = vec![false; self.shard.n_rows()];
        for row in 0..self.shard.n_rows() {
            if !self.shard.row_neighbors_local(row).is_empty() || row < self.shard.n_local() {
                self.activate(row);
            }
        }
        self.propagate(ctx);
    }

    fn on_message(&mut self, _ctx: &mut Ctx<CcMsg>, _from: LocalityId, msg: CcMsg) {
        match msg {
            CcMsg::Labels(batch) => self.inbox.extend(batch.items),
            CcMsg::MirrorLabels(batch) => {
                let n_owned = self.shard.n_local();
                for (gi, label) in batch.items {
                    let row = n_owned + gi as usize;
                    if label < self.labels[row] {
                        self.labels[row] = label;
                        self.activate(row);
                    }
                }
            }
            CcMsg::Count(c) => self.counts_sum += c,
            CcMsg::Continue(b) => self.continue_flag = b,
        }
    }

    fn on_barrier(&mut self, ctx: &mut Ctx<CcMsg>, _epoch: u64) {
        match self.phase {
            Phase::AfterPropagate => {
                let inbox = std::mem::take(&mut self.inbox);
                for (idx, label) in inbox {
                    if self.improve_owned(idx as usize, label) {
                        // The scatter queued by improve_owned ships with
                        // the next round's drain; keep the run alive.
                        self.pending_activity += 1;
                    }
                }
                if ctx.locality() == 0 {
                    let go = self.counts_sum > 0;
                    self.counts_sum = 0;
                    for l in 0..ctx.n_localities() {
                        ctx.send(l, CcMsg::Continue(go));
                    }
                }
                self.phase = Phase::AwaitDecision;
                ctx.request_barrier();
            }
            Phase::AwaitDecision => {
                // The verdict is uniform: every activation was backed by a
                // counted activity (local improvement, sender's proposal,
                // or a scatter batch), so `go` is true whenever any
                // locality still holds active rows or pending scatter.
                if self.continue_flag {
                    self.propagate(ctx);
                }
            }
        }
    }
}

/// Run BSP min-label propagation CC.
pub fn run(dist: &DistGraph, cfg: SimConfig) -> CcResult {
    let actors: Vec<CcActor> = dist
        .shards
        .iter()
        .map(|s| CcActor {
            shard: Arc::new(s.clone()),
            labels: (0..s.n_rows()).map(|r| s.global_of(r)).collect(),
            active: Vec::new(),
            in_active: Vec::new(),
            inbox: Vec::new(),
            counts_sum: 0,
            pending_activity: 0,
            continue_flag: false,
            phase: Phase::AfterPropagate,
            agg: Aggregator::new(
                dist.owned_counts(),
                s.locality,
                FlushPolicy::Manual,
                &cfg.net,
                ITEM_BYTES,
                min_label,
            ),
            mirror_agg: Aggregator::new(
                dist.ghost_counts(),
                s.locality,
                FlushPolicy::Manual,
                &cfg.net,
                ITEM_BYTES,
                min_label,
            ),
        })
        .collect();
    let (actors, mut report) = SimRuntime::new(cfg).run(actors);
    for a in &actors {
        report.agg.merge(a.agg.stats());
        report.agg.merge(a.mirror_agg.stats());
    }
    report.partition = dist.partition_stats();
    let mut labels = vec![0 as VertexId; dist.n()];
    for a in &actors {
        a.shard.scatter_owned(&a.labels[..a.shard.n_local()], &mut labels);
    }
    CcResult { labels, report }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amt::NetConfig;
    use crate::graph::{generators, PartitionKind};

    #[test]
    fn matches_union_find() {
        for p in [1u32, 2, 4, 8] {
            let g = generators::urand(6, 2, 41 + p as u64); // sparse -> many components
            let want = union_find(&g);
            let d = DistGraph::block(&g, p);
            let res = run(&d, SimConfig::deterministic(NetConfig::default()));
            assert_eq!(res.labels, want, "p={p}");
        }
    }

    #[test]
    fn matches_union_find_under_every_partition_scheme() {
        let g = generators::kron(7, 4, 61);
        let want = union_find(&g);
        for kind in PartitionKind::all() {
            for p in [2u32, 4, 8] {
                let d = DistGraph::build_with(&g, kind.build(&g, p));
                let res = run(&d, SimConfig::deterministic(NetConfig::default()));
                assert_eq!(res.labels, want, "{kind:?} p={p}");
            }
        }
    }

    #[test]
    fn connected_graph_has_one_component() {
        let g = generators::grid(8, 8);
        let d = DistGraph::block(&g, 4);
        let res = run(&d, SimConfig::deterministic(NetConfig::default()));
        assert_eq!(component_count(&res.labels), 1);
        assert!(res.labels.iter().all(|&l| l == 0));
    }

    #[test]
    fn isolated_vertices_keep_own_label() {
        let el = crate::graph::EdgeList::new(5);
        let g = Csr::from_edge_list(&el);
        let d = DistGraph::block(&g, 2);
        let res = run(&d, SimConfig::deterministic(NetConfig::default()));
        assert_eq!(res.labels, vec![0, 1, 2, 3, 4]);
        assert_eq!(component_count(&res.labels), 5);
    }

    #[test]
    fn combiner_folds_duplicate_labels_per_round() {
        // Dense graph: many active neighbors push labels at the same
        // remote vertex each round; the combiner ships one min per vertex.
        let g = generators::urand(7, 8, 47);
        let d = DistGraph::block(&g, 4);
        let res = run(&d, SimConfig::deterministic(NetConfig::default()));
        let agg = res.report.agg;
        assert!(agg.folded > 0, "dense rounds must fold duplicates");
        assert_eq!(agg.items, agg.folded + agg.sent_items);
        assert_eq!(agg.envelopes, agg.drain_flushes);
    }

    #[test]
    fn union_find_two_triangles() {
        let g = crate::graph::builder::GraphBuilder::new(6)
            .edges([(0, 1), (1, 2), (2, 0), (3, 4), (4, 5), (5, 3)])
            .symmetrize()
            .build();
        let labels = union_find(&g);
        assert_eq!(labels, vec![0, 0, 0, 3, 3, 3]);
    }
}
