//! Connected components — §6 future-work extension.
//!
//! Sequential oracle: union-find. Distributed: min-label propagation in
//! BSP supersteps (each vertex adopts the smallest label seen) — the
//! standard Shiloach-Vishkin-flavored formulation frameworks like Pregel
//! ship. Remote label updates route through the shared
//! [`amt::aggregate`](crate::amt::aggregate) combiner (fold = min over
//! labels, drained once per superstep), so at most one update per
//! destination vertex hits the wire each round.

use std::sync::Arc;

use crate::amt::aggregate::{Aggregator, Batch, FlushPolicy};
use crate::amt::sim::{Actor, Ctx, LocalityId, Message, SimConfig, SimRuntime};
use crate::amt::SimReport;
use crate::graph::{Csr, DistGraph, Shard, VertexId};

/// Per-item wire size: vertex id + label.
const ITEM_BYTES: usize = 8;

/// Keep the smaller component label.
fn min_label(acc: &mut VertexId, label: VertexId) {
    if label < *acc {
        *acc = label;
    }
}

/// Result of a distributed CC run.
#[derive(Debug)]
pub struct CcResult {
    /// Component label per vertex (smallest vertex id in the component).
    pub labels: Vec<VertexId>,
    /// Runtime report.
    pub report: SimReport,
}

/// Sequential union-find oracle; labels are canonical minimum vertex ids.
pub fn union_find(g: &Csr) -> Vec<VertexId> {
    let n = g.n();
    let mut parent: Vec<u32> = (0..n as u32).collect();
    fn find(parent: &mut [u32], x: u32) -> u32 {
        let mut r = x;
        while parent[r as usize] != r {
            r = parent[r as usize];
        }
        let mut c = x;
        while parent[c as usize] != r {
            let next = parent[c as usize];
            parent[c as usize] = r;
            c = next;
        }
        r
    }
    for u in 0..n as VertexId {
        for &v in g.neighbors(u) {
            let (ru, rv) = (find(&mut parent, u), find(&mut parent, v));
            if ru != rv {
                // union by smaller id to get canonical min labels
                if ru < rv {
                    parent[rv as usize] = ru;
                } else {
                    parent[ru as usize] = rv;
                }
            }
        }
    }
    (0..n as u32).map(|v| find(&mut parent, v)).collect()
}

/// Number of distinct components in a label vector.
pub fn component_count(labels: &[VertexId]) -> usize {
    let mut sorted = labels.to_vec();
    sorted.sort_unstable();
    sorted.dedup();
    sorted.len()
}

/// Label-propagation messages.
#[derive(Debug, Clone)]
pub enum CcMsg {
    /// Batched label updates (one folded min per destination vertex).
    Labels(Batch<VertexId>),
    /// Activity reduction.
    Count(u64),
    /// Coordinator verdict.
    Continue(bool),
}

impl Message for CcMsg {
    fn wire_bytes(&self) -> usize {
        match self {
            CcMsg::Labels(b) => b.wire_bytes(),
            CcMsg::Count(_) => 8,
            CcMsg::Continue(_) => 1,
        }
    }

    fn item_count(&self) -> usize {
        match self {
            CcMsg::Labels(b) => b.len(),
            _ => 1,
        }
    }
}

#[derive(PartialEq)]
enum Phase {
    AfterPropagate,
    AwaitDecision,
}

struct CcActor {
    shard: Arc<Shard>,
    dist: Arc<DistGraph>,
    labels: Vec<VertexId>,
    active: Vec<u32>, // local indices with changed labels
    in_active: Vec<bool>,
    inbox: Vec<(VertexId, VertexId)>,
    counts_sum: u64,
    continue_flag: bool,
    phase: Phase,
    /// Superstep combiner: folded min labels, drained once per round.
    agg: Aggregator<VertexId>,
}

impl CcActor {
    fn propagate(&mut self, ctx: &mut Ctx<CcMsg>) {
        let here = ctx.locality();
        let mut activity = 0u64;
        let active = std::mem::take(&mut self.active);
        for &lu in &active {
            self.in_active[lu as usize] = false;
        }
        let mut next: Vec<u32> = Vec::new();
        for &lu in &active {
            let label = self.labels[lu as usize];
            for &w in self.shard.out_neighbors(lu as usize) {
                let dst = self.dist.owner(w);
                if dst == here {
                    let lw = (w as usize - self.shard.range.start) as u32;
                    if label < self.labels[lw as usize] {
                        self.labels[lw as usize] = label;
                        if !self.in_active[lw as usize] {
                            self.in_active[lw as usize] = true;
                            next.push(lw);
                        }
                        activity += 1;
                    }
                } else {
                    // Manual policy: accumulate never auto-flushes.
                    if let Some(batch) = self.agg.accumulate(dst, w, label) {
                        ctx.send(dst, CcMsg::Labels(batch));
                    }
                    activity += 1;
                }
            }
        }
        self.active = next;
        for (dst, batch) in self.agg.drain() {
            ctx.send(dst, CcMsg::Labels(batch));
        }
        ctx.send(0, CcMsg::Count(activity));
        self.phase = Phase::AfterPropagate;
        ctx.request_barrier();
    }
}

impl Actor for CcActor {
    type Msg = CcMsg;

    fn on_start(&mut self, ctx: &mut Ctx<CcMsg>) {
        // Everyone starts active with their own id as label.
        self.active = (0..self.shard.n_local() as u32).collect();
        self.in_active = vec![true; self.shard.n_local()];
        self.propagate(ctx);
    }

    fn on_message(&mut self, _ctx: &mut Ctx<CcMsg>, _from: LocalityId, msg: CcMsg) {
        match msg {
            CcMsg::Labels(batch) => self.inbox.extend(batch.items),
            CcMsg::Count(c) => self.counts_sum += c,
            CcMsg::Continue(b) => self.continue_flag = b,
        }
    }

    fn on_barrier(&mut self, ctx: &mut Ctx<CcMsg>, _epoch: u64) {
        match self.phase {
            Phase::AfterPropagate => {
                let inbox = std::mem::take(&mut self.inbox);
                for (v, label) in inbox {
                    let lv = (v as usize - self.shard.range.start) as u32;
                    if label < self.labels[lv as usize] {
                        self.labels[lv as usize] = label;
                        if !self.in_active[lv as usize] {
                            self.in_active[lv as usize] = true;
                            self.active.push(lv);
                        }
                    }
                }
                if ctx.locality() == 0 {
                    let go = self.counts_sum > 0;
                    self.counts_sum = 0;
                    for l in 0..ctx.n_localities() {
                        ctx.send(l, CcMsg::Continue(go));
                    }
                }
                self.phase = Phase::AwaitDecision;
                ctx.request_barrier();
            }
            Phase::AwaitDecision => {
                if self.continue_flag {
                    self.propagate(ctx);
                }
            }
        }
    }
}

/// Run BSP min-label propagation CC.
pub fn run(dist: &DistGraph, cfg: SimConfig) -> CcResult {
    let dist = Arc::new(dist.clone());
    let ranges = dist.partition.ranges();
    let actors: Vec<CcActor> = dist
        .shards
        .iter()
        .map(|s| CcActor {
            shard: Arc::new(s.clone()),
            dist: Arc::clone(&dist),
            labels: (s.range.start as VertexId..s.range.end as VertexId).collect(),
            active: Vec::new(),
            in_active: Vec::new(),
            inbox: Vec::new(),
            counts_sum: 0,
            continue_flag: false,
            phase: Phase::AfterPropagate,
            agg: Aggregator::new(
                &ranges,
                s.locality,
                FlushPolicy::Manual,
                &cfg.net,
                ITEM_BYTES,
                min_label,
            ),
        })
        .collect();
    let (actors, mut report) = SimRuntime::new(cfg).run(actors);
    for a in &actors {
        report.agg.merge(a.agg.stats());
    }
    let mut labels = vec![0 as VertexId; dist.n()];
    for a in &actors {
        labels[a.shard.range.clone()].copy_from_slice(&a.labels);
    }
    CcResult { labels, report }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amt::NetConfig;
    use crate::graph::generators;

    #[test]
    fn matches_union_find() {
        for p in [1u32, 2, 4, 8] {
            let g = generators::urand(6, 2, 41 + p as u64); // sparse -> many components
            let want = union_find(&g);
            let d = DistGraph::block(&g, p);
            let res = run(&d, SimConfig::deterministic(NetConfig::default()));
            assert_eq!(res.labels, want, "p={p}");
        }
    }

    #[test]
    fn connected_graph_has_one_component() {
        let g = generators::grid(8, 8);
        let d = DistGraph::block(&g, 4);
        let res = run(&d, SimConfig::deterministic(NetConfig::default()));
        assert_eq!(component_count(&res.labels), 1);
        assert!(res.labels.iter().all(|&l| l == 0));
    }

    #[test]
    fn isolated_vertices_keep_own_label() {
        let el = crate::graph::EdgeList::new(5);
        let g = Csr::from_edge_list(&el);
        let d = DistGraph::block(&g, 2);
        let res = run(&d, SimConfig::deterministic(NetConfig::default()));
        assert_eq!(res.labels, vec![0, 1, 2, 3, 4]);
        assert_eq!(component_count(&res.labels), 5);
    }

    #[test]
    fn combiner_folds_duplicate_labels_per_round() {
        // Dense graph: many active neighbors push labels at the same
        // remote vertex each round; the combiner ships one min per vertex.
        let g = generators::urand(7, 8, 47);
        let d = DistGraph::block(&g, 4);
        let res = run(&d, SimConfig::deterministic(NetConfig::default()));
        let agg = res.report.agg;
        assert!(agg.folded > 0, "dense rounds must fold duplicates");
        assert_eq!(agg.items, agg.folded + agg.sent_items);
        assert_eq!(agg.envelopes, agg.drain_flushes);
    }

    #[test]
    fn union_find_two_triangles() {
        let g = crate::graph::builder::GraphBuilder::new(6)
            .edges([(0, 1), (1, 2), (2, 0), (3, 4), (4, 5), (5, 3)])
            .symmetrize()
            .build();
        let labels = union_find(&g);
        assert_eq!(labels, vec![0, 0, 0, 3, 3, 3]);
    }
}
