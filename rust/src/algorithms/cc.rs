//! Connected components — §6 future-work extension, as a
//! [`VertexProgram`]: min-label propagation (each vertex adopts the
//! smallest label seen — the Shiloach-Vishkin-flavored formulation
//! frameworks like Pregel ship), run on the generic
//! [`engine`](crate::engine) loops. The BSP flavor is the classic
//! superstep baseline; the asynchronous flavor falls out of the engine
//! redesign for free (monotone min-folding converges under any message
//! order).
//!
//! Every vertex seeds with its own id, which under a vertex cut activates
//! mirror rows too — their locally homed edges propagate the initial
//! labels, and master improvements scatter through the engines' mirror
//! combiners.

use crate::amt::{FlushPolicy, SimConfig, SimReport};
use crate::engine::{self, Mode, ProgramInfo, VertexProgram};
use crate::graph::{Csr, DistGraph, VertexId};

/// Min-label propagation CC.
#[derive(Debug, Clone, Default)]
pub struct CcProgram;

impl VertexProgram for CcProgram {
    /// Component label (smallest vertex id seen).
    type State = VertexId;
    type Msg = VertexId;

    fn info(&self) -> ProgramInfo {
        ProgramInfo {
            name: "cc",
            mode: Mode::Converge,
            needs_weights: false,
            ordered: false,
            item_bytes: 8, // vertex id + label
        }
    }

    fn init(&self, v: VertexId, _out_degree: u32) -> VertexId {
        v
    }

    fn seed(&self, v: VertexId) -> Option<VertexId> {
        Some(v) // every row starts active with its own label
    }

    fn combine(acc: &mut VertexId, new: VertexId) {
        if new < *acc {
            *acc = new;
        }
    }

    fn beats(&self, msg: &VertexId, state: &VertexId) -> bool {
        msg < state
    }

    fn apply(&self, state: &mut VertexId, msg: VertexId) -> bool {
        if msg < *state {
            *state = msg;
            true
        } else {
            false
        }
    }

    fn signal(&self, state: &VertexId) -> VertexId {
        *state
    }

    fn along_edge(&self, _u: VertexId, sig: &VertexId, _w: f32) -> VertexId {
        *sig
    }

    fn priority(&self, msg: &VertexId) -> f32 {
        // Smaller labels first: winners propagate before losers re-flood.
        *msg as f32
    }

    /// A label is derived through `src -> dst` when the two agree: min
    /// labels flow along every intra-component edge, so a deletion taints
    /// the whole (old) component reachable from it — exactly the region a
    /// split could re-label. (`can_emit` keeps its `true` default: every
    /// CC row, including one whose label is its own id, has a valid label
    /// to re-offer at a taint frontier.)
    fn depends_on_edge(&self, src: &VertexId, dst: &VertexId, _w: f32) -> bool {
        src == dst
    }
}

/// Result of a distributed CC run.
#[derive(Debug)]
pub struct CcResult {
    /// Component label per vertex (smallest vertex id in the component).
    pub labels: Vec<VertexId>,
    /// Runtime report.
    pub report: SimReport,
}

/// Run BSP min-label propagation CC (per-superstep combiner drains).
pub fn run(dist: &DistGraph, cfg: SimConfig) -> CcResult {
    let run = engine::run_bsp(CcProgram, dist, cfg);
    CcResult { labels: run.states, report: run.report }
}

/// Run asynchronous label-correcting CC with an explicit flush policy.
pub fn run_async(dist: &DistGraph, policy: FlushPolicy, cfg: SimConfig) -> CcResult {
    let run = engine::run_async(CcProgram, dist, policy, cfg);
    CcResult { labels: run.states, report: run.report }
}

/// Sequential union-find oracle; labels are canonical minimum vertex ids.
pub fn union_find(g: &Csr) -> Vec<VertexId> {
    let n = g.n();
    let mut parent: Vec<u32> = (0..n as u32).collect();
    fn find(parent: &mut [u32], x: u32) -> u32 {
        let mut r = x;
        while parent[r as usize] != r {
            r = parent[r as usize];
        }
        let mut c = x;
        while parent[c as usize] != r {
            let next = parent[c as usize];
            parent[c as usize] = r;
            c = next;
        }
        r
    }
    for u in 0..n as VertexId {
        for &v in g.neighbors(u) {
            let (ru, rv) = (find(&mut parent, u), find(&mut parent, v));
            if ru != rv {
                // union by smaller id to get canonical min labels
                if ru < rv {
                    parent[rv as usize] = ru;
                } else {
                    parent[ru as usize] = rv;
                }
            }
        }
    }
    (0..n as u32).map(|v| find(&mut parent, v)).collect()
}

/// Number of distinct components in a label vector.
pub fn component_count(labels: &[VertexId]) -> usize {
    let mut sorted = labels.to_vec();
    sorted.sort_unstable();
    sorted.dedup();
    sorted.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amt::NetConfig;
    use crate::graph::{generators, PartitionKind};

    fn det() -> SimConfig {
        SimConfig::deterministic(NetConfig::default())
    }

    #[test]
    fn matches_union_find() {
        for p in [1u32, 2, 4, 8] {
            let g = generators::urand(6, 2, 41 + p as u64); // sparse -> many components
            let want = union_find(&g);
            let d = DistGraph::block(&g, p);
            assert_eq!(run(&d, det()).labels, want, "bsp p={p}");
            assert_eq!(
                run_async(&d, FlushPolicy::Adaptive, det()).labels,
                want,
                "async p={p}"
            );
        }
    }

    #[test]
    fn matches_union_find_under_every_partition_scheme() {
        let g = generators::kron(7, 4, 61);
        let want = union_find(&g);
        for kind in PartitionKind::all() {
            for p in [2u32, 4, 8] {
                let d = DistGraph::build_with(&g, kind.build(&g, p));
                assert_eq!(run(&d, det()).labels, want, "bsp {kind:?} p={p}");
                assert_eq!(
                    run_async(&d, FlushPolicy::Adaptive, det()).labels,
                    want,
                    "async {kind:?} p={p}"
                );
            }
        }
    }

    #[test]
    fn connected_graph_has_one_component() {
        let g = generators::grid(8, 8);
        let d = DistGraph::block(&g, 4);
        let res = run(&d, det());
        assert_eq!(component_count(&res.labels), 1);
        assert!(res.labels.iter().all(|&l| l == 0));
    }

    #[test]
    fn isolated_vertices_keep_own_label() {
        let el = crate::graph::EdgeList::new(5);
        let g = Csr::from_edge_list(&el);
        let d = DistGraph::block(&g, 2);
        let res = run(&d, det());
        assert_eq!(res.labels, vec![0, 1, 2, 3, 4]);
        assert_eq!(component_count(&res.labels), 5);
    }

    #[test]
    fn combiner_folds_duplicate_labels_per_round() {
        // Dense graph: many active neighbors push labels at the same
        // remote vertex each round; the combiner ships one min per vertex.
        let g = generators::urand(7, 8, 47);
        let d = DistGraph::block(&g, 4);
        let res = run(&d, det());
        let agg = res.report.agg;
        assert!(agg.folded > 0, "dense rounds must fold duplicates");
        assert_eq!(agg.items, agg.folded + agg.sent_items);
        assert_eq!(agg.envelopes, agg.drain_flushes);
    }

    #[test]
    fn async_cc_terminates_without_barriers() {
        let g = generators::urand(7, 4, 53);
        let d = DistGraph::block(&g, 4);
        let res = run_async(&d, FlushPolicy::Adaptive, det());
        assert_eq!(res.report.barriers, 0);
        assert_eq!(res.labels, union_find(&g));
    }

    #[test]
    fn union_find_two_triangles() {
        let g = crate::graph::builder::GraphBuilder::new(6)
            .edges([(0, 1), (1, 2), (2, 0), (3, 4), (4, 5), (5, 3)])
            .symmetrize()
            .build();
        let labels = union_find(&g);
        assert_eq!(labels, vec![0, 0, 0, 3, 3, 3]);
    }
}
