//! Graph algorithms in both of the paper's execution models.
//!
//! Every distributed algorithm is an [`Actor`](crate::amt::Actor) over the
//! simulated AMT runtime and comes in (at least) two flavors:
//!
//! * **`async_*`** — the paper's HPX style: eager fine-grained messages,
//!   no global barriers (or only per-iteration ones), computation and
//!   communication overlapped;
//! * **`bsp_*` / `level_sync`** — the PBGL/Boost baseline style:
//!   supersteps, batched per-destination combiners, global barriers.
//!
//! [`bfs`] and [`pagerank`] are the paper's two evaluated algorithms
//! (Figures 1 and 2); [`sssp`], [`cc`] and [`triangle`] are the §6
//! future-work extensions ("broaden the scope of algorithms ... traversal,
//! centrality, and pattern-matching"). SSSP additionally ships a third
//! execution model — delta-stepping with distributed bucket coordination
//! ([`sssp::delta`]) — the ordered middle ground between the two styles.

pub mod bfs;
pub mod cc;
pub mod pagerank;
pub mod sssp;
pub mod triangle;

/// Damping factor the paper (and Brin & Page) use.
pub const DEFAULT_ALPHA: f32 = 0.85;
