//! Graph algorithms as [`VertexProgram`](crate::engine::VertexProgram)s.
//!
//! Since the engine redesign, an algorithm here is a ~100-line vertex
//! program (state, message, fold, apply, scatter hooks) plus thin runner
//! functions that dispatch it onto the generic execution loops in
//! [`engine`](crate::engine):
//!
//! * **`run_async`** — the paper's HPX style: eager fine-grained messages,
//!   no global barriers (or only per-iteration ones), computation and
//!   communication overlapped;
//! * **`run_bsp`** — the PBGL/Boost baseline style: supersteps, batched
//!   per-destination combiners, global barriers;
//! * **`run_delta`** — the ordered bucket schedule (SSSP only; any
//!   program with a path-metric priority could opt in).
//!
//! [`bfs`] and [`pagerank`] are the paper's two evaluated algorithms
//! (Figures 1 and 2); [`sssp`], [`cc`] and [`triangle`] are the §6
//! future-work extensions ("broaden the scope of algorithms ... traversal,
//! centrality, and pattern-matching"). Three engines remain explicitly
//! specialized behind the same coordinator entry points:
//! direction-optimizing BFS ([`bfs::direction_opt`]), kernel-offloaded
//! PageRank ([`pagerank::kernel`]), and triangle counting ([`triangle`]) —
//! each needs whole vertex rows at the owner and gates on mirror-free
//! partitions through
//! [`engine::require_mirror_free`](crate::engine::require_mirror_free).

pub mod bfs;
pub mod cc;
pub mod pagerank;
pub mod sssp;
pub mod triangle;

/// Damping factor the paper (and Brin & Page) use.
pub const DEFAULT_ALPHA: f32 = 0.85;
