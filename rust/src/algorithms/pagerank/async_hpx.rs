//! Asynchronous HPX-style PageRank — paper §4.2, in two stages of maturity.
//!
//! * **Naive** (`Variant::Naive`) — the paper's "very initial
//!   implementation": every remote edge becomes its own asynchronous
//!   remote action (`Contrib(v, c)` message) issued eagerly during the
//!   contribution phase, applied atomically at the destination on arrival.
//!   The per-message CPU/latency overheads dominate — this is why it was
//!   "significantly worse than the Boost library".
//! * **Optimized** (`Variant::Optimized { flush_block }`) — the paper's
//!   improved prototype: contributions to each destination locality are
//!   folded into a combiner that is flushed every `flush_block` processed
//!   vertices, so communication overlaps the remainder of the compute
//!   phase while per-message costs are amortized. Smaller blocks = more
//!   overlap but more envelopes; `flush_block == n_local` degenerates to
//!   BSP-style batching (minus the at-barrier application).
//!
//! Both keep the paper's per-iteration synchronization (one global barrier
//! between exchange and update), so the *only* experimental difference vs
//! [`bsp`](super::bsp) is message granularity and overlap — exactly the
//! contrast Figure 2 probes.

use std::sync::Arc;

use crate::amt::sim::{Actor, Ctx, LocalityId, Message, SimConfig, SimRuntime};
use crate::graph::{DistGraph, Shard, VertexId};

use super::{PrParams, PrResult};

/// Message granularity of the asynchronous variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// One remote action per remote edge.
    Naive,
    /// Combiner flushed every `flush_block` source vertices.
    Optimized {
        /// Vertices processed between combiner flushes.
        flush_block: usize,
    },
}

/// Contribution messages.
#[derive(Debug, Clone)]
pub enum AsyncPrMsg {
    /// Single fine-grained contribution (naive variant).
    Contrib(VertexId, f32),
    /// Batched combined contributions (optimized variant).
    Batch(Vec<(VertexId, f32)>),
}

impl Message for AsyncPrMsg {
    fn wire_bytes(&self) -> usize {
        match self {
            AsyncPrMsg::Contrib(..) => 8,
            AsyncPrMsg::Batch(b) => 8 * b.len(),
        }
    }

    fn item_count(&self) -> usize {
        match self {
            AsyncPrMsg::Contrib(..) => 1,
            AsyncPrMsg::Batch(b) => b.len(),
        }
    }
}

/// Per-locality asynchronous PageRank state.
pub struct AsyncPrActor {
    shard: Arc<Shard>,
    dist: Arc<DistGraph>,
    params: PrParams,
    variant: Variant,
    /// Owned ranks (local index).
    pub rank: Vec<f32>,
    z: Vec<f32>,
    iter: u32,
    /// Per-iteration local L1 deltas.
    pub deltas: Vec<f32>,
}

impl AsyncPrActor {
    /// Contribution phase. Remote contributions are *applied on arrival*
    /// (the receiving handler updates `z` immediately — HPX remote actions
    /// with atomic updates), so communication overlaps compute.
    fn compute_and_send(&mut self, ctx: &mut Ctx<AsyncPrMsg>) {
        let here = ctx.locality();
        let p = ctx.n_localities() as usize;
        let n_local = self.shard.n_local();
        match self.variant {
            Variant::Naive => {
                for u in 0..n_local {
                    let deg = (self.shard.out_degree[u].max(1)) as f32;
                    let c = self.rank[u] / deg;
                    for &v in self.shard.out_neighbors(u) {
                        let dst = self.dist.owner(v);
                        if dst == here {
                            self.z[v as usize - self.shard.range.start] += c;
                        } else {
                            ctx.send(dst, AsyncPrMsg::Contrib(v, c));
                        }
                    }
                }
            }
            Variant::Optimized { flush_block } => {
                let flush_block = flush_block.max(1);
                let mut combiner: Vec<Vec<f32>> = (0..p)
                    .map(|l| vec![0.0f32; self.dist.partition.len_of(l as LocalityId)])
                    .collect();
                let mut touched: Vec<Vec<u32>> = vec![Vec::new(); p];
                let mut since_flush = 0usize;
                for u in 0..n_local {
                    let deg = (self.shard.out_degree[u].max(1)) as f32;
                    let c = self.rank[u] / deg;
                    for &v in self.shard.out_neighbors(u) {
                        let dst = self.dist.owner(v);
                        let off = v as usize - self.dist.partition.range_of(dst).start;
                        if dst == here {
                            self.z[off] += c;
                        } else {
                            let d = dst as usize;
                            if combiner[d][off] == 0.0 {
                                touched[d].push(off as u32);
                            }
                            combiner[d][off] += c;
                        }
                    }
                    since_flush += 1;
                    if since_flush >= flush_block {
                        self.flush(ctx, &mut combiner, &mut touched);
                        since_flush = 0;
                    }
                }
                self.flush(ctx, &mut combiner, &mut touched);
            }
        }
        ctx.request_barrier();
    }

    fn flush(
        &self,
        ctx: &mut Ctx<AsyncPrMsg>,
        combiner: &mut [Vec<f32>],
        touched: &mut [Vec<u32>],
    ) {
        for dst in 0..combiner.len() {
            if touched[dst].is_empty() {
                continue;
            }
            let start = self.dist.partition.range_of(dst as LocalityId).start;
            let mut batch: Vec<(VertexId, f32)> = touched[dst]
                .iter()
                .map(|&off| ((start + off as usize) as VertexId, combiner[dst][off as usize]))
                .collect();
            batch.sort_by_key(|&(v, _)| v);
            for &off in &touched[dst] {
                combiner[dst][off as usize] = 0.0;
            }
            touched[dst].clear();
            ctx.send(dst as LocalityId, AsyncPrMsg::Batch(batch));
        }
    }

    fn update_ranks(&mut self) {
        let base = (1.0 - self.params.alpha) / self.dist.n() as f32;
        let mut delta = 0.0f32;
        for v in 0..self.shard.n_local() {
            let new = base + self.params.alpha * self.z[v];
            delta += (new - self.rank[v]).abs();
            self.rank[v] = new;
        }
        self.deltas.push(delta);
        self.z.iter_mut().for_each(|x| *x = 0.0);
    }
}

impl Actor for AsyncPrActor {
    type Msg = AsyncPrMsg;

    fn on_start(&mut self, ctx: &mut Ctx<AsyncPrMsg>) {
        if self.params.iterations > 0 {
            self.compute_and_send(ctx);
        }
    }

    fn on_message(&mut self, _ctx: &mut Ctx<AsyncPrMsg>, _from: LocalityId, msg: AsyncPrMsg) {
        // Applied on arrival — the "asynchronous remote action ...
        // atomically updating the destination vertex" of §4.2.
        let start = self.shard.range.start;
        match msg {
            AsyncPrMsg::Contrib(v, c) => self.z[v as usize - start] += c,
            AsyncPrMsg::Batch(batch) => {
                for (v, c) in batch {
                    self.z[v as usize - start] += c;
                }
            }
        }
    }

    fn on_barrier(&mut self, ctx: &mut Ctx<AsyncPrMsg>, _epoch: u64) {
        self.update_ranks();
        self.iter += 1;
        if self.iter < self.params.iterations {
            self.compute_and_send(ctx);
        }
    }
}

/// Run asynchronous PageRank with the given message-granularity variant.
pub fn run(dist: &DistGraph, params: PrParams, variant: Variant, cfg: SimConfig) -> PrResult {
    let dist = Arc::new(dist.clone());
    let n = dist.n();
    let actors: Vec<AsyncPrActor> = dist
        .shards
        .iter()
        .map(|s| AsyncPrActor {
            shard: Arc::new(s.clone()),
            dist: Arc::clone(&dist),
            params,
            variant,
            rank: vec![1.0 / n as f32; s.n_local()],
            z: vec![0.0; s.n_local()],
            iter: 0,
            deltas: Vec::new(),
        })
        .collect();
    let (actors, report) = SimRuntime::new(cfg).run(actors);
    super::bsp::collect(&dist, actors.iter().map(|a| (&a.rank, &a.deltas)), params, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::pagerank::{max_abs_diff, sequential};
    use crate::amt::NetConfig;
    use crate::graph::generators;

    #[test]
    fn naive_matches_oracle() {
        let g = generators::urand_directed(6, 6, 17);
        let params = PrParams { alpha: 0.85, iterations: 12 };
        let want = sequential::pagerank(&g, params);
        for p in [1u32, 2, 4] {
            let dist = DistGraph::block(&g, p);
            let res = run(&dist, params, Variant::Naive,
                          SimConfig::deterministic(NetConfig::default()));
            assert!(max_abs_diff(&res.ranks, &want) < 1e-5, "p={p}");
        }
    }

    #[test]
    fn optimized_matches_oracle_for_any_flush_block() {
        let g = generators::urand_directed(6, 6, 23);
        let params = PrParams { alpha: 0.85, iterations: 12 };
        let want = sequential::pagerank(&g, params);
        let dist = DistGraph::block(&g, 4);
        for fb in [1usize, 8, 64, 1 << 20] {
            let res = run(&dist, params, Variant::Optimized { flush_block: fb },
                          SimConfig::deterministic(NetConfig::default()));
            assert!(max_abs_diff(&res.ranks, &want) < 1e-5, "flush_block={fb}");
        }
    }

    #[test]
    fn naive_sends_one_message_per_remote_edge() {
        let g = generators::complete(16);
        let dist = DistGraph::block(&g, 4);
        let params = PrParams { alpha: 0.85, iterations: 1 };
        let res = run(&dist, params, Variant::Naive,
                      SimConfig::deterministic(NetConfig::default()));
        // complete(16) over 4 localities: each vertex has 12 remote
        // neighbors -> 16 * 12 remote edges.
        assert_eq!(res.report.net.messages, 16 * 12);
    }

    #[test]
    fn optimized_sends_far_fewer_envelopes_than_naive() {
        let g = generators::urand_directed(7, 8, 29);
        let dist = DistGraph::block(&g, 4);
        let params = PrParams { alpha: 0.85, iterations: 3 };
        let naive = run(&dist, params, Variant::Naive,
                        SimConfig::deterministic(NetConfig::default()));
        let opt = run(&dist, params, Variant::Optimized { flush_block: 1 << 20 },
                      SimConfig::deterministic(NetConfig::default()));
        assert!(opt.report.net.envelopes * 10 < naive.report.net.envelopes);
        assert!(opt.report.makespan_us < naive.report.makespan_us);
    }
}
