//! Asynchronous HPX-style PageRank — paper §4.2, on the shared
//! [`amt::aggregate`](crate::amt::aggregate) combiner layer.
//!
//! The paper's "very initial implementation" issued one asynchronous
//! remote action per remote edge and was "significantly worse than the
//! Boost library"; its improved prototype folded contributions into a
//! per-destination combiner flushed in blocks. Both are now spellings of
//! one [`FlushPolicy`]:
//!
//! * [`FlushPolicy::Unbatched`] — the naive per-edge path (ablation
//!   baseline);
//! * [`FlushPolicy::Items`] / [`FlushPolicy::Bytes`] /
//!   [`FlushPolicy::Adaptive`] — chunked combiner flushes shipped eagerly,
//!   so communication overlaps the rest of the contribution phase while
//!   per-message costs amortize (the paper's "optimized" variant);
//! * [`FlushPolicy::Manual`] — everything waits for the end-of-phase
//!   drain, degenerating to BSP-style batching (one envelope per
//!   destination per iteration) minus the at-barrier application.
//!
//! All variants keep the paper's per-iteration synchronization (one global
//! barrier between exchange and update) and apply remote contributions *on
//! arrival*, so the only experimental difference vs [`bsp`](super::bsp) is
//! message granularity and overlap — exactly the contrast Figure 2 probes.
//!
//! Under a vertex cut each owned vertex scatters its per-iteration
//! contribution to its mirrors through a second combiner
//! ([`AsyncPrMsg::ToMirror`]); the mirror expands its share of the row on
//! arrival, forwarding the resulting contributions to their masters
//! before the iteration barrier. 1-D schemes never touch this path.

use std::sync::Arc;

use crate::amt::aggregate::{Aggregator, Batch, FlushPolicy};
use crate::amt::sim::{Actor, Ctx, LocalityId, Message, SimConfig, SimRuntime};
use crate::graph::{DistGraph, Shard};

use super::{PrParams, PrResult};

/// Async PageRank wire format.
#[derive(Debug, Clone)]
pub enum AsyncPrMsg {
    /// `(master index, summed contribution)` toward a vertex's master. An
    /// unbatched flush carries exactly one pair — the paper's naive
    /// `Contrib(v, c)` remote action.
    ToMaster(Batch<f32>),
    /// `(ghost slot, contribution)` toward a vertex's mirror.
    ToMirror(Batch<f32>),
}

/// Per-item wire size: vertex id + contribution.
const ITEM_BYTES: usize = 8;

impl Message for AsyncPrMsg {
    fn wire_bytes(&self) -> usize {
        match self {
            AsyncPrMsg::ToMaster(b) => b.wire_bytes(),
            AsyncPrMsg::ToMirror(b) => b.wire_bytes(),
        }
    }

    fn item_count(&self) -> usize {
        match self {
            AsyncPrMsg::ToMaster(b) => b.len(),
            AsyncPrMsg::ToMirror(b) => b.len(),
        }
    }
}

fn add(acc: &mut f32, c: f32) {
    *acc += c;
}

/// Per-locality asynchronous PageRank state.
pub struct AsyncPrActor {
    shard: Arc<Shard>,
    n_global: usize,
    params: PrParams,
    /// Remote-contribution combiner (shared aggregation subsystem).
    pub agg: Aggregator<f32>,
    /// Mirror-scatter combiner (idle under 1-D schemes).
    pub mirror_agg: Aggregator<f32>,
    /// Owned ranks (local row).
    pub rank: Vec<f32>,
    z: Vec<f32>,
    iter: u32,
    /// Per-iteration local L1 deltas.
    pub deltas: Vec<f32>,
}

impl AsyncPrActor {
    /// Push one row's locally homed edges at contribution `c`: local
    /// targets accumulate into `z`, remote targets fold into the
    /// master-bound combiner (flushed batches ship eagerly).
    fn push_row(&mut self, ctx: &mut Ctx<AsyncPrMsg>, row: usize, c: f32) {
        let n_owned = self.shard.n_local();
        let shard = Arc::clone(&self.shard);
        for &t in shard.row_neighbors_local(row) {
            let t = t as usize;
            if t < n_owned {
                self.z[t] += c;
            } else {
                let gi = t - n_owned;
                let dst = shard.ghost_owner[gi];
                if let Some(batch) =
                    self.agg.accumulate(dst, shard.ghost_master_index[gi], c)
                {
                    ctx.send(dst, AsyncPrMsg::ToMaster(batch));
                }
            }
        }
    }

    /// Contribution phase. Remote contributions are *applied on arrival*
    /// (the receiving handler updates `z` immediately — HPX remote actions
    /// with atomic updates), so communication overlaps compute.
    fn compute_and_send(&mut self, ctx: &mut Ctx<AsyncPrMsg>) {
        let n_local = self.shard.n_local();
        for u in 0..n_local {
            let deg = (self.shard.out_degree[u].max(1)) as f32;
            let c = self.rank[u] / deg;
            let shard = Arc::clone(&self.shard);
            for &(dst, gi) in shard.mirrors(u) {
                if let Some(batch) = self.mirror_agg.accumulate(dst, gi, c) {
                    ctx.send(dst, AsyncPrMsg::ToMirror(batch));
                }
            }
            self.push_row(ctx, u, c);
        }
        for (dst, batch) in self.agg.drain() {
            ctx.send(dst, AsyncPrMsg::ToMaster(batch));
        }
        for (dst, batch) in self.mirror_agg.drain() {
            ctx.send(dst, AsyncPrMsg::ToMirror(batch));
        }
        ctx.request_barrier();
    }

    fn update_ranks(&mut self) {
        let base = (1.0 - self.params.alpha) / self.n_global as f32;
        let mut delta = 0.0f32;
        for v in 0..self.shard.n_local() {
            let new = base + self.params.alpha * self.z[v];
            delta += (new - self.rank[v]).abs();
            self.rank[v] = new;
        }
        self.deltas.push(delta);
        self.z.iter_mut().for_each(|x| *x = 0.0);
    }
}

impl Actor for AsyncPrActor {
    type Msg = AsyncPrMsg;

    fn on_start(&mut self, ctx: &mut Ctx<AsyncPrMsg>) {
        if self.params.iterations > 0 {
            self.compute_and_send(ctx);
        }
    }

    fn on_message(&mut self, ctx: &mut Ctx<AsyncPrMsg>, _from: LocalityId, msg: AsyncPrMsg) {
        match msg {
            // Applied on arrival — the "asynchronous remote action ...
            // atomically updating the destination vertex" of §4.2.
            AsyncPrMsg::ToMaster(b) => {
                for (idx, c) in b.items {
                    self.z[idx as usize] += c;
                }
            }
            // Mirror scatter: expand our share of the row now; the
            // resulting master-bound contributions must reach their
            // destinations before this iteration's barrier, so drain.
            AsyncPrMsg::ToMirror(b) => {
                let n_owned = self.shard.n_local();
                for (gi, c) in b.items {
                    self.push_row(ctx, n_owned + gi as usize, c);
                }
                for (dst, batch) in self.agg.drain() {
                    ctx.send(dst, AsyncPrMsg::ToMaster(batch));
                }
            }
        }
    }

    fn on_barrier(&mut self, ctx: &mut Ctx<AsyncPrMsg>, _epoch: u64) {
        self.update_ranks();
        self.iter += 1;
        if self.iter < self.params.iterations {
            self.compute_and_send(ctx);
        }
    }
}

/// Run asynchronous PageRank with the given flush policy.
pub fn run(dist: &DistGraph, params: PrParams, policy: FlushPolicy, cfg: SimConfig) -> PrResult {
    let n = dist.n();
    let actors: Vec<AsyncPrActor> = dist
        .shards
        .iter()
        .map(|s| AsyncPrActor {
            shard: Arc::new(s.clone()),
            n_global: n,
            params,
            agg: Aggregator::new(
                dist.owned_counts(),
                s.locality,
                policy,
                &cfg.net,
                ITEM_BYTES,
                add,
            ),
            mirror_agg: Aggregator::new(
                dist.ghost_counts(),
                s.locality,
                policy,
                &cfg.net,
                ITEM_BYTES,
                add,
            ),
            rank: vec![1.0 / n as f32; s.n_local()],
            z: vec![0.0; s.n_local()],
            iter: 0,
            deltas: Vec::new(),
        })
        .collect();
    let (actors, mut report) = SimRuntime::new(cfg).run(actors);
    for a in &actors {
        report.agg.merge(a.agg.stats());
        report.agg.merge(a.mirror_agg.stats());
    }
    super::bsp::collect(dist, actors.iter().map(|a| (&a.rank, &a.deltas)), params, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::pagerank::{max_abs_diff, sequential};
    use crate::amt::NetConfig;
    use crate::graph::{generators, PartitionKind};

    fn det() -> SimConfig {
        SimConfig::deterministic(NetConfig::default())
    }

    #[test]
    fn unbatched_matches_oracle() {
        let g = generators::urand_directed(6, 6, 17);
        let params = PrParams { alpha: 0.85, iterations: 12 };
        let want = sequential::pagerank(&g, params);
        for p in [1u32, 2, 4] {
            let dist = DistGraph::block(&g, p);
            let res = run(&dist, params, FlushPolicy::Unbatched, det());
            assert!(max_abs_diff(&res.ranks, &want) < 1e-5, "p={p}");
        }
    }

    #[test]
    fn every_flush_policy_matches_oracle() {
        let g = generators::urand_directed(6, 6, 23);
        let params = PrParams { alpha: 0.85, iterations: 12 };
        let want = sequential::pagerank(&g, params);
        let dist = DistGraph::block(&g, 4);
        for policy in [
            FlushPolicy::Unbatched,
            FlushPolicy::Items(1),
            FlushPolicy::Items(8),
            FlushPolicy::Items(64),
            FlushPolicy::Bytes(256),
            FlushPolicy::Adaptive,
            FlushPolicy::Manual,
        ] {
            let res = run(&dist, params, policy, det());
            assert!(max_abs_diff(&res.ranks, &want) < 1e-5, "{policy:?}");
        }
    }

    #[test]
    fn vertex_cut_matches_oracle_under_every_policy() {
        let g = generators::kron(7, 6, 29);
        let params = PrParams { alpha: 0.85, iterations: 10 };
        let want = sequential::pagerank(&g, params);
        let dist = DistGraph::build_with(&g, PartitionKind::VertexCut.build(&g, 4));
        assert!(dist.has_mirrors());
        for policy in [
            FlushPolicy::Unbatched,
            FlushPolicy::Items(8),
            FlushPolicy::Adaptive,
            FlushPolicy::Manual,
        ] {
            let res = run(&dist, params, policy, det());
            assert!(
                max_abs_diff(&res.ranks, &want) < 1e-4,
                "{policy:?}: {}",
                max_abs_diff(&res.ranks, &want)
            );
        }
    }

    #[test]
    fn unbatched_sends_one_message_per_remote_edge() {
        let g = generators::complete(16);
        let dist = DistGraph::block(&g, 4);
        let params = PrParams { alpha: 0.85, iterations: 1 };
        let res = run(&dist, params, FlushPolicy::Unbatched, det());
        // complete(16) over 4 localities: each vertex has 12 remote
        // neighbors -> 16 * 12 remote edges.
        assert_eq!(res.report.net.messages, 16 * 12);
        assert_eq!(res.report.net.envelopes, 16 * 12);
        assert_eq!(res.report.agg.envelopes, 16 * 12);
    }

    #[test]
    fn manual_drain_sends_far_fewer_envelopes_than_unbatched() {
        let g = generators::urand_directed(7, 8, 29);
        let dist = DistGraph::block(&g, 4);
        let params = PrParams { alpha: 0.85, iterations: 3 };
        let naive = run(&dist, params, FlushPolicy::Unbatched, det());
        let opt = run(&dist, params, FlushPolicy::Manual, det());
        assert!(opt.report.net.envelopes * 10 < naive.report.net.envelopes);
        assert!(opt.report.makespan_us < naive.report.makespan_us);
    }

    #[test]
    fn manual_drain_reproduces_bsp_envelope_schedule() {
        // Maximal batching == the previous Optimized variant with
        // `flush_block == n_local`: exactly one envelope per non-empty
        // destination pair per iteration, the same wire schedule the BSP
        // engine produces.
        let g = generators::urand_directed(7, 8, 31);
        let dist = DistGraph::block(&g, 4);
        let params = PrParams { alpha: 0.85, iterations: 5 };
        let manual = run(&dist, params, FlushPolicy::Manual, det());
        let bsp = super::super::bsp::run(&dist, params, det());
        assert_eq!(manual.report.net.envelopes, bsp.report.net.envelopes);
        assert_eq!(manual.report.agg.envelopes, manual.report.net.envelopes);
    }

    #[test]
    fn flush_accounting_matches_wire_traffic() {
        // Every emitted batch is shipped as exactly one envelope, and
        // every folded item reaches the wire exactly once: the aggregation
        // counters in SimReport must equal the network counters.
        let g = generators::urand_directed(6, 6, 37);
        let dist = DistGraph::block(&g, 4);
        let params = PrParams { alpha: 0.85, iterations: 4 };
        for policy in [
            FlushPolicy::Unbatched,
            FlushPolicy::Items(16),
            FlushPolicy::Adaptive,
            FlushPolicy::Manual,
        ] {
            let res = run(&dist, params, policy, det());
            assert_eq!(res.report.agg.envelopes, res.report.net.envelopes, "{policy:?}");
            assert_eq!(res.report.agg.sent_items, res.report.net.messages, "{policy:?}");
            // Per-iteration phases drain fully: nothing folded is lost.
            assert_eq!(
                res.report.agg.items,
                res.report.agg.folded + res.report.agg.sent_items,
                "{policy:?}"
            );
        }
    }
}
