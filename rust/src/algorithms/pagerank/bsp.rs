//! BSP PageRank — the distributed-BGL (Boost) baseline of Figure 2.
//!
//! Each iteration is one superstep: every locality computes contributions
//! for its owned vertices, applies local ones directly, folds remote ones
//! into a dense per-destination combiner, and ships **one batched message
//! per destination locality**. A global barrier separates the exchange
//! from the rank update; incoming contributions are applied *at the
//! barrier* (strict BSP semantics — no overlap, maximal batching). This is
//! the communication pattern that makes Boost's PageRank hard to beat
//! (paper §5, Fig. 2): PageRank's traffic is dense and regular, so batching
//! amortizes per-message costs that fine-grained asynchrony keeps paying.

use std::sync::Arc;

use crate::amt::executor::{ChunkPolicy, Executor};
use crate::amt::sim::{Actor, Ctx, LocalityId, Message, SimConfig, SimRuntime};
use crate::graph::{DistGraph, Shard, VertexId};

use super::{PrParams, PrResult};

/// Batched contribution exchange: `(destination vertex, contribution)`.
#[derive(Debug, Clone)]
pub struct Contribs(pub Vec<(VertexId, f32)>);

impl Message for Contribs {
    fn wire_bytes(&self) -> usize {
        8 * self.0.len()
    }

    fn item_count(&self) -> usize {
        // One combined contribution per destination vertex.
        self.0.len()
    }
}

/// Per-locality BSP PageRank state.
pub struct BspPrActor {
    shard: Arc<Shard>,
    dist: Arc<DistGraph>,
    params: PrParams,
    /// Ranks of owned vertices (local index).
    pub rank: Vec<f32>,
    z: Vec<f32>,
    inbox: Vec<(VertexId, f32)>,
    iter: u32,
    /// Per-iteration local L1 delta (reduced by the driver afterwards).
    pub deltas: Vec<f32>,
    /// Optional intra-locality executor for the update loop (None = serial).
    executor: Option<Arc<Executor>>,
    chunk_policy: ChunkPolicy,
    /// Dense per-destination combiners, allocated once and reused across
    /// iterations with sparse clears (perf: ~3-4% on the local phase,
    /// EXPERIMENTS.md §Perf iteration 2).
    combiner: Vec<Vec<f32>>,
    touched: Vec<Vec<u32>>,
}

impl BspPrActor {
    /// Phase 1+2 of paper §4.2: contribution accumulation + exchange.
    fn compute_and_send(&mut self, ctx: &mut Ctx<Contribs>) {
        let here = ctx.locality();
        let p = ctx.n_localities() as usize;
        let n_local = self.shard.n_local();
        if self.combiner.is_empty() {
            self.combiner = (0..p)
                .map(|l| vec![0.0f32; self.dist.partition.len_of(l as LocalityId)])
                .collect();
            self.touched = vec![Vec::new(); p];
        }
        let mut combiner = std::mem::take(&mut self.combiner);
        let mut touched = std::mem::take(&mut self.touched);
        for u in 0..n_local {
            let deg = (self.shard.out_degree[u].max(1)) as f32;
            let c = self.rank[u] / deg;
            for &v in self.shard.out_neighbors(u) {
                let dst = self.dist.owner(v);
                let off = v as usize - self.dist.partition.range_of(dst).start;
                if dst == here {
                    self.z[off] += c;
                } else {
                    let d = dst as usize;
                    if combiner[d][off] == 0.0 {
                        touched[d].push(off as u32);
                    }
                    combiner[d][off] += c;
                }
            }
        }
        for dst in 0..p {
            if dst == here as usize || touched[dst].is_empty() {
                continue;
            }
            let start = self.dist.partition.range_of(dst as LocalityId).start;
            let mut batch: Vec<(VertexId, f32)> = touched[dst]
                .iter()
                .map(|&off| ((start + off as usize) as VertexId, combiner[dst][off as usize]))
                .collect();
            batch.sort_by_key(|&(v, _)| v);
            // Reset only the touched slots (sparse clear) for reuse.
            for &off in &touched[dst] {
                combiner[dst][off as usize] = 0.0;
            }
            touched[dst].clear();
            ctx.send(dst as LocalityId, Contribs(batch));
        }
        self.combiner = combiner;
        self.touched = touched;
        ctx.request_barrier();
    }

    /// Phases 2+3 of paper §4.2: rank update + error computation.
    fn update_ranks(&mut self) {
        let n_local = self.shard.n_local();
        let base = (1.0 - self.params.alpha) / self.dist.n() as f32;
        let alpha = self.params.alpha;
        let delta = if let Some(ex) = &self.executor {
            use std::sync::atomic::{AtomicU64, Ordering};
            // f32 delta accumulated as bits of partial sums per chunk.
            let acc = AtomicU64::new(0f64.to_bits());
            let rank_ptr = SendPtr(self.rank.as_mut_ptr());
            let rank_ptr = &rank_ptr;
            let z = &self.z;
            ex.parallel_for(n_local, self.chunk_policy, |r| {
                let mut local = 0.0f64;
                for v in r {
                    // SAFETY: ranges from parallel_for are disjoint.
                    let rv = unsafe { &mut *rank_ptr.get().add(v) };
                    let new = base + alpha * z[v];
                    local += (new - *rv).abs() as f64;
                    *rv = new;
                }
                // fetch_add for f64 via CAS loop.
                let mut cur = acc.load(Ordering::Relaxed);
                loop {
                    let next = (f64::from_bits(cur) + local).to_bits();
                    match acc.compare_exchange(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                        Ok(_) => break,
                        Err(c) => cur = c,
                    }
                }
            });
            f64::from_bits(acc.load(std::sync::atomic::Ordering::Relaxed)) as f32
        } else {
            let mut d = 0.0f32;
            for v in 0..n_local {
                let new = base + alpha * self.z[v];
                d += (new - self.rank[v]).abs();
                self.rank[v] = new;
            }
            d
        };
        self.deltas.push(delta);
        self.z.iter_mut().for_each(|x| *x = 0.0);
    }
}

struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}
impl SendPtr {
    fn get(&self) -> *mut f32 {
        self.0
    }
}

impl Actor for BspPrActor {
    type Msg = Contribs;

    fn on_start(&mut self, ctx: &mut Ctx<Contribs>) {
        if self.params.iterations > 0 {
            self.compute_and_send(ctx);
        }
    }

    fn on_message(&mut self, _ctx: &mut Ctx<Contribs>, _from: LocalityId, msg: Contribs) {
        // Strict BSP: buffer, apply at the barrier.
        self.inbox.extend(msg.0);
    }

    fn on_barrier(&mut self, ctx: &mut Ctx<Contribs>, _epoch: u64) {
        let start = self.shard.range.start;
        let inbox = std::mem::take(&mut self.inbox);
        for (v, c) in inbox {
            self.z[v as usize - start] += c;
        }
        self.update_ranks();
        self.iter += 1;
        if self.iter < self.params.iterations {
            self.compute_and_send(ctx);
        }
    }
}

/// Run BSP PageRank (serial local update loop).
pub fn run(dist: &DistGraph, params: PrParams, cfg: SimConfig) -> PrResult {
    run_with_executor(dist, params, cfg, None, ChunkPolicy::Sequential)
}

/// Run BSP PageRank with an intra-locality executor for the update loop
/// (the `adaptive_core_chunk_size` ablation hooks in here).
pub fn run_with_executor(
    dist: &DistGraph,
    params: PrParams,
    cfg: SimConfig,
    executor: Option<Arc<Executor>>,
    chunk_policy: ChunkPolicy,
) -> PrResult {
    let dist = Arc::new(dist.clone());
    let n = dist.n();
    let actors: Vec<BspPrActor> = dist
        .shards
        .iter()
        .map(|s| BspPrActor {
            shard: Arc::new(s.clone()),
            dist: Arc::clone(&dist),
            params,
            rank: vec![1.0 / n as f32; s.n_local()],
            z: vec![0.0; s.n_local()],
            inbox: Vec::new(),
            iter: 0,
            deltas: Vec::new(),
            executor: executor.clone(),
            chunk_policy,
            combiner: Vec::new(),
            touched: Vec::new(),
        })
        .collect();
    let (actors, report) = SimRuntime::new(cfg).run(actors);
    collect(&dist, actors.iter().map(|a| (&a.rank, &a.deltas)), params, report)
}

/// Assemble global ranks + reduced deltas from per-locality results.
pub(crate) fn collect<'a>(
    dist: &DistGraph,
    parts: impl Iterator<Item = (&'a Vec<f32>, &'a Vec<f32>)>,
    params: PrParams,
    report: crate::amt::SimReport,
) -> PrResult {
    let mut ranks = vec![0.0f32; dist.n()];
    let mut deltas = vec![0.0f32; params.iterations as usize];
    for (l, (rank, local_deltas)) in parts.enumerate() {
        let range = dist.partition.range_of(l as LocalityId);
        ranks[range].copy_from_slice(rank);
        for (i, d) in local_deltas.iter().enumerate() {
            deltas[i] += d;
        }
    }
    PrResult { ranks, deltas, report }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::pagerank::{max_abs_diff, sequential};
    use crate::amt::NetConfig;
    use crate::graph::generators;

    #[test]
    fn matches_sequential_oracle() {
        for (scale, p) in [(6u32, 1u32), (6, 2), (7, 4), (7, 8)] {
            let g = generators::urand_directed(scale, 6, 42 + p as u64);
            let params = PrParams { alpha: 0.85, iterations: 15 };
            let want = sequential::pagerank(&g, params);
            let dist = DistGraph::block(&g, p);
            let res = run(&dist, params, SimConfig::deterministic(NetConfig::default()));
            assert!(
                max_abs_diff(&res.ranks, &want) < 1e-5,
                "scale={scale} p={p} diff={}",
                max_abs_diff(&res.ranks, &want)
            );
        }
    }

    #[test]
    fn one_barrier_per_iteration() {
        let g = generators::urand_directed(6, 4, 1);
        let dist = DistGraph::block(&g, 4);
        let params = PrParams { alpha: 0.85, iterations: 12 };
        let res = run(&dist, params, SimConfig::deterministic(NetConfig::default()));
        assert_eq!(res.report.barriers, 12);
    }

    #[test]
    fn batches_one_envelope_per_destination_pair() {
        let g = generators::complete(32); // all-to-all traffic
        let dist = DistGraph::block(&g, 4);
        let params = PrParams { alpha: 0.85, iterations: 3 };
        let res = run(&dist, params, SimConfig::deterministic(NetConfig::default()));
        // per iteration: each of 4 localities sends to 3 others.
        assert_eq!(res.report.net.envelopes, 3 * 4 * 3);
    }

    #[test]
    fn deltas_shrink() {
        let g = generators::urand_directed(7, 6, 5);
        let dist = DistGraph::block(&g, 4);
        let params = PrParams { alpha: 0.85, iterations: 20 };
        let res = run(&dist, params, SimConfig::deterministic(NetConfig::default()));
        assert!(res.deltas.last().unwrap() < &res.deltas[0]);
    }

    #[test]
    fn threaded_update_matches_serial() {
        let g = generators::urand_directed(7, 6, 9);
        let dist = DistGraph::block(&g, 2);
        let params = PrParams { alpha: 0.85, iterations: 10 };
        let serial = run(&dist, params, SimConfig::deterministic(NetConfig::default()));
        let threaded = run_with_executor(
            &dist,
            params,
            SimConfig::deterministic(NetConfig::default()),
            Some(Arc::new(Executor::new(4))),
            ChunkPolicy::Dynamic { chunk: 64 },
        );
        assert!(max_abs_diff(&serial.ranks, &threaded.ranks) < 1e-6);
    }
}
