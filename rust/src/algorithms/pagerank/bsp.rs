//! BSP PageRank — the distributed-BGL (Boost) baseline of Figure 2.
//!
//! Each iteration is one superstep: every locality computes contributions
//! for its owned vertices, applies local ones directly, folds remote ones
//! into a dense per-destination combiner (keyed by the destination's
//! master index), and ships **one batched message per destination
//! locality**. A global barrier separates the exchange from the rank
//! update; incoming contributions are applied *at the barrier* (strict
//! BSP semantics — no overlap, maximal batching). This is the
//! communication pattern that makes Boost's PageRank hard to beat
//! (paper §5, Fig. 2): PageRank's traffic is dense and regular, so batching
//! amortizes per-message costs that fine-grained asynchrony keeps paying.
//!
//! Under a vertex cut each owned vertex additionally scatters its
//! per-iteration contribution `rank/deg` to its mirrors
//! ([`BspPrMsg::MirrorContribs`]); the mirror expands its share of the
//! row immediately in the handler, so the replicated traffic still lands
//! inside the same superstep. 1-D schemes never take this path.

use std::sync::Arc;

use crate::amt::executor::{ChunkPolicy, Executor};
use crate::amt::sim::{Actor, Ctx, LocalityId, Message, SimConfig, SimRuntime};
use crate::graph::{DistGraph, Shard};

use super::{PrParams, PrResult};

/// BSP PageRank messages.
#[derive(Debug, Clone)]
pub enum BspPrMsg {
    /// Batched contribution exchange: `(destination master index, sum)`.
    Contribs(Vec<(u32, f32)>),
    /// Vertex-cut scatter: `(ghost slot at destination, contribution)`.
    MirrorContribs(Vec<(u32, f32)>),
}

impl Message for BspPrMsg {
    fn wire_bytes(&self) -> usize {
        match self {
            BspPrMsg::Contribs(v) => 8 * v.len(),
            BspPrMsg::MirrorContribs(v) => 8 * v.len(),
        }
    }

    fn item_count(&self) -> usize {
        // One combined contribution per destination slot.
        match self {
            BspPrMsg::Contribs(v) => v.len(),
            BspPrMsg::MirrorContribs(v) => v.len(),
        }
    }
}

/// Per-locality BSP PageRank state.
pub struct BspPrActor {
    shard: Arc<Shard>,
    n_global: usize,
    params: PrParams,
    /// Ranks of owned vertices (local row).
    pub rank: Vec<f32>,
    z: Vec<f32>,
    inbox: Vec<(u32, f32)>,
    iter: u32,
    /// Per-iteration local L1 delta (reduced by the driver afterwards).
    pub deltas: Vec<f32>,
    /// Optional intra-locality executor for the update loop (None = serial).
    executor: Option<Arc<Executor>>,
    chunk_policy: ChunkPolicy,
    /// Dense per-destination combiners (destination master index),
    /// allocated once and reused across iterations with sparse clears
    /// (perf: ~3-4% on the local phase, EXPERIMENTS.md §Perf iteration 2).
    combiner: Vec<Vec<f32>>,
    touched: Vec<Vec<u32>>,
    /// Owned-count layout of every destination (combiner allocation).
    owned_counts: Vec<usize>,
}

impl BspPrActor {
    /// Fold one row's locally homed out-edges at contribution `c` into the
    /// local accumulator / remote combiners.
    fn push_row(
        &mut self,
        row: usize,
        c: f32,
        here: usize,
        combiner: &mut [Vec<f32>],
        touched: &mut [Vec<u32>],
    ) {
        let n_owned = self.shard.n_local();
        let shard = Arc::clone(&self.shard);
        for &t in shard.row_neighbors_local(row) {
            let t = t as usize;
            if t < n_owned {
                self.z[t] += c;
            } else {
                let gi = t - n_owned;
                let d = shard.ghost_owner[gi] as usize;
                let off = shard.ghost_master_index[gi] as usize;
                debug_assert_ne!(d, here);
                if combiner[d][off] == 0.0 {
                    touched[d].push(off as u32);
                }
                combiner[d][off] += c;
            }
        }
    }

    /// Phase 1+2 of paper §4.2: contribution accumulation + exchange.
    fn compute_and_send(&mut self, ctx: &mut Ctx<BspPrMsg>) {
        let here = ctx.locality() as usize;
        let p = ctx.n_localities() as usize;
        let n_local = self.shard.n_local();
        if self.combiner.is_empty() {
            self.combiner = self.owned_counts.iter().map(|&c| vec![0.0f32; c]).collect();
            self.touched = vec![Vec::new(); p];
        }
        let mut combiner = std::mem::take(&mut self.combiner);
        let mut touched = std::mem::take(&mut self.touched);
        let mut mirror_out: Vec<Vec<(u32, f32)>> = vec![Vec::new(); p];
        for u in 0..n_local {
            let deg = (self.shard.out_degree[u].max(1)) as f32;
            let c = self.rank[u] / deg;
            for &(dst, gi) in self.shard.mirrors(u) {
                mirror_out[dst as usize].push((gi, c));
            }
            self.push_row(u, c, here, &mut combiner, &mut touched);
        }
        for (dst, batch) in mirror_out.into_iter().enumerate() {
            if !batch.is_empty() {
                ctx.send(dst as LocalityId, BspPrMsg::MirrorContribs(batch));
            }
        }
        for dst in 0..p {
            if dst == here || touched[dst].is_empty() {
                continue;
            }
            let mut batch: Vec<(u32, f32)> = touched[dst]
                .iter()
                .map(|&off| (off, combiner[dst][off as usize]))
                .collect();
            batch.sort_by_key(|&(v, _)| v);
            // Reset only the touched slots (sparse clear) for reuse.
            for &off in &touched[dst] {
                combiner[dst][off as usize] = 0.0;
            }
            touched[dst].clear();
            ctx.send(dst as LocalityId, BspPrMsg::Contribs(batch));
        }
        self.combiner = combiner;
        self.touched = touched;
        ctx.request_barrier();
    }

    /// Phases 2+3 of paper §4.2: rank update + error computation.
    fn update_ranks(&mut self) {
        let n_local = self.shard.n_local();
        let base = (1.0 - self.params.alpha) / self.n_global as f32;
        let alpha = self.params.alpha;
        let delta = if let Some(ex) = &self.executor {
            use std::sync::atomic::{AtomicU64, Ordering};
            // f32 delta accumulated as bits of partial sums per chunk.
            let acc = AtomicU64::new(0f64.to_bits());
            let rank_ptr = SendPtr(self.rank.as_mut_ptr());
            let rank_ptr = &rank_ptr;
            let z = &self.z;
            ex.parallel_for(n_local, self.chunk_policy, |r| {
                let mut local = 0.0f64;
                for v in r {
                    // SAFETY: ranges from parallel_for are disjoint.
                    let rv = unsafe { &mut *rank_ptr.get().add(v) };
                    let new = base + alpha * z[v];
                    local += (new - *rv).abs() as f64;
                    *rv = new;
                }
                // fetch_add for f64 via CAS loop.
                let mut cur = acc.load(Ordering::Relaxed);
                loop {
                    let next = (f64::from_bits(cur) + local).to_bits();
                    match acc.compare_exchange(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                        Ok(_) => break,
                        Err(c) => cur = c,
                    }
                }
            });
            f64::from_bits(acc.load(std::sync::atomic::Ordering::Relaxed)) as f32
        } else {
            let mut d = 0.0f32;
            for v in 0..n_local {
                let new = base + alpha * self.z[v];
                d += (new - self.rank[v]).abs();
                self.rank[v] = new;
            }
            d
        };
        self.deltas.push(delta);
        self.z.iter_mut().for_each(|x| *x = 0.0);
    }
}

struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}
impl SendPtr {
    fn get(&self) -> *mut f32 {
        self.0
    }
}

impl Actor for BspPrActor {
    type Msg = BspPrMsg;

    fn on_start(&mut self, ctx: &mut Ctx<BspPrMsg>) {
        if self.params.iterations > 0 {
            self.compute_and_send(ctx);
        }
    }

    fn on_message(&mut self, ctx: &mut Ctx<BspPrMsg>, _from: LocalityId, msg: BspPrMsg) {
        match msg {
            // Strict BSP: buffer, apply at the barrier.
            BspPrMsg::Contribs(batch) => self.inbox.extend(batch),
            // Vertex-cut scatter: expand the mirror rows now so the
            // resulting contributions land inside this superstep. The
            // cached combiner is sparse-cleared by compute_and_send (which
            // always runs before any message of the superstep arrives), so
            // it can be reused here instead of re-zeroing O(n) slots.
            BspPrMsg::MirrorContribs(batch) => {
                let here = ctx.locality() as usize;
                let p = ctx.n_localities() as usize;
                let n_owned = self.shard.n_local();
                let mut combiner = std::mem::take(&mut self.combiner);
                let mut touched = std::mem::take(&mut self.touched);
                if combiner.is_empty() {
                    combiner = self.owned_counts.iter().map(|&c| vec![0.0f32; c]).collect();
                    touched = vec![Vec::new(); p];
                }
                for (gi, c) in batch {
                    self.push_row(n_owned + gi as usize, c, here, &mut combiner, &mut touched);
                }
                for dst in 0..p {
                    if touched[dst].is_empty() {
                        continue;
                    }
                    let mut out: Vec<(u32, f32)> = touched[dst]
                        .iter()
                        .map(|&off| (off, combiner[dst][off as usize]))
                        .collect();
                    out.sort_by_key(|&(v, _)| v);
                    for &off in &touched[dst] {
                        combiner[dst][off as usize] = 0.0;
                    }
                    touched[dst].clear();
                    ctx.send(dst as LocalityId, BspPrMsg::Contribs(out));
                }
                self.combiner = combiner;
                self.touched = touched;
            }
        }
    }

    fn on_barrier(&mut self, ctx: &mut Ctx<BspPrMsg>, _epoch: u64) {
        let inbox = std::mem::take(&mut self.inbox);
        for (idx, c) in inbox {
            self.z[idx as usize] += c;
        }
        self.update_ranks();
        self.iter += 1;
        if self.iter < self.params.iterations {
            self.compute_and_send(ctx);
        }
    }
}

/// Run BSP PageRank (serial local update loop).
pub fn run(dist: &DistGraph, params: PrParams, cfg: SimConfig) -> PrResult {
    run_with_executor(dist, params, cfg, None, ChunkPolicy::Sequential)
}

/// Run BSP PageRank with an intra-locality executor for the update loop
/// (the `adaptive_core_chunk_size` ablation hooks in here).
pub fn run_with_executor(
    dist: &DistGraph,
    params: PrParams,
    cfg: SimConfig,
    executor: Option<Arc<Executor>>,
    chunk_policy: ChunkPolicy,
) -> PrResult {
    let n = dist.n();
    let owned_counts: Vec<usize> = dist.owned_counts().to_vec();
    let actors: Vec<BspPrActor> = dist
        .shards
        .iter()
        .map(|s| BspPrActor {
            shard: Arc::new(s.clone()),
            n_global: n,
            params,
            rank: vec![1.0 / n as f32; s.n_local()],
            z: vec![0.0; s.n_local()],
            inbox: Vec::new(),
            iter: 0,
            deltas: Vec::new(),
            executor: executor.clone(),
            chunk_policy,
            combiner: Vec::new(),
            touched: Vec::new(),
            owned_counts: owned_counts.clone(),
        })
        .collect();
    let (actors, report) = SimRuntime::new(cfg).run(actors);
    collect(dist, actors.iter().map(|a| (&a.rank, &a.deltas)), params, report)
}

/// Assemble global ranks + reduced deltas from per-locality results.
pub(crate) fn collect<'a>(
    dist: &DistGraph,
    parts: impl Iterator<Item = (&'a Vec<f32>, &'a Vec<f32>)>,
    params: PrParams,
    report: crate::amt::SimReport,
) -> PrResult {
    let mut ranks = vec![0.0f32; dist.n()];
    let mut deltas = vec![0.0f32; params.iterations as usize];
    for (shard, (rank, local_deltas)) in dist.shards.iter().zip(parts) {
        shard.scatter_owned(rank, &mut ranks);
        for (i, d) in local_deltas.iter().enumerate() {
            deltas[i] += d;
        }
    }
    let mut report = report;
    report.partition = dist.partition_stats();
    PrResult { ranks, deltas, report }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::pagerank::{max_abs_diff, sequential};
    use crate::amt::NetConfig;
    use crate::graph::{generators, PartitionKind};

    #[test]
    fn matches_sequential_oracle() {
        for (scale, p) in [(6u32, 1u32), (6, 2), (7, 4), (7, 8)] {
            let g = generators::urand_directed(scale, 6, 42 + p as u64);
            let params = PrParams { alpha: 0.85, iterations: 15 };
            let want = sequential::pagerank(&g, params);
            let dist = DistGraph::block(&g, p);
            let res = run(&dist, params, SimConfig::deterministic(NetConfig::default()));
            assert!(
                max_abs_diff(&res.ranks, &want) < 1e-5,
                "scale={scale} p={p} diff={}",
                max_abs_diff(&res.ranks, &want)
            );
        }
    }

    #[test]
    fn matches_oracle_under_every_partition_scheme() {
        let g = generators::kron(7, 6, 51);
        let params = PrParams { alpha: 0.85, iterations: 15 };
        let want = sequential::pagerank(&g, params);
        for kind in PartitionKind::all() {
            for p in [2u32, 4, 8] {
                let dist = DistGraph::build_with(&g, kind.build(&g, p));
                let res = run(&dist, params, SimConfig::deterministic(NetConfig::default()));
                assert!(
                    max_abs_diff(&res.ranks, &want) < 1e-4,
                    "{kind:?} p={p} diff={}",
                    max_abs_diff(&res.ranks, &want)
                );
            }
        }
    }

    #[test]
    fn one_barrier_per_iteration() {
        let g = generators::urand_directed(6, 4, 1);
        let dist = DistGraph::block(&g, 4);
        let params = PrParams { alpha: 0.85, iterations: 12 };
        let res = run(&dist, params, SimConfig::deterministic(NetConfig::default()));
        assert_eq!(res.report.barriers, 12);
    }

    #[test]
    fn batches_one_envelope_per_destination_pair() {
        let g = generators::complete(32); // all-to-all traffic
        let dist = DistGraph::block(&g, 4);
        let params = PrParams { alpha: 0.85, iterations: 3 };
        let res = run(&dist, params, SimConfig::deterministic(NetConfig::default()));
        // per iteration: each of 4 localities sends to 3 others.
        assert_eq!(res.report.net.envelopes, 3 * 4 * 3);
    }

    #[test]
    fn deltas_shrink() {
        let g = generators::urand_directed(7, 6, 5);
        let dist = DistGraph::block(&g, 4);
        let params = PrParams { alpha: 0.85, iterations: 20 };
        let res = run(&dist, params, SimConfig::deterministic(NetConfig::default()));
        assert!(res.deltas.last().unwrap() < &res.deltas[0]);
    }

    #[test]
    fn threaded_update_matches_serial() {
        let g = generators::urand_directed(7, 6, 9);
        let dist = DistGraph::block(&g, 2);
        let params = PrParams { alpha: 0.85, iterations: 10 };
        let serial = run(&dist, params, SimConfig::deterministic(NetConfig::default()));
        let threaded = run_with_executor(
            &dist,
            params,
            SimConfig::deterministic(NetConfig::default()),
            Some(Arc::new(Executor::new(4))),
            ChunkPolicy::Dynamic { chunk: 64 },
        );
        assert!(max_abs_diff(&serial.ranks, &threaded.ranks) < 1e-6);
    }
}
