//! Kernel-offloaded PageRank: the local rank-update phase runs on the
//! AOT-compiled Pallas/XLA module (three-layer path).
//!
//! Communication pattern: a per-iteration **contribution allgather** —
//! every locality broadcasts its owned contribution slice, so each shard
//! holds the full contribution vector and the gather inside the kernel can
//! reach any global vertex. That trades the BSP push variant's sparse
//! per-destination traffic for dense, perfectly-batched slices (P·(P-1)
//! envelopes of `4·n/P` bytes per iteration) plus a bulk local SpMV — the
//! classic dense-exchange formulation that suits an accelerator-offloaded
//! local phase. DESIGN.md §4 documents the contrast with `bsp`.
//!
//! The engine is shared behind a mutex: the simulated localities execute
//! their kernel calls serially in the discrete-event loop, and each call's
//! wall time is charged to the owning locality's timeline.

use std::sync::{Arc, Mutex};

use crate::amt::sim::{Actor, Ctx, LocalityId, Message, SimConfig};
use crate::graph::{DistGraph, EllShard, PartitionScheme, Shard};
use crate::runtime::{ArtifactSpec, Engine};
use crate::Result;

use super::{PrParams, PrResult};

/// Allgather fragment: one locality's contribution slice.
#[derive(Debug, Clone)]
pub struct RankSlice {
    /// Global start index of the slice.
    pub start: usize,
    /// Contribution values for the sender's owned vertices.
    pub vals: Vec<f32>,
}

impl Message for RankSlice {
    fn wire_bytes(&self) -> usize {
        8 + 4 * self.vals.len()
    }
}

/// Per-locality kernel-offload PageRank state.
pub struct KernelPrActor {
    shard: Arc<Shard>,
    /// Global start of the shard's contiguous owned range (the allgather
    /// exchanges contiguous slices, so the engine requires a contiguous
    /// 1-D scheme — checked in [`run`]).
    range_start: usize,
    dist: Arc<DistGraph>,
    params: PrParams,
    engine: Arc<Mutex<Engine>>,
    spec: ArtifactSpec,
    ell: EllShard,
    cols: Vec<i32>,
    mask: Vec<f32>,
    row_map: Vec<i32>,
    /// Owned ranks, padded to `spec.n_rows` (padding rows pinned to `base`
    /// per the layout contract with `python/compile/model.py`).
    rank_padded: Vec<f32>,
    /// Full contribution vector, padded to `spec.n_global`.
    contrib: Vec<f32>,
    iter: u32,
    /// Per-iteration local L1 deltas.
    pub deltas: Vec<f32>,
    /// Owned ranks view (filled after each update).
    pub rank: Vec<f32>,
}

impl KernelPrActor {
    fn base(&self) -> f32 {
        (1.0 - self.params.alpha) / self.dist.n() as f32
    }

    /// Compute own contribution slice, broadcast it, install locally.
    fn contribute_and_allgather(&mut self, ctx: &mut Ctx<RankSlice>) {
        let n_local = self.shard.n_local();
        let start = self.range_start;
        let mut slice = vec![0.0f32; n_local];
        for u in 0..n_local {
            let deg = (self.shard.out_degree[u].max(1)) as f32;
            slice[u] = self.rank_padded[u] / deg;
        }
        self.contrib[start..start + n_local].copy_from_slice(&slice);
        for l in 0..ctx.n_localities() {
            if l != ctx.locality() {
                ctx.send(l, RankSlice { start, vals: slice.clone() });
            }
        }
        ctx.request_barrier();
    }

    /// Run the AOT module for the local rank update.
    fn kernel_update(&mut self) -> Result<()> {
        let (rank_new, delta) = self.engine.lock().unwrap().pagerank_step(
            &self.spec,
            &self.contrib,
            &self.rank_padded,
            &self.cols,
            &self.mask,
            &self.row_map_as_i32(),
            self.base(),
            self.params.alpha,
        )?;
        let n_local = self.shard.n_local();
        self.rank_padded = rank_new;
        // Pin padding rows back to base (kernel writes base there anyway
        // since their z is 0, but keep the invariant explicit).
        let b = self.base();
        for v in self.rank_padded.iter_mut().skip(n_local) {
            *v = b;
        }
        self.rank = self.rank_padded[..n_local].to_vec();
        self.deltas.push(delta);
        Ok(())
    }

    fn row_map_as_i32(&self) -> &[i32] {
        &self.row_map
    }
}

impl Actor for KernelPrActor {
    type Msg = RankSlice;

    fn on_start(&mut self, ctx: &mut Ctx<RankSlice>) {
        if self.params.iterations > 0 {
            self.contribute_and_allgather(ctx);
        }
    }

    fn on_message(&mut self, _ctx: &mut Ctx<RankSlice>, _from: LocalityId, msg: RankSlice) {
        self.contrib[msg.start..msg.start + msg.vals.len()].copy_from_slice(&msg.vals);
    }

    fn on_barrier(&mut self, ctx: &mut Ctx<RankSlice>, _epoch: u64) {
        self.kernel_update().expect("kernel execution failed");
        self.iter += 1;
        if self.iter < self.params.iterations {
            self.contribute_and_allgather(ctx);
        }
    }
}

/// Build the kernel-offload actors (prepares + compiles one artifact
/// covering every shard) and run.
pub fn run(
    dist: &DistGraph,
    params: PrParams,
    cfg: SimConfig,
    engine: Arc<Mutex<Engine>>,
) -> Result<PrResult> {
    let dist = Arc::new(dist.clone());
    let n = dist.n();
    let range_starts: Vec<usize> = dist
        .shards
        .iter()
        .map(|s| {
            s.contiguous_range().map(|r| r.start).ok_or_else(|| {
                anyhow::anyhow!(
                    "kernel PageRank exchanges contiguous rank slices and requires a \
                     contiguous 1-D partition (block|edge_balanced), got `{}`",
                    dist.partition.name()
                )
            })
        })
        .collect::<Result<_>>()?;

    // Probe ELL geometry: one spec must cover every shard's virtual rows.
    let max_deg_probe = {
        let eng = engine.lock().unwrap();
        // use the widest pagerank artifact slot width available
        eng.manifest()
            .specs()
            .iter()
            .filter(|s| s.kind == "pagerank")
            .map(|s| s.max_deg)
            .max()
            .ok_or_else(|| anyhow::anyhow!("no pagerank artifacts in manifest"))?
    };
    let mut max_virtual = 0usize;
    let mut ells: Vec<EllShard> = Vec::with_capacity(dist.shards.len());
    for s in &dist.shards {
        let ell = s
            .in_ell(max_deg_probe, 0)
            .ok_or_else(|| anyhow::anyhow!("ELL conversion failed"))?;
        max_virtual = max_virtual.max(ell.n_virtual);
        ells.push(ell);
    }
    let spec = engine.lock().unwrap().prepare("pagerank", n, max_virtual)?;

    let base = (1.0 - params.alpha) / n as f32;
    let actors: Vec<KernelPrActor> = dist
        .shards
        .iter()
        .zip(ells)
        .enumerate()
        .map(|(li, (s, _))| {
            let ell = s.in_ell(spec.max_deg, spec.n_rows).expect("ELL re-pad failed");
            let cols = ell.cols.clone();
            let mask = ell.mask.clone();
            let row_map: Vec<i32> = ell
                .row_map
                .iter()
                .map(|&r| if r == u32::MAX { 0 } else { r as i32 })
                .collect();
            // Padding virtual rows have mask 0 -> z contribution 0, so
            // mapping them to row 0 is inert.
            let mut rank_padded = vec![base; spec.n_rows];
            for v in rank_padded.iter_mut().take(s.n_local()) {
                *v = 1.0 / n as f32;
            }
            let mut contrib = vec![0.0f32; spec.n_global];
            contrib.truncate(spec.n_global);
            contrib.iter_mut().for_each(|c| *c = 0.0);
            KernelPrActor {
                shard: Arc::new(s.clone()),
                range_start: range_starts[li],
                dist: Arc::clone(&dist),
                params,
                engine: Arc::clone(&engine),
                spec: spec.clone(),
                ell,
                cols,
                mask,
                row_map,
                rank_padded,
                contrib,
                iter: 0,
                deltas: Vec::new(),
                rank: Vec::new(),
            }
        })
        .collect();
    let (mut actors, report) = crate::amt::run_actors(&cfg, actors);
    for a in &mut actors {
        if a.rank.is_empty() {
            a.rank = a.rank_padded[..a.shard.n_local()].to_vec();
        }
        let _ = &a.ell; // keep geometry alive for inspection
    }
    Ok(super::collect(
        &dist,
        actors.iter().map(|a| (&a.rank, &a.deltas)),
        params,
        report,
    ))
}

// Integration tests for this module live in rust/tests/kernel_artifacts.rs
// (they require `make artifacts`).
