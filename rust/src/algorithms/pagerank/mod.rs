//! PageRank: sequential oracle, the [`PrProgram`] vertex program run on
//! the generic [`engine`](crate::engine) loops (BSP/PBGL baseline and the
//! asynchronous HPX-style variants of paper §4.2), and the
//! kernel-offloaded variant kept as an explicitly specialized engine
//! (AOT-compiled Pallas/XLA local phase).
//!
//! All variants run a fixed iteration count (GAP-benchmark convention)
//! with one global barrier per iteration separating the contribution
//! exchange from the rank update — the paper's "synchronization across
//! iterations". They differ *only* in how contributions travel (the async
//! flavors are one engine parameterized by
//! [`FlushPolicy`](crate::amt::FlushPolicy)):
//!
//! | variant           | remote contributions                     | applied      |
//! |-------------------|------------------------------------------|--------------|
//! | `bsp`             | per-destination combiner, 1 envelope/dst | at barrier   |
//! | `async Unbatched` | one message per remote edge (naive)      | on arrival   |
//! | `async Items/...` | chunked combiner flushes (overlap knob)  | on arrival   |
//! | `async Manual`    | end-of-phase drain (max batching)        | on arrival   |
//! | `kernel`          | contribution-slice allgather             | local kernel |

pub mod kernel;
pub mod program;
pub mod sequential;

pub use program::{PrProgram, PrState};

use std::sync::Arc;

use crate::amt::executor::{ChunkPolicy, Executor};
use crate::amt::{FlushPolicy, SimConfig, SimReport};
use crate::engine;
use crate::graph::DistGraph;

/// Result of a distributed PageRank run.
#[derive(Debug)]
pub struct PrResult {
    /// Final ranks in global vertex order.
    pub ranks: Vec<f32>,
    /// Per-iteration global L1 deltas (convergence trace).
    pub deltas: Vec<f32>,
    /// Timing/traffic report.
    pub report: SimReport,
}

/// Shared PageRank parameters.
#[derive(Debug, Clone, Copy)]
pub struct PrParams {
    /// Damping factor (paper: 0.85).
    pub alpha: f32,
    /// Fixed iteration count.
    pub iterations: u32,
}

impl Default for PrParams {
    fn default() -> Self {
        PrParams { alpha: super::DEFAULT_ALPHA, iterations: 20 }
    }
}

fn to_result(run: engine::ProgramRun<PrState>) -> PrResult {
    PrResult {
        ranks: run.states.iter().map(|s| s.rank).collect(),
        deltas: run.deltas,
        report: run.report,
    }
}

/// Run asynchronous PageRank with the given flush policy (the naive
/// per-edge path is [`FlushPolicy::Unbatched`]).
pub fn run_async(
    dist: &DistGraph,
    params: PrParams,
    policy: FlushPolicy,
    cfg: SimConfig,
) -> PrResult {
    to_result(engine::run_async(PrProgram { params, n: dist.n() }, dist, policy, cfg))
}

/// Run BSP PageRank (serial local update loop).
pub fn run_bsp(dist: &DistGraph, params: PrParams, cfg: SimConfig) -> PrResult {
    to_result(engine::run_bsp(PrProgram { params, n: dist.n() }, dist, cfg))
}

/// Run BSP PageRank with an intra-locality executor for the update loop
/// (the `adaptive_core_chunk_size` ablation hooks in here).
pub fn run_bsp_with_executor(
    dist: &DistGraph,
    params: PrParams,
    cfg: SimConfig,
    executor: Option<Arc<Executor>>,
    chunk_policy: ChunkPolicy,
) -> PrResult {
    to_result(engine::run_bsp_with_executor(
        PrProgram { params, n: dist.n() },
        dist,
        cfg,
        executor,
        chunk_policy,
    ))
}

/// Assemble global ranks + reduced deltas from per-locality results (used
/// by the specialized kernel engine, which bypasses the generic loops).
pub(crate) fn collect<'a>(
    dist: &DistGraph,
    parts: impl Iterator<Item = (&'a Vec<f32>, &'a Vec<f32>)>,
    params: PrParams,
    report: SimReport,
) -> PrResult {
    let mut ranks = vec![0.0f32; dist.n()];
    let mut deltas = vec![0.0f32; params.iterations as usize];
    for (shard, (rank, local_deltas)) in dist.shards.iter().zip(parts) {
        shard.scatter_owned(rank, &mut ranks);
        for (i, d) in local_deltas.iter().enumerate() {
            deltas[i] += d;
        }
    }
    let mut report = report;
    report.partition = dist.partition_stats();
    report.mem = dist.mem_stats();
    PrResult { ranks, deltas, report }
}

/// Compare two rank vectors with an L∞ tolerance.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amt::NetConfig;
    use crate::graph::{generators, PartitionKind};

    fn det() -> SimConfig {
        SimConfig::deterministic(NetConfig::default())
    }

    #[test]
    fn max_abs_diff_basics() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 2.0]), 0.5);
        assert_eq!(max_abs_diff(&[], &[]), 0.0);
    }

    #[test]
    fn bsp_matches_sequential_oracle() {
        for (scale, p) in [(6u32, 1u32), (6, 2), (7, 4), (7, 8)] {
            let g = generators::urand_directed(scale, 6, 42 + p as u64);
            let params = PrParams { alpha: 0.85, iterations: 15 };
            let want = sequential::pagerank(&g, params);
            let dist = DistGraph::block(&g, p);
            let res = run_bsp(&dist, params, det());
            assert!(
                max_abs_diff(&res.ranks, &want) < 1e-5,
                "scale={scale} p={p} diff={}",
                max_abs_diff(&res.ranks, &want)
            );
        }
    }

    #[test]
    fn every_flush_policy_matches_oracle() {
        let g = generators::urand_directed(6, 6, 23);
        let params = PrParams { alpha: 0.85, iterations: 12 };
        let want = sequential::pagerank(&g, params);
        let dist = DistGraph::block(&g, 4);
        for policy in [
            FlushPolicy::Unbatched,
            FlushPolicy::Items(1),
            FlushPolicy::Items(8),
            FlushPolicy::Items(64),
            FlushPolicy::Bytes(256),
            FlushPolicy::Adaptive,
            FlushPolicy::Manual,
        ] {
            let res = run_async(&dist, params, policy, det());
            assert!(max_abs_diff(&res.ranks, &want) < 1e-5, "{policy:?}");
        }
    }

    #[test]
    fn both_engines_match_oracle_under_every_partition_scheme() {
        let g = generators::kron(7, 6, 51);
        let params = PrParams { alpha: 0.85, iterations: 10 };
        let want = sequential::pagerank(&g, params);
        for kind in PartitionKind::all() {
            for p in [2u32, 4, 8] {
                let dist = DistGraph::build_with(&g, kind.build(&g, p));
                for (name, res) in [
                    ("bsp", run_bsp(&dist, params, det())),
                    ("async", run_async(&dist, params, FlushPolicy::Adaptive, det())),
                ] {
                    assert!(
                        max_abs_diff(&res.ranks, &want) < 1e-4,
                        "{name} {kind:?} p={p} diff={}",
                        max_abs_diff(&res.ranks, &want)
                    );
                }
            }
        }
    }

    #[test]
    fn one_barrier_per_iteration() {
        let g = generators::urand_directed(6, 4, 1);
        let dist = DistGraph::block(&g, 4);
        let params = PrParams { alpha: 0.85, iterations: 12 };
        assert_eq!(run_bsp(&dist, params, det()).report.barriers, 12);
        assert_eq!(
            run_async(&dist, params, FlushPolicy::Adaptive, det()).report.barriers,
            12
        );
    }

    #[test]
    fn unbatched_sends_one_message_per_remote_edge() {
        let g = generators::complete(16);
        let dist = DistGraph::block(&g, 4);
        let params = PrParams { alpha: 0.85, iterations: 1 };
        let res = run_async(&dist, params, FlushPolicy::Unbatched, det());
        // complete(16) over 4 localities: each vertex has 12 remote
        // neighbors -> 16 * 12 remote edges.
        assert_eq!(res.report.net.messages, 16 * 12);
        assert_eq!(res.report.net.envelopes, 16 * 12);
        assert_eq!(res.report.agg.envelopes, 16 * 12);
    }

    #[test]
    fn bsp_batches_one_envelope_per_destination_pair() {
        let g = generators::complete(32); // all-to-all traffic
        let dist = DistGraph::block(&g, 4);
        let params = PrParams { alpha: 0.85, iterations: 3 };
        let res = run_bsp(&dist, params, det());
        // per iteration: each of 4 localities sends to 3 others.
        assert_eq!(res.report.net.envelopes, 3 * 4 * 3);
    }

    #[test]
    fn manual_drain_reproduces_bsp_envelope_schedule() {
        // Maximal batching: exactly one envelope per non-empty destination
        // pair per iteration, the same wire schedule the BSP engine
        // produces.
        let g = generators::urand_directed(7, 8, 31);
        let dist = DistGraph::block(&g, 4);
        let params = PrParams { alpha: 0.85, iterations: 5 };
        let manual = run_async(&dist, params, FlushPolicy::Manual, det());
        let bsp = run_bsp(&dist, params, det());
        assert_eq!(manual.report.net.envelopes, bsp.report.net.envelopes);
        assert_eq!(manual.report.agg.envelopes, manual.report.net.envelopes);
    }

    #[test]
    fn manual_drain_sends_far_fewer_envelopes_than_unbatched() {
        let g = generators::urand_directed(7, 8, 29);
        let dist = DistGraph::block(&g, 4);
        let params = PrParams { alpha: 0.85, iterations: 3 };
        let naive = run_async(&dist, params, FlushPolicy::Unbatched, det());
        let opt = run_async(&dist, params, FlushPolicy::Manual, det());
        assert!(opt.report.net.envelopes * 10 < naive.report.net.envelopes);
        assert!(opt.report.makespan_us < naive.report.makespan_us);
    }

    #[test]
    fn deltas_shrink() {
        let g = generators::urand_directed(7, 6, 5);
        let dist = DistGraph::block(&g, 4);
        let params = PrParams { alpha: 0.85, iterations: 20 };
        let res = run_bsp(&dist, params, det());
        assert!(res.deltas.last().unwrap() < &res.deltas[0]);
    }

    #[test]
    fn flush_accounting_matches_wire_traffic() {
        // Every emitted batch is shipped as exactly one envelope, and
        // every folded item reaches the wire exactly once: the aggregation
        // counters in SimReport must equal the network counters.
        let g = generators::urand_directed(6, 6, 37);
        let dist = DistGraph::block(&g, 4);
        let params = PrParams { alpha: 0.85, iterations: 4 };
        for policy in [
            FlushPolicy::Unbatched,
            FlushPolicy::Items(16),
            FlushPolicy::Adaptive,
            FlushPolicy::Manual,
        ] {
            let res = run_async(&dist, params, policy, det());
            assert_eq!(res.report.agg.envelopes, res.report.net.envelopes, "{policy:?}");
            assert_eq!(res.report.agg.sent_items, res.report.net.messages, "{policy:?}");
            assert_eq!(
                res.report.agg.items,
                res.report.agg.folded + res.report.agg.sent_items,
                "{policy:?}"
            );
        }
    }

    #[test]
    fn threaded_update_matches_serial() {
        let g = generators::urand_directed(7, 6, 9);
        let dist = DistGraph::block(&g, 2);
        let params = PrParams { alpha: 0.85, iterations: 10 };
        let serial = run_bsp(&dist, params, det());
        let threaded = run_bsp_with_executor(
            &dist,
            params,
            det(),
            Some(Arc::new(Executor::new(4))),
            ChunkPolicy::Dynamic { chunk: 64 },
        );
        assert!(max_abs_diff(&serial.ranks, &threaded.ranks) < 1e-6);
    }
}
