//! PageRank: sequential oracle, BSP/PBGL baseline, asynchronous HPX-style
//! variants (naive + optimized, paper §4.2), and the kernel-offloaded
//! variant that runs the local rank-update phase on the AOT-compiled
//! Pallas/XLA module.
//!
//! All distributed variants run a fixed iteration count (GAP-benchmark
//! convention) with one global barrier per iteration separating the
//! contribution exchange from the rank update — the paper's
//! "synchronization across iterations". They differ *only* in how
//! contributions travel (the async flavors are one engine parameterized
//! by [`FlushPolicy`](crate::amt::FlushPolicy)):
//!
//! | variant           | remote contributions                     | applied      |
//! |-------------------|------------------------------------------|--------------|
//! | `bsp`             | per-destination combiner, 1 envelope/dst | at barrier   |
//! | `async Unbatched` | one message per remote edge (naive)      | on arrival   |
//! | `async Items/...` | chunked combiner flushes (overlap knob)  | on arrival   |
//! | `async Manual`    | end-of-phase drain (max batching)        | on arrival   |
//! | `kernel`          | contribution-slice allgather             | local kernel |

pub mod async_hpx;
pub mod bsp;
pub mod kernel;
pub mod sequential;

use crate::amt::SimReport;

/// Result of a distributed PageRank run.
#[derive(Debug)]
pub struct PrResult {
    /// Final ranks in global vertex order.
    pub ranks: Vec<f32>,
    /// Per-iteration global L1 deltas (convergence trace).
    pub deltas: Vec<f32>,
    /// Timing/traffic report.
    pub report: SimReport,
}

/// Shared PageRank parameters.
#[derive(Debug, Clone, Copy)]
pub struct PrParams {
    /// Damping factor (paper: 0.85).
    pub alpha: f32,
    /// Fixed iteration count.
    pub iterations: u32,
}

impl Default for PrParams {
    fn default() -> Self {
        PrParams { alpha: super::DEFAULT_ALPHA, iterations: 20 }
    }
}

/// Compare two rank vectors with an L∞ tolerance.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_abs_diff_basics() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 2.0]), 0.5);
        assert_eq!(max_abs_diff(&[], &[]), 0.0);
    }
}
