//! PageRank as a [`VertexProgram`] — the rank-style ([`Mode::Iterate`])
//! exemplar. Messages are `rank/deg` contributions folded by sum; the
//! engines decide *when* they travel and apply (the paper's §4.2 axis):
//! the async engine applies on arrival and overlaps communication with the
//! contribution phase, the BSP engine buffers to the barrier (strict
//! Boost-style batching). [`VertexProgram::step_update`] is the damped
//! rank update run at every iteration barrier.
//!
//! Mirror rows (vertex cuts) stash the master's per-iteration contribution
//! via [`VertexProgram::apply_mirror`] — `inv_deg` becomes 1 so the row's
//! signal is exactly the installed value — and the engines expand them
//! inside the receiving handler, keeping replicated traffic in the same
//! superstep.

use crate::engine::{Mode, ProgramInfo, VertexProgram};
use crate::graph::VertexId;

use super::PrParams;

/// Damped PageRank over a fixed iteration count (GAP convention).
#[derive(Debug, Clone)]
pub struct PrProgram {
    /// Damping factor + iteration count.
    pub params: PrParams,
    /// Global vertex count (normalization).
    pub n: usize,
}

/// Per-row PageRank state.
#[derive(Debug, Clone)]
pub struct PrState {
    /// Current rank (owned rows) or the installed master contribution
    /// (mirror rows, where `inv_deg == 1`).
    pub rank: f32,
    /// Accumulated incoming contributions this iteration.
    pub acc: f32,
    /// `1 / max(global out-degree, 1)`.
    pub inv_deg: f32,
}

impl VertexProgram for PrProgram {
    type State = PrState;
    /// Summed contribution toward a vertex.
    type Msg = f32;

    fn info(&self) -> ProgramInfo {
        ProgramInfo {
            name: "pagerank",
            mode: Mode::Iterate(self.params.iterations),
            needs_weights: false,
            ordered: false,
            item_bytes: 8, // vertex id + contribution
        }
    }

    fn init(&self, _v: VertexId, out_degree: u32) -> PrState {
        PrState {
            rank: 1.0 / self.n as f32,
            acc: 0.0,
            inv_deg: 1.0 / out_degree.max(1) as f32,
        }
    }

    fn seed(&self, _v: VertexId) -> Option<f32> {
        None // Iterate programs are driven by the engine's supersteps
    }

    fn combine(acc: &mut f32, new: f32) {
        *acc += new;
    }

    fn beats(&self, _msg: &f32, _state: &PrState) -> bool {
        true // contributions always accumulate
    }

    fn apply(&self, state: &mut PrState, msg: f32) -> bool {
        state.acc += msg;
        true
    }

    fn signal(&self, state: &PrState) -> f32 {
        state.rank * state.inv_deg
    }

    fn along_edge(&self, _u: VertexId, sig: &f32, _w: f32) -> f32 {
        *sig
    }

    fn apply_mirror(&self, state: &mut PrState, msg: f32) -> bool {
        state.rank = msg;
        state.inv_deg = 1.0;
        true // always expand the mirror's share of the row
    }

    fn step_update(&self, state: &mut PrState) -> f32 {
        let base = (1.0 - self.params.alpha) / self.n as f32;
        let new = base + self.params.alpha * state.acc;
        let delta = (new - state.rank).abs();
        state.rank = new;
        state.acc = 0.0;
        delta
    }

    /// Warm restart: keep the previous rank but refresh the
    /// degree-derived field — carrying a stale `inv_deg` across an edge
    /// insert/delete would mis-split the row's outgoing contribution
    /// forever. `acc` resets; the first warm superstep rebuilds it.
    fn rewarm(&self, prev: &PrState, _v: VertexId, out_degree: u32) -> PrState {
        PrState { rank: prev.rank, acc: 0.0, inv_deg: 1.0 / out_degree.max(1) as f32 }
    }
}
