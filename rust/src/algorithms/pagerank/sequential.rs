//! Sequential PageRank — the textbook power iteration (paper Eq. 1), used
//! as the correctness oracle and the speedup-normalization baseline.

use crate::graph::{Csr, VertexId};

use super::PrParams;

/// Power-iteration PageRank. Vertices with zero out-degree contribute
/// nothing (contribution divides by `max(out_degree, 1)`), matching the
/// distributed implementations and the python `ref.py` oracle.
pub fn pagerank(g: &Csr, params: PrParams) -> Vec<f32> {
    if g.n() == 0 {
        return Vec::new();
    }
    let init = vec![1.0f32 / g.n() as f32; g.n()];
    pagerank_warm(g, params, &init)
}

/// Power iteration from an arbitrary starting vector — the oracle for
/// incremental PageRank, which restarts from the previous run's ranks
/// after a graph mutation instead of from uniform.
pub fn pagerank_warm(g: &Csr, params: PrParams, init: &[f32]) -> Vec<f32> {
    let n = g.n();
    assert_eq!(init.len(), n, "warm-start vector must cover every vertex");
    let base = (1.0 - params.alpha) / n as f32;
    let mut rank = init.to_vec();
    let mut z = vec![0.0f32; n];
    for _ in 0..params.iterations {
        z.iter_mut().for_each(|x| *x = 0.0);
        for u in 0..n as VertexId {
            let deg = g.degree(u).max(1) as f32;
            let c = rank[u as usize] / deg;
            for &v in g.neighbors(u) {
                z[v as usize] += c;
            }
        }
        for v in 0..n {
            rank[v] = base + params.alpha * z[v];
        }
    }
    rank
}

/// Per-iteration L1 deltas alongside the final ranks (convergence trace).
pub fn pagerank_with_trace(g: &Csr, params: PrParams) -> (Vec<f32>, Vec<f32>) {
    let n = g.n();
    if n == 0 {
        return (Vec::new(), Vec::new());
    }
    let base = (1.0 - params.alpha) / n as f32;
    let mut rank = vec![1.0f32 / n as f32; n];
    let mut z = vec![0.0f32; n];
    let mut deltas = Vec::with_capacity(params.iterations as usize);
    for _ in 0..params.iterations {
        z.iter_mut().for_each(|x| *x = 0.0);
        for u in 0..n as VertexId {
            let deg = g.degree(u).max(1) as f32;
            let c = rank[u as usize] / deg;
            for &v in g.neighbors(u) {
                z[v as usize] += c;
            }
        }
        let mut delta = 0.0f32;
        for v in 0..n {
            let new = base + params.alpha * z[v];
            delta += (new - rank[v]).abs();
            rank[v] = new;
        }
        deltas.push(delta);
    }
    (rank, deltas)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn uniform_on_cycle() {
        // On a directed cycle every vertex is symmetric: rank = 1/n forever.
        let g = generators::cycle(8);
        let r = pagerank(&g, PrParams::default());
        for &x in &r {
            assert!((x - 1.0 / 8.0).abs() < 1e-6);
        }
    }

    #[test]
    fn ranks_sum_to_one_without_dangling() {
        let g = generators::complete(6);
        let r = pagerank(&g, PrParams { alpha: 0.85, iterations: 30 });
        let sum: f32 = r.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "sum={sum}");
    }

    #[test]
    fn star_center_dominates() {
        let g = generators::star(10);
        let r = pagerank(&g, PrParams::default());
        for v in 1..10 {
            assert!(r[0] > r[v], "center must outrank leaf {v}");
        }
    }

    #[test]
    fn warm_start_from_uniform_is_the_cold_start() {
        let g = generators::kron(6, 4, 9);
        let params = PrParams { alpha: 0.85, iterations: 12 };
        let uniform = vec![1.0f32 / g.n() as f32; g.n()];
        assert_eq!(pagerank(&g, params), pagerank_warm(&g, params, &uniform));
    }

    #[test]
    fn warm_start_from_fixpoint_stays_put() {
        let g = generators::urand(7, 4, 5);
        let converged = pagerank(&g, PrParams { alpha: 0.85, iterations: 60 });
        let again = pagerank_warm(&g, PrParams { alpha: 0.85, iterations: 5 }, &converged);
        for (a, b) in converged.iter().zip(&again) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn deltas_decrease() {
        let g = generators::urand(7, 4, 3);
        let (_, deltas) = pagerank_with_trace(&g, PrParams { alpha: 0.85, iterations: 25 });
        assert!(deltas.last().unwrap() < &deltas[0]);
        assert!(deltas.last().unwrap() < &1e-3);
    }

    #[test]
    fn matches_dense_formulation() {
        // Cross-check against an explicit dense matrix-vector iteration.
        let g = generators::kron(6, 4, 7);
        let n = g.n();
        let params = PrParams { alpha: 0.85, iterations: 15 };
        let got = pagerank(&g, params);

        let mut rank = vec![1.0f64 / n as f64; n];
        let base = (1.0 - params.alpha as f64) / n as f64;
        for _ in 0..params.iterations {
            let mut z = vec![0.0f64; n];
            for u in 0..n as VertexId {
                let deg = g.degree(u).max(1) as f64;
                for &v in g.neighbors(u) {
                    z[v as usize] += rank[u as usize] / deg;
                }
            }
            for v in 0..n {
                rank[v] = base + params.alpha as f64 * z[v];
            }
        }
        for v in 0..n {
            assert!((got[v] as f64 - rank[v]).abs() < 1e-4, "v={v}");
        }
    }
}
