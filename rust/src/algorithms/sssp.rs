//! Single-source shortest paths — §6 future-work extension.
//!
//! Sequential oracle: binary-heap Dijkstra. Distributed: asynchronous
//! *label-correcting* relaxation (the natural HPX formulation — an improved
//! tentative distance triggers eager remote relaxations, termination is
//! network quiescence) and a BSP Bellman-Ford-style superstep baseline with
//! per-destination combiners, mirroring the BFS/PageRank pairing.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::amt::sim::{Actor, Ctx, LocalityId, Message, SimConfig, SimRuntime};
use crate::amt::SimReport;
use crate::graph::{Csr, DistGraph, Partition1D, VertexId};

/// Result of a distributed SSSP run.
#[derive(Debug)]
pub struct SsspResult {
    /// Tentative distances (`f32::INFINITY` = unreachable).
    pub dist: Vec<f32>,
    /// Runtime report.
    pub report: SimReport,
}

/// Sequential Dijkstra oracle (non-negative weights).
pub fn dijkstra(g: &Csr, source: VertexId) -> Vec<f32> {
    let n = g.n();
    let mut dist = vec![f32::INFINITY; n];
    if n == 0 {
        return dist;
    }
    dist[source as usize] = 0.0;
    // (ordered-dist, vertex) min-heap via Reverse on bit-ordered f32.
    let mut heap: BinaryHeap<Reverse<(u32, VertexId)>> = BinaryHeap::new();
    heap.push(Reverse((0f32.to_bits(), source)));
    while let Some(Reverse((db, u))) = heap.pop() {
        let d = f32::from_bits(db);
        if d > dist[u as usize] {
            continue;
        }
        for (v, w) in g.neighbors_weighted(u) {
            let nd = d + w;
            if nd < dist[v as usize] {
                dist[v as usize] = nd;
                heap.push(Reverse((nd.to_bits(), v)));
            }
        }
    }
    dist
}

/// Relaxation message: `v` may be reachable at distance `d`.
#[derive(Debug, Clone)]
pub struct Relax {
    /// Target vertex (owned by receiver).
    pub v: VertexId,
    /// Proposed distance.
    pub d: f32,
}

impl Message for Relax {
    fn wire_bytes(&self) -> usize {
        8
    }
}

/// Weighted shard view (weights parallel to `Shard::out_neighbors` order).
struct WeightedShard {
    range: std::ops::Range<usize>,
    offsets: Vec<usize>,
    targets: Vec<VertexId>,
    weights: Vec<f32>,
}

impl WeightedShard {
    fn build(g: &Csr, partition: &Partition1D, l: LocalityId) -> Self {
        let range = partition.range_of(l);
        let mut offsets = vec![0usize];
        let mut targets = Vec::new();
        let mut weights = Vec::new();
        for v in range.clone() {
            if g.is_weighted() {
                for (t, w) in g.neighbors_weighted(v as VertexId) {
                    targets.push(t);
                    weights.push(w);
                }
            } else {
                // Unweighted graphs get unit weights (SSSP == hop count).
                for &t in g.neighbors(v as VertexId) {
                    targets.push(t);
                    weights.push(1.0);
                }
            }
            offsets.push(targets.len());
        }
        WeightedShard { range, offsets, targets, weights }
    }

    fn edges(&self, local: usize) -> impl Iterator<Item = (VertexId, f32)> + '_ {
        let r = self.offsets[local]..self.offsets[local + 1];
        self.targets[r.clone()].iter().cloned().zip(self.weights[r].iter().cloned())
    }
}

/// Asynchronous label-correcting SSSP actor.
struct AsyncSsspActor {
    shard: WeightedShard,
    partition: Partition1D,
    source: VertexId,
    /// Owned tentative distances.
    dist: Vec<f32>,
    /// Best distance already *sent* per remote vertex — legitimate local
    /// knowledge (our own send history) that prunes the label-correcting
    /// flood: re-sending a no-better relaxation is pure waste.
    best_sent: Vec<f32>,
}

impl AsyncSsspActor {
    /// Cascade a relaxation through the local shard in (approximate)
    /// priority order — a per-locality Dijkstra wavefront, the standard
    /// trick that keeps unordered label-correcting from re-relaxing
    /// whole subtrees (re-relaxation factor drops from O(diameter) to
    /// ~1 on random weights).
    fn relax_from(&mut self, ctx: &mut Ctx<Relax>, v: VertexId, d: f32) {
        let here = ctx.locality();
        let mut heap: BinaryHeap<Reverse<(u32, VertexId)>> = BinaryHeap::new();
        heap.push(Reverse((d.to_bits(), v)));
        while let Some(Reverse((db, u))) = heap.pop() {
            let du = f32::from_bits(db);
            let lu = u as usize - self.shard.range.start;
            if du >= self.dist[lu] {
                continue;
            }
            self.dist[lu] = du;
            for (w, wt) in self.shard.edges(lu) {
                let nd = du + wt;
                let dst = self.partition.owner(w);
                if dst == here {
                    if nd < self.dist[w as usize - self.shard.range.start] {
                        heap.push(Reverse((nd.to_bits(), w)));
                    }
                } else if nd < self.best_sent[w as usize] {
                    self.best_sent[w as usize] = nd;
                    ctx.send(dst, Relax { v: w, d: nd });
                }
            }
        }
    }
}

impl Actor for AsyncSsspActor {
    type Msg = Relax;

    fn on_start(&mut self, ctx: &mut Ctx<Relax>) {
        if self.partition.owner(self.source) == ctx.locality() {
            let s = self.source;
            self.relax_from(ctx, s, 0.0);
        }
    }

    fn on_message(&mut self, ctx: &mut Ctx<Relax>, _from: LocalityId, msg: Relax) {
        self.relax_from(ctx, msg.v, msg.d);
    }
}

/// Run asynchronous label-correcting SSSP (requires a weighted graph).
pub fn run_async(g: &Csr, dist_graph: &DistGraph, source: VertexId, cfg: SimConfig) -> SsspResult {
    let p = dist_graph.p();
    let actors: Vec<AsyncSsspActor> = (0..p)
        .map(|l| AsyncSsspActor {
            shard: WeightedShard::build(g, &dist_graph.partition, l),
            partition: dist_graph.partition.clone(),
            source,
            dist: vec![f32::INFINITY; dist_graph.partition.len_of(l)],
            best_sent: vec![f32::INFINITY; dist_graph.n()],
        })
        .collect();
    let (actors, report) = SimRuntime::new(cfg).run(actors);
    let mut dist = vec![f32::INFINITY; dist_graph.n()];
    for a in &actors {
        dist[a.shard.range.clone()].copy_from_slice(&a.dist);
    }
    SsspResult { dist, report }
}

/// BSP SSSP messages.
#[derive(Debug, Clone)]
pub enum BspSsspMsg {
    /// Batched relaxations `(vertex, distance)`.
    Relaxations(Vec<(VertexId, f32)>),
    /// Activity count for the termination reduction.
    Count(u64),
    /// Coordinator verdict.
    Continue(bool),
}

impl Message for BspSsspMsg {
    fn wire_bytes(&self) -> usize {
        match self {
            BspSsspMsg::Relaxations(v) => 8 * v.len(),
            BspSsspMsg::Count(_) => 8,
            BspSsspMsg::Continue(_) => 1,
        }
    }

    fn item_count(&self) -> usize {
        match self {
            BspSsspMsg::Relaxations(v) => v.len(),
            _ => 1,
        }
    }
}

#[derive(PartialEq)]
enum Phase {
    AfterRelax,
    AwaitDecision,
}

/// BSP Bellman-Ford-style actor: relax the active set each superstep.
struct BspSsspActor {
    shard: WeightedShard,
    partition: Partition1D,
    source: VertexId,
    dist: Vec<f32>,
    active: Vec<VertexId>,
    /// O(1) membership test for `active` (local index space).
    in_active: Vec<bool>,
    inbox: Vec<(VertexId, f32)>,
    counts_seen: u32,
    counts_sum: u64,
    continue_flag: bool,
    phase: Phase,
}

impl BspSsspActor {
    fn relax_round(&mut self, ctx: &mut Ctx<BspSsspMsg>) {
        let here = ctx.locality();
        let p = ctx.n_localities() as usize;
        let mut outgoing: Vec<Vec<(VertexId, f32)>> = vec![Vec::new(); p];
        let mut activity = 0u64;
        let mut next: Vec<VertexId> = Vec::new();
        let active = std::mem::take(&mut self.active);
        for &u in &active {
            self.in_active[u as usize - self.shard.range.start] = false;
        }
        for &u in &active {
            let lu = u as usize - self.shard.range.start;
            let du = self.dist[lu];
            for (w, wt) in self.shard.edges(lu) {
                let nd = du + wt;
                let dst = self.partition.owner(w);
                if dst == here {
                    let lw = w as usize - self.shard.range.start;
                    if nd < self.dist[lw] {
                        self.dist[lw] = nd;
                        if !self.in_active[lw] {
                            self.in_active[lw] = true;
                            next.push(w);
                        }
                        activity += 1;
                    }
                } else {
                    outgoing[dst as usize].push((w, nd));
                    activity += 1;
                }
            }
        }
        self.active = next;
        for (dst, batch) in outgoing.into_iter().enumerate() {
            if !batch.is_empty() {
                ctx.send(dst as LocalityId, BspSsspMsg::Relaxations(batch));
            }
        }
        ctx.send(0, BspSsspMsg::Count(activity));
        self.phase = Phase::AfterRelax;
        ctx.request_barrier();
    }
}

impl Actor for BspSsspActor {
    type Msg = BspSsspMsg;

    fn on_start(&mut self, ctx: &mut Ctx<BspSsspMsg>) {
        if self.partition.owner(self.source) == ctx.locality() {
            let ls = self.source as usize - self.shard.range.start;
            self.dist[ls] = 0.0;
            self.in_active[ls] = true;
            self.active.push(self.source);
        }
        self.relax_round(ctx);
    }

    fn on_message(&mut self, _ctx: &mut Ctx<BspSsspMsg>, _from: LocalityId, msg: BspSsspMsg) {
        match msg {
            BspSsspMsg::Relaxations(batch) => self.inbox.extend(batch),
            BspSsspMsg::Count(c) => {
                self.counts_seen += 1;
                self.counts_sum += c;
            }
            BspSsspMsg::Continue(b) => self.continue_flag = b,
        }
    }

    fn on_barrier(&mut self, ctx: &mut Ctx<BspSsspMsg>, _epoch: u64) {
        match self.phase {
            Phase::AfterRelax => {
                let inbox = std::mem::take(&mut self.inbox);
                for (v, d) in inbox {
                    let lv = v as usize - self.shard.range.start;
                    if d < self.dist[lv] {
                        self.dist[lv] = d;
                        if !self.in_active[lv] {
                            self.in_active[lv] = true;
                            self.active.push(v);
                        }
                    }
                }
                if ctx.locality() == 0 {
                    let go = self.counts_sum > 0;
                    self.counts_sum = 0;
                    self.counts_seen = 0;
                    for l in 0..ctx.n_localities() {
                        ctx.send(l, BspSsspMsg::Continue(go));
                    }
                }
                self.phase = Phase::AwaitDecision;
                ctx.request_barrier();
            }
            Phase::AwaitDecision => {
                if self.continue_flag {
                    self.relax_round(ctx);
                }
            }
        }
    }
}

/// Run BSP Bellman-Ford-style SSSP (requires a weighted graph).
pub fn run_bsp(g: &Csr, dist_graph: &DistGraph, source: VertexId, cfg: SimConfig) -> SsspResult {
    let p = dist_graph.p();
    let actors: Vec<BspSsspActor> = (0..p)
        .map(|l| BspSsspActor {
            shard: WeightedShard::build(g, &dist_graph.partition, l),
            partition: dist_graph.partition.clone(),
            source,
            dist: vec![f32::INFINITY; dist_graph.partition.len_of(l)],
            active: Vec::new(),
            in_active: vec![false; dist_graph.partition.len_of(l)],
            inbox: Vec::new(),
            counts_seen: 0,
            counts_sum: 0,
            continue_flag: false,
            phase: Phase::AfterRelax,
        })
        .collect();
    let (actors, report) = SimRuntime::new(cfg).run(actors);
    let mut dist = vec![f32::INFINITY; dist_graph.n()];
    for a in &actors {
        dist[a.shard.range.clone()].copy_from_slice(&a.dist);
    }
    SsspResult { dist, report }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amt::NetConfig;
    use crate::graph::generators;

    fn weighted_graph(scale: u32, seed: u64) -> Csr {
        generators::with_random_weights(&generators::urand(scale, 4, seed), 1.0, 10.0, seed + 1)
    }

    fn close(a: &[f32], b: &[f32]) -> bool {
        a.iter().zip(b).all(|(x, y)| {
            (x.is_infinite() && y.is_infinite()) || (x - y).abs() < 1e-3
        })
    }

    #[test]
    fn async_matches_dijkstra() {
        for p in [1u32, 2, 4, 8] {
            let g = weighted_graph(6, 31 + p as u64);
            let want = dijkstra(&g, 0);
            let d = DistGraph::block(&g, p);
            let res = run_async(&g, &d, 0, SimConfig::deterministic(NetConfig::default()));
            assert!(close(&res.dist, &want), "p={p}");
        }
    }

    #[test]
    fn bsp_matches_dijkstra() {
        for p in [1u32, 3, 4] {
            let g = weighted_graph(6, 77 + p as u64);
            let want = dijkstra(&g, 0);
            let d = DistGraph::block(&g, p);
            let res = run_bsp(&g, &d, 0, SimConfig::deterministic(NetConfig::default()));
            assert!(close(&res.dist, &want), "p={p}");
        }
    }

    #[test]
    fn dijkstra_path_graph() {
        let g = generators::with_random_weights(&generators::path(5), 1.0, 1.0 + 1e-6, 1);
        let d = dijkstra(&g, 0);
        for (i, x) in d.iter().enumerate() {
            assert!((x - i as f32).abs() < 1e-4);
        }
    }

    #[test]
    fn unreachable_is_infinite() {
        let mut el = crate::graph::EdgeList::new(3);
        el.push_weighted(0, 1, 1.0);
        let g = Csr::from_edge_list(&el);
        let d = DistGraph::block(&g, 2);
        let res = run_async(&g, &d, 0, SimConfig::deterministic(NetConfig::default()));
        assert_eq!(res.dist[1], 1.0);
        assert!(res.dist[2].is_infinite());
    }
}
