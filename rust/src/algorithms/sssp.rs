//! Single-source shortest paths — §6 future-work extension.
//!
//! Sequential oracle: binary-heap Dijkstra. Distributed: asynchronous
//! *label-correcting* relaxation (the natural HPX formulation — an improved
//! tentative distance triggers eager remote relaxations, termination is
//! network quiescence) and a BSP Bellman-Ford-style superstep baseline,
//! mirroring the BFS/PageRank pairing. Both route their remote
//! relaxations through the shared [`amt::aggregate`](crate::amt::aggregate)
//! combiner (fold = min over tentative distances): the async engine
//! flushes by the configured [`FlushPolicy`] and drains at handler end,
//! the BSP engine drains once per superstep (maximal batching).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::amt::aggregate::{Aggregator, Batch, FlushPolicy};
use crate::amt::sim::{Actor, Ctx, LocalityId, Message, SimConfig, SimRuntime};
use crate::amt::SimReport;
use crate::graph::{Csr, DistGraph, Partition1D, VertexId};

/// Result of a distributed SSSP run.
#[derive(Debug)]
pub struct SsspResult {
    /// Tentative distances (`f32::INFINITY` = unreachable).
    pub dist: Vec<f32>,
    /// Runtime report.
    pub report: SimReport,
}

/// Per-item wire size: vertex id + distance.
const ITEM_BYTES: usize = 8;

/// Keep the smaller tentative distance.
fn min_f32(acc: &mut f32, d: f32) {
    if d < *acc {
        *acc = d;
    }
}

/// Sequential Dijkstra oracle (non-negative weights).
pub fn dijkstra(g: &Csr, source: VertexId) -> Vec<f32> {
    let n = g.n();
    let mut dist = vec![f32::INFINITY; n];
    if n == 0 {
        return dist;
    }
    dist[source as usize] = 0.0;
    // (ordered-dist, vertex) min-heap via Reverse on bit-ordered f32.
    let mut heap: BinaryHeap<Reverse<(u32, VertexId)>> = BinaryHeap::new();
    heap.push(Reverse((0f32.to_bits(), source)));
    while let Some(Reverse((db, u))) = heap.pop() {
        let d = f32::from_bits(db);
        if d > dist[u as usize] {
            continue;
        }
        for (v, w) in g.neighbors_weighted(u) {
            let nd = d + w;
            if nd < dist[v as usize] {
                dist[v as usize] = nd;
                heap.push(Reverse((nd.to_bits(), v)));
            }
        }
    }
    dist
}

/// A flushed combiner of relaxations: `(vertex, best proposed distance)`.
#[derive(Debug, Clone)]
pub struct RelaxBatch(pub Batch<f32>);

impl Message for RelaxBatch {
    fn wire_bytes(&self) -> usize {
        self.0.wire_bytes()
    }

    fn item_count(&self) -> usize {
        self.0.len()
    }
}

/// Weighted shard view (weights parallel to `Shard::out_neighbors` order).
struct WeightedShard {
    range: std::ops::Range<usize>,
    offsets: Vec<usize>,
    targets: Vec<VertexId>,
    weights: Vec<f32>,
}

impl WeightedShard {
    fn build(g: &Csr, partition: &Partition1D, l: LocalityId) -> Self {
        let range = partition.range_of(l);
        let mut offsets = vec![0usize];
        let mut targets = Vec::new();
        let mut weights = Vec::new();
        for v in range.clone() {
            if g.is_weighted() {
                for (t, w) in g.neighbors_weighted(v as VertexId) {
                    targets.push(t);
                    weights.push(w);
                }
            } else {
                // Unweighted graphs get unit weights (SSSP == hop count).
                for &t in g.neighbors(v as VertexId) {
                    targets.push(t);
                    weights.push(1.0);
                }
            }
            offsets.push(targets.len());
        }
        WeightedShard { range, offsets, targets, weights }
    }

    fn edges(&self, local: usize) -> impl Iterator<Item = (VertexId, f32)> + '_ {
        let r = self.offsets[local]..self.offsets[local + 1];
        self.targets[r.clone()].iter().cloned().zip(self.weights[r].iter().cloned())
    }
}

/// Asynchronous label-correcting SSSP actor.
struct AsyncSsspActor {
    shard: WeightedShard,
    partition: Partition1D,
    source: VertexId,
    /// Owned tentative distances.
    dist: Vec<f32>,
    /// Best distance already *sent* per remote vertex — legitimate local
    /// knowledge (our own send history) that prunes the label-correcting
    /// flood: re-sending a no-better relaxation is pure waste.
    best_sent: Vec<f32>,
    /// Remote-relaxation combiner (shared aggregation subsystem).
    agg: Aggregator<f32>,
}

impl AsyncSsspActor {
    /// Cascade a relaxation through the local shard in (approximate)
    /// priority order — a per-locality Dijkstra wavefront, the standard
    /// trick that keeps unordered label-correcting from re-relaxing
    /// whole subtrees (re-relaxation factor drops from O(diameter) to
    /// ~1 on random weights).
    fn relax_from(&mut self, ctx: &mut Ctx<RelaxBatch>, v: VertexId, d: f32) {
        let here = ctx.locality();
        let mut heap: BinaryHeap<Reverse<(u32, VertexId)>> = BinaryHeap::new();
        heap.push(Reverse((d.to_bits(), v)));
        while let Some(Reverse((db, u))) = heap.pop() {
            let du = f32::from_bits(db);
            let lu = u as usize - self.shard.range.start;
            if du >= self.dist[lu] {
                continue;
            }
            self.dist[lu] = du;
            for (w, wt) in self.shard.edges(lu) {
                let nd = du + wt;
                let dst = self.partition.owner(w);
                if dst == here {
                    if nd < self.dist[w as usize - self.shard.range.start] {
                        heap.push(Reverse((nd.to_bits(), w)));
                    }
                } else if nd < self.best_sent[w as usize] {
                    self.best_sent[w as usize] = nd;
                    if let Some(batch) = self.agg.accumulate(dst, w, nd) {
                        ctx.send(dst, RelaxBatch(batch));
                    }
                }
            }
        }
    }

    fn drain(&mut self, ctx: &mut Ctx<RelaxBatch>) {
        for (dst, batch) in self.agg.drain() {
            ctx.send(dst, RelaxBatch(batch));
        }
    }
}

impl Actor for AsyncSsspActor {
    type Msg = RelaxBatch;

    fn on_start(&mut self, ctx: &mut Ctx<RelaxBatch>) {
        if self.partition.owner(self.source) == ctx.locality() {
            let s = self.source;
            self.relax_from(ctx, s, 0.0);
            self.drain(ctx);
        }
    }

    fn on_message(&mut self, ctx: &mut Ctx<RelaxBatch>, _from: LocalityId, msg: RelaxBatch) {
        for (v, d) in msg.0.items {
            self.relax_from(ctx, v, d);
        }
        self.drain(ctx);
    }
}

/// Run asynchronous label-correcting SSSP with the default
/// [`FlushPolicy::Adaptive`] aggregation.
pub fn run_async(g: &Csr, dist_graph: &DistGraph, source: VertexId, cfg: SimConfig) -> SsspResult {
    run_async_with(g, dist_graph, source, FlushPolicy::Adaptive, cfg)
}

/// Run asynchronous label-correcting SSSP with an explicit flush policy.
pub fn run_async_with(
    g: &Csr,
    dist_graph: &DistGraph,
    source: VertexId,
    policy: FlushPolicy,
    cfg: SimConfig,
) -> SsspResult {
    let p = dist_graph.p();
    let ranges = dist_graph.partition.ranges();
    let actors: Vec<AsyncSsspActor> = (0..p)
        .map(|l| AsyncSsspActor {
            shard: WeightedShard::build(g, &dist_graph.partition, l),
            partition: dist_graph.partition.clone(),
            source,
            dist: vec![f32::INFINITY; dist_graph.partition.len_of(l)],
            best_sent: vec![f32::INFINITY; dist_graph.n()],
            agg: Aggregator::new(&ranges, l, policy, &cfg.net, ITEM_BYTES, min_f32),
        })
        .collect();
    let (actors, mut report) = SimRuntime::new(cfg).run(actors);
    for a in &actors {
        report.agg.merge(a.agg.stats());
    }
    let mut dist = vec![f32::INFINITY; dist_graph.n()];
    for a in &actors {
        dist[a.shard.range.clone()].copy_from_slice(&a.dist);
    }
    SsspResult { dist, report }
}

/// BSP SSSP messages.
#[derive(Debug, Clone)]
pub enum BspSsspMsg {
    /// Batched relaxations (one folded min per destination vertex).
    Relaxations(Batch<f32>),
    /// Activity count for the termination reduction.
    Count(u64),
    /// Coordinator verdict.
    Continue(bool),
}

impl Message for BspSsspMsg {
    fn wire_bytes(&self) -> usize {
        match self {
            BspSsspMsg::Relaxations(b) => b.wire_bytes(),
            BspSsspMsg::Count(_) => 8,
            BspSsspMsg::Continue(_) => 1,
        }
    }

    fn item_count(&self) -> usize {
        match self {
            BspSsspMsg::Relaxations(b) => b.len(),
            _ => 1,
        }
    }
}

#[derive(PartialEq)]
enum Phase {
    AfterRelax,
    AwaitDecision,
}

/// BSP Bellman-Ford-style actor: relax the active set each superstep.
struct BspSsspActor {
    shard: WeightedShard,
    partition: Partition1D,
    source: VertexId,
    dist: Vec<f32>,
    active: Vec<VertexId>,
    /// O(1) membership test for `active` (local index space).
    in_active: Vec<bool>,
    inbox: Vec<(VertexId, f32)>,
    counts_seen: u32,
    counts_sum: u64,
    continue_flag: bool,
    phase: Phase,
    /// Superstep combiner: folded mins, drained once per round.
    agg: Aggregator<f32>,
}

impl BspSsspActor {
    fn relax_round(&mut self, ctx: &mut Ctx<BspSsspMsg>) {
        let here = ctx.locality();
        let mut activity = 0u64;
        let mut next: Vec<VertexId> = Vec::new();
        let active = std::mem::take(&mut self.active);
        for &u in &active {
            self.in_active[u as usize - self.shard.range.start] = false;
        }
        for &u in &active {
            let lu = u as usize - self.shard.range.start;
            let du = self.dist[lu];
            for (w, wt) in self.shard.edges(lu) {
                let nd = du + wt;
                let dst = self.partition.owner(w);
                if dst == here {
                    let lw = w as usize - self.shard.range.start;
                    if nd < self.dist[lw] {
                        self.dist[lw] = nd;
                        if !self.in_active[lw] {
                            self.in_active[lw] = true;
                            next.push(w);
                        }
                        activity += 1;
                    }
                } else {
                    // Manual policy: accumulate never auto-flushes.
                    if let Some(batch) = self.agg.accumulate(dst, w, nd) {
                        ctx.send(dst, BspSsspMsg::Relaxations(batch));
                    }
                    activity += 1;
                }
            }
        }
        self.active = next;
        for (dst, batch) in self.agg.drain() {
            ctx.send(dst, BspSsspMsg::Relaxations(batch));
        }
        ctx.send(0, BspSsspMsg::Count(activity));
        self.phase = Phase::AfterRelax;
        ctx.request_barrier();
    }
}

impl Actor for BspSsspActor {
    type Msg = BspSsspMsg;

    fn on_start(&mut self, ctx: &mut Ctx<BspSsspMsg>) {
        if self.partition.owner(self.source) == ctx.locality() {
            let ls = self.source as usize - self.shard.range.start;
            self.dist[ls] = 0.0;
            self.in_active[ls] = true;
            self.active.push(self.source);
        }
        self.relax_round(ctx);
    }

    fn on_message(&mut self, _ctx: &mut Ctx<BspSsspMsg>, _from: LocalityId, msg: BspSsspMsg) {
        match msg {
            BspSsspMsg::Relaxations(batch) => self.inbox.extend(batch.items),
            BspSsspMsg::Count(c) => {
                self.counts_seen += 1;
                self.counts_sum += c;
            }
            BspSsspMsg::Continue(b) => self.continue_flag = b,
        }
    }

    fn on_barrier(&mut self, ctx: &mut Ctx<BspSsspMsg>, _epoch: u64) {
        match self.phase {
            Phase::AfterRelax => {
                let inbox = std::mem::take(&mut self.inbox);
                for (v, d) in inbox {
                    let lv = v as usize - self.shard.range.start;
                    if d < self.dist[lv] {
                        self.dist[lv] = d;
                        if !self.in_active[lv] {
                            self.in_active[lv] = true;
                            self.active.push(v);
                        }
                    }
                }
                if ctx.locality() == 0 {
                    debug_assert_eq!(self.counts_seen, ctx.n_localities());
                    let go = self.counts_sum > 0;
                    self.counts_sum = 0;
                    self.counts_seen = 0;
                    for l in 0..ctx.n_localities() {
                        ctx.send(l, BspSsspMsg::Continue(go));
                    }
                }
                self.phase = Phase::AwaitDecision;
                ctx.request_barrier();
            }
            Phase::AwaitDecision => {
                if self.continue_flag {
                    self.relax_round(ctx);
                }
            }
        }
    }
}

/// Run BSP Bellman-Ford-style SSSP (requires a weighted graph).
pub fn run_bsp(g: &Csr, dist_graph: &DistGraph, source: VertexId, cfg: SimConfig) -> SsspResult {
    let p = dist_graph.p();
    let ranges = dist_graph.partition.ranges();
    let actors: Vec<BspSsspActor> = (0..p)
        .map(|l| BspSsspActor {
            shard: WeightedShard::build(g, &dist_graph.partition, l),
            partition: dist_graph.partition.clone(),
            source,
            dist: vec![f32::INFINITY; dist_graph.partition.len_of(l)],
            active: Vec::new(),
            in_active: vec![false; dist_graph.partition.len_of(l)],
            inbox: Vec::new(),
            counts_seen: 0,
            counts_sum: 0,
            continue_flag: false,
            phase: Phase::AfterRelax,
            agg: Aggregator::new(&ranges, l, FlushPolicy::Manual, &cfg.net, ITEM_BYTES, min_f32),
        })
        .collect();
    let (actors, mut report) = SimRuntime::new(cfg).run(actors);
    for a in &actors {
        report.agg.merge(a.agg.stats());
    }
    let mut dist = vec![f32::INFINITY; dist_graph.n()];
    for a in &actors {
        dist[a.shard.range.clone()].copy_from_slice(&a.dist);
    }
    SsspResult { dist, report }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amt::NetConfig;
    use crate::graph::generators;

    fn det() -> SimConfig {
        SimConfig::deterministic(NetConfig::default())
    }

    fn weighted_graph(scale: u32, seed: u64) -> Csr {
        generators::with_random_weights(&generators::urand(scale, 4, seed), 1.0, 10.0, seed + 1)
    }

    fn close(a: &[f32], b: &[f32]) -> bool {
        a.iter().zip(b).all(|(x, y)| {
            (x.is_infinite() && y.is_infinite()) || (x - y).abs() < 1e-3
        })
    }

    #[test]
    fn async_matches_dijkstra() {
        for p in [1u32, 2, 4, 8] {
            let g = weighted_graph(6, 31 + p as u64);
            let want = dijkstra(&g, 0);
            let d = DistGraph::block(&g, p);
            let res = run_async(&g, &d, 0, SimConfig::deterministic(NetConfig::default()));
            assert!(close(&res.dist, &want), "p={p}");
        }
    }

    #[test]
    fn async_matches_dijkstra_under_every_policy() {
        let g = weighted_graph(6, 53);
        let want = dijkstra(&g, 0);
        let d = DistGraph::block(&g, 4);
        for policy in [
            FlushPolicy::Unbatched,
            FlushPolicy::Items(8),
            FlushPolicy::Adaptive,
            FlushPolicy::Manual,
        ] {
            let res = run_async_with(&g, &d, 0, policy, det());
            assert!(close(&res.dist, &want), "{policy:?}");
        }
    }

    #[test]
    fn bsp_matches_dijkstra() {
        for p in [1u32, 3, 4] {
            let g = weighted_graph(6, 77 + p as u64);
            let want = dijkstra(&g, 0);
            let d = DistGraph::block(&g, p);
            let res = run_bsp(&g, &d, 0, SimConfig::deterministic(NetConfig::default()));
            assert!(close(&res.dist, &want), "p={p}");
        }
    }

    #[test]
    fn bsp_folds_duplicate_relaxations_per_superstep() {
        // The combiner ships at most one relaxation per destination vertex
        // per superstep, so wire items never exceed aggregation input.
        let g = weighted_graph(6, 91);
        let d = DistGraph::block(&g, 4);
        let res = run_bsp(&g, &d, 0, SimConfig::deterministic(NetConfig::default()));
        assert_eq!(res.report.agg.sent_items + res.report.agg.folded, res.report.agg.items);
        assert_eq!(res.report.agg.envelopes, res.report.agg.drain_flushes);
    }

    #[test]
    fn dijkstra_path_graph() {
        let g = generators::with_random_weights(&generators::path(5), 1.0, 1.0 + 1e-6, 1);
        let d = dijkstra(&g, 0);
        for (i, x) in d.iter().enumerate() {
            assert!((x - i as f32).abs() < 1e-4);
        }
    }

    #[test]
    fn unreachable_is_infinite() {
        let mut el = crate::graph::EdgeList::new(3);
        el.push_weighted(0, 1, 1.0);
        let g = Csr::from_edge_list(&el);
        let d = DistGraph::block(&g, 2);
        let res = run_async(&g, &d, 0, SimConfig::deterministic(NetConfig::default()));
        assert_eq!(res.dist[1], 1.0);
        assert!(res.dist[2].is_infinite());
    }
}
