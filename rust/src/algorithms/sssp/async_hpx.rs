//! Asynchronous label-correcting SSSP (the natural HPX formulation).
//!
//! An improved tentative distance triggers eager remote relaxations;
//! termination is network quiescence. Remote relaxations route through the
//! shared [`Aggregator`] min-fold (keyed by the destination's master
//! index), flushed by the configured [`FlushPolicy`] and drained at
//! handler end. Scheme-generic: under a vertex cut the per-locality
//! wavefront runs over owned *and* mirror rows — a ghost-row improvement
//! notifies the master, a master improvement scatters to the vertex's
//! mirrors so their share of the row relaxes too. Monotone min-folding
//! keeps the flood finite and order-independent.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Arc;

use crate::amt::aggregate::{Aggregator, Batch, FlushPolicy};
use crate::amt::sim::{Actor, Ctx, LocalityId, Message, SimConfig, SimRuntime};
use crate::amt::WorkStats;
use crate::graph::{Csr, DistGraph, Shard, VertexId};

use super::{check_graph_matches, min_f32, SsspResult, ITEM_BYTES};

/// Async SSSP wire format: relaxation batches toward masters or distance
/// scatter toward mirrors — both `(destination-local slot, distance)`.
#[derive(Debug, Clone)]
pub enum SsspMsg {
    /// `(master index, best proposed distance)`.
    ToMaster(Batch<f32>),
    /// `(ghost slot, master's improved distance)`.
    ToMirror(Batch<f32>),
}

impl Message for SsspMsg {
    fn wire_bytes(&self) -> usize {
        match self {
            SsspMsg::ToMaster(b) => b.wire_bytes(),
            SsspMsg::ToMirror(b) => b.wire_bytes(),
        }
    }

    fn item_count(&self) -> usize {
        match self {
            SsspMsg::ToMaster(b) => b.len(),
            SsspMsg::ToMirror(b) => b.len(),
        }
    }
}

/// Asynchronous label-correcting SSSP actor.
struct AsyncSsspActor {
    shard: Arc<Shard>,
    source: VertexId,
    /// Tentative distance per local row — owned rows authoritative, ghost
    /// rows cache the best value seen/sent (doubles as the send-dedup
    /// that prunes the label-correcting flood).
    dist: Vec<f32>,
    /// Master-bound relaxation combiner (shared aggregation subsystem).
    agg: Aggregator<f32>,
    /// Mirror-bound distance-scatter combiner (idle under 1-D schemes).
    mirror_agg: Aggregator<f32>,
    /// Reusable wavefront heap: (bit-ordered distance, local row).
    heap: BinaryHeap<Reverse<(u32, usize)>>,
    /// Relaxation counters (total edge proposals / strict improvements).
    work: WorkStats,
}

impl AsyncSsspActor {
    /// Drain the wavefront heap: cascade relaxations through the local row
    /// space in (approximate) priority order — a per-locality Dijkstra
    /// wavefront, the standard trick that keeps unordered label-correcting
    /// from re-relaxing whole subtrees.
    fn relax(&mut self, ctx: &mut Ctx<SsspMsg>) {
        let n_owned = self.shard.n_local();
        while let Some(Reverse((db, row))) = self.heap.pop() {
            let du = f32::from_bits(db);
            if du >= self.dist[row] {
                continue;
            }
            self.dist[row] = du;
            if row < n_owned {
                self.work.useful_relaxations += 1;
                for &(dst, gi) in self.shard.mirrors(row) {
                    if let Some(b) = self.mirror_agg.accumulate(dst, gi, du) {
                        ctx.send(dst, SsspMsg::ToMirror(b));
                    }
                }
            } else {
                let gi = row - n_owned;
                let dst = self.shard.ghost_owner[gi];
                let idx = self.shard.ghost_master_index[gi];
                if let Some(b) = self.agg.accumulate(dst, idx, du) {
                    ctx.send(dst, SsspMsg::ToMaster(b));
                }
            }
            let shard = Arc::clone(&self.shard);
            for (t, wt) in shard.row_edges(row) {
                self.work.relaxations += 1;
                let nd = du + wt;
                if nd < self.dist[t as usize] {
                    self.heap.push(Reverse((nd.to_bits(), t as usize)));
                }
            }
        }
    }

    fn drain(&mut self, ctx: &mut Ctx<SsspMsg>) {
        for (dst, batch) in self.agg.drain() {
            ctx.send(dst, SsspMsg::ToMaster(batch));
        }
        for (dst, batch) in self.mirror_agg.drain() {
            ctx.send(dst, SsspMsg::ToMirror(batch));
        }
    }
}

impl Actor for AsyncSsspActor {
    type Msg = SsspMsg;

    fn on_start(&mut self, ctx: &mut Ctx<SsspMsg>) {
        if let Ok(r) = self.shard.owned_ids.binary_search(&self.source) {
            self.heap.push(Reverse((0f32.to_bits(), r)));
            self.relax(ctx);
            self.drain(ctx);
        }
    }

    fn on_message(&mut self, ctx: &mut Ctx<SsspMsg>, _from: LocalityId, msg: SsspMsg) {
        let n_owned = self.shard.n_local();
        match msg {
            SsspMsg::ToMaster(b) => {
                for (idx, d) in b.items {
                    self.heap.push(Reverse((d.to_bits(), idx as usize)));
                }
            }
            SsspMsg::ToMirror(b) => {
                // The value came *from* the master: install it directly
                // (no echo back) and expand the locally homed edges.
                for (gi, d) in b.items {
                    let row = n_owned + gi as usize;
                    if d < self.dist[row] {
                        self.dist[row] = d;
                        let shard = Arc::clone(&self.shard);
                        for (t, wt) in shard.row_edges(row) {
                            self.work.relaxations += 1;
                            let nd = d + wt;
                            if nd < self.dist[t as usize] {
                                self.heap.push(Reverse((nd.to_bits(), t as usize)));
                            }
                        }
                    }
                }
            }
        }
        self.relax(ctx);
        self.drain(ctx);
    }
}

/// Run asynchronous label-correcting SSSP with the default
/// [`FlushPolicy::Adaptive`] aggregation.
pub fn run_async(g: &Csr, dist_graph: &DistGraph, source: VertexId, cfg: SimConfig) -> SsspResult {
    run_async_with(g, dist_graph, source, FlushPolicy::Adaptive, cfg)
}

/// Run asynchronous label-correcting SSSP with an explicit flush policy.
pub fn run_async_with(
    g: &Csr,
    dist_graph: &DistGraph,
    source: VertexId,
    policy: FlushPolicy,
    cfg: SimConfig,
) -> SsspResult {
    check_graph_matches(g, dist_graph);
    let actors: Vec<AsyncSsspActor> = dist_graph
        .shards
        .iter()
        .map(|s| AsyncSsspActor {
            shard: Arc::new(s.clone()),
            source,
            dist: vec![f32::INFINITY; s.n_rows()],
            agg: Aggregator::new(
                dist_graph.owned_counts(),
                s.locality,
                policy,
                &cfg.net,
                ITEM_BYTES,
                min_f32,
            ),
            mirror_agg: Aggregator::new(
                dist_graph.ghost_counts(),
                s.locality,
                policy,
                &cfg.net,
                ITEM_BYTES,
                min_f32,
            ),
            heap: BinaryHeap::new(),
            work: WorkStats::default(),
        })
        .collect();
    let (actors, mut report) = SimRuntime::new(cfg).run(actors);
    for a in &actors {
        report.agg.merge(a.agg.stats());
        report.agg.merge(a.mirror_agg.stats());
        report.work.merge(&a.work);
    }
    report.partition = dist_graph.partition_stats();
    let mut dist = vec![f32::INFINITY; dist_graph.n()];
    for a in &actors {
        a.shard.scatter_owned(&a.dist[..a.shard.n_local()], &mut dist);
    }
    SsspResult { dist, report }
}
