//! Asynchronous label-correcting SSSP (the natural HPX formulation).
//!
//! An improved tentative distance triggers eager remote relaxations;
//! termination is network quiescence. Remote relaxations route through the
//! shared [`Aggregator`] min-fold, flushed by the configured
//! [`FlushPolicy`] and drained at handler end.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::amt::aggregate::{Aggregator, Batch, FlushPolicy};
use crate::amt::sim::{Actor, Ctx, LocalityId, Message, SimConfig, SimRuntime};
use crate::amt::WorkStats;
use crate::graph::{Csr, DistGraph, Partition1D, VertexId};

use super::{min_f32, SsspResult, WeightedShard, ITEM_BYTES};

/// A flushed combiner of relaxations: `(vertex, best proposed distance)`.
#[derive(Debug, Clone)]
pub struct RelaxBatch(pub Batch<f32>);

impl Message for RelaxBatch {
    fn wire_bytes(&self) -> usize {
        self.0.wire_bytes()
    }

    fn item_count(&self) -> usize {
        self.0.len()
    }
}

/// Asynchronous label-correcting SSSP actor.
struct AsyncSsspActor {
    shard: WeightedShard,
    partition: Partition1D,
    source: VertexId,
    /// Owned tentative distances.
    dist: Vec<f32>,
    /// Best distance already *sent* per remote vertex — legitimate local
    /// knowledge (our own send history) that prunes the label-correcting
    /// flood: re-sending a no-better relaxation is pure waste.
    best_sent: Vec<f32>,
    /// Remote-relaxation combiner (shared aggregation subsystem).
    agg: Aggregator<f32>,
    /// Relaxation counters (total edge proposals / strict improvements).
    work: WorkStats,
}

impl AsyncSsspActor {
    /// Cascade a relaxation through the local shard in (approximate)
    /// priority order — a per-locality Dijkstra wavefront, the standard
    /// trick that keeps unordered label-correcting from re-relaxing
    /// whole subtrees (re-relaxation factor drops from O(diameter) to
    /// ~1 on random weights).
    fn relax_from(&mut self, ctx: &mut Ctx<RelaxBatch>, v: VertexId, d: f32) {
        let here = ctx.locality();
        let mut heap: BinaryHeap<Reverse<(u32, VertexId)>> = BinaryHeap::new();
        heap.push(Reverse((d.to_bits(), v)));
        while let Some(Reverse((db, u))) = heap.pop() {
            let du = f32::from_bits(db);
            let lu = u as usize - self.shard.range.start;
            if du >= self.dist[lu] {
                continue;
            }
            self.dist[lu] = du;
            self.work.useful_relaxations += 1;
            for (w, wt) in self.shard.edges(lu) {
                self.work.relaxations += 1;
                let nd = du + wt;
                let dst = self.partition.owner(w);
                if dst == here {
                    if nd < self.dist[w as usize - self.shard.range.start] {
                        heap.push(Reverse((nd.to_bits(), w)));
                    }
                } else if nd < self.best_sent[w as usize] {
                    self.best_sent[w as usize] = nd;
                    if let Some(batch) = self.agg.accumulate(dst, w, nd) {
                        ctx.send(dst, RelaxBatch(batch));
                    }
                }
            }
        }
    }

    fn drain(&mut self, ctx: &mut Ctx<RelaxBatch>) {
        for (dst, batch) in self.agg.drain() {
            ctx.send(dst, RelaxBatch(batch));
        }
    }
}

impl Actor for AsyncSsspActor {
    type Msg = RelaxBatch;

    fn on_start(&mut self, ctx: &mut Ctx<RelaxBatch>) {
        if self.partition.owner(self.source) == ctx.locality() {
            let s = self.source;
            self.relax_from(ctx, s, 0.0);
            self.drain(ctx);
        }
    }

    fn on_message(&mut self, ctx: &mut Ctx<RelaxBatch>, _from: LocalityId, msg: RelaxBatch) {
        for (v, d) in msg.0.items {
            self.relax_from(ctx, v, d);
        }
        self.drain(ctx);
    }
}

/// Run asynchronous label-correcting SSSP with the default
/// [`FlushPolicy::Adaptive`] aggregation.
pub fn run_async(g: &Csr, dist_graph: &DistGraph, source: VertexId, cfg: SimConfig) -> SsspResult {
    run_async_with(g, dist_graph, source, FlushPolicy::Adaptive, cfg)
}

/// Run asynchronous label-correcting SSSP with an explicit flush policy.
pub fn run_async_with(
    g: &Csr,
    dist_graph: &DistGraph,
    source: VertexId,
    policy: FlushPolicy,
    cfg: SimConfig,
) -> SsspResult {
    let p = dist_graph.p();
    let ranges = dist_graph.partition.ranges();
    let actors: Vec<AsyncSsspActor> = (0..p)
        .map(|l| AsyncSsspActor {
            shard: WeightedShard::build(g, &dist_graph.partition, l),
            partition: dist_graph.partition.clone(),
            source,
            dist: vec![f32::INFINITY; dist_graph.partition.len_of(l)],
            best_sent: vec![f32::INFINITY; dist_graph.n()],
            agg: Aggregator::new(&ranges, l, policy, &cfg.net, ITEM_BYTES, min_f32),
            work: WorkStats::default(),
        })
        .collect();
    let (actors, mut report) = SimRuntime::new(cfg).run(actors);
    for a in &actors {
        report.agg.merge(a.agg.stats());
        report.work.merge(&a.work);
    }
    let mut dist = vec![f32::INFINITY; dist_graph.n()];
    for a in &actors {
        dist[a.shard.range.clone()].copy_from_slice(&a.dist);
    }
    SsspResult { dist, report }
}
