//! BSP Bellman-Ford-style SSSP: relax the active set each superstep.
//!
//! The PBGL/Boost baseline style: supersteps, per-superstep combiner
//! drains (maximal batching via [`FlushPolicy::Manual`]), and a
//! coordinator-driven termination reduction.
//!
//! Scheme-generic: the active set holds local rows (owned and, under a
//! vertex cut, mirror rows). A master improvement scatters the new
//! distance to the vertex's mirrors through a second Manual-policy
//! combiner; the mirror re-activates the row so its share of the edges
//! relaxes next superstep. Monotone min-folding makes the extra rounds
//! converge to the Bellman-Ford fixpoint.

use std::sync::Arc;

use crate::amt::aggregate::{Aggregator, Batch, FlushPolicy};
use crate::amt::sim::{Actor, Ctx, LocalityId, Message, SimConfig, SimRuntime};
use crate::amt::WorkStats;
use crate::graph::{Csr, DistGraph, Shard, VertexId};

use super::{check_graph_matches, min_f32, SsspResult, ITEM_BYTES};

/// BSP SSSP messages.
#[derive(Debug, Clone)]
pub enum BspSsspMsg {
    /// Batched relaxations toward masters: `(master index, min distance)`.
    Relaxations(Batch<f32>),
    /// Batched distance scatter toward mirrors: `(ghost slot, distance)`.
    MirrorDists(Batch<f32>),
    /// Activity count for the termination reduction.
    Count(u64),
    /// Coordinator verdict.
    Continue(bool),
}

impl Message for BspSsspMsg {
    fn wire_bytes(&self) -> usize {
        match self {
            BspSsspMsg::Relaxations(b) => b.wire_bytes(),
            BspSsspMsg::MirrorDists(b) => b.wire_bytes(),
            BspSsspMsg::Count(_) => 8,
            BspSsspMsg::Continue(_) => 1,
        }
    }

    fn item_count(&self) -> usize {
        match self {
            BspSsspMsg::Relaxations(b) => b.len(),
            BspSsspMsg::MirrorDists(b) => b.len(),
            _ => 1,
        }
    }
}

#[derive(PartialEq)]
enum Phase {
    AfterRelax,
    AwaitDecision,
}

/// BSP Bellman-Ford-style actor: relax the active set each superstep.
struct BspSsspActor {
    shard: Arc<Shard>,
    source: VertexId,
    /// Tentative distance per local row (owned authoritative, ghost
    /// cached from master scatter).
    dist: Vec<f32>,
    active: Vec<u32>,
    /// O(1) membership test for `active` (local row space).
    in_active: Vec<bool>,
    inbox: Vec<(u32, f32)>,
    counts_seen: u32,
    counts_sum: u64,
    /// Activity earned at the barrier (scatter queued by inbox
    /// improvements), folded into the next Count.
    pending_activity: u64,
    continue_flag: bool,
    phase: Phase,
    /// Superstep combiner toward masters: folded mins, drained per round.
    agg: Aggregator<f32>,
    /// Superstep combiner toward mirrors (distance scatter).
    mirror_agg: Aggregator<f32>,
    /// Relaxation counters (total edge proposals / strict improvements).
    work: WorkStats,
}

impl BspSsspActor {
    fn activate(&mut self, row: usize) {
        if !self.in_active[row] {
            self.in_active[row] = true;
            self.active.push(row as u32);
        }
    }

    /// Apply `nd` to the owned `row`; on improvement, activate it and
    /// queue the scatter to its mirrors. Returns whether it improved.
    fn improve_owned(&mut self, row: usize, nd: f32) -> bool {
        if nd >= self.dist[row] {
            return false;
        }
        self.dist[row] = nd;
        self.work.useful_relaxations += 1;
        self.activate(row);
        let shard = Arc::clone(&self.shard);
        for &(dst, gi) in shard.mirrors(row) {
            // Manual policy: accumulate never auto-flushes.
            let flushed = self.mirror_agg.accumulate(dst, gi, nd);
            debug_assert!(flushed.is_none());
        }
        true
    }

    fn relax_round(&mut self, ctx: &mut Ctx<BspSsspMsg>) {
        let n_owned = self.shard.n_local();
        let mut activity = self.pending_activity;
        self.pending_activity = 0;
        let active = std::mem::take(&mut self.active);
        for &row in &active {
            self.in_active[row as usize] = false;
        }
        for &row in &active {
            let du = self.dist[row as usize];
            let shard = Arc::clone(&self.shard);
            for (t, wt) in shard.row_edges(row as usize) {
                self.work.relaxations += 1;
                let nd = du + wt;
                let t = t as usize;
                if t < n_owned {
                    if self.improve_owned(t, nd) {
                        activity += 1;
                    }
                } else {
                    let gi = t - n_owned;
                    // Manual policy: accumulate never auto-flushes.
                    let flushed = self.agg.accumulate(
                        shard.ghost_owner[gi],
                        shard.ghost_master_index[gi],
                        nd,
                    );
                    debug_assert!(flushed.is_none());
                    activity += 1;
                }
            }
        }
        for (dst, batch) in self.agg.drain() {
            ctx.send(dst, BspSsspMsg::Relaxations(batch));
        }
        for (dst, batch) in self.mirror_agg.drain() {
            ctx.send(dst, BspSsspMsg::MirrorDists(batch));
            activity += 1;
        }
        ctx.send(0, BspSsspMsg::Count(activity));
        self.phase = Phase::AfterRelax;
        ctx.request_barrier();
    }
}

impl Actor for BspSsspActor {
    type Msg = BspSsspMsg;

    fn on_start(&mut self, ctx: &mut Ctx<BspSsspMsg>) {
        if let Ok(r) = self.shard.owned_ids.binary_search(&self.source) {
            // Source setup is an improvement like any other: distance 0,
            // activation, and mirror scatter (counted into this round).
            if self.improve_owned(r, 0.0) {
                self.work.useful_relaxations -= 1; // setup, not a relaxation
            }
        }
        self.relax_round(ctx);
    }

    fn on_message(&mut self, _ctx: &mut Ctx<BspSsspMsg>, _from: LocalityId, msg: BspSsspMsg) {
        match msg {
            BspSsspMsg::Relaxations(batch) => self.inbox.extend(batch.items),
            BspSsspMsg::MirrorDists(batch) => {
                let n_owned = self.shard.n_local();
                for (gi, d) in batch.items {
                    let row = n_owned + gi as usize;
                    if d < self.dist[row] {
                        self.dist[row] = d;
                        self.activate(row);
                    }
                }
            }
            BspSsspMsg::Count(c) => {
                self.counts_seen += 1;
                self.counts_sum += c;
            }
            BspSsspMsg::Continue(b) => self.continue_flag = b,
        }
    }

    fn on_barrier(&mut self, ctx: &mut Ctx<BspSsspMsg>, _epoch: u64) {
        match self.phase {
            Phase::AfterRelax => {
                let inbox = std::mem::take(&mut self.inbox);
                for (idx, d) in inbox {
                    if self.improve_owned(idx as usize, d) {
                        // Scatter queued here ships with the next round's
                        // drain; keep the run alive until it lands.
                        self.pending_activity += 1;
                    }
                }
                if ctx.locality() == 0 {
                    debug_assert_eq!(self.counts_seen, ctx.n_localities());
                    let go = self.counts_sum > 0;
                    self.counts_sum = 0;
                    self.counts_seen = 0;
                    for l in 0..ctx.n_localities() {
                        ctx.send(l, BspSsspMsg::Continue(go));
                    }
                }
                self.phase = Phase::AwaitDecision;
                ctx.request_barrier();
            }
            Phase::AwaitDecision => {
                // Uniform verdict: every activation was backed by a
                // counted activity, so `go` is true whenever anyone still
                // holds active rows or pending scatter.
                if self.continue_flag {
                    self.relax_round(ctx);
                }
            }
        }
    }
}

/// Run BSP Bellman-Ford-style SSSP (requires a weighted graph).
pub fn run_bsp(g: &Csr, dist_graph: &DistGraph, source: VertexId, cfg: SimConfig) -> SsspResult {
    check_graph_matches(g, dist_graph);
    let actors: Vec<BspSsspActor> = dist_graph
        .shards
        .iter()
        .map(|s| BspSsspActor {
            shard: Arc::new(s.clone()),
            source,
            dist: vec![f32::INFINITY; s.n_rows()],
            active: Vec::new(),
            in_active: vec![false; s.n_rows()],
            inbox: Vec::new(),
            counts_seen: 0,
            counts_sum: 0,
            pending_activity: 0,
            continue_flag: false,
            phase: Phase::AfterRelax,
            agg: Aggregator::new(
                dist_graph.owned_counts(),
                s.locality,
                FlushPolicy::Manual,
                &cfg.net,
                ITEM_BYTES,
                min_f32,
            ),
            mirror_agg: Aggregator::new(
                dist_graph.ghost_counts(),
                s.locality,
                FlushPolicy::Manual,
                &cfg.net,
                ITEM_BYTES,
                min_f32,
            ),
            work: WorkStats::default(),
        })
        .collect();
    let (actors, mut report) = SimRuntime::new(cfg).run(actors);
    for a in &actors {
        report.agg.merge(a.agg.stats());
        report.agg.merge(a.mirror_agg.stats());
        report.work.merge(&a.work);
    }
    report.partition = dist_graph.partition_stats();
    let mut dist = vec![f32::INFINITY; dist_graph.n()];
    for a in &actors {
        a.shard.scatter_owned(&a.dist[..a.shard.n_local()], &mut dist);
    }
    SsspResult { dist, report }
}
