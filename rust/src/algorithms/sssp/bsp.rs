//! BSP Bellman-Ford-style SSSP: relax the active set each superstep.
//!
//! The PBGL/Boost baseline style: supersteps, per-superstep combiner
//! drains (maximal batching via [`FlushPolicy::Manual`]), and a
//! coordinator-driven termination reduction.

use crate::amt::aggregate::{Aggregator, Batch, FlushPolicy};
use crate::amt::sim::{Actor, Ctx, LocalityId, Message, SimConfig, SimRuntime};
use crate::amt::WorkStats;
use crate::graph::{Csr, DistGraph, Partition1D, VertexId};

use super::{min_f32, SsspResult, WeightedShard, ITEM_BYTES};

/// BSP SSSP messages.
#[derive(Debug, Clone)]
pub enum BspSsspMsg {
    /// Batched relaxations (one folded min per destination vertex).
    Relaxations(Batch<f32>),
    /// Activity count for the termination reduction.
    Count(u64),
    /// Coordinator verdict.
    Continue(bool),
}

impl Message for BspSsspMsg {
    fn wire_bytes(&self) -> usize {
        match self {
            BspSsspMsg::Relaxations(b) => b.wire_bytes(),
            BspSsspMsg::Count(_) => 8,
            BspSsspMsg::Continue(_) => 1,
        }
    }

    fn item_count(&self) -> usize {
        match self {
            BspSsspMsg::Relaxations(b) => b.len(),
            _ => 1,
        }
    }
}

#[derive(PartialEq)]
enum Phase {
    AfterRelax,
    AwaitDecision,
}

/// BSP Bellman-Ford-style actor: relax the active set each superstep.
struct BspSsspActor {
    shard: WeightedShard,
    partition: Partition1D,
    source: VertexId,
    dist: Vec<f32>,
    active: Vec<VertexId>,
    /// O(1) membership test for `active` (local index space).
    in_active: Vec<bool>,
    inbox: Vec<(VertexId, f32)>,
    counts_seen: u32,
    counts_sum: u64,
    continue_flag: bool,
    phase: Phase,
    /// Superstep combiner: folded mins, drained once per round.
    agg: Aggregator<f32>,
    /// Relaxation counters (total edge proposals / strict improvements).
    work: WorkStats,
}

impl BspSsspActor {
    fn relax_round(&mut self, ctx: &mut Ctx<BspSsspMsg>) {
        let here = ctx.locality();
        let mut activity = 0u64;
        let mut next: Vec<VertexId> = Vec::new();
        let active = std::mem::take(&mut self.active);
        for &u in &active {
            self.in_active[u as usize - self.shard.range.start] = false;
        }
        for &u in &active {
            let lu = u as usize - self.shard.range.start;
            let du = self.dist[lu];
            for (w, wt) in self.shard.edges(lu) {
                self.work.relaxations += 1;
                let nd = du + wt;
                let dst = self.partition.owner(w);
                if dst == here {
                    let lw = w as usize - self.shard.range.start;
                    if nd < self.dist[lw] {
                        self.dist[lw] = nd;
                        self.work.useful_relaxations += 1;
                        if !self.in_active[lw] {
                            self.in_active[lw] = true;
                            next.push(w);
                        }
                        activity += 1;
                    }
                } else {
                    // Manual policy: accumulate never auto-flushes.
                    if let Some(batch) = self.agg.accumulate(dst, w, nd) {
                        ctx.send(dst, BspSsspMsg::Relaxations(batch));
                    }
                    activity += 1;
                }
            }
        }
        self.active = next;
        for (dst, batch) in self.agg.drain() {
            ctx.send(dst, BspSsspMsg::Relaxations(batch));
        }
        ctx.send(0, BspSsspMsg::Count(activity));
        self.phase = Phase::AfterRelax;
        ctx.request_barrier();
    }
}

impl Actor for BspSsspActor {
    type Msg = BspSsspMsg;

    fn on_start(&mut self, ctx: &mut Ctx<BspSsspMsg>) {
        if self.partition.owner(self.source) == ctx.locality() {
            let ls = self.source as usize - self.shard.range.start;
            self.dist[ls] = 0.0;
            self.in_active[ls] = true;
            self.active.push(self.source);
        }
        self.relax_round(ctx);
    }

    fn on_message(&mut self, _ctx: &mut Ctx<BspSsspMsg>, _from: LocalityId, msg: BspSsspMsg) {
        match msg {
            BspSsspMsg::Relaxations(batch) => self.inbox.extend(batch.items),
            BspSsspMsg::Count(c) => {
                self.counts_seen += 1;
                self.counts_sum += c;
            }
            BspSsspMsg::Continue(b) => self.continue_flag = b,
        }
    }

    fn on_barrier(&mut self, ctx: &mut Ctx<BspSsspMsg>, _epoch: u64) {
        match self.phase {
            Phase::AfterRelax => {
                let inbox = std::mem::take(&mut self.inbox);
                for (v, d) in inbox {
                    let lv = v as usize - self.shard.range.start;
                    if d < self.dist[lv] {
                        self.dist[lv] = d;
                        self.work.useful_relaxations += 1;
                        if !self.in_active[lv] {
                            self.in_active[lv] = true;
                            self.active.push(v);
                        }
                    }
                }
                if ctx.locality() == 0 {
                    debug_assert_eq!(self.counts_seen, ctx.n_localities());
                    let go = self.counts_sum > 0;
                    self.counts_sum = 0;
                    self.counts_seen = 0;
                    for l in 0..ctx.n_localities() {
                        ctx.send(l, BspSsspMsg::Continue(go));
                    }
                }
                self.phase = Phase::AwaitDecision;
                ctx.request_barrier();
            }
            Phase::AwaitDecision => {
                if self.continue_flag {
                    self.relax_round(ctx);
                }
            }
        }
    }
}

/// Run BSP Bellman-Ford-style SSSP (requires a weighted graph).
pub fn run_bsp(g: &Csr, dist_graph: &DistGraph, source: VertexId, cfg: SimConfig) -> SsspResult {
    let p = dist_graph.p();
    let ranges = dist_graph.partition.ranges();
    let actors: Vec<BspSsspActor> = (0..p)
        .map(|l| BspSsspActor {
            shard: WeightedShard::build(g, &dist_graph.partition, l),
            partition: dist_graph.partition.clone(),
            source,
            dist: vec![f32::INFINITY; dist_graph.partition.len_of(l)],
            active: Vec::new(),
            in_active: vec![false; dist_graph.partition.len_of(l)],
            inbox: Vec::new(),
            counts_seen: 0,
            counts_sum: 0,
            continue_flag: false,
            phase: Phase::AfterRelax,
            agg: Aggregator::new(&ranges, l, FlushPolicy::Manual, &cfg.net, ITEM_BYTES, min_f32),
            work: WorkStats::default(),
        })
        .collect();
    let (actors, mut report) = SimRuntime::new(cfg).run(actors);
    for a in &actors {
        report.agg.merge(a.agg.stats());
        report.work.merge(&a.work);
    }
    let mut dist = vec![f32::INFINITY; dist_graph.n()];
    for a in &actors {
        dist[a.shard.range.clone()].copy_from_slice(&a.dist);
    }
    SsspResult { dist, report }
}
