//! Delta-stepping SSSP with distributed bucket coordination.
//!
//! # Algorithm
//!
//! Meyer & Sanders' delta-stepping organizes relaxations by *priority
//! bucket*: vertex `v` with tentative distance `d` lives in bucket
//! `floor(d / Δ)`. Edges are split at graph-load time into **light**
//! (`w <= Δ`) and **heavy** (`w > Δ`) sets. Buckets are processed in
//! order; bucket `k` is first drained through its light edges — an inner
//! re-relaxation loop, because light relaxations can re-insert vertices
//! into bucket `k` — and only once the light fixpoint is reached are the
//! settled vertices' heavy edges relaxed (each heavy proposal necessarily
//! lands in a strictly later bucket, so heavy edges are relaxed exactly
//! once per settlement). `Δ = ∞` makes every edge light and a single
//! bucket: the schedule degenerates to round-synchronous Bellman-Ford,
//! matching the [`bsp`](super::bsp) engine's relaxing rounds exactly
//! (identical per-round active sets, relaxation totals, and combiner
//! envelope counts; barrier counts agree up to the engines' differing
//! terminal handshakes). `Δ → 0` gives one distance class per bucket:
//! Dijkstra-like ordering with near-minimal relaxation counts.
//!
//! # Distributed current-bucket barrier
//!
//! Each locality keeps its own bucket array over its owned vertices; the
//! *current* bucket index is a global agreement maintained through the
//! runtime's barriers. One phase round is:
//!
//! 1. **work** — every locality drains its current bucket (light phase)
//!    or settled set (heavy phase). Local relaxations update buckets in
//!    place; remote relaxations fold into the shared [`Aggregator`]
//!    min-combiner, flushed by the configured [`FlushPolicy`] and drained
//!    at round end. Arriving relaxations are applied eagerly on receipt.
//! 2. **vote** — at the barrier (the network has drained, so every
//!    relaxation of the round has been applied) each locality broadcasts
//!    `(current bucket non-empty?, min non-empty bucket)` to all
//!    localities — an all-to-all status exchange.
//! 3. **decide** — at the next barrier every locality folds the P votes
//!    with the same pure function, so all reach the identical verdict with
//!    no coordinator round-trip: repeat the light phase (someone still
//!    holds current-bucket vertices), enter the heavy phase (light
//!    fixpoint reached), advance to the globally minimal non-empty bucket,
//!    or terminate (all buckets empty — no locality requests another
//!    barrier and the run quiesces).
//!
//! # Δ heuristic
//!
//! [`auto_delta`] picks `Δ = w̄ / d̄` (mean edge weight over mean degree) —
//! the Meyer–Sanders `Θ(1/d̄)` rule scaled to the weight distribution. On
//! GAP-style weights bounded away from zero this typically classifies
//! every edge heavy, i.e. bucket-Dijkstra with near-minimal relaxation
//! counts, which is exactly the work-efficiency contrast against the
//! chaotic label-correcting engine the "Anatomy" analysis predicts. The
//! `sssp_delta` config key overrides it.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::amt::aggregate::{Aggregator, Batch, FlushPolicy};
use crate::amt::sim::{Actor, Ctx, LocalityId, Message, SimConfig, SimRuntime};
use crate::amt::WorkStats;
use crate::graph::{Csr, DistGraph, Shard, VertexId};

use super::{check_graph_matches, min_f32, SsspResult, ITEM_BYTES};

/// `in_bucket` sentinel: the vertex is not queued in any bucket.
const NOT_QUEUED: u64 = u64::MAX;

/// Bucket index of a (finite, non-negative) tentative distance.
fn bucket_of(d: f32, delta: f32) -> u64 {
    if delta.is_infinite() {
        return 0;
    }
    // f32 -> u64 casts saturate; clamp below the NOT_QUEUED sentinel.
    ((d / delta) as u64).min(NOT_QUEUED - 1)
}

/// Δ auto-tuning heuristic: mean edge weight over mean degree (see the
/// module docs). Returns `f32::INFINITY` (≡ Bellman-Ford, a safe single
/// bucket) for empty or degenerate graphs.
pub fn auto_delta(g: &Csr) -> f32 {
    let (n, m) = (g.n(), g.m());
    if n == 0 || m == 0 {
        return f32::INFINITY;
    }
    let avg_deg = m as f32 / n as f32;
    let avg_w = if g.is_weighted() {
        let mut sum = 0.0f64;
        for u in 0..n as VertexId {
            for (_, w) in g.neighbors_weighted(u) {
                sum += w as f64;
            }
        }
        (sum / m as f64) as f32
    } else {
        1.0
    };
    let d = avg_w / avg_deg;
    if d.is_finite() && d > 0.0 {
        d
    } else {
        f32::INFINITY
    }
}

/// Delta-stepping messages.
#[derive(Debug, Clone)]
pub enum DeltaMsg {
    /// Batched relaxations (one folded min per destination vertex).
    Relaxations(Batch<f32>),
    /// One locality's bucket status, broadcast all-to-all at the vote
    /// barrier (see module docs).
    Status {
        /// The current bucket still holds vertices here.
        nonempty_current: bool,
        /// Smallest non-empty bucket here (`None` = all empty).
        min_bucket: Option<u64>,
    },
}

impl Message for DeltaMsg {
    fn wire_bytes(&self) -> usize {
        match self {
            DeltaMsg::Relaxations(b) => b.wire_bytes(),
            DeltaMsg::Status { .. } => 16,
        }
    }

    fn item_count(&self) -> usize {
        match self {
            DeltaMsg::Relaxations(b) => b.len(),
            DeltaMsg::Status { .. } => 1,
        }
    }
}

/// Light/heavy edge separation over one shard's owned rows, done once at
/// build time. Targets are the shard's dense local rows (owned index or
/// ghost slot), so relaxation needs no owner arithmetic at all.
struct DeltaShard {
    light_offsets: Vec<usize>,
    light_targets: Vec<u32>,
    light_weights: Vec<f32>,
    heavy_offsets: Vec<usize>,
    heavy_targets: Vec<u32>,
    heavy_weights: Vec<f32>,
}

impl DeltaShard {
    fn build(shard: &Shard, delta: f32) -> Self {
        let mut s = DeltaShard {
            light_offsets: vec![0],
            light_targets: Vec::new(),
            light_weights: Vec::new(),
            heavy_offsets: vec![0],
            heavy_targets: Vec::new(),
            heavy_weights: Vec::new(),
        };
        for row in 0..shard.n_local() {
            for (t, w) in shard.row_edges(row) {
                s.push_edge(t, w, delta);
            }
            s.light_offsets.push(s.light_targets.len());
            s.heavy_offsets.push(s.heavy_targets.len());
        }
        s
    }

    fn push_edge(&mut self, t: u32, w: f32, delta: f32) {
        if w <= delta {
            self.light_targets.push(t);
            self.light_weights.push(w);
        } else {
            self.heavy_targets.push(t);
            self.heavy_weights.push(w);
        }
    }

    fn light_edges(&self, local: usize) -> impl Iterator<Item = (u32, f32)> + '_ {
        let r = self.light_offsets[local]..self.light_offsets[local + 1];
        self.light_targets[r.clone()].iter().cloned().zip(self.light_weights[r].iter().cloned())
    }

    fn heavy_edges(&self, local: usize) -> impl Iterator<Item = (u32, f32)> + '_ {
        let r = self.heavy_offsets[local]..self.heavy_offsets[local + 1];
        self.heavy_targets[r.clone()].iter().cloned().zip(self.heavy_weights[r].iter().cloned())
    }
}

/// Which edge class the next work round relaxes.
enum Mode {
    Light,
    Heavy,
}

/// Barrier-protocol step (see module docs: work → vote → decide).
enum Step {
    AwaitVote,
    AwaitDecision,
}

/// Per-locality delta-stepping actor.
struct DeltaSsspActor {
    shard: Arc<Shard>,
    edges: DeltaShard,
    source: VertexId,
    delta: f32,
    /// Owned tentative distances.
    dist: Vec<f32>,
    /// Bucket index → queued owned-local vertices. Sparse (`BTreeMap`) so
    /// tiny Δ cannot blow up memory; entries may go stale when a vertex
    /// moves buckets (`in_bucket` is the source of truth).
    buckets: BTreeMap<u64, Vec<u32>>,
    /// Owned-local vertex → bucket it is queued in ([`NOT_QUEUED`] = none).
    in_bucket: Vec<u64>,
    /// Vertices settled during the current bucket's light phase, awaiting
    /// their one heavy relaxation.
    req: Vec<u32>,
    in_req: Vec<bool>,
    /// Globally agreed current bucket.
    current: u64,
    mode: Mode,
    step: Step,
    /// Vote fold: any locality's current bucket non-empty.
    votes_nonempty: bool,
    /// Vote fold: global min non-empty bucket.
    votes_min: Option<u64>,
    votes_seen: u32,
    /// Remote-relaxation combiner (shared aggregation subsystem).
    agg: Aggregator<f32>,
    /// Relaxation counters (total edge proposals / strict improvements).
    work: WorkStats,
}

impl DeltaSsspActor {
    /// One light round: take the current bucket's members, settle them
    /// into `req`, and relax their light edges. Local re-insertions into
    /// the current bucket are processed next round (round-synchronous, so
    /// `Δ = ∞` reproduces the BSP Bellman-Ford schedule exactly).
    fn light_round(&mut self, ctx: &mut Ctx<DeltaMsg>) {
        let n_owned = self.shard.n_local();
        let members = self.buckets.remove(&self.current).unwrap_or_default();
        for &lv32 in &members {
            let lv = lv32 as usize;
            if self.in_bucket[lv] != self.current {
                continue; // stale entry: the vertex moved buckets
            }
            self.in_bucket[lv] = NOT_QUEUED;
            if !self.in_req[lv] {
                self.in_req[lv] = true;
                self.req.push(lv32);
            }
            let du = self.dist[lv];
            for (t, wt) in self.edges.light_edges(lv) {
                self.work.relaxations += 1;
                let nd = du + wt;
                let t = t as usize;
                if t < n_owned {
                    if nd < self.dist[t] {
                        self.dist[t] = nd;
                        self.work.useful_relaxations += 1;
                        let b = bucket_of(nd, self.delta);
                        if self.in_bucket[t] != b {
                            self.in_bucket[t] = b;
                            self.buckets.entry(b).or_default().push(t as u32);
                        }
                    }
                } else {
                    let gi = t - n_owned;
                    if let Some(batch) = self.agg.accumulate(
                        self.shard.ghost_owner[gi],
                        self.shard.ghost_master_index[gi],
                        nd,
                    ) {
                        ctx.send(self.shard.ghost_owner[gi], DeltaMsg::Relaxations(batch));
                    }
                }
            }
        }
    }

    /// The heavy round: relax the heavy edges of everything settled in
    /// the current bucket, exactly once, at their final distances.
    fn heavy_round(&mut self, ctx: &mut Ctx<DeltaMsg>) {
        let n_owned = self.shard.n_local();
        let req = std::mem::take(&mut self.req);
        for &lv32 in &req {
            let lv = lv32 as usize;
            self.in_req[lv] = false;
            let du = self.dist[lv];
            for (t, wt) in self.edges.heavy_edges(lv) {
                self.work.relaxations += 1;
                let nd = du + wt;
                let t = t as usize;
                if t < n_owned {
                    if nd < self.dist[t] {
                        self.dist[t] = nd;
                        self.work.useful_relaxations += 1;
                        let b = bucket_of(nd, self.delta);
                        if self.in_bucket[t] != b {
                            self.in_bucket[t] = b;
                            self.buckets.entry(b).or_default().push(t as u32);
                        }
                    }
                } else {
                    let gi = t - n_owned;
                    if let Some(batch) = self.agg.accumulate(
                        self.shard.ghost_owner[gi],
                        self.shard.ghost_master_index[gi],
                        nd,
                    ) {
                        ctx.send(self.shard.ghost_owner[gi], DeltaMsg::Relaxations(batch));
                    }
                }
            }
        }
    }

    fn work_round(&mut self, ctx: &mut Ctx<DeltaMsg>) {
        match self.mode {
            Mode::Light => self.light_round(ctx),
            Mode::Heavy => self.heavy_round(ctx),
        }
        for (dst, batch) in self.agg.drain() {
            ctx.send(dst, DeltaMsg::Relaxations(batch));
        }
        self.step = Step::AwaitVote;
        ctx.request_barrier();
    }
}

impl Actor for DeltaSsspActor {
    type Msg = DeltaMsg;

    fn on_start(&mut self, ctx: &mut Ctx<DeltaMsg>) {
        if let Ok(ls) = self.shard.owned_ids.binary_search(&self.source) {
            self.dist[ls] = 0.0;
            self.in_bucket[ls] = 0;
            self.buckets.entry(0).or_default().push(ls as u32);
        }
        self.work_round(ctx);
    }

    fn on_message(&mut self, _ctx: &mut Ctx<DeltaMsg>, _from: LocalityId, msg: DeltaMsg) {
        match msg {
            // Relaxations are applied eagerly: by the time the vote
            // barrier fires the network has drained, so every locality
            // votes on the complete post-round state.
            DeltaMsg::Relaxations(batch) => {
                for (lv, d) in batch.items {
                    let lv = lv as usize;
                    if d < self.dist[lv] {
                        self.dist[lv] = d;
                        self.work.useful_relaxations += 1;
                        let b = bucket_of(d, self.delta);
                        if self.in_bucket[lv] != b {
                            self.in_bucket[lv] = b;
                            self.buckets.entry(b).or_default().push(lv as u32);
                        }
                    }
                }
            }
            DeltaMsg::Status { nonempty_current, min_bucket } => {
                self.votes_seen += 1;
                self.votes_nonempty |= nonempty_current;
                self.votes_min = match (self.votes_min, min_bucket) {
                    (Some(a), Some(b)) => Some(a.min(b)),
                    (a, b) => a.or(b),
                };
            }
        }
    }

    fn on_barrier(&mut self, ctx: &mut Ctx<DeltaMsg>, _epoch: u64) {
        match self.step {
            Step::AwaitVote => {
                // Drop stale bucket entries so emptiness votes are exact.
                let in_bucket = &self.in_bucket;
                self.buckets.retain(|&b, v| {
                    v.retain(|&lv| in_bucket[lv as usize] == b);
                    !v.is_empty()
                });
                let status = DeltaMsg::Status {
                    nonempty_current: self.buckets.contains_key(&self.current),
                    min_bucket: self.buckets.keys().next().copied(),
                };
                for l in 0..ctx.n_localities() {
                    ctx.send(l, status.clone());
                }
                self.step = Step::AwaitDecision;
                ctx.request_barrier();
            }
            Step::AwaitDecision => {
                // All P votes are in; every locality folds them with the
                // same pure function and reaches the identical verdict.
                debug_assert_eq!(self.votes_seen, ctx.n_localities());
                let nonempty = self.votes_nonempty;
                let min_b = self.votes_min;
                self.votes_seen = 0;
                self.votes_nonempty = false;
                self.votes_min = None;
                match self.mode {
                    Mode::Light if nonempty => self.work_round(ctx),
                    Mode::Light => {
                        self.mode = Mode::Heavy;
                        self.work_round(ctx);
                    }
                    Mode::Heavy => match min_b {
                        Some(k) => {
                            self.current = k;
                            self.mode = Mode::Light;
                            self.work_round(ctx);
                        }
                        // Every bucket everywhere is empty and the network
                        // is quiet: no one requests another barrier and
                        // the run terminates at quiescence.
                        None => {}
                    },
                }
            }
        }
    }
}

/// Run delta-stepping SSSP with the [`auto_delta`] heuristic and the
/// default [`FlushPolicy::Adaptive`] aggregation.
pub fn run(g: &Csr, dist_graph: &DistGraph, source: VertexId, cfg: SimConfig) -> SsspResult {
    let delta = auto_delta(g);
    run_with(g, dist_graph, source, delta, FlushPolicy::Adaptive, cfg)
}

/// Run delta-stepping SSSP with an explicit Δ and flush policy.
/// `delta` must be positive (`f32::INFINITY` ≡ Bellman-Ford).
pub fn run_with(
    g: &Csr,
    dist_graph: &DistGraph,
    source: VertexId,
    delta: f32,
    policy: FlushPolicy,
    cfg: SimConfig,
) -> SsspResult {
    assert!(delta > 0.0, "delta must be positive (f32::INFINITY = Bellman-Ford), got {delta}");
    assert!(
        !dist_graph.has_mirrors(),
        "delta-stepping's bucket protocol needs whole rows at the owner; use a mirror-free \
         partition scheme (block|edge_balanced|hash) or the async/bsp engines for vertex cuts"
    );
    check_graph_matches(g, dist_graph);
    let actors: Vec<DeltaSsspActor> = dist_graph
        .shards
        .iter()
        .map(|s| DeltaSsspActor {
            edges: DeltaShard::build(s, delta),
            shard: Arc::new(s.clone()),
            source,
            delta,
            dist: vec![f32::INFINITY; s.n_local()],
            buckets: BTreeMap::new(),
            in_bucket: vec![NOT_QUEUED; s.n_local()],
            req: Vec::new(),
            in_req: vec![false; s.n_local()],
            current: 0,
            mode: Mode::Light,
            step: Step::AwaitVote,
            votes_nonempty: false,
            votes_min: None,
            votes_seen: 0,
            agg: Aggregator::new(
                dist_graph.owned_counts(),
                s.locality,
                policy,
                &cfg.net,
                ITEM_BYTES,
                min_f32,
            ),
            work: WorkStats::default(),
        })
        .collect();
    let (actors, mut report) = SimRuntime::new(cfg).run(actors);
    for a in &actors {
        report.agg.merge(a.agg.stats());
        report.work.merge(&a.work);
    }
    report.partition = dist_graph.partition_stats();
    let mut dist = vec![f32::INFINITY; dist_graph.n()];
    for a in &actors {
        a.shard.scatter_owned(&a.dist, &mut dist);
    }
    SsspResult { dist, report }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amt::NetConfig;
    use crate::graph::generators;

    fn det() -> SimConfig {
        SimConfig::deterministic(NetConfig::default())
    }

    #[test]
    fn bucket_of_is_monotone_and_saturates() {
        assert_eq!(bucket_of(0.0, 0.5), 0);
        assert_eq!(bucket_of(0.49, 0.5), 0);
        assert_eq!(bucket_of(0.5, 0.5), 1);
        assert_eq!(bucket_of(7.3, 0.5), 14);
        assert_eq!(bucket_of(123.0, f32::INFINITY), 0);
        // Saturating cast stays clear of the NOT_QUEUED sentinel.
        assert_eq!(bucket_of(f32::MAX, 1e-30), NOT_QUEUED - 1);
    }

    #[test]
    fn auto_delta_scales_with_weight_and_degree() {
        let g = generators::with_random_weights(&generators::path(64), 2.0, 2.0 + 1e-6, 3);
        // path: avg degree ~2, weights ~2 -> delta ~1.
        let d = auto_delta(&g);
        assert!(d > 0.5 && d < 2.0, "delta {d}");
        // Unweighted graphs fall back to unit weights.
        let du = auto_delta(&generators::path(64));
        assert!(du > 0.25 && du < 1.0, "delta {du}");
        // Degenerate graphs get the safe single-bucket delta.
        assert_eq!(auto_delta(&Csr::from_edge_list(&crate::graph::EdgeList::new(0))), f32::INFINITY);
    }

    #[test]
    fn light_heavy_split_covers_every_edge() {
        let g = generators::with_random_weights(&generators::urand(6, 4, 9), 1.0, 10.0, 10);
        let dg = DistGraph::block(&g, 3);
        let delta = 4.0f32;
        let mut total = 0usize;
        for shard in &dg.shards {
            let s = DeltaShard::build(shard, delta);
            for lv in 0..shard.n_local() {
                for (_, w) in s.light_edges(lv) {
                    assert!(w <= delta);
                    total += 1;
                }
                for (_, w) in s.heavy_edges(lv) {
                    assert!(w > delta);
                    total += 1;
                }
            }
        }
        assert_eq!(total, g.m());
    }

    #[test]
    fn hash_scheme_is_accepted_and_matches_oracle() {
        use crate::graph::PartitionKind;
        let g = generators::with_random_weights(&generators::urand(6, 4, 41), 1.0, 10.0, 42);
        let want = super::super::dijkstra(&g, 0);
        let d = DistGraph::build_with(&g, PartitionKind::Hash.build(&g, 4));
        let res = run_with(&g, &d, 0, auto_delta(&g), FlushPolicy::Adaptive, det());
        for v in 0..g.n() {
            let (a, b) = (res.dist[v], want[v]);
            assert!((a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-3);
        }
    }

    #[test]
    #[should_panic(expected = "mirror-free")]
    fn vertex_cut_is_rejected() {
        use crate::graph::PartitionKind;
        let g = generators::with_random_weights(&generators::kron(6, 6, 43), 1.0, 10.0, 44);
        let d = DistGraph::build_with(&g, PartitionKind::VertexCut.build(&g, 4));
        if !d.has_mirrors() {
            panic!("mirror-free by luck"); // keep the expected message
        }
        let _ = run_with(&g, &d, 0, 1.0, FlushPolicy::Adaptive, det());
    }

    #[test]
    #[should_panic(expected = "delta must be positive")]
    fn zero_delta_is_rejected() {
        let g = generators::with_random_weights(&generators::path(4), 1.0, 2.0, 1);
        let d = DistGraph::block(&g, 2);
        run_with(&g, &d, 0, 0.0, FlushPolicy::Adaptive, det());
    }

    #[test]
    fn delta_run_auto_matches_oracle() {
        let g = generators::with_random_weights(&generators::urand(7, 4, 21), 1.0, 10.0, 22);
        let want = super::super::dijkstra(&g, 3);
        for p in [1u32, 2, 4, 8] {
            let d = DistGraph::block(&g, p);
            let res = run(&g, &d, 3, det());
            for v in 0..g.n() {
                let (a, b) = (res.dist[v], want[v]);
                assert!(
                    (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-3,
                    "p={p} dist[{v}]: {a} vs {b}"
                );
            }
        }
    }
}
