//! Single-source shortest paths — §6 future-work extension, in *three*
//! distributed execution models.
//!
//! Sequential oracle: binary-heap Dijkstra. Distributed engines:
//!
//! * **[`async_hpx`]** — asynchronous *label-correcting* relaxation (the
//!   natural HPX formulation — an improved tentative distance triggers
//!   eager remote relaxations, termination is network quiescence);
//! * **[`bsp`]** — a BSP Bellman-Ford-style superstep baseline mirroring
//!   the BFS/PageRank pairing;
//! * **[`delta`]** — delta-stepping with per-locality bucket arrays and a
//!   distributed current-bucket barrier, the ordered middle ground the
//!   "Anatomy of Large-Scale Distributed Graph Algorithms" analysis shows
//!   dominates work efficiency. Δ = ∞ degenerates to the BSP Bellman-Ford
//!   schedule; Δ → 0 approaches Dijkstra's ordering.
//!
//! All three route remote relaxations through the shared
//! [`amt::aggregate`](crate::amt::aggregate) combiner (fold = min over
//! tentative distances, keyed by the destination's master index from the
//! shard ghost table), so every [`FlushPolicy`] applies uniformly: the
//! async engine flushes by policy and drains at handler end, the BSP and
//! delta engines drain once per superstep/phase. Every engine counts its
//! relaxations into [`WorkStats`](crate::amt::WorkStats) so the
//! work-efficiency axis (total vs. useful relaxations) is measurable per
//! run, not inferred from envelope counts.
//!
//! Partitioning: the async and BSP engines are scheme-generic (vertex
//! cuts scatter master improvements to mirror rows); delta-stepping's
//! bucket protocol assumes whole rows at the owner and is gated to
//! mirror-free schemes.
//!
//! Engines read their weighted adjacency from the [`DistGraph`] shards,
//! so the distributed graph must be built from the *weighted* Csr (the
//! same one handed to the engines for oracle checks); unweighted graphs
//! degenerate to unit weights (SSSP == hop count).
//!
//! The min-fold assumes a NaN-free total order on distances; graph build
//! ([`Csr::from_edge_list`]) debug-asserts that weights are finite and
//! non-negative, which makes `<` a total comparison on every tentative
//! distance that can arise (sums of non-negative finite weights).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::amt::SimReport;
use crate::graph::{Csr, DistGraph, VertexId};

pub mod async_hpx;
pub mod bsp;
pub mod delta;

pub use async_hpx::{run_async, run_async_with};
pub use bsp::run_bsp;
pub use delta::auto_delta;

/// Result of a distributed SSSP run.
#[derive(Debug)]
pub struct SsspResult {
    /// Tentative distances (`f32::INFINITY` = unreachable).
    pub dist: Vec<f32>,
    /// Runtime report (includes relaxation counters in `report.work`).
    pub report: SimReport,
}

/// Per-item wire size: vertex id + distance.
pub(crate) const ITEM_BYTES: usize = 8;

/// Keep the smaller tentative distance. Relies on the graph-build
/// guarantee that weights (and therefore path sums) are never NaN.
pub(crate) fn min_f32(acc: &mut f32, d: f32) {
    debug_assert!(!d.is_nan() && !acc.is_nan(), "SSSP distances must be NaN-free");
    if d < *acc {
        *acc = d;
    }
}

/// The engines run on the shard adjacency, so the `DistGraph` must have
/// been built from the same (weighted) graph the caller validates with.
pub(crate) fn check_graph_matches(g: &Csr, dist_graph: &DistGraph) {
    assert_eq!(g.n(), dist_graph.n(), "DistGraph built from a different graph");
    assert_eq!(g.m(), dist_graph.m(), "DistGraph built from a different graph");
    assert!(
        g.m() == 0 || g.is_weighted() == dist_graph.is_weighted(),
        "build the DistGraph from the weighted Csr so the shards carry weights"
    );
}

/// Sequential Dijkstra oracle (non-negative weights).
pub fn dijkstra(g: &Csr, source: VertexId) -> Vec<f32> {
    let n = g.n();
    let mut dist = vec![f32::INFINITY; n];
    if n == 0 {
        return dist;
    }
    dist[source as usize] = 0.0;
    // (ordered-dist, vertex) min-heap via Reverse on bit-ordered f32.
    let mut heap: BinaryHeap<Reverse<(u32, VertexId)>> = BinaryHeap::new();
    heap.push(Reverse((0f32.to_bits(), source)));
    while let Some(Reverse((db, u))) = heap.pop() {
        let d = f32::from_bits(db);
        if d > dist[u as usize] {
            continue;
        }
        for (v, w) in g.neighbors_weighted(u) {
            let nd = d + w;
            if nd < dist[v as usize] {
                dist[v as usize] = nd;
                heap.push(Reverse((nd.to_bits(), v)));
            }
        }
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amt::{FlushPolicy, NetConfig, SimConfig};
    use crate::graph::generators;
    use crate::graph::PartitionKind;

    fn det() -> SimConfig {
        SimConfig::deterministic(NetConfig::default())
    }

    fn weighted_graph(scale: u32, seed: u64) -> Csr {
        generators::with_random_weights(&generators::urand(scale, 4, seed), 1.0, 10.0, seed + 1)
    }

    fn close(a: &[f32], b: &[f32]) -> bool {
        a.iter().zip(b).all(|(x, y)| {
            (x.is_infinite() && y.is_infinite()) || (x - y).abs() < 1e-3
        })
    }

    #[test]
    fn async_matches_dijkstra() {
        for p in [1u32, 2, 4, 8] {
            let g = weighted_graph(6, 31 + p as u64);
            let want = dijkstra(&g, 0);
            let d = DistGraph::block(&g, p);
            let res = run_async(&g, &d, 0, SimConfig::deterministic(NetConfig::default()));
            assert!(close(&res.dist, &want), "p={p}");
        }
    }

    #[test]
    fn async_matches_dijkstra_under_every_policy() {
        let g = weighted_graph(6, 53);
        let want = dijkstra(&g, 0);
        let d = DistGraph::block(&g, 4);
        for policy in [
            FlushPolicy::Unbatched,
            FlushPolicy::Items(8),
            FlushPolicy::Adaptive,
            FlushPolicy::Manual,
        ] {
            let res = run_async_with(&g, &d, 0, policy, det());
            assert!(close(&res.dist, &want), "{policy:?}");
        }
    }

    #[test]
    fn bsp_matches_dijkstra() {
        for p in [1u32, 3, 4] {
            let g = weighted_graph(6, 77 + p as u64);
            let want = dijkstra(&g, 0);
            let d = DistGraph::block(&g, p);
            let res = run_bsp(&g, &d, 0, SimConfig::deterministic(NetConfig::default()));
            assert!(close(&res.dist, &want), "p={p}");
        }
    }

    #[test]
    fn async_and_bsp_match_dijkstra_under_every_partition_scheme() {
        let g = generators::with_random_weights(&generators::kron(6, 5, 71), 1.0, 10.0, 72);
        let want = dijkstra(&g, 0);
        for kind in PartitionKind::all() {
            for p in [2u32, 4, 8] {
                let d = DistGraph::build_with(&g, kind.build(&g, p));
                let a = run_async(&g, &d, 0, det());
                assert!(close(&a.dist, &want), "async {kind:?} p={p}");
                let b = run_bsp(&g, &d, 0, det());
                assert!(close(&b.dist, &want), "bsp {kind:?} p={p}");
            }
        }
    }

    #[test]
    fn delta_matches_dijkstra_across_deltas() {
        let g = weighted_graph(6, 53);
        let want = dijkstra(&g, 0);
        let d = DistGraph::block(&g, 4);
        for delta_v in [0.1f32, 0.7, 2.0, 8.0, f32::INFINITY] {
            let res = delta::run_with(&g, &d, 0, delta_v, FlushPolicy::Adaptive, det());
            assert!(close(&res.dist, &want), "delta={delta_v}");
        }
    }

    #[test]
    fn bsp_folds_duplicate_relaxations_per_superstep() {
        // The combiner ships at most one relaxation per destination vertex
        // per superstep, so wire items never exceed aggregation input.
        let g = weighted_graph(6, 91);
        let d = DistGraph::block(&g, 4);
        let res = run_bsp(&g, &d, 0, SimConfig::deterministic(NetConfig::default()));
        assert_eq!(res.report.agg.sent_items + res.report.agg.folded, res.report.agg.items);
        assert_eq!(res.report.agg.envelopes, res.report.agg.drain_flushes);
    }

    #[test]
    fn engines_report_relaxation_counters() {
        let g = weighted_graph(6, 17);
        let d = DistGraph::block(&g, 4);
        let delta_v = auto_delta(&g);
        for res in [
            run_async(&g, &d, 0, det()),
            run_bsp(&g, &d, 0, det()),
            delta::run_with(&g, &d, 0, delta_v, FlushPolicy::Adaptive, det()),
        ] {
            let w = res.report.work;
            assert!(w.relaxations > 0, "no relaxations counted");
            assert!(w.useful_relaxations <= w.relaxations, "useful > total: {w:?}");
            // Every reached non-source vertex was improved at least once.
            let reached = res.dist.iter().filter(|d| d.is_finite()).count() as u64;
            assert!(w.useful_relaxations >= reached - 1, "{w:?}, reached {reached}");
        }
    }

    #[test]
    fn dijkstra_path_graph() {
        let g = generators::with_random_weights(&generators::path(5), 1.0, 1.0 + 1e-6, 1);
        let d = dijkstra(&g, 0);
        for (i, x) in d.iter().enumerate() {
            assert!((x - i as f32).abs() < 1e-4);
        }
    }

    #[test]
    fn unreachable_is_infinite() {
        let mut el = crate::graph::EdgeList::new(3);
        el.push_weighted(0, 1, 1.0);
        let g = Csr::from_edge_list(&el);
        let d = DistGraph::block(&g, 2);
        let res = run_async(&g, &d, 0, SimConfig::deterministic(NetConfig::default()));
        assert_eq!(res.dist[1], 1.0);
        assert!(res.dist[2].is_infinite());
    }
}
