//! Single-source shortest paths — §6 future-work extension, in *three*
//! distributed execution models, all running one [`SsspProgram`] on the
//! generic [`engine`](crate::engine) loops:
//!
//! * **[`run_async`]** — asynchronous *label-correcting* relaxation (the
//!   natural HPX formulation — an improved tentative distance triggers
//!   eager remote relaxations, termination is network quiescence);
//! * **[`run_bsp`]** — BSP Bellman-Ford supersteps, the PBGL baseline;
//! * **[`run_delta`]** — delta-stepping: the ordered bucket schedule the
//!   "Anatomy of Large-Scale Distributed Graph Algorithms" analysis shows
//!   dominates work efficiency. Δ = ∞ degenerates to the BSP schedule;
//!   Δ → 0 approaches Dijkstra's ordering. Mirror-aware in the engine, so
//!   vertex-cut partitions are supported.
//!
//! All engines route remote relaxations through the shared
//! [`amt::aggregate`](crate::amt::aggregate) min-fold combiners (keyed by
//! the destination's master index from the shard ghost table) and count
//! relaxations into [`WorkStats`](crate::amt::WorkStats), so the
//! work-efficiency axis (total vs. useful relaxations) is measurable per
//! run.
//!
//! Engines read their weighted adjacency from the [`DistGraph`] shards,
//! so the distributed graph must be built from the *weighted* Csr (the
//! same one handed to the runners for oracle checks); unweighted graphs
//! degenerate to unit weights (SSSP == hop count).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::amt::{FlushPolicy, SimConfig, SimReport};
use crate::engine;
use crate::graph::{Csr, DistGraph, VertexId};

pub mod paths;
pub mod program;

pub use paths::{path_weight, recover_path, run_paths, DistParent, SsspPathProgram, SsspPathResult};
pub use program::SsspProgram;

/// Result of a distributed SSSP run.
#[derive(Debug)]
pub struct SsspResult {
    /// Tentative distances (`f32::INFINITY` = unreachable).
    pub dist: Vec<f32>,
    /// Runtime report (includes relaxation counters in `report.work`).
    pub report: SimReport,
}

/// The engines run on the shard adjacency, so the `DistGraph` must have
/// been built from the same (weighted) graph the caller validates with.
pub(crate) fn check_graph_matches(g: &Csr, dist_graph: &DistGraph) {
    assert_eq!(g.n(), dist_graph.n(), "DistGraph built from a different graph");
    assert_eq!(g.m(), dist_graph.m(), "DistGraph built from a different graph");
    assert!(
        g.m() == 0 || g.is_weighted() == dist_graph.is_weighted(),
        "build the DistGraph from the weighted Csr so the shards carry weights"
    );
}

fn to_result(run: engine::ProgramRun<f32>) -> SsspResult {
    SsspResult { dist: run.states, report: run.report }
}

/// Run asynchronous label-correcting SSSP with the default
/// [`FlushPolicy::Adaptive`] aggregation.
pub fn run_async(g: &Csr, dist_graph: &DistGraph, source: VertexId, cfg: SimConfig) -> SsspResult {
    run_async_with(g, dist_graph, source, FlushPolicy::Adaptive, cfg)
}

/// Run asynchronous label-correcting SSSP with an explicit flush policy.
pub fn run_async_with(
    g: &Csr,
    dist_graph: &DistGraph,
    source: VertexId,
    policy: FlushPolicy,
    cfg: SimConfig,
) -> SsspResult {
    check_graph_matches(g, dist_graph);
    to_result(engine::run_async(SsspProgram { source }, dist_graph, policy, cfg))
}

/// Run BSP Bellman-Ford-style SSSP (per-superstep combiner drains).
pub fn run_bsp(g: &Csr, dist_graph: &DistGraph, source: VertexId, cfg: SimConfig) -> SsspResult {
    check_graph_matches(g, dist_graph);
    to_result(engine::run_bsp(SsspProgram { source }, dist_graph, cfg))
}

/// Run delta-stepping SSSP with the [`auto_delta`] heuristic and the
/// default [`FlushPolicy::Adaptive`] aggregation.
pub fn run_delta(g: &Csr, dist_graph: &DistGraph, source: VertexId, cfg: SimConfig) -> SsspResult {
    let delta = auto_delta(g);
    run_delta_with(g, dist_graph, source, delta, FlushPolicy::Adaptive, cfg)
}

/// Run delta-stepping SSSP with an explicit Δ and flush policy.
/// `delta` must be positive (`f32::INFINITY` ≡ Bellman-Ford). Works under
/// every partition scheme, including vertex cuts (the engine's
/// mirror-aware bucket protocol).
pub fn run_delta_with(
    g: &Csr,
    dist_graph: &DistGraph,
    source: VertexId,
    delta: f32,
    policy: FlushPolicy,
    cfg: SimConfig,
) -> SsspResult {
    check_graph_matches(g, dist_graph);
    to_result(engine::run_delta(SsspProgram { source }, dist_graph, delta, policy, cfg))
}

/// Asynchronous label-correcting SSSP straight from the shards — no
/// whole-graph [`Csr`] required. This is the streaming-ingestion entry
/// point ([`graph::stream`](crate::graph::stream) never materializes the
/// global graph); the `g`-taking runners exist for callers that also hold
/// the oracle graph and want the build-mismatch sanity check.
pub fn run_async_dist(dist_graph: &DistGraph, source: VertexId, cfg: SimConfig) -> SsspResult {
    run_async_dist_with(dist_graph, source, FlushPolicy::Adaptive, cfg)
}

/// [`run_async_dist`] with an explicit flush policy.
pub fn run_async_dist_with(
    dist_graph: &DistGraph,
    source: VertexId,
    policy: FlushPolicy,
    cfg: SimConfig,
) -> SsspResult {
    to_result(engine::run_async(SsspProgram { source }, dist_graph, policy, cfg))
}

/// BSP Bellman-Ford SSSP straight from the shards (see [`run_async_dist`]).
pub fn run_bsp_dist(dist_graph: &DistGraph, source: VertexId, cfg: SimConfig) -> SsspResult {
    to_result(engine::run_bsp(SsspProgram { source }, dist_graph, cfg))
}

/// Delta-stepping SSSP straight from the shards, with Δ from
/// [`auto_delta_dist`] (see [`run_async_dist`]).
pub fn run_delta_dist(dist_graph: &DistGraph, source: VertexId, cfg: SimConfig) -> SsspResult {
    let delta = auto_delta_dist(dist_graph);
    run_delta_dist_with(dist_graph, source, delta, FlushPolicy::Adaptive, cfg)
}

/// [`run_delta_dist`] with an explicit Δ and flush policy.
pub fn run_delta_dist_with(
    dist_graph: &DistGraph,
    source: VertexId,
    delta: f32,
    policy: FlushPolicy,
    cfg: SimConfig,
) -> SsspResult {
    to_result(engine::run_delta(SsspProgram { source }, dist_graph, delta, policy, cfg))
}

/// [`auto_delta`] computed from the shards instead of a whole-graph
/// [`Csr`]. Every homed edge lives in exactly one shard row (owned or
/// ghost), so the weight sum — and therefore Δ — matches the
/// materialized heuristic on the same graph (identical up to the f64
/// summation order; the f32-rounded mean agrees in practice).
pub fn auto_delta_dist(dist_graph: &DistGraph) -> f32 {
    let (n, m) = (dist_graph.n(), dist_graph.m());
    if n == 0 || m == 0 {
        return f32::INFINITY;
    }
    let avg_deg = m as f32 / n as f32;
    let avg_w = if dist_graph.is_weighted() {
        let mut sum = 0.0f64;
        for s in &dist_graph.shards {
            for row in 0..s.n_rows() {
                for (_, w) in s.row_edges(row) {
                    sum += w as f64;
                }
            }
        }
        (sum / m as f64) as f32
    } else {
        1.0
    };
    let d = avg_w / avg_deg;
    if d.is_finite() && d > 0.0 {
        d
    } else {
        f32::INFINITY
    }
}

/// Δ auto-tuning heuristic: `Δ = w̄ / d̄` (mean edge weight over mean
/// degree) — the Meyer–Sanders `Θ(1/d̄)` rule scaled to the weight
/// distribution. On GAP-style weights bounded away from zero this
/// typically classifies every edge heavy, i.e. bucket-Dijkstra with
/// near-minimal relaxation counts. Returns `f32::INFINITY` (≡
/// Bellman-Ford, a safe single bucket) for empty or degenerate graphs.
/// The `sssp_delta` config key overrides it.
pub fn auto_delta(g: &Csr) -> f32 {
    let (n, m) = (g.n(), g.m());
    if n == 0 || m == 0 {
        return f32::INFINITY;
    }
    let avg_deg = m as f32 / n as f32;
    let avg_w = if g.is_weighted() {
        let mut sum = 0.0f64;
        for u in 0..n as VertexId {
            for (_, w) in g.neighbors_weighted(u) {
                sum += w as f64;
            }
        }
        (sum / m as f64) as f32
    } else {
        1.0
    };
    let d = avg_w / avg_deg;
    if d.is_finite() && d > 0.0 {
        d
    } else {
        f32::INFINITY
    }
}

/// Sequential Dijkstra oracle (non-negative weights).
pub fn dijkstra(g: &Csr, source: VertexId) -> Vec<f32> {
    let n = g.n();
    let mut dist = vec![f32::INFINITY; n];
    if n == 0 {
        return dist;
    }
    dist[source as usize] = 0.0;
    // (ordered-dist, vertex) min-heap via Reverse on bit-ordered f32.
    let mut heap: BinaryHeap<Reverse<(u32, VertexId)>> = BinaryHeap::new();
    heap.push(Reverse((0f32.to_bits(), source)));
    while let Some(Reverse((db, u))) = heap.pop() {
        let d = f32::from_bits(db);
        if d > dist[u as usize] {
            continue;
        }
        for (v, w) in g.neighbors_weighted(u) {
            let nd = d + w;
            if nd < dist[v as usize] {
                dist[v as usize] = nd;
                heap.push(Reverse((nd.to_bits(), v)));
            }
        }
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amt::NetConfig;
    use crate::graph::generators;
    use crate::graph::PartitionKind;

    fn det() -> SimConfig {
        SimConfig::deterministic(NetConfig::default())
    }

    fn weighted_graph(scale: u32, seed: u64) -> Csr {
        generators::with_random_weights(&generators::urand(scale, 4, seed), 1.0, 10.0, seed + 1)
    }

    fn close(a: &[f32], b: &[f32]) -> bool {
        a.iter().zip(b).all(|(x, y)| {
            (x.is_infinite() && y.is_infinite()) || (x - y).abs() < 1e-3
        })
    }

    #[test]
    fn async_matches_dijkstra() {
        for p in [1u32, 2, 4, 8] {
            let g = weighted_graph(6, 31 + p as u64);
            let want = dijkstra(&g, 0);
            let d = DistGraph::block(&g, p);
            let res = run_async(&g, &d, 0, det());
            assert!(close(&res.dist, &want), "p={p}");
        }
    }

    #[test]
    fn async_matches_dijkstra_under_every_policy() {
        let g = weighted_graph(6, 53);
        let want = dijkstra(&g, 0);
        let d = DistGraph::block(&g, 4);
        for policy in [
            FlushPolicy::Unbatched,
            FlushPolicy::Items(8),
            FlushPolicy::Adaptive,
            FlushPolicy::Manual,
        ] {
            let res = run_async_with(&g, &d, 0, policy, det());
            assert!(close(&res.dist, &want), "{policy:?}");
        }
    }

    #[test]
    fn bsp_matches_dijkstra() {
        for p in [1u32, 3, 4] {
            let g = weighted_graph(6, 77 + p as u64);
            let want = dijkstra(&g, 0);
            let d = DistGraph::block(&g, p);
            let res = run_bsp(&g, &d, 0, det());
            assert!(close(&res.dist, &want), "p={p}");
        }
    }

    #[test]
    fn every_engine_matches_dijkstra_under_every_partition_scheme() {
        // Includes the previously gated combination: delta × vertex cut.
        let g = generators::with_random_weights(&generators::kron(6, 5, 71), 1.0, 10.0, 72);
        let want = dijkstra(&g, 0);
        for kind in PartitionKind::all() {
            for p in [2u32, 4, 8] {
                let d = DistGraph::build_with(&g, kind.build(&g, p));
                let a = run_async(&g, &d, 0, det());
                assert!(close(&a.dist, &want), "async {kind:?} p={p}");
                let b = run_bsp(&g, &d, 0, det());
                assert!(close(&b.dist, &want), "bsp {kind:?} p={p}");
                let dl = run_delta(&g, &d, 0, det());
                assert!(close(&dl.dist, &want), "delta {kind:?} p={p}");
            }
        }
    }

    #[test]
    fn delta_matches_dijkstra_across_deltas() {
        let g = weighted_graph(6, 53);
        let want = dijkstra(&g, 0);
        let d = DistGraph::block(&g, 4);
        for delta_v in [0.1f32, 0.7, 2.0, 8.0, f32::INFINITY] {
            let res = run_delta_with(&g, &d, 0, delta_v, FlushPolicy::Adaptive, det());
            assert!(close(&res.dist, &want), "delta={delta_v}");
        }
    }

    #[test]
    fn delta_under_vertex_cut_matches_dijkstra() {
        // The tentpole acceptance point: the bucket schedule's mirror
        // protocol (settle-scatter + heavy-expand + vote-after-quiescence)
        // yields exact distances on a mirroring partition.
        let g = generators::with_random_weights(&generators::kron(6, 6, 43), 1.0, 10.0, 44);
        let d = DistGraph::build_with(&g, PartitionKind::VertexCut.build(&g, 4));
        assert!(d.has_mirrors(), "kron@4 vertex cut should mirror");
        let want = dijkstra(&g, 0);
        for delta_v in [0.5f32, 2.0, f32::INFINITY] {
            let res = run_delta_with(&g, &d, 0, delta_v, FlushPolicy::Adaptive, det());
            assert!(close(&res.dist, &want), "delta={delta_v}");
        }
    }

    #[test]
    fn bsp_folds_duplicate_relaxations_per_superstep() {
        // The combiner ships at most one relaxation per destination vertex
        // per superstep, so wire items never exceed aggregation input.
        let g = weighted_graph(6, 91);
        let d = DistGraph::block(&g, 4);
        let res = run_bsp(&g, &d, 0, det());
        assert_eq!(res.report.agg.sent_items + res.report.agg.folded, res.report.agg.items);
        assert_eq!(res.report.agg.envelopes, res.report.agg.drain_flushes);
    }

    #[test]
    fn engines_report_relaxation_counters() {
        let g = weighted_graph(6, 17);
        let d = DistGraph::block(&g, 4);
        for res in [
            run_async(&g, &d, 0, det()),
            run_bsp(&g, &d, 0, det()),
            run_delta(&g, &d, 0, det()),
        ] {
            let w = res.report.work;
            assert!(w.relaxations > 0, "no relaxations counted");
            assert!(w.useful_relaxations <= w.relaxations, "useful > total: {w:?}");
            // Every reached non-source vertex was improved at least once.
            let reached = res.dist.iter().filter(|d| d.is_finite()).count() as u64;
            assert!(w.useful_relaxations >= reached - 1, "{w:?}, reached {reached}");
        }
    }

    #[test]
    fn dist_only_entries_match_csr_checked_entries() {
        let g = generators::with_random_weights(&generators::kron(6, 5, 81), 1.0, 10.0, 82);
        let want = dijkstra(&g, 0);
        for kind in PartitionKind::all() {
            let d = DistGraph::build_with(&g, kind.build(&g, 4));
            let ad = auto_delta_dist(&d);
            assert!((ad - auto_delta(&g)).abs() < 1e-4, "{kind:?}: {ad}");
            for res in [
                run_async_dist(&d, 0, det()),
                run_bsp_dist(&d, 0, det()),
                run_delta_dist(&d, 0, det()),
            ] {
                assert!(close(&res.dist, &want), "{kind:?}");
            }
        }
    }

    #[test]
    fn auto_delta_scales_with_weight_and_degree() {
        let g = generators::with_random_weights(&generators::path(64), 2.0, 2.0 + 1e-6, 3);
        // path: avg degree ~2, weights ~2 -> delta ~1.
        let d = auto_delta(&g);
        assert!(d > 0.5 && d < 2.0, "delta {d}");
        // Unweighted graphs fall back to unit weights.
        let du = auto_delta(&generators::path(64));
        assert!(du > 0.25 && du < 1.0, "delta {du}");
        // Degenerate graphs get the safe single-bucket delta.
        assert_eq!(
            auto_delta(&Csr::from_edge_list(&crate::graph::EdgeList::new(0))),
            f32::INFINITY
        );
    }

    #[test]
    #[should_panic(expected = "delta must be positive")]
    fn zero_delta_is_rejected() {
        let g = generators::with_random_weights(&generators::path(4), 1.0, 2.0, 1);
        let d = DistGraph::block(&g, 2);
        run_delta_with(&g, &d, 0, 0.0, FlushPolicy::Adaptive, det());
    }

    #[test]
    fn dijkstra_path_graph() {
        let g = generators::with_random_weights(&generators::path(5), 1.0, 1.0 + 1e-6, 1);
        let d = dijkstra(&g, 0);
        for (i, x) in d.iter().enumerate() {
            assert!((x - i as f32).abs() < 1e-4);
        }
    }

    #[test]
    fn unreachable_is_infinite() {
        let mut el = crate::graph::EdgeList::new(3);
        el.push_weighted(0, 1, 1.0);
        let g = Csr::from_edge_list(&el);
        let d = DistGraph::block(&g, 2);
        let res = run_async(&g, &d, 0, det());
        assert_eq!(res.dist[1], 1.0);
        assert!(res.dist[2].is_infinite());
    }
}
