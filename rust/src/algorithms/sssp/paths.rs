//! SSSP with path recovery as a [`VertexProgram`]: messages carry a
//! `(tentative distance, parent)` pair, folded by distance-min, so the
//! converged states form a shortest-path tree and any `s → t` path can be
//! reconstructed by walking parent pointers — the query-serving layer
//! ([`serve`](crate::serve)) is built on this program and its multi-source
//! generalization ([`serve::wave`](crate::serve::wave)).
//!
//! Parents ride inside [`DistParent`] atomically with their distance, so
//! aggregation, mirror installs, and message reordering can never pair a
//! distance with a stale parent. Ties break toward the smaller parent id,
//! keeping the [`VertexProgram::combine`] fold associative, commutative,
//! and deterministic; with that order `<` on `(dist, parent)` is total
//! (graph build asserts weights finite and non-negative, so distances are
//! NaN-free).

use crate::amt::{FlushPolicy, SimConfig, SimReport};
use crate::engine::{self, Mode, ProgramInfo, VertexProgram};
use crate::graph::{Csr, DistGraph, VertexId};

/// A tentative distance plus the parent that proposed it (`-1` =
/// unreached; the source is its own parent).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DistParent {
    /// Tentative distance (`f32::INFINITY` = unreached).
    pub dist: f32,
    /// Global id of the relaxing neighbor (`-1` = none yet).
    pub parent: i64,
}

impl Default for DistParent {
    fn default() -> Self {
        DistParent { dist: f32::INFINITY, parent: -1 }
    }
}

impl DistParent {
    /// Strict improvement order: smaller distance wins; equal distances
    /// break toward the smaller parent id so the min-fold stays
    /// deterministic under any message interleaving.
    pub fn beats(&self, other: &DistParent) -> bool {
        self.dist < other.dist || (self.dist == other.dist && self.parent < other.parent)
    }
}

/// Label-correcting SSSP from a source vertex, recording parent pointers.
#[derive(Debug, Clone)]
pub struct SsspPathProgram {
    /// Source vertex.
    pub source: VertexId,
}

impl VertexProgram for SsspPathProgram {
    type State = DistParent;
    type Msg = DistParent;

    fn info(&self) -> ProgramInfo {
        ProgramInfo {
            name: "sssp-paths",
            mode: Mode::Converge,
            needs_weights: true,
            ordered: true, // distances remain a path metric: delta applies
            item_bytes: 16, // vertex id + distance + parent
        }
    }

    fn init(&self, _v: VertexId, _out_degree: u32) -> DistParent {
        DistParent::default()
    }

    fn seed(&self, v: VertexId) -> Option<DistParent> {
        (v == self.source).then_some(DistParent { dist: 0.0, parent: v as i64 })
    }

    fn combine(acc: &mut DistParent, new: DistParent) {
        debug_assert!(!new.dist.is_nan() && !acc.dist.is_nan(), "distances must be NaN-free");
        if new.beats(acc) {
            *acc = new;
        }
    }

    fn beats(&self, msg: &DistParent, state: &DistParent) -> bool {
        msg.beats(state)
    }

    fn apply(&self, state: &mut DistParent, msg: DistParent) -> bool {
        if msg.beats(state) {
            *state = msg;
            true
        } else {
            false
        }
    }

    fn signal(&self, state: &DistParent) -> DistParent {
        *state
    }

    fn along_edge(&self, u: VertexId, sig: &DistParent, w: f32) -> DistParent {
        DistParent { dist: sig.dist + w, parent: u as i64 }
    }

    fn priority(&self, msg: &DistParent) -> f32 {
        msg.dist
    }
}

/// Result of a path-recovering SSSP run.
#[derive(Debug)]
pub struct SsspPathResult {
    /// Tentative distances (`f32::INFINITY` = unreachable).
    pub dist: Vec<f32>,
    /// Shortest-path-tree parents (`-1` = unreachable; source is its own
    /// parent). Walk with [`recover_path`].
    pub parents: Vec<i64>,
    /// Runtime report.
    pub report: SimReport,
}

/// Run asynchronous label-correcting SSSP with path recovery. Runs on the
/// generic mirror-aware engine, so every partition scheme (vertex cuts
/// included) is supported.
pub fn run_paths(
    g: &Csr,
    dist_graph: &DistGraph,
    source: VertexId,
    policy: FlushPolicy,
    cfg: SimConfig,
) -> SsspPathResult {
    super::check_graph_matches(g, dist_graph);
    let run = engine::run_async(SsspPathProgram { source }, dist_graph, policy, cfg);
    let (dist, parents) = run.states.iter().map(|s| (s.dist, s.parent)).unzip();
    SsspPathResult { dist, parents, report: run.report }
}

/// Walk a shortest-path tree from `target` back to `source`. Returns the
/// vertex sequence `source, ..., target`, `Some([source])` for
/// `source == target`, and `None` when `target` is unreachable (or the
/// tree is malformed — the walk is bounded by `parents.len()` hops).
pub fn recover_path(parents: &[i64], source: VertexId, target: VertexId) -> Option<Vec<VertexId>> {
    let mut path = vec![target];
    let mut cur = target;
    for _ in 0..parents.len() {
        if cur == source {
            path.reverse();
            return Some(path);
        }
        let p = *parents.get(cur as usize)?;
        if p < 0 {
            return None;
        }
        cur = p as VertexId;
        path.push(cur);
    }
    None // cycle or over-long walk: malformed tree
}

/// Sum of edge weights along `path`, validating that every hop is a real
/// edge of `g`. Parallel edges contribute their minimum weight (the one a
/// shortest path would use). Returns `None` on a missing edge. An empty or
/// single-vertex path weighs `0.0`.
pub fn path_weight(g: &Csr, path: &[VertexId]) -> Option<f32> {
    let mut total = 0.0f32;
    for hop in path.windows(2) {
        let (u, v) = (hop[0], hop[1]);
        let w = g
            .neighbors_weighted(u)
            .filter(|&(x, _)| x == v)
            .map(|(_, w)| w)
            .fold(f32::INFINITY, f32::min);
        if !w.is_finite() {
            return None;
        }
        total += w;
    }
    Some(total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amt::NetConfig;
    use crate::graph::{generators, PartitionKind};

    fn det() -> SimConfig {
        SimConfig::deterministic(NetConfig::default())
    }

    fn weighted_graph(scale: u32, seed: u64) -> Csr {
        generators::with_symmetric_random_weights(
            &generators::urand(scale, 4, seed),
            1.0,
            10.0,
            seed + 1,
        )
    }

    #[test]
    fn distances_match_dijkstra_and_paths_are_valid() {
        for p in [1u32, 2, 4, 8] {
            let g = weighted_graph(6, 19 + p as u64);
            let want = super::super::dijkstra(&g, 0);
            let d = DistGraph::block(&g, p);
            let res = run_paths(&g, &d, 0, FlushPolicy::Adaptive, det());
            for (v, (&got, &exp)) in res.dist.iter().zip(&want).enumerate() {
                let ok = (got.is_infinite() && exp.is_infinite()) || (got - exp).abs() < 1e-3;
                assert!(ok, "p={p} v={v}: {got} vs {exp}");
                let path = recover_path(&res.parents, 0, v as VertexId);
                if exp.is_infinite() {
                    assert!(path.is_none(), "p={p} v={v}: path to unreachable vertex");
                } else {
                    let path = path.unwrap_or_else(|| panic!("p={p} v={v}: no path"));
                    assert_eq!(path[0], 0);
                    assert_eq!(*path.last().unwrap(), v as VertexId);
                    let w = path_weight(&g, &path).expect("path uses real edges");
                    assert!((w - got).abs() < 1e-3, "p={p} v={v}: weight {w} vs dist {got}");
                }
            }
        }
    }

    #[test]
    fn paths_are_valid_under_every_partition_scheme() {
        let g = generators::with_symmetric_random_weights(
            &generators::kron(6, 5, 61),
            1.0,
            10.0,
            62,
        );
        let want = super::super::dijkstra(&g, 0);
        for kind in PartitionKind::all() {
            let d = DistGraph::build_with(&g, kind.build(&g, 4));
            let res = run_paths(&g, &d, 0, FlushPolicy::Adaptive, det());
            for (v, &exp) in want.iter().enumerate() {
                if !exp.is_finite() {
                    continue;
                }
                let path = recover_path(&res.parents, 0, v as VertexId)
                    .unwrap_or_else(|| panic!("{kind:?} v={v}: no path"));
                let w = path_weight(&g, &path).expect("edge-valid");
                assert!((w - exp).abs() < 1e-3, "{kind:?} v={v}: {w} vs {exp}");
            }
        }
    }

    #[test]
    fn source_path_is_trivial() {
        let parents = vec![0i64, 0, 1];
        assert_eq!(recover_path(&parents, 0, 0), Some(vec![0]));
        assert_eq!(recover_path(&parents, 0, 2), Some(vec![0, 1, 2]));
        // Unreached vertex.
        assert_eq!(recover_path(&[0, -1], 0, 1), None);
        // Parent cycle never loops forever.
        assert_eq!(recover_path(&[1, 0], 0, 1), Some(vec![0, 1]));
        assert_eq!(recover_path(&[1, 2, 1], 0, 2), None);
    }
}
