//! SSSP as a [`VertexProgram`]: states and messages are tentative
//! distances, folded by min. The same ~60 lines run on all three engines —
//! asynchronous label-correcting, BSP Bellman-Ford supersteps, and the
//! ordered bucket schedule (delta-stepping), including under vertex cuts.
//!
//! The min-fold assumes a NaN-free total order on distances; graph build
//! ([`Csr::from_edge_list`](crate::graph::Csr::from_edge_list))
//! debug-asserts that weights are finite and non-negative, which makes `<`
//! a total comparison on every tentative distance that can arise (sums of
//! non-negative finite weights).

use crate::engine::{Mode, ProgramInfo, VertexProgram};
use crate::graph::VertexId;

/// Label-correcting SSSP from a source vertex.
#[derive(Debug, Clone)]
pub struct SsspProgram {
    /// Source vertex.
    pub source: VertexId,
}

impl VertexProgram for SsspProgram {
    /// Tentative distance (`f32::INFINITY` = unreached).
    type State = f32;
    type Msg = f32;

    fn info(&self) -> ProgramInfo {
        ProgramInfo {
            name: "sssp",
            mode: Mode::Converge,
            needs_weights: true,
            ordered: true, // distances are a path metric: delta applies
            item_bytes: 8, // vertex id + distance
        }
    }

    fn init(&self, _v: VertexId, _out_degree: u32) -> f32 {
        f32::INFINITY
    }

    fn seed(&self, v: VertexId) -> Option<f32> {
        (v == self.source).then_some(0.0)
    }

    fn combine(acc: &mut f32, new: f32) {
        debug_assert!(!new.is_nan() && !acc.is_nan(), "SSSP distances must be NaN-free");
        if new < *acc {
            *acc = new;
        }
    }

    fn beats(&self, msg: &f32, state: &f32) -> bool {
        msg < state
    }

    fn apply(&self, state: &mut f32, msg: f32) -> bool {
        if msg < *state {
            *state = msg;
            true
        } else {
            false
        }
    }

    fn signal(&self, state: &f32) -> f32 {
        *state
    }

    fn along_edge(&self, _u: VertexId, sig: &f32, w: f32) -> f32 {
        sig + w
    }

    fn priority(&self, msg: &f32) -> f32 {
        *msg
    }

    /// A converged distance is justified through `src -> dst` exactly when
    /// it equals `dist(src) + w` — and f32 equality is the right test,
    /// because `dst`'s converged value *is* the f32 sum computed through
    /// some such edge. The finite guard stops `INF == INF + w` from
    /// tainting whole unreached regions.
    fn depends_on_edge(&self, src: &f32, dst: &f32, w: f32) -> bool {
        src.is_finite() && *dst == *src + w
    }

    fn can_emit(&self, state: &f32) -> bool {
        state.is_finite()
    }
}
