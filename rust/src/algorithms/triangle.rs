//! Triangle counting — §6 "pattern-matching" extension.
//!
//! Sequential oracle: sorted-adjacency intersection over the degree-ordered
//! direction. Distributed: each locality enumerates wedges `(u, v, w)` with
//! `u` owned and `u < v < w` both neighbors of `u`; the edge query
//! `(v, w)?` is shipped to `v`'s owner in per-destination batches, answered
//! by local intersection, and the counts are reduced at locality 0.
//!
//! Wedge enumeration and the intersection answers both need whole rows at
//! the owner, so the engine accepts any mirror-free
//! [`PartitionScheme`](crate::graph::partition::PartitionScheme) (block,
//! edge-balanced, hash) and rejects vertex cuts.

use std::sync::Arc;

use crate::amt::sim::{Actor, Ctx, LocalityId, Message, SimConfig};
use crate::amt::SimReport;
use crate::graph::{Csr, DistGraph, Shard, VertexId};

/// Result of a distributed triangle count.
#[derive(Debug)]
pub struct TriangleResult {
    /// Number of unique triangles.
    pub triangles: u64,
    /// Runtime report.
    pub report: SimReport,
}

/// Sequential triangle count (graph must be symmetric, loop-free).
pub fn count_sequential(g: &Csr) -> u64 {
    let n = g.n();
    let mut count = 0u64;
    for u in 0..n as VertexId {
        let nu = g.neighbors(u);
        for &v in nu {
            if v <= u {
                continue;
            }
            // count w > v adjacent to both u and v
            let nv = g.neighbors(v);
            let (mut i, mut j) = (0usize, 0usize);
            while i < nu.len() && j < nv.len() {
                let (a, b) = (nu[i], nv[j]);
                if a == b {
                    if a > v {
                        count += 1;
                    }
                    i += 1;
                    j += 1;
                } else if a < b {
                    i += 1;
                } else {
                    j += 1;
                }
            }
        }
    }
    count
}

/// Triangle-count messages.
#[derive(Debug, Clone)]
pub enum TriMsg {
    /// Edge queries batched per destination: for each `(v, ws)`, how many
    /// `w in ws` are adjacent to `v`?
    Queries(Vec<(VertexId, Vec<VertexId>)>),
    /// Partial triangle count, reduced at locality 0.
    Partial(u64),
}

impl Message for TriMsg {
    fn wire_bytes(&self) -> usize {
        match self {
            TriMsg::Queries(qs) => qs.iter().map(|(_, ws)| 8 + 4 * ws.len()).sum(),
            TriMsg::Partial(_) => 8,
        }
    }

    fn item_count(&self) -> usize {
        match self {
            TriMsg::Queries(qs) => qs.len(),
            TriMsg::Partial(_) => 1,
        }
    }
}

struct TriActor {
    shard: Arc<Shard>,
    dist: Arc<DistGraph>,
    local_count: u64,
    /// Populated on locality 0 after the run.
    total: u64,
    phase: u8,
    /// Row-decode scratch (reused; plain storage never touches it).
    scratch: Vec<VertexId>,
}

impl TriActor {
    fn local_intersect(&mut self, v_local: usize, ws: &[VertexId]) -> u64 {
        let TriActor { shard, scratch, .. } = self;
        let nv = shard.out_neighbors_into(v_local, scratch);
        let mut c = 0u64;
        for &w in ws {
            if nv.binary_search(&w).is_ok() {
                c += 1;
            }
        }
        c
    }
}

impl Actor for TriActor {
    type Msg = TriMsg;

    fn on_start(&mut self, ctx: &mut Ctx<TriMsg>) {
        let here = ctx.locality();
        let p = ctx.n_localities() as usize;
        // wedge enumeration: u owned, v > u, w > v both adjacent to u.
        let mut outgoing: Vec<Vec<(VertexId, Vec<VertexId>)>> = vec![Vec::new(); p];
        let shard = Arc::clone(&self.shard);
        let mut row: Vec<VertexId> = Vec::new();
        for lu in 0..shard.n_local() {
            let u = shard.global_id(lu);
            let nu = shard.out_neighbors_into(lu, &mut row);
            for (i, &v) in nu.iter().enumerate() {
                if v <= u {
                    continue;
                }
                let ws: Vec<VertexId> = nu[i + 1..].iter().cloned().filter(|&w| w > v).collect();
                if ws.is_empty() {
                    continue;
                }
                let dst = self.dist.owner(v);
                if dst == here {
                    let c = self.local_intersect(shard.local_index(v), &ws);
                    self.local_count += c;
                } else {
                    outgoing[dst as usize].push((v, ws));
                }
            }
        }
        for (dst, batch) in outgoing.into_iter().enumerate() {
            if !batch.is_empty() {
                ctx.send(dst as LocalityId, TriMsg::Queries(batch));
            }
        }
        self.phase = 1;
        ctx.request_barrier();
    }

    fn on_message(&mut self, _ctx: &mut Ctx<TriMsg>, _from: LocalityId, msg: TriMsg) {
        match msg {
            TriMsg::Queries(qs) => {
                for (v, ws) in qs {
                    let l = self.shard.local_index(v);
                    let c = self.local_intersect(l, &ws);
                    self.local_count += c;
                }
            }
            TriMsg::Partial(c) => {
                self.total += c;
            }
        }
    }

    fn on_barrier(&mut self, ctx: &mut Ctx<TriMsg>, _epoch: u64) {
        if self.phase == 1 {
            ctx.send(0, TriMsg::Partial(self.local_count));
            self.phase = 2;
            ctx.request_barrier();
        }
        // phase 2 barrier: locality 0 has summed all partials; quiesce.
    }
}

/// Run the distributed triangle count.
pub fn run(dist: &DistGraph, cfg: SimConfig) -> TriangleResult {
    // Coordinator callers reject this combination gracefully up front;
    // the re-check here turns direct library misuse into a clear panic
    // instead of silently wrong counts over unexpanded mirror rows.
    if let Err(e) = crate::engine::require_mirror_free(dist, "triangle counting") {
        panic!("{e}");
    }
    let dist = Arc::new(dist.clone());
    let actors: Vec<TriActor> = dist
        .shards
        .iter()
        .map(|s| TriActor {
            shard: Arc::new(s.clone()),
            dist: Arc::clone(&dist),
            local_count: 0,
            total: 0,
            phase: 0,
            scratch: Vec::new(),
        })
        .collect();
    let (actors, mut report) = crate::amt::run_actors(&cfg, actors);
    report.partition = dist.partition_stats();
    report.mem = dist.mem_stats();
    TriangleResult { triangles: actors[0].total, report }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amt::NetConfig;
    use crate::graph::{builder::GraphBuilder, generators};

    #[test]
    fn single_triangle() {
        let g = GraphBuilder::new(3).edges([(0, 1), (1, 2), (2, 0)]).symmetrize().build();
        assert_eq!(count_sequential(&g), 1);
        let d = DistGraph::block(&g, 2);
        let res = run(&d, SimConfig::deterministic(NetConfig::default()));
        assert_eq!(res.triangles, 1);
    }

    #[test]
    fn complete_graph_count() {
        // K5 has C(5,3) = 10 triangles.
        let g = generators::complete(5);
        assert_eq!(count_sequential(&g), 10);
        for p in [1u32, 2, 3] {
            let d = DistGraph::block(&g, p);
            let res = run(&d, SimConfig::deterministic(NetConfig::default()));
            assert_eq!(res.triangles, 10, "p={p}");
        }
    }

    #[test]
    fn distributed_matches_sequential_on_random_graphs() {
        for p in [1u32, 2, 4, 8] {
            let g = generators::kron(7, 6, 55 + p as u64);
            let want = count_sequential(&g);
            let d = DistGraph::block(&g, p);
            let res = run(&d, SimConfig::deterministic(NetConfig::default()));
            assert_eq!(res.triangles, want, "p={p}");
        }
    }

    #[test]
    fn triangle_free_graph() {
        let g = generators::grid(4, 4); // bipartite, no triangles
        assert_eq!(count_sequential(&g), 0);
        let d = DistGraph::block(&g, 4);
        assert_eq!(run(&d, SimConfig::deterministic(NetConfig::default())).triangles, 0);
    }
}
