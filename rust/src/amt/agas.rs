//! AGAS-style global address resolution.
//!
//! HPX's Active Global Address Space lets a program hold a *global* id and
//! resolve it to (locality, local address) at runtime, so distributed data
//! structures can be addressed uniformly. Our equivalent is deliberately
//! small: block-distributed objects register their [`super::sim::LocalityId`]
//! mapping here, and algorithms resolve global indices through it instead of
//! hard-coding partition arithmetic.

use super::sim::LocalityId;

/// Resolved global address: which locality owns the element and at what
/// local offset it lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GlobalAddress {
    /// Owning locality.
    pub locality: LocalityId,
    /// Offset within that locality's segment.
    pub offset: usize,
}

/// Block-cyclic-free 1-D block resolver: element `i` of a length-`len`
/// object distributed over `n_localities` in contiguous blocks.
///
/// The block sizes follow HPX's `container_layout` convention: the first
/// `len % n` localities get `ceil(len / n)` elements, the rest get
/// `floor(len / n)`.
#[derive(Debug, Clone)]
pub struct BlockMap {
    len: usize,
    n_localities: u32,
    big: usize,   // ceil(len / n)
    small: usize, // floor(len / n)
    n_big: usize, // how many localities carry `big`
}

impl BlockMap {
    /// Create a block map for `len` elements over `n_localities`.
    pub fn new(len: usize, n_localities: u32) -> Self {
        assert!(n_localities > 0, "need at least one locality");
        let n = n_localities as usize;
        let small = len / n;
        let n_big = len % n;
        let big = small + usize::from(n_big > 0);
        BlockMap { len, n_localities, big, small, n_big }
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the map covers no elements.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of localities.
    pub fn n_localities(&self) -> u32 {
        self.n_localities
    }

    /// Resolve a global index to its owner + offset.
    pub fn resolve(&self, index: usize) -> GlobalAddress {
        debug_assert!(index < self.len, "index {index} out of bounds {}", self.len);
        let big_span = self.n_big * self.big;
        if index < big_span {
            GlobalAddress {
                locality: (index / self.big) as LocalityId,
                offset: index % self.big,
            }
        } else {
            let rest = index - big_span;
            GlobalAddress {
                locality: (self.n_big + rest / self.small.max(1)) as LocalityId,
                offset: rest % self.small.max(1),
            }
        }
    }

    /// Owning locality of a global index.
    pub fn owner(&self, index: usize) -> LocalityId {
        self.resolve(index).locality
    }

    /// Half-open global index range owned by `locality`.
    pub fn range_of(&self, locality: LocalityId) -> std::ops::Range<usize> {
        let l = locality as usize;
        assert!(l < self.n_localities as usize);
        if l < self.n_big {
            let start = l * self.big;
            start..start + self.big
        } else {
            let start = self.n_big * self.big + (l - self.n_big) * self.small;
            start..start + self.small
        }
    }

    /// Number of elements owned by `locality`.
    pub fn segment_len(&self, locality: LocalityId) -> usize {
        let r = self.range_of(locality);
        r.end - r.start
    }

    /// Convert a (locality, offset) pair back to the global index.
    pub fn global_index(&self, addr: GlobalAddress) -> usize {
        self.range_of(addr.locality).start + addr.offset
    }
}

/// A tiny AGAS registry: names distributed objects and returns their block
/// maps. Algorithms that hold several distributed vectors (parents, ranks,
/// contributions) register them once and resolve through the handle.
#[derive(Debug, Default)]
pub struct Agas {
    objects: Vec<(String, BlockMap)>,
}

/// Handle to a registered distributed object.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AgasHandle(usize);

impl Agas {
    /// Create an empty registry.
    pub fn new() -> Self {
        Agas::default()
    }

    /// Register a distributed object layout under `name`.
    pub fn register(&mut self, name: &str, map: BlockMap) -> AgasHandle {
        self.objects.push((name.to_string(), map));
        AgasHandle(self.objects.len() - 1)
    }

    /// Resolve `index` within the object behind `handle`.
    pub fn resolve(&self, handle: AgasHandle, index: usize) -> GlobalAddress {
        self.objects[handle.0].1.resolve(index)
    }

    /// Look up a handle by registration name.
    pub fn lookup(&self, name: &str) -> Option<AgasHandle> {
        self.objects.iter().position(|(n, _)| n == name).map(AgasHandle)
    }

    /// The block map behind a handle.
    pub fn map(&self, handle: AgasHandle) -> &BlockMap {
        &self.objects[handle.0].1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_split() {
        let m = BlockMap::new(12, 4);
        assert_eq!(m.segment_len(0), 3);
        assert_eq!(m.segment_len(3), 3);
        assert_eq!(m.owner(0), 0);
        assert_eq!(m.owner(11), 3);
        assert_eq!(m.range_of(2), 6..9);
    }

    #[test]
    fn uneven_split_front_loads_remainder() {
        let m = BlockMap::new(10, 4); // 3,3,2,2
        assert_eq!(m.segment_len(0), 3);
        assert_eq!(m.segment_len(1), 3);
        assert_eq!(m.segment_len(2), 2);
        assert_eq!(m.segment_len(3), 2);
        let total: usize = (0..4).map(|l| m.segment_len(l)).sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn resolve_roundtrips_with_global_index() {
        for (len, n) in [(1usize, 1u32), (10, 3), (17, 5), (100, 7), (5, 8)] {
            let m = BlockMap::new(len, n);
            for i in 0..len {
                let a = m.resolve(i);
                assert_eq!(m.global_index(a), i, "len={len} n={n} i={i}");
                assert!(m.range_of(a.locality).contains(&i));
            }
        }
    }

    #[test]
    fn more_localities_than_elements() {
        let m = BlockMap::new(3, 8);
        // 3 localities get 1 element each, the rest get 0.
        let total: usize = (0..8).map(|l| m.segment_len(l)).sum();
        assert_eq!(total, 3);
        assert_eq!(m.owner(2), 2);
        assert_eq!(m.segment_len(7), 0);
    }

    #[test]
    fn agas_registry_named_lookup() {
        let mut agas = Agas::new();
        let h = agas.register("parents", BlockMap::new(100, 4));
        assert_eq!(agas.lookup("parents"), Some(h));
        assert_eq!(agas.lookup("missing"), None);
        let a = agas.resolve(h, 99);
        assert_eq!(a.locality, 3);
    }
}
