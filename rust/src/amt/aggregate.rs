//! Message aggregation: typed per-destination combiners with pluggable
//! flush policies, a self-tuning coalescing layer, and a zero-allocation
//! steady-state hot path.
//!
//! The paper's central negative result is that fine-grained asynchronous
//! algorithms lose to BSP because per-message CPU/latency overheads
//! dominate; its follow-up ("Overcoming Latency-bound Limitations of
//! Distributed Graph Algorithms using the HPX Runtime System") and the
//! AM++ lineage show that a *runtime-level* coalescing layer — one that
//! adapts to observed network behaviour, not per-algorithm hacks — is what
//! closes the gap. This module is that layer: every asynchronous algorithm
//! folds its remote actions into an [`Aggregator`] instead of calling
//! [`Ctx::send`](super::sim::Ctx::send) per action.
//!
//! # Slot spaces
//!
//! An [`Aggregator`] keeps one dense combiner per destination locality,
//! indexed by **destination-local slot**, and is constructed for exactly
//! one [`SlotSpace`]:
//!
//! * [`SlotSpace::Master`] — the slot is the destination's dense owned-row
//!   index ([`PartitionScheme::master_index`](crate::graph::partition::PartitionScheme::master_index),
//!   precomputed per ghost in the [`Shard`](crate::graph::Shard) ghost
//!   table). Ghost-row improvements and remote emissions ride here.
//! * [`SlotSpace::Mirror`] — the slot is the destination's ghost-row slot
//!   (the master's mirror table). Master→mirror scatter rides here.
//!
//! The two spaces have very different fan-in under vertex cuts (a few hot
//! masters absorb most relaxations; scatter spreads thin across mirrors),
//! which is why the engines hold one `Aggregator` per space and why the
//! latency estimator below is keyed by `(destination, slot space)` — each
//! instance tunes its own destinations independently.
//!
//! # Flush policies
//!
//! Pushing a value either claims an empty slot or *folds* into the pending
//! one through the reduction hook (sum for PageRank contributions, min for
//! BFS levels / SSSP distances / CC labels), so a flushed batch carries at
//! most one item per destination slot. When the [`FlushPolicy`] fires, the
//! destination's batch is handed back to the caller to ship as one
//! envelope; whatever is still buffered is shipped by an explicit
//! [`Aggregator::drain`] at the end of a handler or superstep phase (the
//! quiescence/barrier drain). Two policies go beyond static item counts:
//!
//! * [`FlushPolicy::TimeWindow`] — flush a destination once its *oldest*
//!   pending item has waited the window out. Engines drive it with the sim
//!   clock through [`Aggregator::poll`] at handler/step boundaries and a
//!   timer at [`Aggregator::next_deadline`]; see the poll contract in
//!   `ARCHITECTURE.md`. `time:0` degenerates to [`FlushPolicy::Unbatched`].
//! * [`FlushPolicy::LatencyAdaptive`] — starts at the static break-even
//!   threshold ([`adaptive_items`]) and then *observes*: every emitted
//!   envelope is traced through the runtime
//!   ([`Ctx::send_traced`](super::sim::Ctx::send_traced)), the delivery
//!   ack feeds [`Aggregator::observe_ack`], and a per-destination EWMA +
//!   hill-climbing tuner grows the item threshold while the amortized
//!   per-item latency share keeps falling and shrinks it back toward the
//!   break-even floor when queueing delay inflates observed latency.
//!
//! # Hot path
//!
//! Combiner storage is flat: one dense value array per destination plus a
//! generation-stamped occupancy array — a push is one integer compare
//! (stamp vs. the destination's current generation), never an `Option`
//! discriminant; a flush retires the whole combiner by bumping the
//! generation instead of clearing slots. Flushed batch vectors come from a
//! recycle pool ([`Aggregator::recycle`] — receivers hand consumed batch
//! vectors back), so steady-state aggregation allocates nothing;
//! [`AggStats::pool_reuses`]/[`AggStats::pool_allocs`] measure it.
//!
//! [`AggStats`] counts items, folds, emitted envelopes, pool traffic, and
//! delivery observations; algorithm drivers merge them into
//! [`SimReport::agg`](super::metrics::SimReport) (and per-slot-space into
//! `agg_master`/`agg_mirror`) so every experiment reports the
//! naive-vs-aggregated axis without side channels.
//!
//! # Reliable delivery
//!
//! Under `reliability=acked` ([`Aggregator::with_reliability`]) the
//! aggregator doubles as the end-to-end reliable-delivery layer the
//! fault-injection harness ([`fault`](super::fault)) exercises: every
//! sealed batch carries a per-`(source, destination, slot space)`
//! sequence number and a delivery-trace token; the receiver's window
//! ([`Aggregator::admit`]) rejects duplicates idempotently; an unacked
//! envelope is retransmitted from [`Aggregator::poll`] with exponential
//! backoff until [`RETRANSMIT_MAX_ATTEMPTS`] is exhausted (the give-up
//! counter is the engines' failure detector for crashed destinations).
//! With reliability off, none of this state exists: no sequence numbers,
//! no extra tokens, [`Aggregator::admit`] is a constant `true` — the
//! envelope-parity properties the suites pin are untouched.

use super::net::NetConfig;
use super::sim::{LocalityId, SimTime};

/// Which destination-local index space an [`Aggregator`] combines over.
/// See the module docs; the engines hold one instance per space so
/// master-bound and mirror-bound traffic tune and report independently.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotSpace {
    /// Slots are dense owned-row (master) indices at the destination.
    Master,
    /// Slots are ghost-row (mirror) indices at the destination.
    Mirror,
}

/// When a per-destination combiner is flushed into an envelope.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlushPolicy {
    /// One envelope per item — the paper's naive per-remote-edge path,
    /// kept only as the ablation baseline.
    Unbatched,
    /// Flush a destination once it holds this many (distinct) items.
    Items(usize),
    /// Flush a destination once its payload reaches this many bytes.
    Bytes(usize),
    /// Derive a static item threshold from the [`NetConfig`] cost model
    /// once, at construction time (see [`adaptive_items`]).
    Adaptive,
    /// Flush a destination when its oldest pending item has waited this
    /// many microseconds, measured on the simulated clock via
    /// [`Aggregator::poll`]. `TimeWindow(0)` ≡ [`FlushPolicy::Unbatched`].
    TimeWindow(u64),
    /// Self-tuning item threshold: starts at the [`adaptive_items`]
    /// break-even and hill-climbs on observed per-envelope delivery
    /// latency fed back through [`Aggregator::observe_ack`], separately
    /// per destination.
    LatencyAdaptive,
    /// Never auto-flush; everything waits for the explicit drain at the
    /// end of the handler or superstep phase (maximal batching).
    Manual,
}

impl FlushPolicy {
    /// Parse a config/CLI spelling: `unbatched` (alias `naive`),
    /// `items:N`, `bytes:N`, `adaptive`, `latency`, `time:US`, `manual`.
    /// Zero thresholds that would silently degenerate (`items:0`,
    /// `bytes:0`) are rejected with an explanation; `time:0` is accepted
    /// as the documented [`FlushPolicy::Unbatched`] degeneration.
    pub fn parse(s: &str) -> std::result::Result<FlushPolicy, String> {
        match s {
            "unbatched" | "naive" => return Ok(FlushPolicy::Unbatched),
            "adaptive" => return Ok(FlushPolicy::Adaptive),
            "latency" | "latency-adaptive" => return Ok(FlushPolicy::LatencyAdaptive),
            "manual" => return Ok(FlushPolicy::Manual),
            _ => {}
        }
        let bad = || {
            format!(
                "unknown flush policy `{s}` (want unbatched|items:N|bytes:N|adaptive|\
                 latency|time:US|manual)"
            )
        };
        let (kind, val) = s.split_once(':').ok_or_else(bad)?;
        let n: u64 = val.parse().map_err(|_| bad())?;
        match kind {
            "items" if n == 0 => Err(
                "flush policy `items:0` would flush before any item is buffered; use \
                 `unbatched` for per-item envelopes or `manual` for drain-only batching"
                    .into(),
            ),
            "bytes" if n == 0 => Err(
                "flush policy `bytes:0` would flush before any item is buffered; use \
                 `unbatched` for per-item envelopes or `manual` for drain-only batching"
                    .into(),
            ),
            "items" => Ok(FlushPolicy::Items(n as usize)),
            "bytes" => Ok(FlushPolicy::Bytes(n as usize)),
            "time" => Ok(FlushPolicy::TimeWindow(n)),
            _ => Err(bad()),
        }
    }

    /// Distinct-item threshold that triggers a flush; `None` = drain-only
    /// (or, for a non-zero [`FlushPolicy::TimeWindow`], time-driven via
    /// [`Aggregator::poll`]). For [`FlushPolicy::LatencyAdaptive`] this is
    /// the *starting* threshold; the per-destination tuners move it.
    pub fn item_threshold(&self, net: &NetConfig, item_bytes: usize) -> Option<usize> {
        match *self {
            FlushPolicy::Unbatched => Some(1),
            FlushPolicy::Items(k) => Some(k.max(1)),
            FlushPolicy::Bytes(b) => Some((b / item_bytes.max(1)).max(1)),
            FlushPolicy::Adaptive | FlushPolicy::LatencyAdaptive => {
                Some(adaptive_items(net, item_bytes))
            }
            FlushPolicy::TimeWindow(0) => Some(1),
            FlushPolicy::TimeWindow(_) => None,
            FlushPolicy::Manual => None,
        }
    }

    /// The time window in microseconds when this policy is a non-zero
    /// [`FlushPolicy::TimeWindow`] (the zero window is the unbatched
    /// degeneration and needs no clock).
    pub fn time_window_us(&self) -> Option<f64> {
        match *self {
            FlushPolicy::TimeWindow(w) if w > 0 => Some(w as f64),
            _ => None,
        }
    }

    /// Whether emitted batches should be traced through the runtime so
    /// delivery latency is observed ([`Aggregator::observe_ack`]). True
    /// for the policies the A7 ablation compares — the static break-even,
    /// the time window, and the self-tuner — so their observed-latency
    /// columns populate; the tuner is the only one that *acts* on it.
    pub fn traced(&self) -> bool {
        matches!(
            *self,
            FlushPolicy::Adaptive | FlushPolicy::LatencyAdaptive | FlushPolicy::TimeWindow(1..)
        )
    }
}

/// Break-even batch size for [`FlushPolicy::Adaptive`] (and the starting
/// point / floor of [`FlushPolicy::LatencyAdaptive`]): the item count at
/// which the fixed per-envelope cost amortizes to 10% of the marginal
/// per-item cost. On a zero-cost network there is nothing to amortize and
/// a fixed 1024 is used.
pub fn adaptive_items(net: &NetConfig, item_bytes: usize) -> usize {
    let fixed = net.send_cpu_us
        + net.recv_cpu_us
        + net.latency_us
        + net.overhead_bytes as f64 / net.bandwidth_bytes_per_us;
    let per_item = 2.0 * net.per_item_cpu_us + item_bytes as f64 / net.bandwidth_bytes_per_us;
    if fixed <= 0.0 || per_item <= 0.0 || !fixed.is_finite() || !per_item.is_finite() {
        return 1024;
    }
    ((fixed / (0.1 * per_item)).ceil() as usize).clamp(16, 1 << 16)
}

/// One flushed combiner: `(destination-local slot, folded value)` pairs
/// sorted by slot (deterministic wire order; slots ascend with global ids,
/// so this is the same order the old global-id batches had). Algorithms
/// wrap this in their message enum; [`Batch::wire_bytes`] / [`Batch::len`]
/// feed the [`Message`](super::sim::Message) impl. Receivers should hand
/// the consumed vector back through [`Aggregator::recycle`] (via
/// [`Batch::into_items`]) so the steady state allocates nothing.
#[derive(Debug, Clone)]
pub struct Batch<V> {
    /// Folded items, sorted by destination-local slot.
    pub items: Vec<(u32, V)>,
    item_bytes: usize,
    /// Delivery-trace token under traced policies (see
    /// [`FlushPolicy::traced`]); the shipper passes it to
    /// [`Ctx::send_traced`](super::sim::Ctx::send_traced) and routes the
    /// ack back to [`Aggregator::observe_ack`]. Always minted under
    /// `reliability=acked` (the ack doubles as the delivery receipt).
    token: Option<u64>,
    /// Per-`(source, destination, slot space)` sequence number under
    /// `reliability=acked`; `None` with reliability off.
    seq: Option<u64>,
}

impl<V> Batch<V> {
    /// Serialized payload size (items x per-item wire bytes, plus the
    /// 8-byte sequence header under `reliability=acked`). The trace
    /// token is runtime bookkeeping, not payload.
    pub fn wire_bytes(&self) -> usize {
        self.items.len() * self.item_bytes + if self.seq.is_some() { 8 } else { 0 }
    }

    /// Number of folded items carried.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// True when the batch carries nothing.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Delivery-trace token, when the emitting policy is traced or the
    /// aggregator runs reliable delivery.
    pub fn token(&self) -> Option<u64> {
        self.token
    }

    /// Sequence number under `reliability=acked`; receivers feed it to
    /// [`Aggregator::admit`] before applying the batch.
    pub fn seq(&self) -> Option<u64> {
        self.seq
    }

    /// Consume the batch, returning the item vector (e.g. to drain it and
    /// hand the empty vector to [`Aggregator::recycle`]).
    pub fn into_items(self) -> Vec<(u32, V)> {
        self.items
    }
}

/// Aggregation accounting, merged into
/// [`SimReport::agg`](super::metrics::SimReport) (and per-slot-space into
/// `agg_master` / `agg_mirror`) by the engines after a run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AggStats {
    /// Values pushed through [`Aggregator::accumulate`].
    pub items: u64,
    /// Values folded into an already-pending slot (combiner hits — traffic
    /// that never reaches the wire).
    pub folded: u64,
    /// Batches handed back to the caller (== envelopes if each batch is
    /// shipped as one send).
    pub envelopes: u64,
    /// Items across all emitted batches.
    pub sent_items: u64,
    /// Batches emitted because the policy threshold (item count or time
    /// window) fired.
    pub policy_flushes: u64,
    /// Batches emitted by explicit drains (handler end / barrier).
    pub drain_flushes: u64,
    /// Batch vectors served from the recycle pool.
    pub pool_reuses: u64,
    /// Batch vectors freshly allocated (pool empty).
    pub pool_allocs: u64,
    /// Delivery observations received ([`Aggregator::observe_ack`]).
    pub acks: u64,
    /// Sum of observed per-envelope delivery latencies, in nanoseconds
    /// (fixed point so the stats block stays `Eq`-comparable).
    pub ack_latency_ns: u64,
}

impl AggStats {
    /// Accumulate another stats block into this one.
    pub fn merge(&mut self, other: &AggStats) {
        self.items += other.items;
        self.folded += other.folded;
        self.envelopes += other.envelopes;
        self.sent_items += other.sent_items;
        self.policy_flushes += other.policy_flushes;
        self.drain_flushes += other.drain_flushes;
        self.pool_reuses += other.pool_reuses;
        self.pool_allocs += other.pool_allocs;
        self.acks += other.acks;
        self.ack_latency_ns += other.ack_latency_ns;
    }

    /// Mean items per emitted batch.
    pub fn fold_factor(&self) -> f64 {
        if self.envelopes == 0 {
            0.0
        } else {
            self.items as f64 / self.envelopes as f64
        }
    }

    /// Mean observed per-envelope delivery latency, us (0 when untraced).
    pub fn mean_obs_latency_us(&self) -> f64 {
        if self.acks == 0 {
            0.0
        } else {
            self.ack_latency_ns as f64 / 1e3 / self.acks as f64
        }
    }

    /// Fraction of emitted batches whose vector came from the recycle
    /// pool (1.0 == allocation-free steady state reached immediately).
    pub fn pool_reuse_ratio(&self) -> f64 {
        let total = self.pool_reuses + self.pool_allocs;
        if total == 0 {
            0.0
        } else {
            self.pool_reuses as f64 / total as f64
        }
    }
}

/// EWMA epoch length: tuning decisions are made every this many acks.
const TUNER_EPOCH: u32 = 8;
/// EWMA smoothing factor for the latency / per-item-cost estimators.
const TUNER_ALPHA: f64 = 0.25;
/// Observed envelope latency above this multiple of the uncongested floor
/// is read as queueing delay: time to shrink the batch size.
const TUNER_QUEUE_INFLATION: f64 = 4.0;
/// The threshold may grow to at most this multiple of the break-even
/// floor (and never below the floor — batching below break-even provably
/// wastes, which is also what pins `LatencyAdaptive` envelopes at or
/// under the static `Adaptive` count).
const TUNER_MAX_GROWTH: usize = 64;

/// Per-destination latency estimator + hill climber for
/// [`FlushPolicy::LatencyAdaptive`]. Purely observation-driven: it sees
/// only `(observed envelope latency, items carried)` pairs from
/// [`Aggregator::observe_ack`] and outputs the destination's current item
/// threshold. Deterministic — state advances only on acks, which in the
/// simulated runtime arrive at deterministic times.
#[derive(Debug, Clone)]
struct Tuner {
    /// Current item threshold for this destination.
    limit: usize,
    /// EWMA of per-item latency share (envelope latency / items).
    per_item_ewma: f64,
    /// EWMA of whole-envelope delivery latency.
    latency_ewma: f64,
    /// Smallest envelope latency seen — the uncongested baseline.
    floor_latency: f64,
    /// Acks since the last tuning decision.
    epoch_acks: u32,
    /// Per-item cost at the last decision (hill-climb comparison point).
    last_cost: f64,
    /// Current hill-climb direction.
    grow: bool,
}

impl Tuner {
    fn new(base: usize) -> Self {
        Tuner {
            limit: base,
            per_item_ewma: 0.0,
            latency_ewma: 0.0,
            floor_latency: f64::INFINITY,
            epoch_acks: 0,
            last_cost: f64::INFINITY,
            grow: true,
        }
    }

    fn observe(&mut self, latency_us: f64, items: u32, base: usize) {
        let per_item = latency_us / items.max(1) as f64;
        if self.epoch_acks == 0 && self.last_cost.is_infinite() && self.per_item_ewma == 0.0 {
            self.per_item_ewma = per_item;
            self.latency_ewma = latency_us;
        } else {
            self.per_item_ewma += TUNER_ALPHA * (per_item - self.per_item_ewma);
            self.latency_ewma += TUNER_ALPHA * (latency_us - self.latency_ewma);
        }
        self.floor_latency = self.floor_latency.min(latency_us);
        self.epoch_acks += 1;
        if self.epoch_acks < TUNER_EPOCH {
            return;
        }
        self.epoch_acks = 0;
        let cost = self.per_item_ewma;
        if self.latency_ewma > TUNER_QUEUE_INFLATION * self.floor_latency.max(f64::MIN_POSITIVE) {
            // Queueing delay inflates observed latency: envelopes are
            // waiting on each other, not on the wire. Back off.
            self.grow = false;
        } else if cost > self.last_cost * 1.02 {
            // Amortized per-item cost got worse: reverse direction.
            self.grow = !self.grow;
        }
        // else: cost still falling (or flat) — keep climbing.
        self.last_cost = cost;
        self.limit = if self.grow {
            (self.limit.saturating_mul(2)).min(base * TUNER_MAX_GROWTH)
        } else {
            (self.limit / 2).max(base)
        };
    }
}

/// Batch vectors kept for reuse (bounds pool memory).
const POOL_CAP: usize = 32;
/// `limit` sentinel: no item-count threshold (drain/time-driven only).
const NO_LIMIT: usize = usize::MAX;

/// Initial retransmit timeout under `reliability=acked`, in simulated us;
/// doubles per attempt (exponential backoff).
pub const RETRANSMIT_RTO_US: f64 = 500.0;
/// Retransmissions attempted before an unacked envelope is abandoned and
/// counted as a give-up — the engines' failure detector for a crashed
/// destination (a live peer on a lossy link acks well within the backoff
/// schedule; a fail-stopped one never will).
pub const RETRANSMIT_MAX_ATTEMPTS: u32 = 6;

/// One sent-but-unacked envelope retained for retransmission.
#[derive(Debug, Clone)]
struct Outstanding<V> {
    /// Trace token of the most recent transmission (acks for earlier
    /// transmissions of the same envelope arrive as unknown tokens and
    /// are ignored — the sequence number, not the token, is identity).
    token: u64,
    dst: LocalityId,
    seq: u64,
    items: Vec<(u32, V)>,
    /// Simulated time after which [`Aggregator::poll`] resends.
    deadline: SimTime,
    /// Retransmissions performed so far.
    attempt: u32,
}

/// Receive-side dedup window for one source locality: sequence numbers
/// below `next_expected` (or parked in `ahead`) have been applied, so a
/// second arrival is a duplicate and is rejected idempotently.
#[derive(Debug, Clone, Default)]
struct SeqWindow {
    next_expected: u64,
    ahead: std::collections::BTreeSet<u64>,
}

impl SeqWindow {
    /// Returns true when `seq` is new (and records it), false when it is
    /// a duplicate.
    fn admit(&mut self, seq: u64) -> bool {
        if seq < self.next_expected || self.ahead.contains(&seq) {
            return false;
        }
        if seq == self.next_expected {
            self.next_expected += 1;
            while self.ahead.remove(&self.next_expected) {
                self.next_expected += 1;
            }
        } else {
            self.ahead.insert(seq);
        }
        true
    }
}

/// Sender + receiver state for `reliability=acked`. Exists only when the
/// aggregator was built [`Aggregator::with_reliability`]`(true)`; the
/// fast path carries none of it.
#[derive(Debug, Clone)]
struct ReliableState<V> {
    /// Next sequence number per destination locality.
    next_seq: Vec<u64>,
    /// Sent-but-unacked envelopes, retransmitted from [`Aggregator::poll`].
    outstanding: Vec<Outstanding<V>>,
    /// Per-source receive windows.
    windows: Vec<SeqWindow>,
    /// Envelopes resent after an ack timeout.
    retransmits: u64,
    /// Incoming duplicates rejected by [`Aggregator::admit`].
    dedup_hits: u64,
    /// Envelopes abandoned after [`RETRANSMIT_MAX_ATTEMPTS`].
    give_ups: u64,
}

impl<V> ReliableState<V> {
    fn new(n: usize) -> Self {
        ReliableState {
            next_seq: vec![0; n],
            outstanding: Vec::new(),
            windows: vec![SeqWindow::default(); n],
            retransmits: 0,
            dedup_hits: 0,
            give_ups: 0,
        }
    }
}

/// Typed per-destination message combiner. See the module docs.
pub struct Aggregator<V> {
    here: LocalityId,
    space: SlotSpace,
    /// Dense value slots per destination; a slot holds live data iff its
    /// stamp equals the destination's current generation.
    values: Vec<Vec<V>>,
    stamp: Vec<Vec<u32>>,
    generation: Vec<u32>,
    /// Occupied slot offsets per destination, in first-touch order.
    touched: Vec<Vec<u32>>,
    /// Per-destination flush threshold ([`NO_LIMIT`] = drain/time only).
    limit: Vec<usize>,
    /// First-touch time per destination (drives [`FlushPolicy::TimeWindow`]).
    oldest: Vec<SimTime>,
    window_us: Option<f64>,
    /// All destinations flush at one item (no combiner state at all).
    unbatched: bool,
    /// Per-destination hill climbers ([`FlushPolicy::LatencyAdaptive`]).
    tuners: Vec<Tuner>,
    /// Break-even threshold — the tuners' floor and starting point.
    base_items: usize,
    traced: bool,
    next_token: u64,
    /// Outstanding traced envelopes: `(token, destination, items)`.
    inflight: Vec<(u64, LocalityId, u32)>,
    pool: Vec<Vec<(u32, V)>>,
    item_bytes: usize,
    fold: fn(&mut V, V),
    stats: AggStats,
    /// Reliable-delivery state (`reliability=acked`); `None` keeps the
    /// zero-fault fast path byte-identical.
    reliable: Option<ReliableState<V>>,
    /// Most recent simulated time seen (via [`Aggregator::accumulate`] /
    /// [`Aggregator::poll`]); stamps retransmit deadlines for batches
    /// sealed from clock-less paths like [`Aggregator::drain`].
    clock: SimTime,
}

impl<V: Clone + Default> Aggregator<V> {
    /// Create a combiner over the destinations' dense slot spaces
    /// (`counts[l]` = locality `l`'s slot count: its owned-row count for
    /// [`SlotSpace::Master`] traffic, its ghost-row count for
    /// [`SlotSpace::Mirror`] scatter —
    /// [`DistGraph::owned_counts`](crate::graph::DistGraph::owned_counts) /
    /// [`DistGraph::ghost_counts`](crate::graph::DistGraph::ghost_counts)).
    /// `item_bytes` is the per-item wire size; `fold` merges a new value
    /// into a pending one and must be associative and insensitive to
    /// arrival order (sum, min, ...), so batching never changes results.
    pub fn new(
        counts: &[usize],
        here: LocalityId,
        space: SlotSpace,
        policy: FlushPolicy,
        net: &NetConfig,
        item_bytes: usize,
        fold: fn(&mut V, V),
    ) -> Self {
        let threshold = policy.item_threshold(net, item_bytes);
        let unbatched = threshold == Some(1);
        let base_items = adaptive_items(net, item_bytes);
        let n = counts.len();
        let alloc = |c: usize, l: usize| !(l == here as usize || unbatched || c == 0);
        let values = counts
            .iter()
            .enumerate()
            .map(|(l, &c)| if alloc(c, l) { vec![V::default(); c] } else { Vec::new() })
            .collect();
        let stamp = counts
            .iter()
            .enumerate()
            .map(|(l, &c)| if alloc(c, l) { vec![0u32; c] } else { Vec::new() })
            .collect();
        let tuners = if policy == FlushPolicy::LatencyAdaptive {
            vec![Tuner::new(base_items); n]
        } else {
            Vec::new()
        };
        Aggregator {
            here,
            space,
            values,
            stamp,
            generation: vec![1; n],
            touched: vec![Vec::new(); n],
            limit: vec![threshold.unwrap_or(NO_LIMIT); n],
            oldest: vec![0.0; n],
            window_us: policy.time_window_us(),
            unbatched,
            tuners,
            base_items,
            traced: policy.traced(),
            next_token: 0,
            inflight: Vec::new(),
            pool: Vec::new(),
            item_bytes,
            fold,
            stats: AggStats::default(),
            reliable: None,
            clock: 0.0,
        }
    }

    /// Builder: turn on `reliability=acked` sequenced/acked delivery (see
    /// the module docs). Every sealed batch then carries a sequence
    /// number and a trace token, so callers must ship with
    /// [`Ctx::send_traced`](super::sim::Ctx::send_traced) and uphold the
    /// poll/timer contract ([`Aggregator::needs_clock`]) or unacked
    /// envelopes would never retransmit. A no-op when `on` is false.
    pub fn with_reliability(mut self, on: bool) -> Self {
        if on {
            self.reliable = Some(ReliableState::new(self.values.len()));
        }
        self
    }

    /// Whether this aggregator needs the poll/timer contract upheld
    /// (call [`Aggregator::poll`] at handler/step boundaries and keep a
    /// timer armed at [`Aggregator::next_deadline`]): true for a non-zero
    /// time window and for reliable delivery's retransmit schedule.
    pub fn needs_clock(&self) -> bool {
        self.window_us.is_some() || self.reliable.is_some()
    }

    /// Reliable-delivery counters `(retransmits, dedup hits, give-ups)`;
    /// zeros when reliability is off. Merged into
    /// [`FaultStats`](super::metrics::FaultStats) by the engine drivers.
    pub fn reliability_stats(&self) -> (u64, u64, u64) {
        self.reliable
            .as_ref()
            .map_or((0, 0, 0), |r| (r.retransmits, r.dedup_hits, r.give_ups))
    }

    /// Per-destination `next_seq` cursors under reliable delivery (empty
    /// vector otherwise); snapshotted into checkpoints as forensic state.
    pub fn seq_cursors(&self) -> Vec<u64> {
        self.reliable.as_ref().map_or(Vec::new(), |r| r.next_seq.clone())
    }

    /// Number of destinations (localities) configured.
    pub fn n_destinations(&self) -> usize {
        self.values.len()
    }

    /// Which destination-local index space this combiner covers.
    pub fn space(&self) -> SlotSpace {
        self.space
    }

    /// The time window in us when the policy is a non-zero
    /// [`FlushPolicy::TimeWindow`] — callers that see `Some` must uphold
    /// the poll contract (call [`Aggregator::poll`] at handler/step
    /// boundaries and keep a timer armed at [`Aggregator::next_deadline`]).
    pub fn time_window_us(&self) -> Option<f64> {
        self.window_us
    }

    /// Grab a batch vector from the recycle pool (or allocate).
    fn fresh_items(&mut self, cap_hint: usize) -> Vec<(u32, V)> {
        match self.pool.pop() {
            Some(v) => {
                self.stats.pool_reuses += 1;
                v
            }
            None => {
                self.stats.pool_allocs += 1;
                Vec::with_capacity(cap_hint)
            }
        }
    }

    /// Hand a consumed batch vector back for reuse. Receivers call this
    /// after draining a delivered batch's items; steady-state aggregation
    /// then allocates nothing.
    pub fn recycle(&mut self, mut items: Vec<(u32, V)>) {
        if self.pool.len() < POOL_CAP && items.capacity() > 0 {
            items.clear();
            self.pool.push(items);
        }
    }

    /// Fold `(slot, val)` into `dst`'s combiner, where `slot` is the
    /// destination-local index (master index or ghost slot) and `now` is
    /// the simulated clock (drives [`FlushPolicy::TimeWindow`] ages).
    /// Returns a batch when the flush policy fired — the caller must ship
    /// it to `dst` now.
    pub fn accumulate(
        &mut self,
        dst: LocalityId,
        slot: u32,
        val: V,
        now: SimTime,
    ) -> Option<Batch<V>> {
        debug_assert_ne!(dst, self.here, "aggregate only remote sends");
        self.clock = self.clock.max(now);
        self.stats.items += 1;
        if self.unbatched {
            // Unbatched fast path: no combiner state at all.
            self.stats.policy_flushes += 1;
            let mut items = self.fresh_items(1);
            items.push((slot, val));
            return Some(self.seal(dst, items));
        }
        let d = dst as usize;
        let g = self.generation[d];
        if self.stamp[d][slot as usize] == g {
            (self.fold)(&mut self.values[d][slot as usize], val);
            self.stats.folded += 1;
        } else {
            self.stamp[d][slot as usize] = g;
            self.values[d][slot as usize] = val;
            if self.touched[d].is_empty() {
                self.oldest[d] = now;
            }
            self.touched[d].push(slot);
        }
        if self.touched[d].len() >= self.limit[d] {
            self.stats.policy_flushes += 1;
            return self.take(dst);
        }
        None
    }

    /// Stamp envelope-level accounting (and a trace token under traced
    /// policies or reliable delivery, plus a sequence number and a
    /// retransmit-buffer entry under reliable delivery) onto an outgoing
    /// item vector.
    fn seal(&mut self, dst: LocalityId, items: Vec<(u32, V)>) -> Batch<V> {
        self.stats.envelopes += 1;
        self.stats.sent_items += items.len() as u64;
        let token = if self.traced || self.reliable.is_some() {
            let t = self.next_token;
            self.next_token += 1;
            if self.traced {
                self.inflight.push((t, dst, items.len() as u32));
            }
            Some(t)
        } else {
            None
        };
        let seq = if let Some(r) = self.reliable.as_mut() {
            let s = r.next_seq[dst as usize];
            r.next_seq[dst as usize] += 1;
            r.outstanding.push(Outstanding {
                token: token.expect("reliable batches always carry a token"),
                dst,
                seq: s,
                items: items.clone(),
                deadline: self.clock + RETRANSMIT_RTO_US,
                attempt: 0,
            });
            Some(s)
        } else {
            None
        };
        Batch { items, item_bytes: self.item_bytes, token, seq }
    }

    /// Receiver-side dedup: feed an incoming batch's source and
    /// [`Batch::seq`] before applying it. Returns `false` for a
    /// duplicate (apply nothing — the fold would double-count sums;
    /// counted as a dedup hit), `true` otherwise. A constant `true` with
    /// reliability off or for unsequenced batches, with zero state.
    pub fn admit(&mut self, from: LocalityId, seq: Option<u64>) -> bool {
        let Some(r) = self.reliable.as_mut() else {
            return true;
        };
        let Some(seq) = seq else {
            return true;
        };
        if r.windows[from as usize].admit(seq) {
            true
        } else {
            r.dedup_hits += 1;
            false
        }
    }

    /// Take `dst`'s pending batch (no stats-class attribution).
    fn take(&mut self, dst: LocalityId) -> Option<Batch<V>> {
        let d = dst as usize;
        if self.touched[d].is_empty() {
            return None;
        }
        self.touched[d].sort_unstable();
        let mut items = self.fresh_items(self.touched[d].len());
        // Move values out (replacing with the default) rather than clone;
        // the generation bump below retires the whole combiner in O(1).
        for i in 0..self.touched[d].len() {
            let slot = self.touched[d][i];
            items.push((slot, std::mem::take(&mut self.values[d][slot as usize])));
        }
        self.touched[d].clear();
        self.generation[d] = self.generation[d].wrapping_add(1);
        if self.generation[d] == 0 {
            // u32 generation wrapped (2^32 flushes to one destination):
            // reset the stamps to 0 — the live generation restarts at 1
            // and is never 0 again, so stamp 0 can never read as occupied.
            for s in &mut self.stamp[d] {
                *s = 0;
            }
            self.generation[d] = 1;
        }
        Some(self.seal(dst, items))
    }

    /// Drain one destination's pending items (explicit flush).
    pub fn drain_one(&mut self, dst: LocalityId) -> Option<Batch<V>> {
        let b = self.take(dst);
        if b.is_some() {
            self.stats.drain_flushes += 1;
        }
        b
    }

    /// Drain every destination, in locality order. Call at handler end
    /// (asynchronous algorithms) or right before requesting a barrier
    /// (BSP supersteps) so nothing is left behind at quiescence.
    pub fn drain(&mut self) -> Vec<(LocalityId, Batch<V>)> {
        let (here, n) = (self.here, self.values.len() as LocalityId);
        (0..n)
            .filter(|&l| l != here)
            .filter_map(|l| self.drain_one(l).map(|b| (l, b)))
            .collect()
    }

    /// Time-window flush: emit every destination whose oldest pending item
    /// has waited [`FlushPolicy::TimeWindow`] out as of `now`. A no-op
    /// (empty result) under every other policy. Engines call this at
    /// handler/step boundaries and from the timer armed at
    /// [`Aggregator::next_deadline`]; counted as policy flushes.
    pub fn poll(&mut self, now: SimTime) -> Vec<(LocalityId, Batch<V>)> {
        self.clock = self.clock.max(now);
        let mut out = Vec::new();
        if let Some(w) = self.window_us {
            let (here, n) = (self.here, self.values.len() as LocalityId);
            out.extend((0..n).filter(|&l| l != here).filter_map(|l| {
                let d = l as usize;
                if self.touched[d].is_empty() || now - self.oldest[d] < w {
                    return None;
                }
                self.stats.policy_flushes += 1;
                self.take(l).map(|b| (l, b))
            }));
        }
        if self.reliable.is_some() {
            self.retransmit_due(now, &mut out);
        }
        out
    }

    /// Resend every outstanding envelope whose ack timeout has expired as
    /// of `now`: same sequence number (the receiver window makes the
    /// redundant copy idempotent), fresh token, doubled deadline. An
    /// envelope that has exhausted [`RETRANSMIT_MAX_ATTEMPTS`] is
    /// abandoned and counted as a give-up — its destination is presumed
    /// fail-stopped.
    fn retransmit_due(&mut self, now: SimTime, out: &mut Vec<(LocalityId, Batch<V>)>) {
        loop {
            let r = self.reliable.as_mut().expect("caller checked");
            let Some(i) = r.outstanding.iter().position(|o| o.deadline <= now) else {
                return;
            };
            if r.outstanding[i].attempt >= RETRANSMIT_MAX_ATTEMPTS {
                r.give_ups += 1;
                r.outstanding.swap_remove(i);
                continue;
            }
            let token = self.next_token;
            self.next_token += 1;
            let o = &mut r.outstanding[i];
            o.attempt += 1;
            o.deadline = now + RETRANSMIT_RTO_US * f64::from(1u32 << o.attempt.min(16));
            o.token = token;
            r.retransmits += 1;
            let (dst, seq, items) = {
                let o = &r.outstanding[i];
                (o.dst, o.seq, o.items.clone())
            };
            self.stats.envelopes += 1;
            self.stats.sent_items += items.len() as u64;
            out.push((
                dst,
                Batch {
                    items,
                    item_bytes: self.item_bytes,
                    token: Some(token),
                    seq: Some(seq),
                },
            ));
        }
    }

    /// Earliest time at which [`Aggregator::poll`] would emit something:
    /// the minimum over pending destinations of (first touch + window)
    /// and, under reliable delivery, over outstanding envelopes' ack
    /// timeouts. `None` when nothing is pending. Callers for which
    /// [`Aggregator::needs_clock`] is true must keep a runtime timer
    /// armed here, or pending items / retransmits could outlive
    /// quiescence.
    pub fn next_deadline(&self) -> Option<SimTime> {
        let window = self.window_us.and_then(|w| {
            self.touched
                .iter()
                .enumerate()
                .filter(|(d, t)| *d != self.here as usize && !t.is_empty())
                .map(|(d, _)| self.oldest[d] + w)
                .min_by(|a, b| a.total_cmp(b))
        });
        let retrans = self.reliable.as_ref().and_then(|r| {
            r.outstanding
                .iter()
                .map(|o| o.deadline)
                .min_by(|a, b| a.total_cmp(b))
        });
        match (window, retrans) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    /// Feed one delivery observation back (the ack of a traced envelope):
    /// `sent`/`delivered` are simulated times from
    /// [`Actor::on_ack`](super::sim::Actor::on_ack). Updates the observed
    /// latency stats; under [`FlushPolicy::LatencyAdaptive`] it also
    /// advances the destination's hill climber and adopts its new item
    /// threshold.
    pub fn observe_ack(&mut self, token: u64, sent: SimTime, delivered: SimTime) {
        let mut known = false;
        if let Some(i) = self.inflight.iter().position(|e| e.0 == token) {
            let (_, dst, items) = self.inflight.swap_remove(i);
            let latency_us = (delivered - sent).max(0.0);
            self.stats.acks += 1;
            self.stats.ack_latency_ns += (latency_us * 1e3) as u64;
            if let Some(t) = self.tuners.get_mut(dst as usize) {
                t.observe(latency_us, items, self.base_items);
                self.limit[dst as usize] = t.limit;
            }
            known = true;
        }
        // Reliable delivery: the ack is the receipt that settles the
        // retransmit-buffer entry. Acks for superseded tokens (an earlier
        // transmission of a since-retransmitted or already-settled
        // envelope) are expected under faults and ignored.
        let mut settled = None;
        if let Some(r) = self.reliable.as_mut() {
            if let Some(i) = r.outstanding.iter().position(|o| o.token == token) {
                settled = Some(r.outstanding.swap_remove(i).items);
            }
            known = true;
        }
        if let Some(items) = settled {
            self.recycle(items);
        }
        debug_assert!(known, "ack for unknown token {token}");
        let _ = known;
    }

    /// The current item threshold for `dst` (`usize::MAX` = drain/time
    /// only). Under [`FlushPolicy::LatencyAdaptive`] this moves as acks
    /// arrive; exposed for tests and diagnostics.
    pub fn current_limit(&self, dst: LocalityId) -> usize {
        self.limit[dst as usize]
    }

    /// Items currently buffered across all destinations.
    pub fn pending(&self) -> usize {
        self.touched.iter().map(|t| t.len()).sum()
    }

    /// Accounting so far.
    pub fn stats(&self) -> &AggStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn add(a: &mut f32, b: f32) {
        *a += b;
    }

    fn min_u32(a: &mut u32, b: u32) {
        *a = (*a).min(b);
    }

    fn agg_f32(
        counts: &[usize],
        here: LocalityId,
        policy: FlushPolicy,
        net: &NetConfig,
    ) -> Aggregator<f32> {
        Aggregator::new(counts, here, SlotSpace::Master, policy, net, 8, add)
    }

    #[test]
    fn unbatched_emits_one_batch_per_item() {
        let counts = [4usize, 4];
        let mut agg = agg_f32(&counts, 0, FlushPolicy::Unbatched, &NetConfig::default());
        for i in 0..5u32 {
            let b = agg.accumulate(1, i % 4, 1.0, 0.0).expect("unbatched flushes per item");
            assert_eq!(b.len(), 1);
        }
        assert_eq!(agg.stats().envelopes, 5);
        assert_eq!(agg.stats().sent_items, 5);
        assert_eq!(agg.pending(), 0);
        assert!(agg.drain().is_empty());
    }

    #[test]
    fn items_policy_flushes_at_threshold_and_folds_duplicates() {
        let counts = [4usize, 8];
        let mut agg = agg_f32(&counts, 0, FlushPolicy::Items(3), &NetConfig::zero());
        assert!(agg.accumulate(1, 0, 1.0, 0.0).is_none());
        assert!(agg.accumulate(1, 0, 2.0, 0.0).is_none(), "fold, not a new slot");
        assert!(agg.accumulate(1, 1, 1.0, 0.0).is_none());
        let b = agg.accumulate(1, 2, 1.0, 0.0).expect("3rd distinct item flushes");
        assert_eq!(b.items, vec![(0, 3.0), (1, 1.0), (2, 1.0)]);
        assert_eq!(agg.stats().folded, 1);
        assert_eq!(agg.stats().policy_flushes, 1);
        assert_eq!(agg.pending(), 0);
    }

    #[test]
    fn manual_policy_only_drains() {
        let counts = [2usize, 2, 2];
        let mut agg = agg_f32(&counts, 1, FlushPolicy::Manual, &NetConfig::default());
        for _ in 0..100 {
            assert!(agg.accumulate(0, 0, 1.0, 0.0).is_none());
            assert!(agg.accumulate(2, 1, 1.0, 0.0).is_none());
        }
        assert_eq!(agg.pending(), 2);
        let out = agg.drain();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].0, 0);
        assert_eq!(out[0].1.items, vec![(0, 100.0)]);
        assert_eq!(out[1].0, 2);
        assert_eq!(out[1].1.items, vec![(1, 100.0)]);
        assert_eq!(agg.stats().items, 200);
        assert_eq!(agg.stats().folded, 198);
        assert_eq!(agg.stats().sent_items, 2);
        assert_eq!(agg.stats().envelopes, 2);
    }

    #[test]
    fn min_fold_keeps_smallest() {
        let counts = [2usize, 2];
        let mut agg: Aggregator<u32> = Aggregator::new(
            &counts,
            0,
            SlotSpace::Master,
            FlushPolicy::Manual,
            &NetConfig::default(),
            8,
            min_u32,
        );
        agg.accumulate(1, 0, 7, 0.0);
        agg.accumulate(1, 0, 3, 0.0);
        agg.accumulate(1, 0, 5, 0.0);
        let out = agg.drain();
        assert_eq!(out[0].1.items, vec![(0, 3)]);
    }

    #[test]
    fn bytes_policy_translates_to_items() {
        let net = NetConfig::default();
        assert_eq!(FlushPolicy::Bytes(64).item_threshold(&net, 8), Some(8));
        assert_eq!(FlushPolicy::Bytes(4).item_threshold(&net, 8), Some(1));
        assert_eq!(FlushPolicy::Items(0).item_threshold(&net, 8), Some(1));
        assert_eq!(FlushPolicy::Manual.item_threshold(&net, 8), None);
        assert_eq!(FlushPolicy::TimeWindow(0).item_threshold(&net, 8), Some(1));
        assert_eq!(FlushPolicy::TimeWindow(5).item_threshold(&net, 8), None);
    }

    #[test]
    fn adaptive_threshold_tracks_cost_model() {
        let net = NetConfig::default();
        let t = adaptive_items(&net, 8);
        // fixed ~3.0us, per-item ~0.1us -> ~300 items to amortize to 10%.
        assert!((200..500).contains(&t), "threshold {t}");
        // Zero-cost network: nothing to amortize, fixed default.
        assert_eq!(adaptive_items(&NetConfig::zero(), 8), 1024);
        // Pricier envelopes -> bigger batches.
        let expensive = NetConfig { latency_us: 20.0, ..NetConfig::default() };
        assert!(adaptive_items(&expensive, 8) > t);
    }

    #[test]
    fn parse_spellings() {
        assert_eq!(FlushPolicy::parse("unbatched"), Ok(FlushPolicy::Unbatched));
        assert_eq!(FlushPolicy::parse("naive"), Ok(FlushPolicy::Unbatched));
        assert_eq!(FlushPolicy::parse("adaptive"), Ok(FlushPolicy::Adaptive));
        assert_eq!(FlushPolicy::parse("latency"), Ok(FlushPolicy::LatencyAdaptive));
        assert_eq!(FlushPolicy::parse("latency-adaptive"), Ok(FlushPolicy::LatencyAdaptive));
        assert_eq!(FlushPolicy::parse("manual"), Ok(FlushPolicy::Manual));
        assert_eq!(FlushPolicy::parse("items:64"), Ok(FlushPolicy::Items(64)));
        assert_eq!(FlushPolicy::parse("bytes:4096"), Ok(FlushPolicy::Bytes(4096)));
        assert_eq!(FlushPolicy::parse("time:25"), Ok(FlushPolicy::TimeWindow(25)));
        assert_eq!(FlushPolicy::parse("time:0"), Ok(FlushPolicy::TimeWindow(0)));
        assert!(FlushPolicy::parse("items:x").is_err());
        assert!(FlushPolicy::parse("warp").is_err());
    }

    #[test]
    fn parse_rejects_zero_thresholds_with_guidance() {
        let e = FlushPolicy::parse("items:0").unwrap_err();
        assert!(e.contains("items:0"), "{e}");
        assert!(e.contains("unbatched") && e.contains("manual"), "{e}");
        let e = FlushPolicy::parse("bytes:0").unwrap_err();
        assert!(e.contains("bytes:0"), "{e}");
    }

    #[test]
    fn batches_are_sorted_by_slot() {
        let counts = [0usize, 16];
        let mut agg = agg_f32(&counts, 0, FlushPolicy::Manual, &NetConfig::default());
        for v in [9u32, 3, 12, 1] {
            agg.accumulate(1, v, 1.0, 0.0);
        }
        let out = agg.drain();
        let vs: Vec<u32> = out[0].1.items.iter().map(|&(v, _)| v).collect();
        assert_eq!(vs, vec![1, 3, 9, 12]);
    }

    #[test]
    fn stats_conservation_invariant() {
        let counts = [8usize, 8];
        let mut agg = agg_f32(&counts, 0, FlushPolicy::Items(4), &NetConfig::zero());
        let mut shipped = 0u64;
        for i in 0..37u32 {
            if let Some(b) = agg.accumulate(1, i % 8, 1.0, 0.0) {
                shipped += b.len() as u64;
            }
        }
        let s = *agg.stats();
        assert_eq!(s.sent_items, shipped);
        assert_eq!(s.items, s.folded + s.sent_items + agg.pending() as u64);
    }

    #[test]
    fn generations_retire_flushed_slots() {
        // After a flush, the same slot must claim fresh (not fold into the
        // retired value): the generation bump, not a slot clear, is what
        // empties the combiner.
        let counts = [2usize, 4];
        let mut agg = agg_f32(&counts, 0, FlushPolicy::Manual, &NetConfig::zero());
        agg.accumulate(1, 2, 5.0, 0.0);
        let out = agg.drain();
        assert_eq!(out[0].1.items, vec![(2, 5.0)]);
        agg.accumulate(1, 2, 7.0, 0.0);
        let out = agg.drain();
        assert_eq!(out[0].1.items, vec![(2, 7.0)], "stale value folded in");
        assert_eq!(agg.stats().folded, 0);
    }

    #[test]
    fn time_window_flushes_when_oldest_expires() {
        let counts = [4usize, 4, 4];
        let mut agg = agg_f32(&counts, 0, FlushPolicy::TimeWindow(10), &NetConfig::default());
        assert!(agg.accumulate(1, 0, 1.0, 100.0).is_none(), "no item threshold");
        assert!(agg.accumulate(1, 1, 1.0, 105.0).is_none());
        assert!(agg.accumulate(2, 0, 1.0, 104.0).is_none());
        // The window runs from the destination's oldest pending item.
        assert_eq!(agg.next_deadline(), Some(110.0));
        assert!(agg.poll(109.9).is_empty(), "window not out yet");
        let out = agg.poll(110.0);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].0, 1);
        assert_eq!(out[0].1.items, vec![(0, 1.0), (1, 1.0)]);
        // Destination 2's clock started later.
        assert_eq!(agg.next_deadline(), Some(114.0));
        let out = agg.poll(120.0);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].0, 2);
        assert_eq!(agg.next_deadline(), None);
        assert_eq!(agg.stats().policy_flushes, 2);
        assert_eq!(agg.stats().drain_flushes, 0);
    }

    #[test]
    fn time_window_zero_is_unbatched() {
        let counts = [4usize, 4];
        let mut tw = agg_f32(&counts, 0, FlushPolicy::TimeWindow(0), &NetConfig::default());
        let mut ub = agg_f32(&counts, 0, FlushPolicy::Unbatched, &NetConfig::default());
        for i in 0..7u32 {
            let a = tw.accumulate(1, i % 4, 1.0, i as f64).expect("flush per item");
            let b = ub.accumulate(1, i % 4, 1.0, i as f64).expect("flush per item");
            assert_eq!(a.items, b.items);
        }
        assert_eq!(tw.stats(), ub.stats());
        assert_eq!(tw.next_deadline(), None);
    }

    /// Fill `dst` to its current threshold so a traced envelope is
    /// emitted, and return its token.
    fn emit_traced(agg: &mut Aggregator<f32>, dst: LocalityId) -> u64 {
        let limit = agg.current_limit(dst);
        for i in 0..limit as u32 {
            if let Some(b) = agg.accumulate(dst, i, 1.0, 0.0) {
                return b.token().expect("latency policy traces envelopes");
            }
        }
        panic!("threshold {limit} never fired");
    }

    #[test]
    fn latency_adaptive_starts_at_break_even_and_tunes() {
        let net = NetConfig::default();
        let base = adaptive_items(&net, 8);
        let counts = [64usize, 65536];
        let mut agg = agg_f32(&counts, 0, FlushPolicy::LatencyAdaptive, &net);
        assert_eq!(agg.current_limit(1), base);

        // Constant envelope latency, one ack per emitted envelope: the
        // amortized per-item share keeps falling as batches grow, so after
        // one epoch of acks the climber must have grown the threshold.
        for _ in 0..TUNER_EPOCH {
            let tok = emit_traced(&mut agg, 1);
            agg.observe_ack(tok, 0.0, 10.0);
        }
        assert!(
            agg.current_limit(1) > base,
            "constant-latency acks must grow the threshold ({} vs base {base})",
            agg.current_limit(1)
        );
        assert!(agg.current_limit(1) <= base * TUNER_MAX_GROWTH);
        assert_eq!(agg.stats().acks, TUNER_EPOCH as u64);
        assert!(agg.stats().mean_obs_latency_us() > 0.0);
    }

    #[test]
    fn latency_adaptive_never_drops_below_break_even() {
        let net = NetConfig::default();
        let base = adaptive_items(&net, 8);
        let counts = [8usize, 65536];
        let mut agg = agg_f32(&counts, 0, FlushPolicy::LatencyAdaptive, &net);
        // Queueing-inflated latencies: first establish a floor, then blow
        // past TUNER_QUEUE_INFLATION x floor; the climber must shrink but
        // clamp at the break-even base.
        for round in 0..40 {
            let tok = emit_traced(&mut agg, 1);
            let lat = if round == 0 { 5.0 } else { 500.0 };
            agg.observe_ack(tok, 0.0, lat);
            assert!(agg.current_limit(1) >= base, "dropped below break-even floor");
        }
        assert_eq!(agg.current_limit(1), base, "inflated latency must shrink to the floor");
    }

    #[test]
    fn pool_recycling_reaches_allocation_free_steady_state() {
        let counts = [4usize, 4];
        let mut agg = agg_f32(&counts, 0, FlushPolicy::Items(2), &NetConfig::zero());
        let mut reclaimed = 0;
        for i in 0..20u32 {
            if let Some(b) = agg.accumulate(1, i % 4, 1.0, 0.0) {
                let mut items = b.into_items();
                items.drain(..).count();
                agg.recycle(items);
                reclaimed += 1;
            }
        }
        assert_eq!(reclaimed, 10);
        let s = *agg.stats();
        assert_eq!(s.pool_reuses + s.pool_allocs, s.envelopes);
        // Only the very first flush had an empty pool.
        assert_eq!(s.pool_allocs, 1, "{s:?}");
        assert_eq!(s.pool_reuses, 9);
        assert!(s.pool_reuse_ratio() > 0.8);
    }

    #[test]
    fn reliability_off_is_the_zero_cost_baseline() {
        let counts = [4usize, 4];
        let mut agg = agg_f32(&counts, 0, FlushPolicy::Items(2), &NetConfig::zero());
        agg.accumulate(1, 0, 1.0, 0.0);
        let b = agg.accumulate(1, 1, 1.0, 0.0).unwrap();
        assert_eq!(b.seq(), None, "no sequence header with reliability off");
        assert_eq!(b.wire_bytes(), 2 * 8, "no +8 header bytes");
        assert!(!agg.needs_clock());
        assert!(agg.admit(1, None), "admit is a constant true");
        assert_eq!(agg.reliability_stats(), (0, 0, 0));
    }

    #[test]
    fn reliable_batches_are_sequenced_and_settled_by_acks() {
        let counts = [4usize, 4];
        let mut agg = agg_f32(&counts, 0, FlushPolicy::Items(1), &NetConfig::zero())
            .with_reliability(true);
        assert!(agg.needs_clock(), "retransmit schedule needs the clock");
        let b = agg.accumulate(1, 0, 1.0, 100.0).unwrap();
        assert_eq!(b.seq(), Some(0));
        let tok = b.token().expect("reliable batches always carry a token");
        assert_eq!(b.wire_bytes(), 8 + 8, "payload + sequence header");
        let b2 = agg.accumulate(1, 1, 1.0, 100.0).unwrap();
        assert_eq!(b2.seq(), Some(1), "sequence numbers ascend per destination");
        // Two unacked envelopes -> the earliest ack timeout is armed.
        assert_eq!(agg.next_deadline(), Some(100.0 + RETRANSMIT_RTO_US));
        agg.observe_ack(tok, 100.0, 101.0);
        agg.observe_ack(b2.token().unwrap(), 100.0, 101.0);
        assert_eq!(agg.next_deadline(), None, "all settled: nothing to retransmit");
        assert_eq!(agg.reliability_stats(), (0, 0, 0));
    }

    #[test]
    fn unacked_envelopes_retransmit_with_backoff_then_give_up() {
        let counts = [4usize, 4];
        let mut agg = agg_f32(&counts, 0, FlushPolicy::Items(1), &NetConfig::zero())
            .with_reliability(true);
        let b = agg.accumulate(1, 0, 2.5, 0.0).unwrap();
        let first_tok = b.token().unwrap();
        let mut resends = 0u32;
        let mut last_deadline = 0.0;
        while let Some(at) = agg.next_deadline() {
            assert!(at > last_deadline, "backoff must push the deadline out");
            last_deadline = at;
            for (dst, rb) in agg.poll(at) {
                assert_eq!(dst, 1);
                assert_eq!(rb.seq(), Some(0), "retransmits reuse the sequence number");
                assert_ne!(rb.token().unwrap(), first_tok, "fresh token per transmission");
                assert_eq!(rb.items, vec![(0, 2.5)]);
                resends += 1;
            }
        }
        assert_eq!(resends, RETRANSMIT_MAX_ATTEMPTS);
        let (retransmits, dedup, give_ups) = agg.reliability_stats();
        assert_eq!(retransmits, u64::from(RETRANSMIT_MAX_ATTEMPTS));
        assert_eq!(dedup, 0);
        assert_eq!(give_ups, 1, "abandoned after the attempt budget: failure detected");
    }

    #[test]
    fn late_ack_for_a_superseded_token_is_ignored() {
        let counts = [4usize, 4];
        let mut agg = agg_f32(&counts, 0, FlushPolicy::Items(1), &NetConfig::zero())
            .with_reliability(true);
        let b = agg.accumulate(1, 0, 1.0, 0.0).unwrap();
        let old_tok = b.token().unwrap();
        let resent = agg.poll(RETRANSMIT_RTO_US + 1.0);
        assert_eq!(resent.len(), 1);
        let new_tok = resent[0].1.token().unwrap();
        // The original copy finally arrives and acks: superseded token.
        agg.observe_ack(old_tok, 0.0, 900.0);
        assert!(agg.next_deadline().is_some(), "entry still waits on the live token");
        agg.observe_ack(new_tok, 0.0, 901.0);
        assert_eq!(agg.next_deadline(), None);
    }

    #[test]
    fn dedup_window_rejects_duplicates_and_handles_reordering() {
        let counts = [4usize, 4];
        let mut agg = agg_f32(&counts, 0, FlushPolicy::Items(1), &NetConfig::zero())
            .with_reliability(true);
        assert!(agg.admit(1, Some(0)), "first arrival");
        assert!(!agg.admit(1, Some(0)), "duplicate rejected");
        assert!(agg.admit(1, Some(2)), "out-of-order arrival is new");
        assert!(agg.admit(1, Some(1)), "the gap fills in");
        assert!(!agg.admit(1, Some(1)), "late duplicate of the gap-filler");
        assert!(!agg.admit(1, Some(2)), "duplicate of the early arrival");
        assert!(agg.admit(0, Some(0)), "windows are per source locality");
        let (_, dedup_hits, _) = agg.reliability_stats();
        assert_eq!(dedup_hits, 3);
    }

    #[test]
    fn untraced_policies_mint_no_tokens() {
        let counts = [4usize, 4];
        let mut agg = agg_f32(&counts, 0, FlushPolicy::Items(1), &NetConfig::zero());
        let b = agg.accumulate(1, 0, 1.0, 0.0).unwrap();
        assert_eq!(b.token(), None);
        assert!(!FlushPolicy::Manual.traced());
        assert!(!FlushPolicy::Unbatched.traced());
        assert!(!FlushPolicy::TimeWindow(0).traced());
        assert!(FlushPolicy::TimeWindow(3).traced());
        assert!(FlushPolicy::Adaptive.traced());
        assert!(FlushPolicy::LatencyAdaptive.traced());
    }
}
