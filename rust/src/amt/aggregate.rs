//! Message aggregation: typed per-destination combiners with pluggable
//! flush policies.
//!
//! The paper's central negative result is that fine-grained asynchronous
//! algorithms lose to BSP because per-message CPU/latency overheads
//! dominate; its follow-up work and the AM++ lineage show that a
//! *runtime-level* coalescing layer — not per-algorithm hacks — is what
//! closes the gap. This module is that layer: every asynchronous algorithm
//! folds its remote actions into an [`Aggregator`] instead of calling
//! [`Ctx::send`](super::sim::Ctx::send) per action.
//!
//! An [`Aggregator`] keeps one dense combiner per destination locality,
//! indexed by **destination-local slot**. For master-bound traffic the
//! slot is the destination's dense owned-row index
//! ([`PartitionScheme::master_index`](crate::graph::partition::PartitionScheme::master_index),
//! precomputed per ghost in the
//! [`Shard`](crate::graph::Shard) ghost table); for mirror-bound scatter
//! it is the destination's ghost-row slot (the master's mirror table).
//! Either way the receiver applies batch items directly by index with no
//! translation, and nothing assumes the partition is contiguous — this is
//! what lets hash and vertex-cut schemes ride the same combiner layer as
//! the paper's block layout. Pushing a value either claims an empty slot
//! or *folds* into the pending one through the reduction hook (sum for
//! PageRank contributions, min for BFS levels / SSSP distances / CC
//! labels), so a flushed batch carries at most one item per destination
//! slot. When the [`FlushPolicy`] threshold fires, the destination's
//! batch is handed back to the caller to ship as one envelope; whatever is
//! still buffered is shipped by an explicit [`Aggregator::drain`] at the
//! end of a handler or superstep phase (the quiescence/barrier drain).
//!
//! [`AggStats`] counts items, folds, and emitted envelopes; algorithm
//! drivers merge them into [`SimReport::agg`](super::metrics::SimReport)
//! so every experiment reports the naive-vs-aggregated axis.

use super::net::NetConfig;
use super::sim::LocalityId;

/// When a per-destination combiner is flushed into an envelope.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlushPolicy {
    /// One envelope per item — the paper's naive per-remote-edge path,
    /// kept only as the ablation baseline.
    Unbatched,
    /// Flush a destination once it holds this many (distinct) items.
    Items(usize),
    /// Flush a destination once its payload reaches this many bytes.
    Bytes(usize),
    /// Derive the item threshold from the [`NetConfig`] cost model: batch
    /// until the amortized per-item share of the fixed envelope cost
    /// (latency + per-envelope CPU + framing) drops below 10% of the
    /// marginal per-item cost.
    Adaptive,
    /// Never auto-flush; everything waits for the explicit drain at the
    /// end of the handler or superstep phase (maximal batching).
    Manual,
}

impl FlushPolicy {
    /// Parse a config/CLI spelling: `unbatched`, `adaptive`, `manual`,
    /// `items:N`, `bytes:N`.
    pub fn parse(s: &str) -> Option<FlushPolicy> {
        match s {
            "unbatched" | "naive" => return Some(FlushPolicy::Unbatched),
            "adaptive" => return Some(FlushPolicy::Adaptive),
            "manual" => return Some(FlushPolicy::Manual),
            _ => {}
        }
        let (kind, val) = s.split_once(':')?;
        let n: usize = val.parse().ok()?;
        match kind {
            "items" => Some(FlushPolicy::Items(n)),
            "bytes" => Some(FlushPolicy::Bytes(n)),
            _ => None,
        }
    }

    /// Distinct-item threshold that triggers a flush; `None` = drain-only.
    pub fn item_threshold(&self, net: &NetConfig, item_bytes: usize) -> Option<usize> {
        match *self {
            FlushPolicy::Unbatched => Some(1),
            FlushPolicy::Items(k) => Some(k.max(1)),
            FlushPolicy::Bytes(b) => Some((b / item_bytes.max(1)).max(1)),
            FlushPolicy::Adaptive => Some(adaptive_items(net, item_bytes)),
            FlushPolicy::Manual => None,
        }
    }
}

/// Break-even batch size for [`FlushPolicy::Adaptive`]: the item count at
/// which the fixed per-envelope cost amortizes to 10% of the marginal
/// per-item cost. On a zero-cost network there is nothing to amortize and
/// a fixed 1024 is used.
pub fn adaptive_items(net: &NetConfig, item_bytes: usize) -> usize {
    let fixed = net.send_cpu_us
        + net.recv_cpu_us
        + net.latency_us
        + net.overhead_bytes as f64 / net.bandwidth_bytes_per_us;
    let per_item = 2.0 * net.per_item_cpu_us + item_bytes as f64 / net.bandwidth_bytes_per_us;
    if fixed <= 0.0 || per_item <= 0.0 || !fixed.is_finite() || !per_item.is_finite() {
        return 1024;
    }
    ((fixed / (0.1 * per_item)).ceil() as usize).clamp(16, 1 << 16)
}

/// One flushed combiner: `(destination-local slot, folded value)` pairs
/// sorted by slot (deterministic wire order; slots ascend with global ids,
/// so this is the same order the old global-id batches had). Algorithms
/// wrap this in their message enum; [`Batch::wire_bytes`] / [`Batch::len`]
/// feed the [`Message`](super::sim::Message) impl.
#[derive(Debug, Clone)]
pub struct Batch<V> {
    /// Folded items, sorted by destination-local slot.
    pub items: Vec<(u32, V)>,
    item_bytes: usize,
}

impl<V> Batch<V> {
    /// Serialized payload size (items x per-item wire bytes).
    pub fn wire_bytes(&self) -> usize {
        self.items.len() * self.item_bytes
    }

    /// Number of folded items carried.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// True when the batch carries nothing.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

/// Aggregation accounting, merged into
/// [`SimReport::agg`](super::metrics::SimReport) by algorithm drivers.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AggStats {
    /// Values pushed through [`Aggregator::accumulate`].
    pub items: u64,
    /// Values folded into an already-pending slot (combiner hits — traffic
    /// that never reaches the wire).
    pub folded: u64,
    /// Batches handed back to the caller (== envelopes if each batch is
    /// shipped as one send).
    pub envelopes: u64,
    /// Items across all emitted batches.
    pub sent_items: u64,
    /// Batches emitted because the policy threshold fired.
    pub policy_flushes: u64,
    /// Batches emitted by explicit drains (handler end / barrier).
    pub drain_flushes: u64,
}

impl AggStats {
    /// Accumulate another stats block into this one.
    pub fn merge(&mut self, other: &AggStats) {
        self.items += other.items;
        self.folded += other.folded;
        self.envelopes += other.envelopes;
        self.sent_items += other.sent_items;
        self.policy_flushes += other.policy_flushes;
        self.drain_flushes += other.drain_flushes;
    }

    /// Mean items per emitted batch.
    pub fn fold_factor(&self) -> f64 {
        if self.envelopes == 0 {
            0.0
        } else {
            self.items as f64 / self.envelopes as f64
        }
    }
}

/// Typed per-destination message combiner. See the module docs.
pub struct Aggregator<V> {
    here: LocalityId,
    /// Dense pending slots per destination (destination-local slot index).
    slots: Vec<Vec<Option<V>>>,
    /// Occupied slot offsets per destination, in first-touch order.
    touched: Vec<Vec<u32>>,
    threshold: Option<usize>,
    item_bytes: usize,
    fold: fn(&mut V, V),
    stats: AggStats,
}

impl<V: Clone> Aggregator<V> {
    /// Create a combiner over the destinations' dense slot spaces
    /// (`counts[l]` = locality `l`'s slot count: its owned-row count for
    /// master-bound traffic, its ghost-row count for mirror scatter —
    /// [`DistGraph::owned_counts`](crate::graph::DistGraph::owned_counts) /
    /// [`DistGraph::ghost_counts`](crate::graph::DistGraph::ghost_counts)).
    /// `item_bytes` is the per-item wire size; `fold` merges a new value
    /// into a pending one and must be associative and insensitive to
    /// arrival order (sum, min, ...), so batching never changes results.
    pub fn new(
        counts: &[usize],
        here: LocalityId,
        policy: FlushPolicy,
        net: &NetConfig,
        item_bytes: usize,
        fold: fn(&mut V, V),
    ) -> Self {
        let threshold = policy.item_threshold(net, item_bytes);
        let slots = counts
            .iter()
            .enumerate()
            .map(|(l, &c)| {
                if l == here as usize || threshold == Some(1) {
                    Vec::new() // never buffered
                } else {
                    vec![None; c]
                }
            })
            .collect();
        Aggregator {
            here,
            slots,
            touched: vec![Vec::new(); counts.len()],
            threshold,
            item_bytes,
            fold,
            stats: AggStats::default(),
        }
    }

    /// Number of destinations (localities) configured.
    pub fn n_destinations(&self) -> usize {
        self.slots.len()
    }

    /// Fold `(slot, val)` into `dst`'s combiner, where `slot` is the
    /// destination-local index (master index or ghost slot). Returns a
    /// batch when the flush policy fired — the caller must ship it to
    /// `dst` now.
    pub fn accumulate(&mut self, dst: LocalityId, slot: u32, val: V) -> Option<Batch<V>> {
        debug_assert_ne!(dst, self.here, "aggregate only remote sends");
        self.stats.items += 1;
        if self.threshold == Some(1) {
            // Unbatched fast path: no combiner state at all.
            self.stats.envelopes += 1;
            self.stats.policy_flushes += 1;
            self.stats.sent_items += 1;
            return Some(Batch { items: vec![(slot, val)], item_bytes: self.item_bytes });
        }
        let d = dst as usize;
        match &mut self.slots[d][slot as usize] {
            Some(pending) => {
                (self.fold)(pending, val);
                self.stats.folded += 1;
            }
            empty => {
                *empty = Some(val);
                self.touched[d].push(slot);
            }
        }
        if let Some(t) = self.threshold {
            if self.touched[d].len() >= t {
                self.stats.policy_flushes += 1;
                return self.take(dst);
            }
        }
        None
    }

    /// Take `dst`'s pending batch (no stats-class attribution).
    fn take(&mut self, dst: LocalityId) -> Option<Batch<V>> {
        let d = dst as usize;
        if self.touched[d].is_empty() {
            return None;
        }
        let mut offs = std::mem::take(&mut self.touched[d]);
        offs.sort_unstable();
        let items: Vec<(u32, V)> = offs
            .iter()
            .map(|&o| (o, self.slots[d][o as usize].take().unwrap()))
            .collect();
        self.stats.envelopes += 1;
        self.stats.sent_items += items.len() as u64;
        Some(Batch { items, item_bytes: self.item_bytes })
    }

    /// Drain one destination's pending items (explicit flush).
    pub fn drain_one(&mut self, dst: LocalityId) -> Option<Batch<V>> {
        let b = self.take(dst);
        if b.is_some() {
            self.stats.drain_flushes += 1;
        }
        b
    }

    /// Drain every destination, in locality order. Call at handler end
    /// (asynchronous algorithms) or right before requesting a barrier
    /// (BSP supersteps) so nothing is left behind at quiescence.
    pub fn drain(&mut self) -> Vec<(LocalityId, Batch<V>)> {
        let (here, n) = (self.here, self.slots.len() as LocalityId);
        (0..n)
            .filter(|&l| l != here)
            .filter_map(|l| self.drain_one(l).map(|b| (l, b)))
            .collect()
    }

    /// Items currently buffered across all destinations.
    pub fn pending(&self) -> usize {
        self.touched.iter().map(|t| t.len()).sum()
    }

    /// Accounting so far.
    pub fn stats(&self) -> &AggStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn add(a: &mut f32, b: f32) {
        *a += b;
    }

    fn min_u32(a: &mut u32, b: u32) {
        *a = (*a).min(b);
    }

    #[test]
    fn unbatched_emits_one_batch_per_item() {
        let counts = [4usize, 4];
        let mut agg =
            Aggregator::new(&counts, 0, FlushPolicy::Unbatched, &NetConfig::default(), 8, add);
        for i in 0..5u32 {
            let b = agg.accumulate(1, i % 4, 1.0).expect("unbatched flushes per item");
            assert_eq!(b.len(), 1);
        }
        assert_eq!(agg.stats().envelopes, 5);
        assert_eq!(agg.stats().sent_items, 5);
        assert_eq!(agg.pending(), 0);
        assert!(agg.drain().is_empty());
    }

    #[test]
    fn items_policy_flushes_at_threshold_and_folds_duplicates() {
        let counts = [4usize, 8];
        let mut agg =
            Aggregator::new(&counts, 0, FlushPolicy::Items(3), &NetConfig::zero(), 8, add);
        assert!(agg.accumulate(1, 0, 1.0).is_none());
        assert!(agg.accumulate(1, 0, 2.0).is_none(), "fold, not a new slot");
        assert!(agg.accumulate(1, 1, 1.0).is_none());
        let b = agg.accumulate(1, 2, 1.0).expect("3rd distinct item flushes");
        assert_eq!(b.items, vec![(0, 3.0), (1, 1.0), (2, 1.0)]);
        assert_eq!(agg.stats().folded, 1);
        assert_eq!(agg.stats().policy_flushes, 1);
        assert_eq!(agg.pending(), 0);
    }

    #[test]
    fn manual_policy_only_drains() {
        let counts = [2usize, 2, 2];
        let mut agg =
            Aggregator::new(&counts, 1, FlushPolicy::Manual, &NetConfig::default(), 8, add);
        for _ in 0..100 {
            assert!(agg.accumulate(0, 0, 1.0).is_none());
            assert!(agg.accumulate(2, 1, 1.0).is_none());
        }
        assert_eq!(agg.pending(), 2);
        let out = agg.drain();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].0, 0);
        assert_eq!(out[0].1.items, vec![(0, 100.0)]);
        assert_eq!(out[1].0, 2);
        assert_eq!(out[1].1.items, vec![(1, 100.0)]);
        assert_eq!(agg.stats().items, 200);
        assert_eq!(agg.stats().folded, 198);
        assert_eq!(agg.stats().sent_items, 2);
        assert_eq!(agg.stats().envelopes, 2);
    }

    #[test]
    fn min_fold_keeps_smallest() {
        let counts = [2usize, 2];
        let mut agg =
            Aggregator::new(&counts, 0, FlushPolicy::Manual, &NetConfig::default(), 8, min_u32);
        agg.accumulate(1, 0, 7);
        agg.accumulate(1, 0, 3);
        agg.accumulate(1, 0, 5);
        let out = agg.drain();
        assert_eq!(out[0].1.items, vec![(0, 3)]);
    }

    #[test]
    fn bytes_policy_translates_to_items() {
        let net = NetConfig::default();
        assert_eq!(FlushPolicy::Bytes(64).item_threshold(&net, 8), Some(8));
        assert_eq!(FlushPolicy::Bytes(4).item_threshold(&net, 8), Some(1));
        assert_eq!(FlushPolicy::Items(0).item_threshold(&net, 8), Some(1));
        assert_eq!(FlushPolicy::Manual.item_threshold(&net, 8), None);
    }

    #[test]
    fn adaptive_threshold_tracks_cost_model() {
        let net = NetConfig::default();
        let t = adaptive_items(&net, 8);
        // fixed ~3.0us, per-item ~0.1us -> ~300 items to amortize to 10%.
        assert!((200..500).contains(&t), "threshold {t}");
        // Zero-cost network: nothing to amortize, fixed default.
        assert_eq!(adaptive_items(&NetConfig::zero(), 8), 1024);
        // Pricier envelopes -> bigger batches.
        let expensive = NetConfig { latency_us: 20.0, ..NetConfig::default() };
        assert!(adaptive_items(&expensive, 8) > t);
    }

    #[test]
    fn parse_spellings() {
        assert_eq!(FlushPolicy::parse("unbatched"), Some(FlushPolicy::Unbatched));
        assert_eq!(FlushPolicy::parse("naive"), Some(FlushPolicy::Unbatched));
        assert_eq!(FlushPolicy::parse("adaptive"), Some(FlushPolicy::Adaptive));
        assert_eq!(FlushPolicy::parse("manual"), Some(FlushPolicy::Manual));
        assert_eq!(FlushPolicy::parse("items:64"), Some(FlushPolicy::Items(64)));
        assert_eq!(FlushPolicy::parse("bytes:4096"), Some(FlushPolicy::Bytes(4096)));
        assert_eq!(FlushPolicy::parse("items:x"), None);
        assert_eq!(FlushPolicy::parse("warp"), None);
    }

    #[test]
    fn batches_are_sorted_by_slot() {
        let counts = [0usize, 16];
        let mut agg =
            Aggregator::new(&counts, 0, FlushPolicy::Manual, &NetConfig::default(), 8, add);
        for v in [9u32, 3, 12, 1] {
            agg.accumulate(1, v, 1.0);
        }
        let out = agg.drain();
        let vs: Vec<u32> = out[0].1.items.iter().map(|&(v, _)| v).collect();
        assert_eq!(vs, vec![1, 3, 9, 12]);
    }

    #[test]
    fn stats_conservation_invariant() {
        let counts = [8usize, 8];
        let mut agg =
            Aggregator::new(&counts, 0, FlushPolicy::Items(4), &NetConfig::zero(), 8, add);
        let mut shipped = 0u64;
        for i in 0..37u32 {
            if let Some(b) = agg.accumulate(1, i % 8, 1.0) {
                shipped += b.len() as u64;
            }
        }
        let s = *agg.stats();
        assert_eq!(s.sent_items, shipped);
        assert_eq!(s.items, s.folded + s.sent_items + agg.pending() as u64);
    }
}
