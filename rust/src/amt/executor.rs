//! Intra-locality parallel-for executors with pluggable chunking policies.
//!
//! The paper's cluster nodes have 64 cores each; HPX exposes that through
//! parallel algorithms parameterized by *executors*, and §6 highlights the
//! `adaptive_core_chunk_size` executor (Mohammadiporshokooh et al.) that
//! tunes chunk size from observed workload behaviour. This module is the
//! equivalent substrate: a work-stealing-style chunked `parallel_for` on
//! `std::thread::scope`, with
//!
//! * [`ChunkPolicy::Sequential`] — no threads (baseline),
//! * [`ChunkPolicy::Static`] — fixed chunk, round-robin stripes,
//! * [`ChunkPolicy::Dynamic`] — fixed chunk, atomically claimed (work
//!   stealing degenerates to a shared claim counter for index ranges,
//!   which is the standard chunk-self-scheduling formulation),
//! * [`ChunkPolicy::Adaptive`] — chunk size hill-climbed across
//!   invocations from measured throughput, a simplified
//!   `adaptive_core_chunk_size`.
//!
//! The ablation bench `ablation_adaptive_chunk` compares these policies on
//! the PageRank local phase (DESIGN.md experiment A2).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Chunking policy for [`Executor::parallel_for`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChunkPolicy {
    /// Run on the calling thread.
    Sequential,
    /// Fixed-size chunks assigned round-robin to workers up front.
    Static {
        /// Elements per chunk.
        chunk: usize,
    },
    /// Fixed-size chunks claimed dynamically from a shared counter.
    Dynamic {
        /// Elements per chunk.
        chunk: usize,
    },
    /// Dynamically claimed chunks whose size is adapted across calls.
    Adaptive,
}

/// Adaptive-chunk state: multiplicative hill climbing on throughput.
#[derive(Debug)]
struct AdaptiveState {
    chunk: usize,
    /// Last measured throughput (elements/us) and the direction we moved.
    last_throughput: f64,
    grow: bool,
}

impl Default for AdaptiveState {
    fn default() -> Self {
        AdaptiveState { chunk: 256, last_throughput: 0.0, grow: true }
    }
}

/// A parallel-for executor bound to a worker count.
#[derive(Debug)]
pub struct Executor {
    workers: usize,
    adaptive: Mutex<AdaptiveState>,
}

impl Executor {
    /// Executor with `workers` threads (0 → available_parallelism).
    pub fn new(workers: usize) -> Self {
        let workers = if workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            workers
        };
        Executor { workers, adaptive: Mutex::new(AdaptiveState::default()) }
    }

    /// Number of worker threads used.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Current adaptive chunk size (for reporting/ablation).
    pub fn adaptive_chunk(&self) -> usize {
        self.adaptive.lock().unwrap().chunk
    }

    /// Apply `f` to every index in `0..len`, chunked per `policy`.
    /// `f` receives a half-open index range and must be safe to run
    /// concurrently on disjoint ranges.
    pub fn parallel_for<F>(&self, len: usize, policy: ChunkPolicy, f: F)
    where
        F: Fn(std::ops::Range<usize>) + Sync,
    {
        if len == 0 {
            return;
        }
        match policy {
            ChunkPolicy::Sequential => f(0..len),
            ChunkPolicy::Static { chunk } => self.run_static(len, chunk.max(1), &f),
            ChunkPolicy::Dynamic { chunk } => self.run_dynamic(len, chunk.max(1), &f),
            ChunkPolicy::Adaptive => self.run_adaptive(len, &f),
        }
    }

    fn run_static<F>(&self, len: usize, chunk: usize, f: &F)
    where
        F: Fn(std::ops::Range<usize>) + Sync,
    {
        let n_chunks = len.div_ceil(chunk);
        let workers = self.workers.min(n_chunks).max(1);
        std::thread::scope(|s| {
            for w in 0..workers {
                let f = &f;
                s.spawn(move || {
                    let mut c = w;
                    while c < n_chunks {
                        let start = c * chunk;
                        let end = (start + chunk).min(len);
                        f(start..end);
                        c += workers;
                    }
                });
            }
        });
    }

    fn run_dynamic<F>(&self, len: usize, chunk: usize, f: &F)
    where
        F: Fn(std::ops::Range<usize>) + Sync,
    {
        let next = AtomicUsize::new(0);
        let workers = self.workers.min(len.div_ceil(chunk)).max(1);
        std::thread::scope(|s| {
            for _ in 0..workers {
                let next = &next;
                let f = &f;
                s.spawn(move || loop {
                    let start = next.fetch_add(chunk, Ordering::Relaxed);
                    if start >= len {
                        break;
                    }
                    let end = (start + chunk).min(len);
                    f(start..end);
                });
            }
        });
    }

    fn run_adaptive<F>(&self, len: usize, f: &F)
    where
        F: Fn(std::ops::Range<usize>) + Sync,
    {
        let chunk = {
            let st = self.adaptive.lock().unwrap();
            st.chunk.min(len.div_ceil(self.workers).max(1))
        };
        let t0 = Instant::now();
        self.run_dynamic(len, chunk, f);
        let elapsed_us = t0.elapsed().as_secs_f64() * 1e6;
        let throughput = len as f64 / elapsed_us.max(1e-9);

        // Hill climb: keep moving chunk size in the current direction while
        // throughput improves; reverse when it regresses.
        let mut st = self.adaptive.lock().unwrap();
        if throughput < st.last_throughput {
            st.grow = !st.grow;
        }
        st.chunk = if st.grow {
            (st.chunk * 2).min(1 << 20)
        } else {
            (st.chunk / 2).max(16)
        };
        st.last_throughput = throughput;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    fn sum_with(policy: ChunkPolicy, len: usize, workers: usize) -> u64 {
        let ex = Executor::new(workers);
        let acc = AtomicU64::new(0);
        ex.parallel_for(len, policy, |r| {
            let local: u64 = r.map(|i| i as u64).sum();
            acc.fetch_add(local, Ordering::Relaxed);
        });
        acc.load(Ordering::Relaxed)
    }

    fn expected(len: usize) -> u64 {
        (0..len as u64).sum()
    }

    #[test]
    fn all_policies_cover_every_index_exactly_once() {
        for len in [0usize, 1, 7, 100, 1000, 4097] {
            let want = expected(len);
            for policy in [
                ChunkPolicy::Sequential,
                ChunkPolicy::Static { chunk: 3 },
                ChunkPolicy::Static { chunk: 1024 },
                ChunkPolicy::Dynamic { chunk: 7 },
                ChunkPolicy::Adaptive,
            ] {
                assert_eq!(sum_with(policy, len, 4), want, "len={len} {policy:?}");
            }
        }
    }

    #[test]
    fn zero_workers_means_available_parallelism() {
        let ex = Executor::new(0);
        assert!(ex.workers() >= 1);
    }

    #[test]
    fn adaptive_chunk_changes_across_calls() {
        let ex = Executor::new(2);
        let initial = ex.adaptive_chunk();
        // Every adaptive call moves log2(chunk) by exactly ±1 (the clamps
        // at 16 and 1<<20 are unreachable within 3 steps of 256), so after
        // an ODD number of calls the chunk cannot equal the initial value
        // regardless of which way each hill-climb step went. An even count
        // would be flaky: grow-then-shrink lands back on 256.
        for _ in 0..3 {
            ex.parallel_for(10_000, ChunkPolicy::Adaptive, |r| {
                std::hint::black_box(r.map(|i| i as f64).sum::<f64>());
            });
        }
        assert_ne!(
            ex.adaptive_chunk(),
            initial,
            "hill climbing never moved the chunk from its initial value"
        );
    }

    #[test]
    fn adaptive_chunk_is_clamped_to_len_for_small_inputs() {
        // The stored chunk hill-climbs without bound, but the chunk used
        // for a given call must never exceed ceil(len / workers): a tiny
        // parallel_for after large ones must still split across workers
        // instead of handing one worker the whole range.
        let ex = Executor::new(4);
        for _ in 0..12 {
            // Drive the stored chunk upward past any small-input bound.
            ex.parallel_for(1 << 20, ChunkPolicy::Adaptive, |r| {
                std::hint::black_box(r.len());
            });
        }
        for len in [1usize, 5, 33, 100] {
            let max_seen = AtomicUsize::new(0);
            ex.parallel_for(len, ChunkPolicy::Adaptive, |r| {
                max_seen.fetch_max(r.len(), Ordering::Relaxed);
            });
            let bound = len.div_ceil(ex.workers()).max(1);
            let got = max_seen.load(Ordering::Relaxed);
            assert!(
                got <= bound,
                "len={len}: saw a range of {got} > clamp bound {bound}"
            );
        }
    }

    #[test]
    fn more_workers_than_chunks_is_fine() {
        assert_eq!(
            sum_with(ChunkPolicy::Dynamic { chunk: 1000 }, 10, 16),
            expected(10)
        );
    }
}
