//! Fault model — seeded fault plans injected at the runtime wire seams.
//!
//! A [`FaultPlan`] describes *what* can go wrong on the wire: per-envelope
//! drop/duplicate/extra-delay probabilities, one "locality L crashes at
//! time T" event, and one straggler slowdown. Both runtimes consume the
//! same plan at their single delivery seam — the simulator where
//! `group_outbox` output is scheduled onto the wire, the threads runtime
//! where dispatch effects push into destination inboxes — so a plan is
//! substrate-portable by construction.
//!
//! [`FaultState`] is the per-run mutable companion: a splitmix64 stream
//! seeded from the plan (decisions are a deterministic function of
//! `(seed, envelope ordinal)`), crash flags, and injection counters that
//! the runtimes stamp into [`FaultStats`](super::metrics::FaultStats)
//! at teardown.
//!
//! Fault decisions apply to *data* envelopes only. Messages whose
//! [`Message::fault_immune`](super::sim::Message::fault_immune) returns
//! true (the engines' thin Count/Continue/Status control plane) ride a
//! modeled-reliable channel: a grouped envelope mixing immune and
//! faultable items is split at the seam and only the faultable part is
//! subject to the plan. Runtime-internal events (acks, barrier
//! bookkeeping, timers) are never faulted.

use super::sim::LocalityId;

/// Delivery-reliability mode for the aggregator layer.
///
/// `None` is the historical fast path: no sequence numbers, no
/// retransmit buffers, no dedup state — envelope parity with every
/// pre-fault PR is property-pinned. `Acked` turns on sequence-numbered
/// envelopes with receiver dedup and ack-driven retransmit, which makes
/// drop/duplicate faults survivable.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Reliability {
    #[default]
    None,
    Acked,
}

impl Reliability {
    /// Parse the `reliability=none|acked` config value.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "none" => Ok(Reliability::None),
            "acked" => Ok(Reliability::Acked),
            other => Err(format!("unknown reliability '{other}' (none|acked)")),
        }
    }

    pub fn is_acked(self) -> bool {
        self == Reliability::Acked
    }
}

/// Seeded description of the faults to inject into one run.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Per-envelope drop probability in `[0, 1]`.
    pub drop_p: f64,
    /// Per-envelope duplication probability in `[0, 1]`.
    pub dup_p: f64,
    /// Upper bound on per-envelope extra delivery delay (µs); the drawn
    /// delay is uniform in `[0, delay_us)`.
    pub delay_us: f64,
    /// `(locality, time_us)`: the locality fail-stops at that point of
    /// the run (simulated time on the sim substrate, wall-clock elapsed
    /// on the threads substrate).
    pub crash: Option<(LocalityId, f64)>,
    /// `(locality, factor)`: straggler — that locality's compute charges
    /// are scaled by `factor` (sim substrate only; real threads already
    /// exhibit genuine stragglers).
    pub slow: Option<(LocalityId, f64)>,
    /// Seed for the decision stream.
    pub seed: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan::none()
    }
}

impl FaultPlan {
    /// The no-fault plan: injection seams stay completely inert (no RNG
    /// draws, no envelope splitting, no extra events).
    pub fn none() -> Self {
        FaultPlan {
            drop_p: 0.0,
            dup_p: 0.0,
            delay_us: 0.0,
            crash: None,
            slow: None,
            seed: 0,
        }
    }

    /// True when the plan injects nothing at the delivery seams.
    pub fn is_none(&self) -> bool {
        self.drop_p == 0.0
            && self.dup_p == 0.0
            && self.delay_us == 0.0
            && self.crash.is_none()
            && self.slow.is_none()
    }

    /// Parse a `"L@T"` crash spec (locality `L` crashes at time `T` µs).
    pub fn parse_crash(s: &str) -> Result<(LocalityId, f64), String> {
        Self::parse_at(s).map_err(|e| format!("fault_crash: {e} (expected L@T, e.g. 2@500)"))
    }

    /// Parse a `"L@F"` straggler spec (locality `L` slowed by factor `F`).
    pub fn parse_slow(s: &str) -> Result<(LocalityId, f64), String> {
        let (l, f) = Self::parse_at(s)
            .map_err(|e| format!("fault_slow: {e} (expected L@F, e.g. 2@4.0)"))?;
        if f < 1.0 {
            return Err(format!("fault_slow: factor {f} must be >= 1"));
        }
        Ok((l, f))
    }

    fn parse_at(s: &str) -> Result<(LocalityId, f64), String> {
        let (l, t) = s
            .split_once('@')
            .ok_or_else(|| format!("missing '@' in '{s}'"))?;
        let l: LocalityId = l
            .trim()
            .parse()
            .map_err(|_| format!("bad locality in '{s}'"))?;
        let t: f64 = t.trim().parse().map_err(|_| format!("bad value in '{s}'"))?;
        if !t.is_finite() || t < 0.0 {
            return Err(format!("value in '{s}' must be finite and >= 0"));
        }
        Ok((l, t))
    }
}

/// splitmix64 — tiny, seedable, dependency-free; decision streams are a
/// pure function of the plan seed.
#[derive(Clone, Debug)]
pub(crate) struct FaultRng(u64);

impl FaultRng {
    pub(crate) fn new(seed: u64) -> Self {
        FaultRng(seed)
    }

    pub(crate) fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    pub(crate) fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }
}

/// One envelope's injection verdict.
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultDecision {
    pub drop: bool,
    pub dup: bool,
    pub extra_delay_us: f64,
}

/// Per-run mutable fault state: the decision stream, crash flags, and
/// injection counters. Lives as a run-loop local on the sim substrate
/// and under the shared mutex on the threads substrate.
#[derive(Clone, Debug)]
pub struct FaultState {
    plan: FaultPlan,
    rng: FaultRng,
    crashed: Vec<bool>,
    /// Injection counters, stamped into `FaultStats` at teardown.
    pub drops: u64,
    pub dups: u64,
    pub delays: u64,
    pub crashes: u64,
}

impl FaultState {
    pub fn new(plan: FaultPlan, n_localities: usize) -> Self {
        let rng = FaultRng::new(plan.seed ^ 0xFA17_FA17_FA17_FA17);
        FaultState {
            plan,
            rng,
            crashed: vec![false; n_localities],
            drops: 0,
            dups: 0,
            delays: 0,
            crashes: 0,
        }
    }

    /// True when any injection seam needs to do work; callers gate every
    /// fault-path branch on this so a no-fault run stays byte-identical.
    pub fn active(&self) -> bool {
        !self.plan.is_none()
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Draw one envelope's verdict. Three draws are consumed regardless
    /// of the outcome so the stream position depends only on the
    /// envelope ordinal, not on earlier verdicts.
    pub fn decide(&mut self) -> FaultDecision {
        let drop = self.rng.next_f64() < self.plan.drop_p;
        let dup = self.rng.next_f64() < self.plan.dup_p;
        let delay_draw = self.rng.next_f64();
        let extra_delay_us = if self.plan.delay_us > 0.0 {
            delay_draw * self.plan.delay_us
        } else {
            0.0
        };
        if drop {
            self.drops += 1;
            // A dropped envelope is gone; it cannot also be duplicated
            // or delayed.
            return FaultDecision { drop: true, dup: false, extra_delay_us: 0.0 };
        }
        if dup {
            self.dups += 1;
        }
        if extra_delay_us > 0.0 {
            self.delays += 1;
        }
        FaultDecision { drop: false, dup, extra_delay_us }
    }

    /// The crash deadline for `l`, if the plan crashes it.
    pub fn crash_time(&self, l: LocalityId) -> Option<f64> {
        match self.plan.crash {
            Some((c, t)) if c == l => Some(t),
            _ => None,
        }
    }

    /// Mark `l` fail-stopped; returns true the first time.
    pub fn mark_crashed(&mut self, l: LocalityId) -> bool {
        let i = l as usize;
        if i < self.crashed.len() && !self.crashed[i] {
            self.crashed[i] = true;
            self.crashes += 1;
            true
        } else {
            false
        }
    }

    pub fn is_crashed(&self, l: LocalityId) -> bool {
        self.crashed.get(l as usize).copied().unwrap_or(false)
    }

    pub fn any_crashed(&self) -> bool {
        self.crashed.iter().any(|&c| c)
    }

    /// Indices of fail-stopped localities.
    pub fn crashed_localities(&self) -> Vec<LocalityId> {
        self.crashed
            .iter()
            .enumerate()
            .filter_map(|(i, &c)| c.then_some(i as LocalityId))
            .collect()
    }

    /// Compute-charge multiplier for `l` (straggler model; 1.0 default).
    pub fn slow_factor(&self, l: LocalityId) -> f64 {
        match self.plan.slow {
            Some((s, f)) if s == l => f,
            _ => 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_plan_is_inert() {
        let p = FaultPlan::none();
        assert!(p.is_none());
        let st = FaultState::new(p, 4);
        assert!(!st.active());
        assert!(!st.any_crashed());
    }

    #[test]
    fn decision_stream_is_deterministic() {
        let plan = FaultPlan { drop_p: 0.3, dup_p: 0.3, delay_us: 50.0, seed: 7, ..FaultPlan::none() };
        let mut a = FaultState::new(plan.clone(), 2);
        let mut b = FaultState::new(plan, 2);
        for _ in 0..256 {
            let (da, db) = (a.decide(), b.decide());
            assert_eq!(da.drop, db.drop);
            assert_eq!(da.dup, db.dup);
            assert_eq!(da.extra_delay_us, db.extra_delay_us);
        }
        assert_eq!(a.drops, b.drops);
        assert_eq!(a.dups, b.dups);
        assert_eq!(a.delays, b.delays);
        assert!(a.drops > 0 && a.dups > 0 && a.delays > 0);
    }

    #[test]
    fn zero_probabilities_never_fire() {
        let plan = FaultPlan { crash: Some((1, 100.0)), seed: 3, ..FaultPlan::none() };
        let mut st = FaultState::new(plan, 2);
        assert!(st.active()); // crash makes the plan non-trivial
        for _ in 0..128 {
            let d = st.decide();
            assert!(!d.drop && !d.dup && d.extra_delay_us == 0.0);
        }
        assert_eq!(st.drops + st.dups + st.delays, 0);
    }

    #[test]
    fn crash_spec_parses() {
        assert_eq!(FaultPlan::parse_crash("2@500").unwrap(), (2, 500.0));
        assert_eq!(FaultPlan::parse_crash(" 0 @ 1.5 ").unwrap(), (0, 1.5));
        assert!(FaultPlan::parse_crash("2").is_err());
        assert!(FaultPlan::parse_crash("x@5").is_err());
        assert!(FaultPlan::parse_crash("1@-3").is_err());
        assert_eq!(FaultPlan::parse_slow("1@4.0").unwrap(), (1, 4.0));
        assert!(FaultPlan::parse_slow("1@0.5").is_err());
    }

    #[test]
    fn crash_bookkeeping() {
        let plan = FaultPlan { crash: Some((1, 100.0)), ..FaultPlan::none() };
        let mut st = FaultState::new(plan, 4);
        assert_eq!(st.crash_time(1), Some(100.0));
        assert_eq!(st.crash_time(0), None);
        assert!(st.mark_crashed(1));
        assert!(!st.mark_crashed(1)); // idempotent
        assert!(st.is_crashed(1));
        assert_eq!(st.crashes, 1);
        assert_eq!(st.crashed_localities(), vec![1]);
    }

    #[test]
    fn reliability_parses() {
        assert_eq!(Reliability::parse("none").unwrap(), Reliability::None);
        assert_eq!(Reliability::parse("acked").unwrap(), Reliability::Acked);
        assert!(Reliability::parse("tcp").is_err());
        assert!(Reliability::Acked.is_acked());
        assert!(!Reliability::None.is_acked());
    }

    #[test]
    fn slow_factor_targets_one_locality() {
        let plan = FaultPlan { slow: Some((2, 4.0)), ..FaultPlan::none() };
        let st = FaultState::new(plan, 4);
        assert_eq!(st.slow_factor(2), 4.0);
        assert_eq!(st.slow_factor(0), 1.0);
    }
}
