//! Run reports and timing helpers for the simulated runtime and benches.

use super::aggregate::AggStats;
use super::net::NetStats;

/// Fault-injection and recovery accounting: what the seeded
/// [`FaultPlan`](super::fault::FaultPlan) did to the wire, what the
/// reliable-delivery layer did about it, and what checkpoint/restart
/// recovery cost. The runtimes stamp the injection counters, the
/// aggregators stamp the delivery counters (merged like [`AggStats`]),
/// and the engine recovery wrapper stamps the checkpoint/restore block.
/// All-zero for a fault-free `reliability=none` run by construction.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FaultStats {
    /// Envelopes dropped on the wire by the fault plan.
    pub injected_drops: u64,
    /// Envelopes duplicated on the wire by the fault plan.
    pub injected_dups: u64,
    /// Envelopes given extra delivery delay by the fault plan.
    pub injected_delays: u64,
    /// Localities fail-stopped by the fault plan.
    pub crashes: u64,
    /// Envelopes re-sent by the ack-driven retransmit layer.
    pub retransmits: u64,
    /// Duplicate envelopes suppressed by receiver-side dedup windows.
    pub dedup_hits: u64,
    /// Retransmit entries abandoned after the attempt cap (the failure
    /// detector for crashed destinations).
    pub give_ups: u64,
    /// Per-locality state snapshots taken.
    pub checkpoints: u64,
    /// Crashed localities restored from a snapshot.
    pub restores: u64,
    /// Host wall-clock of the post-crash recovery run, us.
    pub recovery_wall_us: f64,
}

impl FaultStats {
    /// Accumulate another stats block into this one (report merging).
    pub fn merge(&mut self, other: &FaultStats) {
        self.injected_drops += other.injected_drops;
        self.injected_dups += other.injected_dups;
        self.injected_delays += other.injected_delays;
        self.crashes += other.crashes;
        self.retransmits += other.retransmits;
        self.dedup_hits += other.dedup_hits;
        self.give_ups += other.give_ups;
        self.checkpoints += other.checkpoints;
        self.restores += other.restores;
        self.recovery_wall_us += other.recovery_wall_us;
    }

    /// True when nothing was injected and nothing was recovered — the
    /// envelope-parity fast path.
    pub fn is_quiet(&self) -> bool {
        *self == FaultStats::default()
    }
}

/// Structured diagnosis of a stalled run: which localities the barrier
/// (or quiescence check) is still waiting on and what state was left in
/// flight. Built by the simulator when its event heap drains with a
/// partial barrier outstanding, and by the threads runtime's stall
/// watchdog when no event has been processed for `stall_timeout_us`.
/// Surfaced through `run_actors` as a panic whose message starts with
/// `"deadlock:"` followed by this report's [`Display`](std::fmt::Display)
/// rendering.
#[derive(Debug, Clone, Default)]
pub struct StallReport {
    /// Localities that reached the barrier (or quiesced) and are waiting.
    pub waiting: Vec<usize>,
    /// Localities the barrier is still missing (crashed localities are
    /// excluded from the quorum and never appear here).
    pub missing: Vec<usize>,
    /// Per-locality undelivered inbox/event depth.
    pub inbox_depths: Vec<usize>,
    /// Per-locality pending timer count.
    pub pending_timers: Vec<usize>,
    /// In-flight traced envelopes awaiting acks, per locality.
    pub inflight_acks: Vec<usize>,
    /// Undelivered message events (sim substrate's `messages_pending`).
    pub messages_pending: u64,
    /// Barrier epoch the run stalled in.
    pub epoch: u64,
}

impl std::fmt::Display for StallReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // The leading word is load-bearing: the partial-barrier tests pin
        // the failure mode with `#[should_panic(expected = "deadlock")]`.
        write!(
            f,
            "deadlock: localities {:?} waiting on a barrier {:?} never reached \
             (epoch {}, {} message(s) pending; inbox depths {:?}, pending timers {:?}, \
             in-flight acks {:?})",
            self.waiting,
            self.missing,
            self.epoch,
            self.messages_pending,
            self.inbox_depths,
            self.pending_timers,
            self.inflight_acks,
        )
    }
}

/// Algorithm-level work accounting: how many edge relaxations (or other
/// per-edge update proposals) an engine executed and how many of them
/// actually improved state. The Firoz et al. "Anatomy" line of work shows
/// that *ordering* — chaotic label-correcting vs. delta-stepping buckets —
/// is what separates distributed SSSP variants, and the separation shows up
/// here, not in envelope counts: a work-inefficient engine performs many
/// relaxations that never improve a tentative distance.
///
/// The engine itself knows nothing about relaxations; algorithm drivers
/// merge their actors' counters into [`SimReport::work`] after the run,
/// exactly like [`AggStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkStats {
    /// Update proposals executed (each scanned edge proposes one tentative
    /// distance, whether or not it wins).
    pub relaxations: u64,
    /// Proposals that strictly improved the target's tentative value.
    pub useful_relaxations: u64,
}

impl WorkStats {
    /// Accumulate another stats block into this one.
    pub fn merge(&mut self, other: &WorkStats) {
        self.relaxations += other.relaxations;
        self.useful_relaxations += other.useful_relaxations;
    }

    /// Useful fraction of the executed relaxations (1.0 == no wasted work;
    /// an empty run counts as perfectly efficient).
    pub fn efficiency(&self) -> f64 {
        if self.relaxations == 0 {
            1.0
        } else {
            self.useful_relaxations as f64 / self.relaxations as f64
        }
    }
}

/// Partition-quality summary of the distributed graph a run executed on.
/// All three factors are `>= 1.0`; 1.0 is perfect. Like [`AggStats`] and
/// [`WorkStats`], the engine knows nothing about partitions — algorithm
/// drivers stamp [`SimReport::partition`] from
/// [`DistGraph::partition_stats`](crate::graph::DistGraph::partition_stats).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PartitionStats {
    /// Max / mean owned-vertex count across localities.
    pub vertex_imbalance: f64,
    /// Max / mean locally-stored-edge count across localities.
    pub edge_imbalance: f64,
    /// Mean vertex copies (master + mirrors); 1.0 for 1-D schemes.
    pub replication_factor: f64,
}

impl Default for PartitionStats {
    fn default() -> Self {
        PartitionStats { vertex_imbalance: 1.0, edge_imbalance: 1.0, replication_factor: 1.0 }
    }
}

/// Query-serving accounting for [`serve`](crate::serve) runs: how a
/// stream of point-to-point queries was answered (precomputed landmark
/// tables, the hot-source LRU cache, or batched multi-source SSSP waves)
/// and the end-to-end latency distribution. Like [`WorkStats`], the
/// runtimes know nothing about queries — this starts zeroed and the serve
/// front-end stamps it after the run. `waves < queries` is the batching
/// win; `oracle_hits + cache_hits` is the precompute win.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct QueryStats {
    /// Queries answered.
    pub queries: u64,
    /// Queries answered exactly from the landmark distance tables.
    pub oracle_hits: u64,
    /// Queries answered from the hot-source LRU cache.
    pub cache_hits: u64,
    /// Multi-source SSSP waves executed for the uncovered remainder.
    pub waves: u64,
    /// Waves re-executed after a fault-suspect result (bounded to one
    /// retry per window by the graceful-degradation path).
    pub retries: u64,
    /// Queries answered with landmark triangle-inequality *bounds*
    /// (flagged approximate) because the exact wave missed its deadline.
    pub degraded: u64,
    /// Queries per second of host wall-clock.
    pub qps: f64,
    /// Median per-query latency, us (wall-clock from arrival to answer).
    pub p50_us: f64,
    /// 99th-percentile per-query latency, us.
    pub p99_us: f64,
}

impl QueryStats {
    /// Covered fraction: queries that never left the serving locality
    /// (oracle + cache hits over total; an empty run counts as 0).
    pub fn hit_rate(&self) -> f64 {
        if self.queries == 0 {
            0.0
        } else {
            (self.oracle_hits + self.cache_hits) as f64 / self.queries as f64
        }
    }
}

/// Graph-storage footprint of the distributed graph a run executed on:
/// what the shards cost in memory and what building them cost. Stamped by
/// algorithm drivers from
/// [`DistGraph::mem_stats`](crate::graph::DistGraph::mem_stats) next to
/// [`SimReport::partition`] — the scoreboard for the `storage` key and
/// the A9 scale-sweep ablation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemStats {
    /// Adjacency encoding (`plain` / `compressed`).
    pub storage: &'static str,
    /// Sum of shard heap bytes across localities (replication-weighted:
    /// mirrored rows count at every holder).
    pub total_shard_bytes: usize,
    /// Largest single shard, bytes — the per-locality memory bound.
    pub max_shard_bytes: usize,
    /// `total_shard_bytes / m` over the global directed edge count.
    pub bytes_per_edge: f64,
    /// Peak transient builder bytes. On the materialized path this counts
    /// the whole-graph CSR plus the full routing buffers (all resident at
    /// the leader at once); on the streaming path it is the largest
    /// *per-locality* transient (ingest bucket + routed edges), the
    /// quantity that bounds a distributed-memory build.
    pub peak_builder_bytes: usize,
    /// Wall-clock build time of the distributed graph, ms.
    pub build_ms: f64,
}

impl Default for MemStats {
    fn default() -> Self {
        MemStats {
            storage: "plain",
            total_shard_bytes: 0,
            max_shard_bytes: 0,
            bytes_per_edge: 0.0,
            peak_builder_bytes: 0,
            build_ms: 0.0,
        }
    }
}

/// Dynamic-graph accounting for `mutate` runs: what an
/// [`UpdateBatch`](crate::graph::mutation::UpdateBatch) did to the shards
/// and what the incremental re-convergence that followed cost. Like
/// [`WorkStats`], the runtimes know nothing about updates — this starts
/// zeroed and [`engine::rerun_incremental`](crate::engine) /
/// [`DistGraph::apply_updates`](crate::graph::DistGraph::apply_updates)
/// stamp it after the run. The A10 ablation compares
/// `reconverge_relaxations`/`reconverge_envelopes` against a full
/// recompute of the same post-update graph.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct UpdateStats {
    /// Operations carried by the batch (inserts + deletes as requested,
    /// before no-op filtering).
    pub batch_edges: u64,
    /// Edge inserts actually applied (absent-edge inserts only).
    pub applied: u64,
    /// Edge deletes actually applied (present-edge deletes only).
    pub retracted: u64,
    /// Envelopes spent scatter-routing the batch to owning localities
    /// through the aggregator.
    pub route_envelopes: u64,
    /// Routed edge-update items across those envelopes.
    pub route_items: u64,
    /// Vertices re-seeded into the wavefront for re-convergence.
    pub reseeded: u64,
    /// Vertices whose previous state was invalidated (reset to the cold
    /// initial value) by the deletion dependency taint.
    pub tainted: u64,
    /// Re-convergences that fell back to a full cold recompute because
    /// the deletion taint exceeded the `taint_cap` fraction of the graph.
    pub fallbacks: u64,
    /// Relaxations executed by the incremental re-convergence run.
    pub reconverge_relaxations: u64,
    /// Envelopes shipped by the incremental re-convergence run.
    pub reconverge_envelopes: u64,
    /// Modeled makespan of the re-convergence run, us.
    pub reconverge_makespan_us: f64,
    /// Host wall-clock of the re-convergence run, us.
    pub reconverge_wall_us: f64,
}

impl UpdateStats {
    /// Accumulate another stats block into this one (report merging).
    pub fn merge(&mut self, other: &UpdateStats) {
        self.batch_edges += other.batch_edges;
        self.applied += other.applied;
        self.retracted += other.retracted;
        self.route_envelopes += other.route_envelopes;
        self.route_items += other.route_items;
        self.reseeded += other.reseeded;
        self.tainted += other.tainted;
        self.fallbacks += other.fallbacks;
        self.reconverge_relaxations += other.reconverge_relaxations;
        self.reconverge_envelopes += other.reconverge_envelopes;
        self.reconverge_makespan_us += other.reconverge_makespan_us;
        self.reconverge_wall_us += other.reconverge_wall_us;
    }

    /// Fraction of the batch that changed the graph (applied + retracted
    /// over requested ops; an empty batch counts as 0).
    pub fn effective_rate(&self) -> f64 {
        if self.batch_edges == 0 {
            0.0
        } else {
            (self.applied + self.retracted) as f64 / self.batch_edges as f64
        }
    }
}

/// Outcome of one simulated run: the modeled makespan plus the quantities
/// the paper's analysis hinges on (per-locality busy time → load balance,
/// barrier count → synchronization cost, traffic → communication overhead).
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Localities simulated.
    pub n_localities: u32,
    /// Modeled end-to-end time, us (max over locality timelines).
    pub makespan_us: f64,
    /// Per-locality accumulated compute+overhead charge, us.
    pub busy_us: Vec<f64>,
    /// Completed global barriers.
    pub barriers: u64,
    /// Events processed by the engine.
    pub events: u64,
    /// Aggregate interconnect traffic.
    pub net: NetStats,
    /// Traffic broken down by source locality.
    pub per_locality_net: Vec<NetStats>,
    /// Application-level message-aggregation accounting
    /// ([`amt::aggregate`](super::aggregate)). The engine itself knows
    /// nothing about combiners, so this starts empty and algorithm drivers
    /// merge their actors' [`AggStats`] in after the run.
    pub agg: AggStats,
    /// The master-bound slice of [`SimReport::agg`]: combiners whose slots
    /// are destination owned-row indices
    /// ([`SlotSpace::Master`](super::aggregate::SlotSpace)). Master-bound
    /// and mirror-bound traffic have different fan-in under vertex cuts,
    /// so observed latency is reported per slot space.
    pub agg_master: AggStats,
    /// The mirror-bound slice of [`SimReport::agg`]
    /// ([`SlotSpace::Mirror`](super::aggregate::SlotSpace)): master→mirror
    /// scatter (idle under 1-D schemes).
    pub agg_mirror: AggStats,
    /// Algorithm-level work accounting (relaxation counters). Starts empty;
    /// algorithm drivers merge their actors' [`WorkStats`] in after the run.
    pub work: WorkStats,
    /// Partition quality of the distributed graph (defaults to the perfect
    /// 1.0 factors; drivers overwrite it from the built
    /// [`DistGraph`](crate::graph::DistGraph)).
    pub partition: PartitionStats,
    /// Query-serving accounting. Zero for one-shot analytics runs; the
    /// [`serve`](crate::serve) front-end stamps it like drivers stamp
    /// [`SimReport::work`].
    pub query: QueryStats,
    /// Graph-storage footprint of the distributed graph (defaults to
    /// zeros; drivers stamp it from
    /// [`DistGraph::mem_stats`](crate::graph::DistGraph::mem_stats)).
    pub mem: MemStats,
    /// Dynamic-graph accounting. Zero for static runs; the `mutate`
    /// driver stamps it from [`DistGraph::apply_updates`] routing stats
    /// and the incremental re-convergence run.
    ///
    /// [`DistGraph::apply_updates`]: crate::graph::DistGraph::apply_updates
    pub update: UpdateStats,
    /// Fault-injection and recovery accounting. Zero unless a
    /// [`FaultPlan`](super::fault::FaultPlan) or `reliability=acked` was
    /// active: the runtimes stamp injections, the drivers merge the
    /// aggregators' delivery counters, and the recovery wrapper stamps
    /// checkpoints/restores.
    pub fault: FaultStats,
    /// Host wall-clock for the whole run, us. For the simulator this is
    /// the cost of executing the simulation itself; for the threaded
    /// runtime it *is* the end-to-end time (`makespan_us == wall_us`).
    /// Always nonzero: every run takes real time.
    pub wall_us: f64,
    /// Host wall-clock per barrier-delimited phase, us. A run with B
    /// completed barriers has B+1 segments (the segment after the last
    /// barrier — or the whole run for barrier-free asynchronous
    /// execution — is included), so the entries always sum to
    /// [`SimReport::wall_us`].
    pub phase_wall_us: Vec<f64>,
}

impl SimReport {
    /// The single construction site: a zeroed report over `n_localities`.
    /// Runtimes and drivers create a report here and stamp the blocks they
    /// own afterwards, so a newly added stats block (like
    /// [`SimReport::update`]) gets its zero default at every site instead
    /// of a compile error — or worse, a silent omission — per literal.
    pub fn new(n_localities: u32) -> SimReport {
        SimReport {
            n_localities,
            makespan_us: 0.0,
            busy_us: Vec::new(),
            barriers: 0,
            events: 0,
            net: NetStats::default(),
            per_locality_net: Vec::new(),
            agg: AggStats::default(),
            agg_master: AggStats::default(),
            agg_mirror: AggStats::default(),
            work: WorkStats::default(),
            partition: PartitionStats::default(),
            query: QueryStats::default(),
            mem: MemStats::default(),
            update: UpdateStats::default(),
            fault: FaultStats::default(),
            wall_us: 0.0,
            phase_wall_us: Vec::new(),
        }
    }

    /// Mean per-locality busy time, us.
    pub fn mean_busy_us(&self) -> f64 {
        if self.busy_us.is_empty() {
            0.0
        } else {
            self.busy_us.iter().sum::<f64>() / self.busy_us.len() as f64
        }
    }

    /// Load-imbalance factor: max busy / mean busy (1.0 == perfectly
    /// balanced). The paper attributes BSP BFS slowdowns to exactly this
    /// quantity under skewed frontiers.
    pub fn load_imbalance(&self) -> f64 {
        let mean = self.mean_busy_us();
        if mean == 0.0 {
            1.0
        } else {
            self.busy_us.iter().cloned().fold(0.0_f64, f64::max) / mean
        }
    }

    /// Fraction of the makespan the average locality spent busy.
    pub fn utilization(&self) -> f64 {
        if self.makespan_us == 0.0 {
            1.0
        } else {
            self.mean_busy_us() / self.makespan_us
        }
    }
}

/// Convert absolute barrier-completion wall-clock marks into per-phase
/// segment durations, closing the final segment at `wall_us`. Both
/// runtimes use this so `phase_wall_us` has one schema: B barriers →
/// B+1 segments summing to `wall_us`.
pub(crate) fn phase_segments(marks: &[f64], wall_us: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(marks.len() + 1);
    let mut last = 0.0;
    for &m in marks {
        out.push(m - last);
        last = m;
    }
    out.push(wall_us - last);
    out
}

/// Simple online mean/min/max/stddev accumulator for bench repetitions.
#[derive(Debug, Clone, Default)]
pub struct Stats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Stats {
    /// Create an empty accumulator.
    pub fn new() -> Self {
        Stats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Add one observation (Welford update).
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Smallest observation.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest observation.
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_mean_min_max() {
        let mut s = Stats::new();
        for x in [2.0, 4.0, 6.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 3);
        assert!((s.mean() - 4.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 6.0);
        assert!((s.stddev() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn report_load_imbalance() {
        let mut r = SimReport::new(2);
        r.makespan_us = 100.0;
        r.busy_us = vec![100.0, 50.0];
        assert!((r.mean_busy_us() - 75.0).abs() < 1e-12);
        assert!((r.load_imbalance() - 100.0 / 75.0).abs() < 1e-12);
        assert!((r.utilization() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn empty_report_is_balanced() {
        let r = SimReport::new(0);
        assert_eq!(r.load_imbalance(), 1.0);
        assert_eq!(r.utilization(), 1.0);
    }

    #[test]
    fn new_report_is_zeroed() {
        let r = SimReport::new(4);
        assert_eq!(r.n_localities, 4);
        assert_eq!(r.barriers, 0);
        assert_eq!(r.work, WorkStats::default());
        assert_eq!(r.update, UpdateStats::default());
        assert!(r.fault.is_quiet());
        assert!(r.busy_us.is_empty() && r.phase_wall_us.is_empty());
    }

    #[test]
    fn update_stats_merge_and_rate() {
        let mut u = UpdateStats::default();
        assert_eq!(u.effective_rate(), 0.0);
        u.merge(&UpdateStats {
            batch_edges: 10,
            applied: 4,
            retracted: 2,
            route_envelopes: 3,
            route_items: 6,
            reseeded: 5,
            tainted: 1,
            fallbacks: 0,
            reconverge_relaxations: 100,
            reconverge_envelopes: 7,
            reconverge_makespan_us: 2.0,
            reconverge_wall_us: 1.0,
        });
        u.merge(&UpdateStats { batch_edges: 10, applied: 2, ..UpdateStats::default() });
        assert_eq!(u.batch_edges, 20);
        assert_eq!(u.applied, 6);
        assert_eq!(u.retracted, 2);
        assert!((u.effective_rate() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn phase_segments_close_the_final_segment() {
        // Two barriers at t=10 and t=30, run ends at t=45: three phases.
        let segs = phase_segments(&[10.0, 30.0], 45.0);
        assert_eq!(segs, vec![10.0, 20.0, 15.0]);
        assert!((segs.iter().sum::<f64>() - 45.0).abs() < 1e-12);
        // No barriers: one segment spanning the whole run.
        assert_eq!(phase_segments(&[], 7.5), vec![7.5]);
    }

    #[test]
    fn query_stats_hit_rate() {
        assert_eq!(QueryStats::default().hit_rate(), 0.0);
        let q = QueryStats {
            queries: 100,
            oracle_hits: 30,
            cache_hits: 20,
            waves: 5,
            retries: 0,
            degraded: 0,
            qps: 1000.0,
            p50_us: 10.0,
            p99_us: 50.0,
        };
        assert!((q.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn fault_stats_merge_and_quiet() {
        let mut f = FaultStats::default();
        assert!(f.is_quiet());
        f.merge(&FaultStats { injected_drops: 3, retransmits: 4, ..FaultStats::default() });
        f.merge(&FaultStats { injected_drops: 1, dedup_hits: 2, restores: 1, ..FaultStats::default() });
        assert_eq!(f.injected_drops, 4);
        assert_eq!(f.retransmits, 4);
        assert_eq!(f.dedup_hits, 2);
        assert_eq!(f.restores, 1);
        assert!(!f.is_quiet());
    }

    #[test]
    fn stall_report_display_starts_with_deadlock() {
        let r = StallReport {
            waiting: vec![0, 2],
            missing: vec![1],
            inbox_depths: vec![0, 3, 0],
            pending_timers: vec![0, 0, 1],
            inflight_acks: vec![0, 2, 0],
            messages_pending: 3,
            epoch: 5,
        };
        let s = r.to_string();
        assert!(s.starts_with("deadlock:"), "{s}");
        assert!(s.contains("[0, 2]") && s.contains("epoch 5"), "{s}");
    }

    #[test]
    fn work_stats_merge_and_efficiency() {
        let mut w = WorkStats::default();
        assert_eq!(w.efficiency(), 1.0);
        w.merge(&WorkStats { relaxations: 8, useful_relaxations: 2 });
        w.merge(&WorkStats { relaxations: 2, useful_relaxations: 3 });
        assert_eq!(w.relaxations, 10);
        assert_eq!(w.useful_relaxations, 5);
        assert!((w.efficiency() - 0.5).abs() < 1e-12);
    }
}
