//! HPX-equivalent asynchronous many-task (AMT) substrate.
//!
//! The paper runs on HPX: lightweight tasks, futures, an active global
//! address space (AGAS), `hpx::partitioned_vector`, and an MPI-backed
//! parcelport across 32 cluster nodes. We do not have a cluster, so this
//! module provides the same *execution model* over two cooperating pieces
//! (substitution table in DESIGN.md §4):
//!
//! * **[`sim`]** — a discrete-event simulated multi-locality runtime. Each
//!   locality is an actor with real Rust state; handlers execute real code
//!   and are charged wall-clock compute, while inter-locality messages are
//!   charged through a parameterized latency/bandwidth/overhead model
//!   ([`net`]). Asynchronous (eager, fine-grained, overlap-friendly) and
//!   BSP (superstep + barrier + batched delivery) styles are both
//!   expressible, which is exactly the HPX-vs-PBGL contrast the paper
//!   evaluates.
//! * **[`threads`]** — a thread-per-locality runtime executing the *same*
//!   actors on real OS threads with real queueing and host wall-clock
//!   time. [`run_actors`] dispatches between [`sim`] and [`threads`] on
//!   [`SimConfig::runtime`], so `--runtime sim|threads` switches every
//!   algorithm's substrate without touching engine code.
//! * **[`executor`]** — real threaded parallel-for executors for
//!   *intra*-locality parallelism (the paper's nodes have 64 cores),
//!   including the `adaptive_core_chunk_size` policy of §6.
//! * **[`aggregate`]** — runtime-level message aggregation: typed
//!   per-destination combiners with pluggable flush policies
//!   ([`FlushPolicy`]) and a fold hook for idempotent reductions. This is
//!   the AM++-style coalescing layer every asynchronous algorithm routes
//!   its remote actions through; the naive per-edge path survives only as
//!   [`FlushPolicy::Unbatched`] for ablations.
//!
//! [`agas`] and [`partitioned_vector`] round out the HPX surface the
//! algorithms program against. [`fault`] supplies the seeded fault plans
//! both runtimes inject at their delivery seams, and the aggregate layer
//! optionally runs `reliability=acked` sequenced/acked delivery on top.

pub mod agas;
pub mod aggregate;
pub mod executor;
pub mod fault;
pub mod metrics;
pub mod net;
pub mod partitioned_vector;
pub mod sim;
pub mod threads;

pub use agas::{Agas, GlobalAddress};
pub use aggregate::{AggStats, Aggregator, Batch, FlushPolicy, SlotSpace};
pub use executor::{ChunkPolicy, Executor};
pub use fault::{FaultPlan, FaultState, Reliability};
pub use metrics::{
    FaultStats, PartitionStats, QueryStats, SimReport, StallReport, UpdateStats, WorkStats,
};
pub use net::{NetConfig, NetStats};
pub use partitioned_vector::{AtomicLongVector, PartitionedVector};
pub use sim::{Actor, Ctx, LocalityId, RuntimeKind, SimConfig, SimRuntime, SimTime};
pub use threads::ThreadedRuntime;

/// Run `actors` on the substrate selected by [`SimConfig::runtime`]: the
/// discrete-event simulator or the thread-per-locality runtime. This is
/// the single seam the engines call, so one config key retargets every
/// algorithm. The `Send` bounds are what the threaded substrate needs;
/// all engine actors satisfy them (plain owned state, `Send` messages).
pub fn run_actors<A>(cfg: &SimConfig, actors: Vec<A>) -> (Vec<A>, SimReport)
where
    A: Actor + Send,
    A::Msg: Send,
{
    match cfg.runtime {
        RuntimeKind::Sim => SimRuntime::new(cfg.clone()).run(actors),
        RuntimeKind::Threads => ThreadedRuntime::new(cfg.clone()).run(actors),
    }
}
