//! Interconnect cost model for the simulated multi-locality runtime.
//!
//! The paper's testbed is a 32-node Intel Ice Lake cluster; HPX parcels ride
//! an MPI parcelport. We model that interconnect with the standard
//! latency/bandwidth (alpha-beta) decomposition plus per-message CPU
//! overheads:
//!
//! ```text
//! wire(msg)   = latency_us + (overhead_bytes + payload_bytes) / bandwidth
//! sender CPU  = send_cpu_us          (serialization, parcel dispatch)
//! receiver CPU= recv_cpu_us          (deserialization, action scheduling)
//! ```
//!
//! The CPU terms are what make fine-grained asynchronous messaging *not*
//! free — the effect behind the paper's PageRank result, where per-edge
//! remote actions lose to PBGL's batched supersteps. Message aggregation
//! (the "optimized" HPX variant) amortizes the latency and CPU terms over
//! an envelope of messages to the same destination; see
//! [`sim::Ctx::send`](super::sim::Ctx::send).

/// Interconnect parameters. Defaults approximate a commodity cluster fabric
/// (HDR-ish InfiniBand with MPI software overheads): 2 us one-way latency,
/// 12.5 GB/s effective bandwidth, ~0.5 us of CPU per message on each side.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// One-way wire latency per message (or per aggregated envelope), in us.
    pub latency_us: f64,
    /// Effective point-to-point bandwidth in bytes/us (12_500.0 == 12.5 GB/s).
    pub bandwidth_bytes_per_us: f64,
    /// Fixed per-envelope header bytes (parcel framing).
    pub overhead_bytes: usize,
    /// Sender-side CPU charge per envelope, in us.
    pub send_cpu_us: f64,
    /// Receiver-side CPU charge per envelope, in us.
    pub recv_cpu_us: f64,
    /// Per-item CPU charge inside an envelope (marshalling each action).
    pub per_item_cpu_us: f64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            latency_us: 2.0,
            bandwidth_bytes_per_us: 12_500.0,
            overhead_bytes: 64,
            send_cpu_us: 0.5,
            recv_cpu_us: 0.5,
            per_item_cpu_us: 0.05,
        }
    }
}

impl NetConfig {
    /// An idealized zero-cost network (useful for isolating compute effects
    /// in tests and ablations).
    pub fn zero() -> Self {
        NetConfig {
            latency_us: 0.0,
            bandwidth_bytes_per_us: f64::INFINITY,
            overhead_bytes: 0,
            send_cpu_us: 0.0,
            recv_cpu_us: 0.0,
            per_item_cpu_us: 0.0,
        }
    }

    /// Wire transit time for an envelope carrying `payload_bytes` across
    /// `items` aggregated messages.
    pub fn wire_us(&self, payload_bytes: usize) -> f64 {
        self.latency_us + (self.overhead_bytes + payload_bytes) as f64 / self.bandwidth_bytes_per_us
    }

    /// Sender CPU charge for an envelope of `items` messages.
    pub fn send_cpu(&self, items: usize) -> f64 {
        self.send_cpu_us + self.per_item_cpu_us * items as f64
    }

    /// Receiver CPU charge for an envelope of `items` messages.
    pub fn recv_cpu(&self, items: usize) -> f64 {
        self.recv_cpu_us + self.per_item_cpu_us * items as f64
    }
}

/// Per-run interconnect accounting (per source locality).
#[derive(Debug, Clone, Default)]
pub struct NetStats {
    /// Envelopes put on the wire.
    pub envelopes: u64,
    /// Application messages carried (>= envelopes when aggregating).
    pub messages: u64,
    /// Payload bytes carried (excluding per-envelope overhead).
    pub payload_bytes: u64,
    /// Total wire time accumulated, in us.
    pub wire_us: f64,
}

impl NetStats {
    /// Merge another stats block into this one.
    pub fn merge(&mut self, other: &NetStats) {
        self.envelopes += other.envelopes;
        self.messages += other.messages;
        self.payload_bytes += other.payload_bytes;
        self.wire_us += other.wire_us;
    }

    /// Mean messages per envelope (aggregation factor).
    pub fn aggregation_factor(&self) -> f64 {
        if self.envelopes == 0 {
            0.0
        } else {
            self.messages as f64 / self.envelopes as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_time_is_latency_plus_bytes_over_bandwidth() {
        let net = NetConfig {
            latency_us: 2.0,
            bandwidth_bytes_per_us: 100.0,
            overhead_bytes: 50,
            ..NetConfig::default()
        };
        let t = net.wire_us(150); // (50 + 150) / 100 = 2.0 + latency 2.0
        assert!((t - 4.0).abs() < 1e-9);
    }

    #[test]
    fn zero_network_is_free() {
        let net = NetConfig::zero();
        assert_eq!(net.wire_us(1_000_000), 0.0);
        assert_eq!(net.send_cpu(1000), 0.0);
        assert_eq!(net.recv_cpu(1000), 0.0);
    }

    #[test]
    fn aggregation_factor_counts_messages_per_envelope() {
        let mut s = NetStats::default();
        s.envelopes = 4;
        s.messages = 64;
        assert_eq!(s.aggregation_factor(), 16.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = NetStats { envelopes: 1, messages: 2, payload_bytes: 3, wire_us: 4.0 };
        let b = NetStats { envelopes: 10, messages: 20, payload_bytes: 30, wire_us: 40.0 };
        a.merge(&b);
        assert_eq!(a.envelopes, 11);
        assert_eq!(a.messages, 22);
        assert_eq!(a.payload_bytes, 33);
        assert!((a.wire_us - 44.0).abs() < 1e-9);
    }
}
