//! `hpx::partitioned_vector` equivalents.
//!
//! The paper leans on `hpx::partitioned_vector` as the drop-in distributed
//! replacement for `std::vector` in NWGraph's algorithms (§4.1). Two
//! flavors are provided:
//!
//! * [`PartitionedVector<T>`] — a block-distributed vector with local-slice
//!   access and owner queries, for data that each locality reads/writes only
//!   in its own segment (ranks, contributions).
//! * [`AtomicLongVector`] — an `i64` vector with per-element
//!   compare-exchange, the substrate for the paper's `set_parent`
//!   (Listing 1.2: "the parent update must now occur atomically ... using
//!   compare_exchange"). It is shared (`Arc`) across the simulated
//!   localities and safe under the real threaded executors too.

use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::Arc;

use super::agas::BlockMap;
use super::sim::LocalityId;

/// Block-distributed vector. Segment `l` lives with locality `l`; remote
/// access goes through messages in the simulated runtime (the type itself
/// only hands out local views and owner information).
#[derive(Debug, Clone)]
pub struct PartitionedVector<T> {
    map: BlockMap,
    segments: Vec<Vec<T>>,
}

impl<T: Clone> PartitionedVector<T> {
    /// Create with every element set to `init`.
    pub fn new(len: usize, n_localities: u32, init: T) -> Self {
        let map = BlockMap::new(len, n_localities);
        let segments = (0..n_localities)
            .map(|l| vec![init.clone(); map.segment_len(l)])
            .collect();
        PartitionedVector { map, segments }
    }

    /// Total length.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// The layout map.
    pub fn map(&self) -> &BlockMap {
        &self.map
    }

    /// Owner of global index `i`.
    pub fn owner(&self, i: usize) -> LocalityId {
        self.map.owner(i)
    }

    /// Immutable view of a locality's segment.
    pub fn segment(&self, l: LocalityId) -> &[T] {
        &self.segments[l as usize]
    }

    /// Mutable view of a locality's segment.
    pub fn segment_mut(&mut self, l: LocalityId) -> &mut [T] {
        &mut self.segments[l as usize]
    }

    /// Read element at global index (any locality — used by sequential
    /// oracles and result collection, not by the distributed hot paths).
    pub fn get(&self, i: usize) -> &T {
        let a = self.map.resolve(i);
        &self.segments[a.locality as usize][a.offset]
    }

    /// Write element at global index.
    pub fn set(&mut self, i: usize, value: T) {
        let a = self.map.resolve(i);
        self.segments[a.locality as usize][a.offset] = value;
    }

    /// Flatten into a plain `Vec` in global index order.
    pub fn to_vec(&self) -> Vec<T> {
        let mut out = Vec::with_capacity(self.len());
        for seg in &self.segments {
            out.extend(seg.iter().cloned());
        }
        out
    }
}

/// Shared atomic `i64` vector with block distribution — the `parents`
/// array of the distributed BFS. `cas` mirrors HPX's remote
/// `compare_exchange` action; in the simulation the *time* of a remote CAS
/// is charged by the message that triggers it, while the data effect goes
/// through this shared structure.
#[derive(Debug, Clone)]
pub struct AtomicLongVector {
    map: BlockMap,
    data: Arc<Vec<AtomicI64>>,
}

impl AtomicLongVector {
    /// Create with every element set to `init`.
    pub fn new(len: usize, n_localities: u32, init: i64) -> Self {
        let data = (0..len).map(|_| AtomicI64::new(init)).collect::<Vec<_>>();
        AtomicLongVector { map: BlockMap::new(len, n_localities), data: Arc::new(data) }
    }

    /// Total length.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// The layout map.
    pub fn map(&self) -> &BlockMap {
        &self.map
    }

    /// Owner of global index `i`.
    pub fn owner(&self, i: usize) -> LocalityId {
        self.map.owner(i)
    }

    /// Atomic load.
    pub fn load(&self, i: usize) -> i64 {
        self.data[i].load(Ordering::Acquire)
    }

    /// Atomic store.
    pub fn store(&self, i: usize, v: i64) {
        self.data[i].store(v, Ordering::Release);
    }

    /// Compare-exchange: returns `true` when `i` still held `expected` and
    /// was updated to `new` (the paper's `set_parent` primitive).
    pub fn cas(&self, i: usize, expected: i64, new: i64) -> bool {
        self.data[i]
            .compare_exchange(expected, new, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }

    /// Snapshot into a plain `Vec<i64>`.
    pub fn to_vec(&self) -> Vec<i64> {
        self.data.iter().map(|a| a.load(Ordering::Acquire)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partitioned_vector_get_set_roundtrip() {
        let mut v = PartitionedVector::new(10, 3, 0i32);
        for i in 0..10 {
            v.set(i, i as i32 * 10);
        }
        for i in 0..10 {
            assert_eq!(*v.get(i), i as i32 * 10);
        }
        assert_eq!(v.to_vec(), (0..10).map(|i| i * 10).collect::<Vec<_>>());
    }

    #[test]
    fn segments_partition_the_whole_vector() {
        let v = PartitionedVector::new(11, 4, 0u8);
        let total: usize = (0..4).map(|l| v.segment(l).len()).sum();
        assert_eq!(total, 11);
    }

    #[test]
    fn segment_mut_writes_through() {
        let mut v = PartitionedVector::new(6, 2, 0i32);
        v.segment_mut(1)[0] = 42;
        let first_of_seg1 = v.map().range_of(1).start;
        assert_eq!(*v.get(first_of_seg1), 42);
    }

    #[test]
    fn atomic_cas_set_parent_semantics() {
        let parents = AtomicLongVector::new(8, 2, -1);
        assert!(parents.cas(3, -1, 7), "first discovery wins");
        assert!(!parents.cas(3, -1, 9), "second discovery must fail");
        assert_eq!(parents.load(3), 7);
    }

    #[test]
    fn atomic_vector_is_shared_across_clones() {
        let a = AtomicLongVector::new(4, 2, 0);
        let b = a.clone();
        a.store(2, 5);
        assert_eq!(b.load(2), 5);
    }

    #[test]
    fn concurrent_cas_has_exactly_one_winner() {
        let v = AtomicLongVector::new(1, 1, -1);
        let winners: usize = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|t| {
                    let v = v.clone();
                    s.spawn(move || usize::from(v.cas(0, -1, t as i64)))
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).sum()
        });
        assert_eq!(winners, 1);
        assert!(v.load(0) >= 0);
    }
}
