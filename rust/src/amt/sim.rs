//! Discrete-event simulated multi-locality AMT runtime.
//!
//! This is the distributed-execution substrate standing in for "HPX across
//! 32 cluster nodes" (DESIGN.md §4). Each *locality* is an [`Actor`] with
//! real Rust state whose handlers execute real code; what is *modeled* is
//! time:
//!
//! * **compute** — handlers are charged their measured wall-clock time
//!   (scaled by [`SimConfig::compute_scale`]) plus any explicit
//!   [`Ctx::charge_us`] charges;
//! * **communication** — inter-locality messages pay the
//!   latency/bandwidth/CPU-overhead model of [`NetConfig`];
//! * **synchronization** — global barriers pay a tree-barrier cost and
//!   complete only when every locality has requested one and the network
//!   has drained.
//!
//! The virtual clock advances per locality (`avail[l]` = time locality `l`
//! next becomes free), so a run over P simulated localities on one physical
//! machine still produces the P-way-parallel makespan: it is the *maximum*
//! of per-locality timelines, not their sum. Both execution styles in the
//! paper map directly:
//!
//! * **asynchronous HPX style** — send eagerly from handlers, let delivery
//!   trigger work, never request a barrier; termination is network
//!   quiescence (exactly the active-message termination of AM++/PBGL 2.0).
//! * **BSP / PBGL style** — buffer incoming messages, request a barrier,
//!   do the superstep's work in [`Actor::on_barrier`].

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::time::Instant;

use super::fault::{FaultPlan, FaultState, Reliability};
use super::metrics::{SimReport, StallReport};
use super::net::{NetConfig, NetStats};

/// Identifies one simulated locality (paper: one cluster node).
pub type LocalityId = u32;

/// Simulated time, in microseconds.
pub type SimTime = f64;

/// Wire-size trait for application messages; drives the bandwidth term of
/// the network model. `Clone` is required so the fault layer can put a
/// duplicated copy of an envelope on the wire.
pub trait Message: Clone {
    /// Serialized payload size in bytes.
    fn wire_bytes(&self) -> usize;

    /// Number of application-level actions this message carries (a batched
    /// message of k vertex updates counts k). Drives the per-item CPU term
    /// so batching amortizes envelope costs but never hides marshalling
    /// work.
    fn item_count(&self) -> usize {
        1
    }

    /// True for thin control-plane messages (termination votes, barrier
    /// verdicts) that ride a modeled-reliable channel: the fault plan
    /// never drops, duplicates, or delays them. A grouped envelope mixing
    /// immune and faultable items is split at the injection seam and only
    /// the faultable part is subject to the plan. Default: faultable.
    fn fault_immune(&self) -> bool {
        false
    }
}

/// A per-locality algorithm state machine.
pub trait Actor {
    /// Message type exchanged between localities.
    type Msg: Message;

    /// Called once at t=0 on every locality.
    fn on_start(&mut self, ctx: &mut Ctx<Self::Msg>);

    /// Called when a message from `from` is delivered.
    fn on_message(&mut self, ctx: &mut Ctx<Self::Msg>, from: LocalityId, msg: Self::Msg);

    /// Called when a requested global barrier completes (`epoch` counts
    /// completed barriers, starting at 1).
    fn on_barrier(&mut self, _ctx: &mut Ctx<Self::Msg>, _epoch: u64) {}

    /// Delivery acknowledgement for a [`Ctx::send_traced`] message: the
    /// runtime reports the send-call time and the receiver's handler-start
    /// time (so receiver-side queueing delay is included in the observed
    /// latency). Models the parcelport's send-completion callback; the
    /// return channel itself is free. Default: ignored.
    fn on_ack(
        &mut self,
        _ctx: &mut Ctx<Self::Msg>,
        _token: u64,
        _sent: SimTime,
        _delivered: SimTime,
    ) {
    }

    /// A timer requested via [`Ctx::set_timer`] fired (`ctx.now()` is at
    /// or after the requested time). Timers count as in-flight work:
    /// quiescence and barriers wait for them, which is what lets
    /// time-windowed coalescing buffer across handler boundaries without
    /// stranding traffic. Default: ignored.
    fn on_timer(&mut self, _ctx: &mut Ctx<Self::Msg>) {}
}

/// Which runtime executes a set of [`Actor`]s: the discrete-event
/// simulator ([`SimRuntime`]) or the thread-per-locality runtime
/// ([`ThreadedRuntime`](super::threads::ThreadedRuntime)). Both run the
/// same actors unmodified; they differ only in what "time" means
/// (modeled virtual clock vs host wall-clock).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RuntimeKind {
    /// Discrete-event simulation with the modeled interconnect.
    #[default]
    Sim,
    /// One OS thread per locality; real queueing, real wall-clock.
    Threads,
}

impl RuntimeKind {
    /// Parse a `--runtime` / `runtime=` value.
    pub fn parse(s: &str) -> std::result::Result<RuntimeKind, String> {
        match s {
            "sim" => Ok(RuntimeKind::Sim),
            "threads" => Ok(RuntimeKind::Threads),
            other => Err(format!("unknown runtime `{other}` (want sim|threads)")),
        }
    }

    /// Canonical config-key spelling.
    pub fn name(&self) -> &'static str {
        match self {
            RuntimeKind::Sim => "sim",
            RuntimeKind::Threads => "threads",
        }
    }
}

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Interconnect model.
    pub net: NetConfig,
    /// Which substrate executes the actors (see [`RuntimeKind`]). The
    /// engines dispatch through [`run_actors`](super::run_actors), so a
    /// single config key switches every algorithm between the simulator
    /// and real threads.
    pub runtime: RuntimeKind,
    /// Global barrier cost in us; `None` derives a tree barrier:
    /// `2 * latency * ceil(log2 P)`.
    pub barrier_latency_us: Option<f64>,
    /// Charge handlers their measured wall time (disable for deterministic
    /// unit tests that use only explicit charges).
    pub measure_compute: bool,
    /// Multiplier applied to measured handler wall time. `1/64.0` would
    /// approximate the paper's 64-core nodes if handlers were serial
    /// whole-node work; algorithms here instead express intra-locality
    /// parallelism explicitly, so the default is 1.0.
    pub compute_scale: f64,
    /// Coalesce all sends to the same destination within one handler into
    /// one envelope (the paper's "optimized" aggregating variant).
    pub aggregate_sends: bool,
    /// HPX-style parcel coalescing: sends to the same destination are
    /// buffered for up to this many us (across handler boundaries) and
    /// flushed as one envelope. `0.0` disables. This is the
    /// `hpx::plugins::parcel::coalescing` behaviour the paper's runtime
    /// ships with, and what keeps fine-grained asynchronous algorithms
    /// from paying one envelope per remote action.
    pub coalesce_window_us: f64,
    /// Hard cap on processed events (runaway guard).
    pub max_events: u64,
    /// Seeded wire/crash fault plan injected at the delivery seams.
    /// [`FaultPlan::none`] (the default) keeps every seam inert — no RNG
    /// draws, no envelope splitting, no extra events — so fault-free runs
    /// keep exact envelope parity with the pre-fault substrate. A crash
    /// spec naming a locality `>= n` is ignored (config sweeps may shrink
    /// the locality count below the spec).
    pub fault: FaultPlan,
    /// Delivery guarantee of the aggregator layer. The runtimes ignore
    /// this; the engines read it and enable sequence-numbered envelopes,
    /// receiver dedup, and ack-driven retransmit under
    /// [`Reliability::Acked`].
    pub reliability: Reliability,
    /// Threads-runtime stall watchdog: if no event is processed for this
    /// many µs of wall-clock while the run is incomplete, fail with a
    /// [`StallReport`] instead of hanging forever. `0` disables. The
    /// simulator needs no watchdog — a stall is detected exactly when its
    /// event heap drains with a partial barrier outstanding.
    pub stall_timeout_us: f64,
    /// Engine checkpoint cadence: handled events per locality (Converge
    /// programs) or supersteps (Iterate programs) between snapshots.
    /// `0` = checkpoint only when the fault plan schedules a crash, at
    /// the engines' default cadence.
    pub checkpoint_every: u64,
    /// Incremental re-convergence taint cap: when deletion taint exceeds
    /// this fraction of the graph, `rerun_incremental` falls back to a
    /// full cold recompute instead of warm re-seeding.
    pub taint_cap: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            net: NetConfig::default(),
            runtime: RuntimeKind::Sim,
            barrier_latency_us: None,
            measure_compute: true,
            compute_scale: 1.0,
            aggregate_sends: false,
            coalesce_window_us: 0.0,
            max_events: u64::MAX,
            fault: FaultPlan::none(),
            reliability: Reliability::None,
            stall_timeout_us: 0.0,
            checkpoint_every: 0,
            taint_cap: 0.5,
        }
    }
}

impl SimConfig {
    /// Deterministic config for unit tests: no wall-clock measurement,
    /// explicit charges only.
    pub fn deterministic(net: NetConfig) -> Self {
        SimConfig { net, measure_compute: false, ..SimConfig::default() }
    }

    fn barrier_cost(&self, n: u32) -> f64 {
        self.barrier_latency_us.unwrap_or_else(|| {
            let stages = (n.max(2) as f64).log2().ceil();
            2.0 * self.net.latency_us * stages
        })
    }
}

/// Ack requests riding an envelope: `(token, send-call time)` per traced
/// message. Reported back to the sender at the receiver's handler start.
pub(crate) type AckReqs = Vec<(u64, SimTime)>;

enum Payload<M> {
    Start,
    Envelope { from: LocalityId, items: Vec<M>, acks: AckReqs },
    BarrierDone { epoch: u64 },
    /// Parcel-coalescing flush: the event's `dst` is the *sender* (the
    /// flush runs on its timeline); `to` is the wire destination.
    Flush { to: LocalityId },
    /// Delivery report for one traced message (see [`Ctx::send_traced`]).
    Ack { token: u64, sent: SimTime, delivered: SimTime },
    /// A [`Ctx::set_timer`] deadline arrived.
    Timer,
    /// The fault plan fail-stops the event's locality at this time.
    Crash,
}

struct Event<M> {
    time: SimTime,
    seq: u64,
    dst: LocalityId,
    payload: Payload<M>,
}

impl<M> PartialEq for Event<M> {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl<M> Eq for Event<M> {}
impl<M> PartialOrd for Event<M> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<M> Ord for Event<M> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; reverse for earliest-first, tie-break
        // on sequence number for determinism.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Handler-side interface to the runtime: clock, sends, charges, barriers.
///
/// Fields are `pub(crate)` so the two runtimes ([`SimRuntime`] and
/// [`ThreadedRuntime`](super::threads::ThreadedRuntime)) can construct and
/// drain a `Ctx` around each handler call; actors only see the methods.
pub struct Ctx<'a, M> {
    pub(crate) locality: LocalityId,
    pub(crate) n_localities: u32,
    pub(crate) now: SimTime,
    pub(crate) epoch: u64,
    pub(crate) explicit_charge_us: f64,
    pub(crate) barrier_requested: &'a mut bool,
    pub(crate) outbox: Vec<(LocalityId, M, Option<u64>)>,
    pub(crate) timers: Vec<SimTime>,
}

impl<'a, M: Message> Ctx<'a, M> {
    /// This locality's id.
    pub fn locality(&self) -> LocalityId {
        self.locality
    }

    /// Number of localities in the run.
    pub fn n_localities(&self) -> u32 {
        self.n_localities
    }

    /// Simulated time at which this handler started.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Completed-barrier count so far.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Queue a message. Messages depart when the handler finishes
    /// (HPX parcels are dispatched by the scheduler, not inline). Sends to
    /// `self` become local task-queue events with zero network cost — this
    /// is the `hpx::async`-on-same-locality case.
    pub fn send(&mut self, dst: LocalityId, msg: M) {
        debug_assert!(dst < self.n_localities, "send to unknown locality {dst}");
        self.outbox.push((dst, msg, None));
    }

    /// Queue a message and request a delivery observation: when the
    /// envelope carrying it starts processing at the receiver, the runtime
    /// calls [`Actor::on_ack`] on this locality with `token`, the current
    /// time (`sent`), and the receiver's handler-start time (`delivered`).
    /// The return channel models the parcelport's completion callback and
    /// is free; the observation *includes* receiver-side queueing, which
    /// is the signal the latency-adaptive flush policy tunes on.
    pub fn send_traced(&mut self, dst: LocalityId, msg: M, token: u64) {
        debug_assert!(dst < self.n_localities, "send to unknown locality {dst}");
        self.outbox.push((dst, msg, Some(token)));
    }

    /// Request an [`Actor::on_timer`] callback at absolute simulated time
    /// `at` (clamped forward to now). Pending timers count as in-flight
    /// work: quiescence and barrier completion wait for them.
    pub fn set_timer(&mut self, at: SimTime) {
        debug_assert!(at.is_finite(), "timer at non-finite time {at}");
        self.timers.push(at.max(self.now));
    }

    /// Add an explicit compute charge (model-based costing; used by tests
    /// and by phases whose cost is computed rather than measured).
    pub fn charge_us(&mut self, us: f64) {
        debug_assert!(us >= 0.0);
        self.explicit_charge_us += us;
    }

    /// Request participation in a global barrier. The barrier completes —
    /// triggering [`Actor::on_barrier`] everywhere — once every locality
    /// has an outstanding request and all in-flight messages have drained.
    pub fn request_barrier(&mut self) {
        *self.barrier_requested = true;
    }
}

/// The discrete-event engine. See module docs.
pub struct SimRuntime {
    cfg: SimConfig,
}

impl SimRuntime {
    /// Create a runtime with the given configuration.
    pub fn new(cfg: SimConfig) -> Self {
        SimRuntime { cfg }
    }

    /// Run `actors` (one per locality) to quiescence; returns the final
    /// actor states plus the timing/traffic report.
    pub fn run<A: Actor>(&self, mut actors: Vec<A>) -> (Vec<A>, SimReport) {
        let n = actors.len() as u32;
        assert!(n > 0, "need at least one locality");
        let barrier_cost = self.cfg.barrier_cost(n);
        // Host wall-clock for the whole run and per barrier-delimited
        // phase — the simulator's own execution cost, reported next to
        // the modeled makespan so sim and threaded runs share a schema.
        let run_start = Instant::now();
        let mut phase_marks: Vec<f64> = Vec::new();

        let mut heap: BinaryHeap<Event<A::Msg>> = BinaryHeap::new();
        let mut seq: u64 = 0;
        let mut avail: Vec<SimTime> = vec![0.0; n as usize];
        let mut busy: Vec<f64> = vec![0.0; n as usize];
        let mut waiting: Vec<bool> = vec![false; n as usize];
        let mut net_stats: Vec<NetStats> = vec![NetStats::default(); n as usize];
        let mut epoch: u64 = 0;
        let mut events_processed: u64 = 0;
        // Start/Envelope/Flush/Ack/Timer events in heap: everything the
        // network (and therefore quiescence and barriers) must wait for.
        let mut messages_pending: u64 = 0;
        // Parcel-coalescing buffers: (src, dst) -> queued items + ack reqs.
        #[allow(clippy::type_complexity)]
        let mut pending: std::collections::HashMap<
            (LocalityId, LocalityId),
            (Vec<A::Msg>, AckReqs),
        > = std::collections::HashMap::new();
        let coalesce = self.cfg.coalesce_window_us > 0.0;
        // Fault injection: the per-run decision stream plus crash flags.
        // Every fault branch below is gated on `fault_active`, so a
        // `FaultPlan::none` run takes exactly the historical event
        // sequence (no RNG draws, no envelope splitting, no extra events).
        let mut fault = FaultState::new(self.cfg.fault.clone(), n as usize);
        let fault_active = fault.active();

        for l in 0..n {
            heap.push(Event { time: 0.0, seq, dst: l, payload: Payload::Start });
            seq += 1;
            messages_pending += 1;
        }
        if let Some((cl, ct)) = self.cfg.fault.crash {
            if cl < n {
                // Deliberately not counted in `messages_pending`: a
                // pending crash must not hold barriers open; the non-empty
                // heap keeps the run alive until it fires.
                heap.push(Event { time: ct, seq, dst: cl, payload: Payload::Crash });
                seq += 1;
            }
        }

        // Barrier completion: every live locality waiting + network
        // drained. Crashed localities are excluded from the quorum and
        // from delivery; at least one live locality must be waiting.
        macro_rules! barrier_check {
            () => {
                if messages_pending == 0
                    && waiting.iter().any(|w| *w)
                    && waiting
                        .iter()
                        .enumerate()
                        .all(|(i, w)| *w || fault.is_crashed(i as LocalityId))
                {
                    epoch += 1;
                    phase_marks.push(run_start.elapsed().as_secs_f64() * 1e6);
                    let fire = avail.iter().cloned().fold(0.0_f64, f64::max) + barrier_cost;
                    for d in 0..n {
                        if fault.is_crashed(d) {
                            continue;
                        }
                        waiting[d as usize] = false;
                        avail[d as usize] = fire;
                        heap.push(Event {
                            time: fire,
                            seq,
                            dst: d,
                            payload: Payload::BarrierDone { epoch },
                        });
                        seq += 1;
                    }
                }
            };
        }

        while let Some(ev) = heap.pop() {
            events_processed += 1;
            assert!(
                events_processed <= self.cfg.max_events,
                "simulation exceeded max_events={} (runaway?)",
                self.cfg.max_events
            );
            let l = ev.dst as usize;
            let start = if ev.time > avail[l] { ev.time } else { avail[l] };

            // A fail-stopped locality neither sends nor receives: events
            // destined to it are discarded as they pop (their in-flight
            // count released), which is what starves the sender-side
            // retransmit layer into its give-up failure detector.
            if fault_active && fault.is_crashed(ev.dst) {
                match ev.payload {
                    Payload::BarrierDone { .. } | Payload::Crash => {}
                    Payload::Flush { to } => {
                        messages_pending -= 1;
                        pending.remove(&(ev.dst, to));
                    }
                    _ => messages_pending -= 1,
                }
                barrier_check!();
                continue;
            }

            // Fail-stop: mark the locality dead and drop its barrier
            // participation and queued parcels; everything else headed its
            // way is discarded above as it pops.
            if let Payload::Crash = ev.payload {
                if fault.mark_crashed(ev.dst) {
                    waiting[l] = false;
                    pending.retain(|(src, _), _| *src != ev.dst);
                }
                barrier_check!();
                continue;
            }

            // Coalescing flush: not an actor handler — take the buffer,
            // charge the sender's send CPU, put one envelope on the wire.
            if let Payload::Flush { to } = ev.payload {
                messages_pending -= 1;
                let (items, acks) = pending.remove(&(ev.dst, to)).unwrap_or_default();
                if !items.is_empty() {
                    let n_items: usize = items.iter().map(|m| m.item_count()).sum();
                    let scpu = self.cfg.net.send_cpu(n_items);
                    avail[l] = start + scpu;
                    busy[l] += scpu;
                    let deliveries = if fault_active {
                        fault_deliveries(&mut fault, items, acks)
                    } else {
                        vec![(items, acks, 0.0)]
                    };
                    for (items, acks, extra) in deliveries {
                        let n_items: usize = items.iter().map(|m| m.item_count()).sum();
                        let payload_bytes: usize = items.iter().map(|m| m.wire_bytes()).sum();
                        let wire = self.cfg.net.wire_us(payload_bytes);
                        let st = &mut net_stats[l];
                        st.envelopes += 1;
                        st.messages += n_items as u64;
                        st.payload_bytes += payload_bytes as u64;
                        st.wire_us += wire;
                        heap.push(Event {
                            time: avail[l] + wire + extra,
                            seq,
                            dst: to,
                            payload: Payload::Envelope { from: ev.dst, items, acks },
                        });
                        seq += 1;
                        messages_pending += 1;
                    }
                }
                // Barrier check below still applies after a flush.
                barrier_check!();
                continue;
            }

            let mut barrier_requested = waiting[ev.dst as usize];
            let mut ctx = Ctx {
                locality: ev.dst,
                n_localities: n,
                now: start,
                epoch,
                explicit_charge_us: 0.0,
                barrier_requested: &mut barrier_requested,
                outbox: Vec::new(),
                timers: Vec::new(),
            };

            let wall = Instant::now();
            let mut recv_charge = 0.0;
            match ev.payload {
                Payload::Start => {
                    messages_pending -= 1;
                    actors[l].on_start(&mut ctx);
                }
                Payload::Envelope { from, items, acks } => {
                    messages_pending -= 1;
                    // Report traced deliveries back to the sender at the
                    // handler-start time, queueing delay included. The
                    // return channel is free (completion callback).
                    for (token, sent) in acks {
                        heap.push(Event {
                            time: start,
                            seq,
                            dst: from,
                            payload: Payload::Ack { token, sent, delivered: start },
                        });
                        seq += 1;
                        messages_pending += 1;
                    }
                    if from != ev.dst {
                        let n_items: usize = items.iter().map(|m| m.item_count()).sum();
                        recv_charge = self.cfg.net.recv_cpu(n_items);
                    }
                    for msg in items {
                        actors[l].on_message(&mut ctx, from, msg);
                    }
                }
                Payload::BarrierDone { epoch: e } => {
                    actors[l].on_barrier(&mut ctx, e);
                }
                Payload::Ack { token, sent, delivered } => {
                    messages_pending -= 1;
                    actors[l].on_ack(&mut ctx, token, sent, delivered);
                }
                Payload::Timer => {
                    messages_pending -= 1;
                    actors[l].on_timer(&mut ctx);
                }
                Payload::Flush { .. } | Payload::Crash => unreachable!("handled above"),
            }
            let measured = if self.cfg.measure_compute {
                wall.elapsed().as_secs_f64() * 1e6 * self.cfg.compute_scale
            } else {
                0.0
            };

            let explicit = ctx.explicit_charge_us;
            let outbox = std::mem::take(&mut ctx.outbox);
            let timers = std::mem::take(&mut ctx.timers);
            drop(ctx);
            waiting[l] = barrier_requested;

            let mut charge = measured + explicit + recv_charge;
            if fault_active {
                // Straggler model: scale this locality's handler compute.
                charge *= fault.slow_factor(ev.dst);
            }

            // Dispatch outbox: aggregate per destination if configured.
            // Traced sends stamp the handler-start time as their send time.
            let depart_base = start;
            let mut send_cpu_total = 0.0;
            let groups = group_outbox(outbox, self.cfg.aggregate_sends, start);
            for (dst, items, acks) in groups {
                let n_items: usize = items.iter().map(|m| m.item_count()).sum();
                if dst == ev.dst {
                    // Local spawn: no network, delivered when we are free.
                    heap.push(Event {
                        time: depart_base + charge + send_cpu_total,
                        seq,
                        dst,
                        payload: Payload::Envelope { from: ev.dst, items, acks },
                    });
                    seq += 1;
                    messages_pending += 1;
                    continue;
                }
                if coalesce {
                    // Buffer into the (src, dst) parcel; schedule a flush
                    // if this is the first item since the last flush.
                    let buf = pending.entry((ev.dst, dst)).or_default();
                    let first = buf.0.is_empty();
                    buf.0.extend(items);
                    buf.1.extend(acks);
                    if first {
                        heap.push(Event {
                            time: depart_base + charge + self.cfg.coalesce_window_us,
                            seq,
                            dst: ev.dst, // flush runs on the sender
                            payload: Payload::Flush { to: dst },
                        });
                        seq += 1;
                        messages_pending += 1;
                    }
                    continue;
                }
                let scpu = self.cfg.net.send_cpu(n_items);
                send_cpu_total += scpu;
                let depart = depart_base + charge + send_cpu_total;
                let deliveries = if fault_active {
                    fault_deliveries(&mut fault, items, acks)
                } else {
                    vec![(items, acks, 0.0)]
                };
                for (items, acks, extra) in deliveries {
                    let n_items: usize = items.iter().map(|m| m.item_count()).sum();
                    let payload_bytes: usize = items.iter().map(|m| m.wire_bytes()).sum();
                    let wire = self.cfg.net.wire_us(payload_bytes);
                    let st = &mut net_stats[l];
                    st.envelopes += 1;
                    st.messages += n_items as u64;
                    st.payload_bytes += payload_bytes as u64;
                    st.wire_us += wire;
                    heap.push(Event {
                        time: depart + wire + extra,
                        seq,
                        dst,
                        payload: Payload::Envelope { from: ev.dst, items, acks },
                    });
                    seq += 1;
                    messages_pending += 1;
                }
            }
            charge += send_cpu_total;
            // Arm requested timers (absolute times; already clamped to
            // >= now by set_timer). They hold quiescence and barriers
            // open until they fire.
            for at in timers {
                heap.push(Event { time: at, seq, dst: ev.dst, payload: Payload::Timer });
                seq += 1;
                messages_pending += 1;
            }
            avail[l] = start + charge;
            busy[l] += charge;

            barrier_check!();
        }

        let stuck: Vec<usize> = waiting
            .iter()
            .enumerate()
            .filter(|(_, w)| **w)
            .map(|(i, _)| i)
            .collect();
        if !stuck.is_empty() {
            let missing: Vec<usize> = waiting
                .iter()
                .enumerate()
                .filter(|(i, w)| !**w && !fault.is_crashed(*i as LocalityId))
                .map(|(i, _)| i)
                .collect();
            let report = StallReport {
                waiting: stuck,
                missing,
                // The event heap has drained, so nothing is queued or
                // armed anywhere; the sim holds no per-locality ack state
                // (the aggregators own the in-flight tables).
                inbox_depths: vec![0; n as usize],
                pending_timers: vec![0; n as usize],
                inflight_acks: vec![0; n as usize],
                messages_pending,
                epoch,
            };
            panic!("{report}");
        }

        let makespan = avail.iter().cloned().fold(0.0_f64, f64::max);
        let mut total_net = NetStats::default();
        for s in &net_stats {
            total_net.merge(s);
        }
        let wall_us = run_start.elapsed().as_secs_f64() * 1e6;
        let mut report = SimReport::new(n);
        report.makespan_us = makespan;
        report.busy_us = busy;
        report.barriers = epoch;
        report.events = events_processed;
        report.net = total_net;
        report.per_locality_net = net_stats;
        report.wall_us = wall_us;
        report.phase_wall_us = super::metrics::phase_segments(&phase_marks, wall_us);
        report.fault.injected_drops = fault.drops;
        report.fault.injected_dups = fault.dups;
        report.fault.injected_delays = fault.delays;
        report.fault.crashes = fault.crashes;
        (actors, report)
    }
}

/// Apply the fault plan to one wire-bound envelope. Immune control items
/// (see [`Message::fault_immune`]) are split off and always delivered;
/// the faultable remainder is dropped, duplicated (the copy carries no
/// ack requests — each traced token is acked at most once), and/or
/// delayed per the plan's decision stream. Returns the deliveries to
/// schedule as `(items, acks, extra_delay_us)`.
#[allow(clippy::type_complexity)]
pub(crate) fn fault_deliveries<M: Message>(
    fault: &mut FaultState,
    items: Vec<M>,
    acks: AckReqs,
) -> Vec<(Vec<M>, AckReqs, f64)> {
    let (immune, faultable): (Vec<M>, Vec<M>) =
        items.into_iter().partition(|m| m.fault_immune());
    let mut out = Vec::new();
    if !immune.is_empty() {
        out.push((immune, AckReqs::new(), 0.0));
    }
    if faultable.is_empty() {
        // All-immune envelope: no decision drawn (the stream position
        // depends only on faultable-envelope ordinals). Ack requests, if
        // any, ride the reliable part.
        if let Some(first) = out.first_mut() {
            first.1 = acks;
        }
        return out;
    }
    let d = fault.decide();
    if !d.drop {
        if d.dup {
            out.push((faultable.clone(), AckReqs::new(), d.extra_delay_us));
        }
        out.push((faultable, acks, d.extra_delay_us));
    }
    out
}

#[allow(clippy::type_complexity)]
pub(crate) fn group_outbox<M>(
    outbox: Vec<(LocalityId, M, Option<u64>)>,
    aggregate: bool,
    now: SimTime,
) -> Vec<(LocalityId, Vec<M>, AckReqs)> {
    let ack = |tok: Option<u64>| -> AckReqs { tok.map(|t| (t, now)).into_iter().collect() };
    if !aggregate {
        return outbox.into_iter().map(|(d, m, t)| (d, vec![m], ack(t))).collect();
    }
    // Preserve first-appearance destination order for determinism.
    let mut order: Vec<LocalityId> = Vec::new();
    let mut buckets: std::collections::HashMap<LocalityId, (Vec<M>, AckReqs)> =
        std::collections::HashMap::new();
    for (d, m, t) in outbox {
        let b = buckets.entry(d).or_insert_with(|| {
            order.push(d);
            (Vec::new(), Vec::new())
        });
        b.0.push(m);
        if let Some(tok) = t {
            b.1.push((tok, now));
        }
    }
    order
        .into_iter()
        .map(|d| {
            let (items, acks) = buckets.remove(&d).unwrap();
            (d, items, acks)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Clone)]
    struct Ping(u32);
    impl Message for Ping {
        fn wire_bytes(&self) -> usize {
            4
        }
    }

    /// Each locality pings the next one `hops` times around a ring.
    struct RingActor {
        hops_left: u32,
        received: u32,
    }
    impl Actor for RingActor {
        type Msg = Ping;
        fn on_start(&mut self, ctx: &mut Ctx<Ping>) {
            if ctx.locality() == 0 && self.hops_left > 0 {
                ctx.send(1 % ctx.n_localities(), Ping(self.hops_left));
            }
        }
        fn on_message(&mut self, ctx: &mut Ctx<Ping>, _from: LocalityId, msg: Ping) {
            self.received += 1;
            if msg.0 > 1 {
                let next = (ctx.locality() + 1) % ctx.n_localities();
                ctx.send(next, Ping(msg.0 - 1));
            }
        }
    }

    #[test]
    fn ring_of_pings_terminates_and_charges_latency() {
        let net = NetConfig { latency_us: 10.0, ..NetConfig::zero() };
        let cfg = SimConfig::deterministic(net);
        let actors = (0..4).map(|_| RingActor { hops_left: 8, received: 0 }).collect();
        let (actors, report) = SimRuntime::new(cfg).run(actors);
        let total: u32 = actors.iter().map(|a| a.received).sum();
        assert_eq!(total, 8);
        // 8 hops, 10 us each, no compute.
        assert!((report.makespan_us - 80.0).abs() < 1e-6, "{}", report.makespan_us);
        assert_eq!(report.net.messages, 8);
        assert_eq!(report.net.envelopes, 8);
    }

    #[test]
    fn explicit_charges_advance_the_clock() {
        struct Worker;
        #[derive(Clone)]
        struct Nop;
        impl Message for Nop {
            fn wire_bytes(&self) -> usize {
                0
            }
        }
        impl Actor for Worker {
            type Msg = Nop;
            fn on_start(&mut self, ctx: &mut Ctx<Nop>) {
                ctx.charge_us(123.0);
            }
            fn on_message(&mut self, _: &mut Ctx<Nop>, _: LocalityId, _: Nop) {}
        }
        let cfg = SimConfig::deterministic(NetConfig::zero());
        let (_, report) = SimRuntime::new(cfg).run(vec![Worker, Worker]);
        assert!((report.makespan_us - 123.0).abs() < 1e-9);
        assert!((report.busy_us[0] - 123.0).abs() < 1e-9);
        assert!((report.busy_us[1] - 123.0).abs() < 1e-9);
    }

    /// BSP-style: everyone requests a barrier in on_start; counts epochs.
    struct BspActor {
        rounds: u64,
    }
    #[derive(Clone)]
    struct Nothing;
    impl Message for Nothing {
        fn wire_bytes(&self) -> usize {
            0
        }
    }
    impl Actor for BspActor {
        type Msg = Nothing;
        fn on_start(&mut self, ctx: &mut Ctx<Nothing>) {
            ctx.request_barrier();
        }
        fn on_message(&mut self, _: &mut Ctx<Nothing>, _: LocalityId, _: Nothing) {}
        fn on_barrier(&mut self, ctx: &mut Ctx<Nothing>, epoch: u64) {
            if epoch < self.rounds {
                ctx.request_barrier();
            }
        }
    }

    #[test]
    fn barriers_complete_and_cost_time() {
        let net = NetConfig { latency_us: 5.0, ..NetConfig::zero() };
        let cfg = SimConfig {
            barrier_latency_us: Some(7.0),
            ..SimConfig::deterministic(net)
        };
        let actors = (0..3).map(|_| BspActor { rounds: 4 }).collect();
        let (_, report) = SimRuntime::new(cfg).run(actors);
        assert_eq!(report.barriers, 4);
        assert!((report.makespan_us - 28.0).abs() < 1e-9, "{}", report.makespan_us);
    }

    #[test]
    fn aggregation_reduces_envelopes_but_not_messages() {
        struct Fanout;
        impl Actor for Fanout {
            type Msg = Ping;
            fn on_start(&mut self, ctx: &mut Ctx<Ping>) {
                if ctx.locality() == 0 {
                    for i in 0..10 {
                        ctx.send(1, Ping(i));
                    }
                }
            }
            fn on_message(&mut self, _: &mut Ctx<Ping>, _: LocalityId, _: Ping) {}
        }
        let run = |aggregate| {
            let cfg = SimConfig {
                aggregate_sends: aggregate,
                ..SimConfig::deterministic(NetConfig::default())
            };
            SimRuntime::new(cfg).run(vec![Fanout, Fanout]).1
        };
        let loose = run(false);
        let packed = run(true);
        assert_eq!(loose.net.messages, 10);
        assert_eq!(packed.net.messages, 10);
        assert_eq!(loose.net.envelopes, 10);
        assert_eq!(packed.net.envelopes, 1);
        assert!(packed.makespan_us < loose.makespan_us);
    }

    #[test]
    fn coalescing_merges_sends_across_handlers() {
        // Locality 0 self-spawns 5 tasks; each sends one Ping to 1. With a
        // coalescing window larger than the spawn spacing, all 5 ride one
        // envelope.
        struct Spray {
            left: u32,
        }
        impl Actor for Spray {
            type Msg = Ping;
            fn on_start(&mut self, ctx: &mut Ctx<Ping>) {
                if ctx.locality() == 0 {
                    ctx.send(0, Ping(self.left));
                }
            }
            fn on_message(&mut self, ctx: &mut Ctx<Ping>, _: LocalityId, msg: Ping) {
                if ctx.locality() == 0 {
                    ctx.send(1, Ping(msg.0));
                    if msg.0 > 1 {
                        ctx.send(0, Ping(msg.0 - 1));
                    }
                }
            }
        }
        let cfg = SimConfig {
            coalesce_window_us: 50.0,
            ..SimConfig::deterministic(NetConfig::default())
        };
        let (_, report) = SimRuntime::new(cfg).run(vec![Spray { left: 5 }, Spray { left: 5 }]);
        assert_eq!(report.net.messages, 5);
        assert_eq!(report.net.envelopes, 1, "coalescing must merge all 5 sends");

        let cfg0 = SimConfig::deterministic(NetConfig::default());
        let (_, loose) = SimRuntime::new(cfg0).run(vec![Spray { left: 5 }, Spray { left: 5 }]);
        assert_eq!(loose.net.envelopes, 5);
    }

    #[test]
    fn coalescing_preserves_barrier_semantics() {
        // A BSP round with coalescing on: messages must still drain before
        // the barrier fires.
        struct OneShot {
            got: u32,
        }
        impl Actor for OneShot {
            type Msg = Ping;
            fn on_start(&mut self, ctx: &mut Ctx<Ping>) {
                let next = (ctx.locality() + 1) % ctx.n_localities();
                ctx.send(next, Ping(1));
                ctx.request_barrier();
            }
            fn on_message(&mut self, _: &mut Ctx<Ping>, _: LocalityId, _: Ping) {
                self.got += 1;
            }
            fn on_barrier(&mut self, _: &mut Ctx<Ping>, _: u64) {
                assert_eq!(self.got, 1, "barrier fired before coalesced delivery");
            }
        }
        let cfg = SimConfig {
            coalesce_window_us: 25.0,
            ..SimConfig::deterministic(NetConfig::default())
        };
        let (actors, report) =
            SimRuntime::new(cfg).run(vec![OneShot { got: 0 }, OneShot { got: 0 }, OneShot { got: 0 }]);
        assert_eq!(report.barriers, 1);
        assert!(actors.iter().all(|a| a.got == 1));
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn partial_barrier_is_a_deadlock() {
        struct OnlyZeroWaits;
        impl Actor for OnlyZeroWaits {
            type Msg = Nothing;
            fn on_start(&mut self, ctx: &mut Ctx<Nothing>) {
                if ctx.locality() == 0 {
                    ctx.request_barrier();
                }
            }
            fn on_message(&mut self, _: &mut Ctx<Nothing>, _: LocalityId, _: Nothing) {}
        }
        let cfg = SimConfig::deterministic(NetConfig::zero());
        SimRuntime::new(cfg).run(vec![OnlyZeroWaits, OnlyZeroWaits]);
    }

    #[test]
    fn traced_send_reports_queueing_inclusive_latency() {
        // Locality 0 sends two traced pings back-to-back. The second one
        // arrives while the receiver is still busy with an explicit
        // charge, so its observed latency must include the queueing delay,
        // not just the wire time.
        struct Tracer {
            acks: Vec<(u64, SimTime, SimTime)>,
        }
        impl Actor for Tracer {
            type Msg = Ping;
            fn on_start(&mut self, ctx: &mut Ctx<Ping>) {
                if ctx.locality() == 0 {
                    ctx.send_traced(1, Ping(1), 7);
                    ctx.send_traced(1, Ping(2), 8);
                }
            }
            fn on_message(&mut self, ctx: &mut Ctx<Ping>, _: LocalityId, _: Ping) {
                ctx.charge_us(100.0); // make the receiver busy
            }
            fn on_ack(&mut self, _: &mut Ctx<Ping>, token: u64, sent: SimTime, del: SimTime) {
                self.acks.push((token, sent, del));
            }
        }
        let net = NetConfig { latency_us: 10.0, ..NetConfig::zero() };
        let cfg = SimConfig::deterministic(net);
        let actors = (0..2).map(|_| Tracer { acks: Vec::new() }).collect();
        let (actors, _) = SimRuntime::new(cfg).run(actors);
        let acks = &actors[0].acks;
        assert_eq!(acks.len(), 2, "every traced send is acked");
        let lat = |i: usize| acks[i].2 - acks[i].1;
        assert!((lat(0) - 10.0).abs() < 1e-9, "first ping pays wire latency: {}", lat(0));
        // The second envelope lands while the receiver is 100us busy.
        assert!(lat(1) > 10.0 + 50.0, "queueing delay must show: {}", lat(1));
        assert!(actors[1].acks.is_empty());
    }

    #[test]
    fn timers_fire_at_requested_time_and_hold_barriers() {
        // Locality 0 arms a timer and requests a barrier; the barrier must
        // not complete until the timer has fired (timers are in-flight
        // work), and on_timer runs at the requested simulated time.
        struct Alarm {
            fired_at: Option<SimTime>,
            barrier_at: Option<SimTime>,
        }
        impl Actor for Alarm {
            type Msg = Nothing;
            fn on_start(&mut self, ctx: &mut Ctx<Nothing>) {
                if ctx.locality() == 0 {
                    ctx.set_timer(40.0);
                }
                ctx.request_barrier();
            }
            fn on_message(&mut self, _: &mut Ctx<Nothing>, _: LocalityId, _: Nothing) {}
            fn on_timer(&mut self, ctx: &mut Ctx<Nothing>) {
                self.fired_at = Some(ctx.now());
            }
            fn on_barrier(&mut self, ctx: &mut Ctx<Nothing>, _: u64) {
                self.barrier_at = Some(ctx.now());
            }
        }
        let cfg = SimConfig {
            barrier_latency_us: Some(1.0),
            ..SimConfig::deterministic(NetConfig::zero())
        };
        let actors = (0..2).map(|_| Alarm { fired_at: None, barrier_at: None }).collect();
        let (actors, report) = SimRuntime::new(cfg).run(actors);
        assert_eq!(actors[0].fired_at, Some(40.0));
        assert_eq!(report.barriers, 1);
        for a in &actors {
            assert!(a.barrier_at.expect("barrier completed") >= 40.0, "barrier outran timer");
        }
    }

    #[test]
    fn self_sends_are_free_local_tasks() {
        struct SelfSpawn {
            seen: u32,
        }
        impl Actor for SelfSpawn {
            type Msg = Ping;
            fn on_start(&mut self, ctx: &mut Ctx<Ping>) {
                ctx.send(ctx.locality(), Ping(3));
            }
            fn on_message(&mut self, ctx: &mut Ctx<Ping>, _: LocalityId, msg: Ping) {
                self.seen += 1;
                if msg.0 > 1 {
                    ctx.send(ctx.locality(), Ping(msg.0 - 1));
                }
            }
        }
        let cfg = SimConfig::deterministic(NetConfig::default());
        let (actors, report) = SimRuntime::new(cfg).run(vec![SelfSpawn { seen: 0 }]);
        assert_eq!(actors[0].seen, 3);
        assert_eq!(report.net.messages, 0, "self-sends must not hit the network");
        assert_eq!(report.makespan_us, 0.0);
    }

    use crate::amt::fault::FaultPlan;

    #[test]
    fn fault_drop_loses_the_envelope() {
        let cfg = SimConfig {
            fault: FaultPlan { drop_p: 1.0, seed: 11, ..FaultPlan::none() },
            ..SimConfig::deterministic(NetConfig::zero())
        };
        let actors = (0..2).map(|_| RingActor { hops_left: 1, received: 0 }).collect();
        let (actors, report) = SimRuntime::new(cfg).run(actors);
        assert_eq!(actors[1].received, 0, "certain drop must lose the ping");
        assert_eq!(report.fault.injected_drops, 1);
        assert_eq!(report.fault.injected_dups, 0);
    }

    #[test]
    fn fault_dup_delivers_twice() {
        let cfg = SimConfig {
            fault: FaultPlan { dup_p: 1.0, seed: 11, ..FaultPlan::none() },
            ..SimConfig::deterministic(NetConfig::zero())
        };
        let actors = (0..2).map(|_| RingActor { hops_left: 1, received: 0 }).collect();
        let (actors, report) = SimRuntime::new(cfg).run(actors);
        assert_eq!(actors[1].received, 2, "certain dup must deliver twice");
        assert_eq!(report.fault.injected_dups, 1);
        assert_eq!(report.net.envelopes, 2, "the duplicate is real traffic");
    }

    #[test]
    fn fault_delay_postpones_delivery() {
        let base = SimConfig {
            fault: FaultPlan::none(),
            ..SimConfig::deterministic(NetConfig { latency_us: 10.0, ..NetConfig::zero() })
        };
        let actors = |_: &SimConfig| (0..2).map(|_| RingActor { hops_left: 1, received: 0 }).collect();
        let (_, clean) = SimRuntime::new(base.clone()).run(actors(&base));
        let delayed_cfg = SimConfig {
            fault: FaultPlan { delay_us: 500.0, seed: 5, ..FaultPlan::none() },
            ..base
        };
        let (a, delayed) = SimRuntime::new(delayed_cfg.clone()).run(actors(&delayed_cfg));
        assert_eq!(a[1].received, 1, "delay must not lose the ping");
        assert_eq!(delayed.fault.injected_delays, 1);
        assert!(
            delayed.makespan_us > clean.makespan_us,
            "extra delay must show in the makespan: {} vs {}",
            delayed.makespan_us,
            clean.makespan_us
        );
    }

    #[test]
    fn crash_excludes_locality_from_barrier_quorum() {
        // Both localities request barriers every round; locality 1 crashes
        // after the first round's requests are in. The run must wind down
        // through the remaining rounds on locality 0 alone instead of
        // deadlocking or waiting on the dead locality.
        let cfg = SimConfig {
            barrier_latency_us: Some(7.0),
            fault: FaultPlan { crash: Some((1, 0.5)), ..FaultPlan::none() },
            ..SimConfig::deterministic(NetConfig::zero())
        };
        let actors = (0..2).map(|_| BspActor { rounds: 4 }).collect();
        let (_, report) = SimRuntime::new(cfg).run(actors);
        assert_eq!(report.fault.crashes, 1);
        assert_eq!(report.barriers, 4, "surviving locality finishes all rounds");
    }

    #[test]
    fn crash_spec_beyond_locality_count_is_ignored() {
        let cfg = SimConfig {
            fault: FaultPlan { crash: Some((9, 1.0)), ..FaultPlan::none() },
            ..SimConfig::deterministic(NetConfig::zero())
        };
        let actors = (0..2).map(|_| RingActor { hops_left: 2, received: 0 }).collect();
        let (actors, report) = SimRuntime::new(cfg).run(actors);
        assert_eq!(actors.iter().map(|a| a.received).sum::<u32>(), 2);
        assert_eq!(report.fault.crashes, 0);
    }

    #[test]
    fn immune_control_items_survive_certain_drop() {
        // One grouped envelope carries an immune control item and a
        // faultable data item; under a certain-drop plan the envelope is
        // split at the seam and only the data part is lost.
        #[derive(Clone)]
        enum CtlOrData {
            Ctl,
            Data,
        }
        impl Message for CtlOrData {
            fn wire_bytes(&self) -> usize {
                4
            }
            fn fault_immune(&self) -> bool {
                matches!(self, CtlOrData::Ctl)
            }
        }
        #[derive(Default)]
        struct Mixed {
            ctl: u32,
            data: u32,
        }
        impl Actor for Mixed {
            type Msg = CtlOrData;
            fn on_start(&mut self, ctx: &mut Ctx<CtlOrData>) {
                if ctx.locality() == 0 {
                    ctx.send(1, CtlOrData::Ctl);
                    ctx.send(1, CtlOrData::Data);
                }
            }
            fn on_message(&mut self, _: &mut Ctx<CtlOrData>, _: LocalityId, m: CtlOrData) {
                match m {
                    CtlOrData::Ctl => self.ctl += 1,
                    CtlOrData::Data => self.data += 1,
                }
            }
        }
        let cfg = SimConfig {
            aggregate_sends: true,
            fault: FaultPlan { drop_p: 1.0, seed: 2, ..FaultPlan::none() },
            ..SimConfig::deterministic(NetConfig::zero())
        };
        let (actors, report) = SimRuntime::new(cfg).run(vec![Mixed::default(), Mixed::default()]);
        assert_eq!(actors[1].ctl, 1, "control plane is modeled reliable");
        assert_eq!(actors[1].data, 0, "data item rides the faultable part");
        assert_eq!(report.fault.injected_drops, 1);
    }

    #[test]
    fn straggler_slowdown_scales_charges() {
        struct Busy;
        impl Actor for Busy {
            type Msg = Nothing;
            fn on_start(&mut self, ctx: &mut Ctx<Nothing>) {
                ctx.charge_us(100.0);
            }
            fn on_message(&mut self, _: &mut Ctx<Nothing>, _: LocalityId, _: Nothing) {}
        }
        let cfg = SimConfig {
            fault: FaultPlan { slow: Some((1, 4.0)), ..FaultPlan::none() },
            ..SimConfig::deterministic(NetConfig::zero())
        };
        let (_, report) = SimRuntime::new(cfg).run(vec![Busy, Busy]);
        assert!((report.busy_us[0] - 100.0).abs() < 1e-9);
        assert!((report.busy_us[1] - 400.0).abs() < 1e-9, "{}", report.busy_us[1]);
    }
}
