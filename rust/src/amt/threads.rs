//! Thread-per-locality AMT runtime: the same [`Actor`]s as [`sim`](super::sim),
//! executed on real OS threads with real queueing.
//!
//! The discrete-event simulator reproduces the paper's *message economics*
//! (envelope counts, modeled latency); this runtime reproduces its
//! *execution model*: each locality is a worker thread, inter-locality
//! envelopes are std-only MPSC channels (a `Mutex<VecDeque>` inbox per
//! locality — the vendored-deps constraint rules out crossbeam), and
//! quiescence, barriers, timers, and delivery acks are re-implemented over
//! a shared mutex + condvar so the exact same `VertexProgram`-driven
//! engines run unmodified on either substrate (`--runtime sim|threads`).
//!
//! Semantics match the simulator one-for-one:
//!
//! * **sends** depart when the handler finishes; per-destination grouping
//!   under [`SimConfig::aggregate_sends`] uses the same
//!   [`group_outbox`] the simulator uses, so envelope counts agree.
//!   Self-sends are local task-queue entries with no network accounting.
//! * **barriers** complete only when every locality has an outstanding
//!   request, every inbox is empty, no handler is mid-flight, and no
//!   timer is pending — the threaded reading of "the network has
//!   drained". A partial barrier at quiescence is the same deadlock
//!   panic the simulator raises.
//! * **quiescence** is the termination condition: all inboxes empty, no
//!   active handler, no pending timer, nobody waiting on a barrier.
//! * **timers** ([`Ctx::set_timer`]) hold barriers and quiescence open
//!   and fire on the owning worker via condvar timeout.
//! * **acks** ([`Ctx::send_traced`]) report the *real* send-to-handler-start
//!   latency — actual inter-thread queueing delay, which is what lets the
//!   latency-adaptive flush policy be validated against real queueing
//!   instead of the cost model (ablation A7).
//!
//! What is *not* reproduced: the modeled interconnect. `NetConfig`
//! latencies, explicit [`Ctx::charge_us`] charges, and
//! `coalesce_window_us` parcel buffering are cost-model features; here an
//! envelope is delivered as fast as the receiving thread can pick it up,
//! and time is host wall-clock (`SimReport::makespan_us == wall_us`,
//! `busy_us` is measured in-handler time).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::metrics::{phase_segments, SimReport};
use super::net::NetStats;
use super::sim::{group_outbox, AckReqs, Actor, Ctx, LocalityId, Message, SimConfig, SimTime};

/// One inbox entry. Envelopes carry the batched items plus any ack
/// requests stamped by [`group_outbox`]; `Barrier` fan-out entries are
/// pushed by whichever worker observes barrier completion.
enum Delivery<M> {
    Start,
    Envelope { from: LocalityId, items: Vec<M>, acks: AckReqs },
    Ack { token: u64, sent: SimTime, delivered: SimTime },
    Barrier { epoch: u64 },
}

/// State shared by all workers, guarded by one mutex; the paired condvar
/// is broadcast on every enqueue, handler completion, barrier release,
/// and shutdown.
struct Shared<M> {
    inboxes: Vec<VecDeque<Delivery<M>>>,
    /// Armed [`Ctx::set_timer`] deadlines per locality, in wall-us since
    /// run start. Pending timers hold barriers and quiescence open.
    timers: Vec<Vec<SimTime>>,
    /// Outstanding barrier requests per locality.
    waiting: Vec<bool>,
    /// Workers currently inside a handler (between inbox pop and effect
    /// dispatch). Terminal conditions require `active == 0` so a
    /// mid-handler worker's pending sends are never missed.
    active: u32,
    epoch: u64,
    events: u64,
    done: bool,
    /// Localities stuck on a partial barrier at quiescence (deadlock).
    stuck: Vec<usize>,
    /// Fatal condition raised by a worker (runaway guard).
    error: Option<String>,
    net: Vec<NetStats>,
    /// Wall-us marks at each barrier completion (per-phase reporting).
    phase_marks: Vec<f64>,
}

impl<M> Shared<M> {
    /// Nothing in flight anywhere: no queued delivery, no mid-handler
    /// worker, no armed timer. The threaded equivalent of the simulator's
    /// `messages_pending == 0` with an empty event heap.
    fn quiesced(&self) -> bool {
        self.active == 0
            && self.inboxes.iter().all(|q| q.is_empty())
            && self.timers.iter().all(|t| t.is_empty())
    }
}

/// Ensures a panicking worker (actor assertion, poisoned lock) releases
/// the others instead of leaving them parked on the condvar forever; the
/// scope join then propagates the original panic.
struct Bail<'a, M> {
    shared: &'a Mutex<Shared<M>>,
    cv: &'a Condvar,
}

impl<M> Drop for Bail<'_, M> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            if let Ok(mut g) = self.shared.lock() {
                g.done = true;
            }
            self.cv.notify_all();
        }
    }
}

/// The thread-per-locality runtime. See module docs.
pub struct ThreadedRuntime {
    cfg: SimConfig,
}

impl ThreadedRuntime {
    /// Create a runtime with the given configuration. Only
    /// `aggregate_sends` and `max_events` are consulted; the modeled
    /// interconnect fields are cost-model-only (see module docs).
    pub fn new(cfg: SimConfig) -> Self {
        ThreadedRuntime { cfg }
    }

    /// Run `actors` (one per locality, one worker thread each) to
    /// quiescence; returns the final actor states plus the report with
    /// real wall-clock timings.
    pub fn run<A>(&self, actors: Vec<A>) -> (Vec<A>, SimReport)
    where
        A: Actor + Send,
        A::Msg: Send,
    {
        let n = actors.len() as u32;
        assert!(n > 0, "need at least one locality");
        let run_start = Instant::now();

        let shared = Mutex::new(Shared {
            inboxes: (0..n).map(|_| VecDeque::from([Delivery::<A::Msg>::Start])).collect(),
            timers: vec![Vec::new(); n as usize],
            waiting: vec![false; n as usize],
            active: 0,
            epoch: 0,
            events: 0,
            done: false,
            stuck: Vec::new(),
            error: None,
            net: vec![NetStats::default(); n as usize],
            phase_marks: Vec::new(),
        });
        let cv = Condvar::new();

        let (actors, busy): (Vec<A>, Vec<f64>) = std::thread::scope(|s| {
            let handles: Vec<_> = actors
                .into_iter()
                .enumerate()
                .map(|(l, mut actor)| {
                    let shared = &shared;
                    let cv = &cv;
                    let cfg = &self.cfg;
                    s.spawn(move || {
                        let _bail = Bail { shared, cv };
                        let busy = worker(l, n, run_start, cfg, shared, cv, &mut actor);
                        (actor, busy)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).unzip()
        });

        let g = shared.into_inner().unwrap();
        if let Some(e) = g.error {
            panic!("{e}");
        }
        assert!(
            g.stuck.is_empty(),
            "deadlock: localities {:?} waiting on a barrier that can never \
             complete (not all localities requested one)",
            g.stuck
        );

        let wall_us = run_start.elapsed().as_secs_f64() * 1e6;
        let mut total_net = NetStats::default();
        for st in &g.net {
            total_net.merge(st);
        }
        let mut report = SimReport::new(n);
        report.makespan_us = wall_us;
        report.busy_us = busy;
        report.barriers = g.epoch;
        report.events = g.events;
        report.net = total_net;
        report.per_locality_net = g.net;
        report.wall_us = wall_us;
        report.phase_wall_us = phase_segments(&g.phase_marks, wall_us);
        (actors, report)
    }
}

fn elapsed_us(t0: Instant) -> f64 {
    t0.elapsed().as_secs_f64() * 1e6
}

/// One locality's worker loop: pop work, run the handler outside the
/// lock, dispatch effects under the lock, decide barriers/quiescence.
/// Returns the accumulated in-handler wall time (the locality's busy_us).
fn worker<A>(
    l: usize,
    n: u32,
    t0: Instant,
    cfg: &SimConfig,
    shared: &Mutex<Shared<A::Msg>>,
    cv: &Condvar,
    actor: &mut A,
) -> f64
where
    A: Actor,
{
    let mut busy_us = 0.0;
    let mut g = shared.lock().unwrap();
    loop {
        if g.done {
            return busy_us;
        }

        // 1. A due timer? (Timers fire on their owning worker.)
        let now = elapsed_us(t0);
        let due = g.timers[l].iter().position(|&at| at <= now);
        if let Some(i) = due {
            g.timers[l].swap_remove(i);
            g = dispatch(l, n, t0, cfg, shared, cv, actor, g, None, &mut busy_us, |a, ctx| {
                a.on_timer(ctx)
            });
            continue;
        }

        // 2. Queued delivery?
        if let Some(d) = g.inboxes[l].pop_front() {
            g = match d {
                Delivery::Start => dispatch(
                    l, n, t0, cfg, shared, cv, actor, g, None, &mut busy_us,
                    |a, ctx| a.on_start(ctx),
                ),
                Delivery::Envelope { from, items, acks } => dispatch(
                    l, n, t0, cfg, shared, cv, actor, g,
                    Some((from, acks)),
                    &mut busy_us,
                    move |a, ctx| {
                        for msg in items {
                            a.on_message(ctx, from, msg);
                        }
                    },
                ),
                Delivery::Ack { token, sent, delivered } => dispatch(
                    l, n, t0, cfg, shared, cv, actor, g, None, &mut busy_us,
                    move |a, ctx| a.on_ack(ctx, token, sent, delivered),
                ),
                Delivery::Barrier { epoch } => dispatch(
                    l, n, t0, cfg, shared, cv, actor, g, None, &mut busy_us,
                    move |a, ctx| a.on_barrier(ctx, epoch),
                ),
            };
            continue;
        }

        // 3. Nothing runnable here — is the whole system terminal?
        if g.quiesced() {
            if g.waiting.iter().all(|w| *w) {
                // Barrier completion: everyone waiting + network drained.
                g.epoch += 1;
                let epoch = g.epoch;
                g.phase_marks.push(elapsed_us(t0));
                for d in 0..n as usize {
                    g.waiting[d] = false;
                    g.inboxes[d].push_back(Delivery::Barrier { epoch });
                }
                cv.notify_all();
                continue;
            }
            if g.waiting.iter().any(|w| *w) {
                // Partial barrier with nothing left to deliver: the same
                // deadlock the simulator asserts on. Recorded here,
                // panicked on the main thread after join.
                g.stuck = g
                    .waiting
                    .iter()
                    .enumerate()
                    .filter(|(_, w)| **w)
                    .map(|(i, _)| i)
                    .collect();
                g.done = true;
                cv.notify_all();
                return busy_us;
            }
            g.done = true;
            cv.notify_all();
            return busy_us;
        }

        // 4. Park until notified, or until our earliest timer is due.
        let next = g.timers[l].iter().cloned().fold(f64::INFINITY, f64::min);
        if next.is_finite() {
            let wait = (next - elapsed_us(t0)).max(0.0);
            let (g2, _) = cv
                .wait_timeout(g, Duration::from_micros(wait as u64 + 1))
                .unwrap();
            g = g2;
        } else {
            g = cv.wait(g).unwrap();
        }
    }
}

/// Run one handler outside the lock and apply its effects under it:
/// barrier flag, acks for the consumed envelope, outbox fan-out (with the
/// simulator's per-destination grouping), timer arming, event accounting.
#[allow(clippy::too_many_arguments)]
fn dispatch<'m, A, F>(
    l: usize,
    n: u32,
    t0: Instant,
    cfg: &SimConfig,
    shared: &'m Mutex<Shared<A::Msg>>,
    cv: &Condvar,
    actor: &mut A,
    mut g: std::sync::MutexGuard<'m, Shared<A::Msg>>,
    envelope_acks: Option<(LocalityId, AckReqs)>,
    busy_us: &mut f64,
    f: F,
) -> std::sync::MutexGuard<'m, Shared<A::Msg>>
where
    A: Actor,
    F: FnOnce(&mut A, &mut Ctx<A::Msg>),
{
    g.active += 1;
    let epoch = g.epoch;
    let was_waiting = g.waiting[l];
    drop(g);

    let now = elapsed_us(t0);
    let mut barrier_requested = was_waiting;
    let mut ctx = Ctx {
        locality: l as LocalityId,
        n_localities: n,
        now,
        epoch,
        explicit_charge_us: 0.0,
        barrier_requested: &mut barrier_requested,
        outbox: Vec::new(),
        timers: Vec::new(),
    };
    let wall = Instant::now();
    f(actor, &mut ctx);
    *busy_us += wall.elapsed().as_secs_f64() * 1e6;
    let outbox = std::mem::take(&mut ctx.outbox);
    let timers = std::mem::take(&mut ctx.timers);
    drop(ctx);

    let mut g = shared.lock().unwrap();
    g.waiting[l] = barrier_requested;
    g.events += 1;
    if g.events > cfg.max_events && g.error.is_none() {
        g.error = Some(format!(
            "threaded run exceeded max_events={} (runaway?)",
            cfg.max_events
        ));
        g.done = true;
    }
    // Ack the envelope we just consumed: real send-to-handler-start
    // latency, receiver-side queueing included (the A7 signal).
    if let Some((from, acks)) = envelope_acks {
        for (token, sent) in acks {
            g.inboxes[from as usize]
                .push_back(Delivery::Ack { token, sent, delivered: now });
        }
    }
    // Outbox fan-out. Same grouping as the simulator (envelope counts
    // agree); traced sends stamp the handler-start time. Self-sends skip
    // the network accounting, exactly like the simulator's local spawns.
    for (dst, items, acks) in group_outbox(outbox, cfg.aggregate_sends, now) {
        if dst as usize != l {
            let n_items: usize = items.iter().map(|m| m.item_count()).sum();
            let payload_bytes: usize = items.iter().map(|m| m.wire_bytes()).sum();
            let st = &mut g.net[l];
            st.envelopes += 1;
            st.messages += n_items as u64;
            st.payload_bytes += payload_bytes as u64;
        }
        g.inboxes[dst as usize].push_back(Delivery::Envelope {
            from: l as LocalityId,
            items,
            acks,
        });
    }
    for at in timers {
        g.timers[l].push(at);
    }
    g.active -= 1;
    cv.notify_all();
    g
}

#[cfg(test)]
mod tests {
    use super::super::sim::RuntimeKind;
    use super::*;

    fn threads_cfg() -> SimConfig {
        SimConfig { runtime: RuntimeKind::Threads, ..SimConfig::default() }
    }

    #[derive(Clone)]
    struct Ping(u32);
    impl Message for Ping {
        fn wire_bytes(&self) -> usize {
            4
        }
    }

    struct RingActor {
        hops_left: u32,
        received: u32,
    }
    impl Actor for RingActor {
        type Msg = Ping;
        fn on_start(&mut self, ctx: &mut Ctx<Ping>) {
            if ctx.locality() == 0 && self.hops_left > 0 {
                ctx.send(1 % ctx.n_localities(), Ping(self.hops_left));
            }
        }
        fn on_message(&mut self, ctx: &mut Ctx<Ping>, _from: LocalityId, msg: Ping) {
            self.received += 1;
            if msg.0 > 1 {
                let next = (ctx.locality() + 1) % ctx.n_localities();
                ctx.send(next, Ping(msg.0 - 1));
            }
        }
    }

    #[test]
    fn ring_of_pings_terminates_with_real_wall_clock() {
        let actors = (0..4).map(|_| RingActor { hops_left: 8, received: 0 }).collect();
        let (actors, report) = ThreadedRuntime::new(threads_cfg()).run(actors);
        let total: u32 = actors.iter().map(|a| a.received).sum();
        assert_eq!(total, 8);
        assert_eq!(report.net.messages, 8);
        assert_eq!(report.net.envelopes, 8);
        assert!(report.wall_us > 0.0, "a real run takes real time");
        assert_eq!(report.makespan_us, report.wall_us);
        assert_eq!(report.phase_wall_us.len(), 1, "no barriers: one phase");
    }

    struct BspActor {
        rounds: u64,
    }
    #[derive(Clone)]
    struct Nothing;
    impl Message for Nothing {
        fn wire_bytes(&self) -> usize {
            0
        }
    }
    impl Actor for BspActor {
        type Msg = Nothing;
        fn on_start(&mut self, ctx: &mut Ctx<Nothing>) {
            ctx.request_barrier();
        }
        fn on_message(&mut self, _: &mut Ctx<Nothing>, _: LocalityId, _: Nothing) {}
        fn on_barrier(&mut self, ctx: &mut Ctx<Nothing>, epoch: u64) {
            if epoch < self.rounds {
                ctx.request_barrier();
            }
        }
    }

    #[test]
    fn barriers_complete_and_phases_are_reported() {
        let actors = (0..3).map(|_| BspActor { rounds: 4 }).collect();
        let (_, report) = ThreadedRuntime::new(threads_cfg()).run(actors);
        assert_eq!(report.barriers, 4);
        assert_eq!(report.phase_wall_us.len(), 5, "4 barriers => 5 phases");
        let sum: f64 = report.phase_wall_us.iter().sum();
        assert!((sum - report.wall_us).abs() < 1e-6, "{sum} vs {}", report.wall_us);
    }

    #[test]
    fn messages_drain_before_barriers() {
        // A BSP round: messages sent before a barrier request must be
        // delivered before the barrier fires, however threads interleave.
        struct OneShot {
            got: u32,
        }
        impl Actor for OneShot {
            type Msg = Ping;
            fn on_start(&mut self, ctx: &mut Ctx<Ping>) {
                let next = (ctx.locality() + 1) % ctx.n_localities();
                ctx.send(next, Ping(1));
                ctx.request_barrier();
            }
            fn on_message(&mut self, _: &mut Ctx<Ping>, _: LocalityId, _: Ping) {
                self.got += 1;
            }
            fn on_barrier(&mut self, _: &mut Ctx<Ping>, _: u64) {
                assert_eq!(self.got, 1, "barrier fired before delivery");
            }
        }
        let actors = (0..3).map(|_| OneShot { got: 0 }).collect();
        let (actors, report) = ThreadedRuntime::new(threads_cfg()).run(actors);
        assert_eq!(report.barriers, 1);
        assert!(actors.iter().all(|a| a.got == 1));
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn partial_barrier_is_a_deadlock() {
        struct OnlyZeroWaits;
        impl Actor for OnlyZeroWaits {
            type Msg = Nothing;
            fn on_start(&mut self, ctx: &mut Ctx<Nothing>) {
                if ctx.locality() == 0 {
                    ctx.request_barrier();
                }
            }
            fn on_message(&mut self, _: &mut Ctx<Nothing>, _: LocalityId, _: Nothing) {}
        }
        ThreadedRuntime::new(threads_cfg()).run(vec![OnlyZeroWaits, OnlyZeroWaits]);
    }

    #[test]
    fn traced_sends_are_acked_with_real_latency() {
        struct Tracer {
            acks: Vec<(u64, SimTime, SimTime)>,
        }
        impl Actor for Tracer {
            type Msg = Ping;
            fn on_start(&mut self, ctx: &mut Ctx<Ping>) {
                if ctx.locality() == 0 {
                    ctx.send_traced(1, Ping(1), 7);
                    ctx.send_traced(1, Ping(2), 8);
                }
            }
            fn on_message(&mut self, _: &mut Ctx<Ping>, _: LocalityId, _: Ping) {}
            fn on_ack(&mut self, _: &mut Ctx<Ping>, token: u64, sent: SimTime, del: SimTime) {
                self.acks.push((token, sent, del));
            }
        }
        let actors = (0..2).map(|_| Tracer { acks: Vec::new() }).collect();
        let (actors, _) = ThreadedRuntime::new(threads_cfg()).run(actors);
        let acks = &actors[0].acks;
        assert_eq!(acks.len(), 2, "every traced send is acked");
        for &(_, sent, delivered) in acks {
            assert!(delivered >= sent, "latency cannot be negative");
        }
        assert!(actors[1].acks.is_empty());
    }

    #[test]
    fn timers_fire_and_hold_barriers() {
        struct Alarm {
            fired_at: Option<SimTime>,
            barrier_at: Option<SimTime>,
        }
        impl Actor for Alarm {
            type Msg = Nothing;
            fn on_start(&mut self, ctx: &mut Ctx<Nothing>) {
                if ctx.locality() == 0 {
                    ctx.set_timer(ctx.now() + 200.0);
                }
                ctx.request_barrier();
            }
            fn on_message(&mut self, _: &mut Ctx<Nothing>, _: LocalityId, _: Nothing) {}
            fn on_timer(&mut self, ctx: &mut Ctx<Nothing>) {
                self.fired_at = Some(ctx.now());
            }
            fn on_barrier(&mut self, ctx: &mut Ctx<Nothing>, _: u64) {
                self.barrier_at = Some(ctx.now());
            }
        }
        let actors = (0..2).map(|_| Alarm { fired_at: None, barrier_at: None }).collect();
        let (actors, report) = ThreadedRuntime::new(threads_cfg()).run(actors);
        let fired = actors[0].fired_at.expect("timer fired");
        assert_eq!(report.barriers, 1);
        for a in &actors {
            assert!(a.barrier_at.expect("barrier completed") >= fired, "barrier outran timer");
        }
    }

    #[test]
    fn self_sends_do_not_hit_the_network() {
        struct SelfSpawn {
            seen: u32,
        }
        impl Actor for SelfSpawn {
            type Msg = Ping;
            fn on_start(&mut self, ctx: &mut Ctx<Ping>) {
                ctx.send(ctx.locality(), Ping(3));
            }
            fn on_message(&mut self, ctx: &mut Ctx<Ping>, _: LocalityId, msg: Ping) {
                self.seen += 1;
                if msg.0 > 1 {
                    ctx.send(ctx.locality(), Ping(msg.0 - 1));
                }
            }
        }
        let (actors, report) =
            ThreadedRuntime::new(threads_cfg()).run(vec![SelfSpawn { seen: 0 }]);
        assert_eq!(actors[0].seen, 3);
        assert_eq!(report.net.messages, 0, "self-sends must not hit the network");
    }

    #[test]
    fn aggregate_sends_group_envelopes_like_the_simulator() {
        struct Fanout;
        impl Actor for Fanout {
            type Msg = Ping;
            fn on_start(&mut self, ctx: &mut Ctx<Ping>) {
                if ctx.locality() == 0 {
                    for i in 0..10 {
                        ctx.send(1, Ping(i));
                    }
                }
            }
            fn on_message(&mut self, _: &mut Ctx<Ping>, _: LocalityId, _: Ping) {}
        }
        let run = |aggregate| {
            let cfg = SimConfig { aggregate_sends: aggregate, ..threads_cfg() };
            ThreadedRuntime::new(cfg).run(vec![Fanout, Fanout]).1
        };
        let loose = run(false);
        let packed = run(true);
        assert_eq!(loose.net.messages, 10);
        assert_eq!(packed.net.messages, 10);
        assert_eq!(loose.net.envelopes, 10);
        assert_eq!(packed.net.envelopes, 1);
    }

    #[test]
    #[should_panic(expected = "max_events")]
    fn runaway_guard_trips() {
        struct Bouncer;
        impl Actor for Bouncer {
            type Msg = Ping;
            fn on_start(&mut self, ctx: &mut Ctx<Ping>) {
                if ctx.locality() == 0 {
                    ctx.send(1, Ping(0));
                }
            }
            fn on_message(&mut self, ctx: &mut Ctx<Ping>, from: LocalityId, msg: Ping) {
                ctx.send(from, msg); // ping-pong forever
            }
        }
        let cfg = SimConfig { max_events: 1000, ..threads_cfg() };
        ThreadedRuntime::new(cfg).run(vec![Bouncer, Bouncer]);
    }
}
