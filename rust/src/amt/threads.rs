//! Thread-per-locality AMT runtime: the same [`Actor`]s as [`sim`](super::sim),
//! executed on real OS threads with real queueing.
//!
//! The discrete-event simulator reproduces the paper's *message economics*
//! (envelope counts, modeled latency); this runtime reproduces its
//! *execution model*: each locality is a worker thread, inter-locality
//! envelopes are std-only MPSC channels (a `Mutex<VecDeque>` inbox per
//! locality — the vendored-deps constraint rules out crossbeam), and
//! quiescence, barriers, timers, and delivery acks are re-implemented over
//! a shared mutex + condvar so the exact same `VertexProgram`-driven
//! engines run unmodified on either substrate (`--runtime sim|threads`).
//!
//! Semantics match the simulator one-for-one:
//!
//! * **sends** depart when the handler finishes; per-destination grouping
//!   under [`SimConfig::aggregate_sends`] uses the same
//!   [`group_outbox`] the simulator uses, so envelope counts agree.
//!   Self-sends are local task-queue entries with no network accounting.
//! * **barriers** complete only when every locality has an outstanding
//!   request, every inbox is empty, no handler is mid-flight, and no
//!   timer is pending — the threaded reading of "the network has
//!   drained". A partial barrier at quiescence is the same deadlock
//!   panic the simulator raises.
//! * **quiescence** is the termination condition: all inboxes empty, no
//!   active handler, no pending timer, nobody waiting on a barrier.
//! * **timers** ([`Ctx::set_timer`]) hold barriers and quiescence open
//!   and fire on the owning worker via condvar timeout.
//! * **acks** ([`Ctx::send_traced`]) report the *real* send-to-handler-start
//!   latency — actual inter-thread queueing delay, which is what lets the
//!   latency-adaptive flush policy be validated against real queueing
//!   instead of the cost model (ablation A7).
//! * **faults** — an armed [`FaultPlan`](super::fault::FaultPlan) routes
//!   wire envelopes through the same drop/duplicate/delay decisions as
//!   the simulator (one shared seam, [`fault_deliveries`]); crash
//!   deadlines and injected delays are read as host wall-us. A crashed
//!   locality fail-stops: its queued work vanishes and survivors exclude
//!   it from barrier quorum. `stall_timeout_us` arms a watchdog that
//!   turns a silent hang into a structured
//!   [`StallReport`](super::metrics::StallReport) panic.
//!
//! What is *not* reproduced: the modeled interconnect. `NetConfig`
//! latencies, explicit [`Ctx::charge_us`] charges, and
//! `coalesce_window_us` parcel buffering are cost-model features; here an
//! envelope is delivered as fast as the receiving thread can pick it up,
//! and time is host wall-clock (`SimReport::makespan_us == wall_us`,
//! `busy_us` is measured in-handler time).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::fault::FaultState;
use super::metrics::{phase_segments, SimReport, StallReport};
use super::net::NetStats;
use super::sim::{
    fault_deliveries, group_outbox, AckReqs, Actor, Ctx, LocalityId, Message, SimConfig, SimTime,
};

/// One inbox entry. Envelopes carry the batched items plus any ack
/// requests stamped by [`group_outbox`]; `Barrier` fan-out entries are
/// pushed by whichever worker observes barrier completion.
enum Delivery<M> {
    Start,
    Envelope { from: LocalityId, items: Vec<M>, acks: AckReqs },
    Ack { token: u64, sent: SimTime, delivered: SimTime },
    Barrier { epoch: u64 },
}

/// State shared by all workers, guarded by one mutex; the paired condvar
/// is broadcast on every enqueue, handler completion, barrier release,
/// and shutdown.
struct Shared<M> {
    inboxes: Vec<VecDeque<Delivery<M>>>,
    /// Armed [`Ctx::set_timer`] deadlines per locality, in wall-us since
    /// run start. Pending timers hold barriers and quiescence open.
    timers: Vec<Vec<SimTime>>,
    /// Outstanding barrier requests per locality.
    waiting: Vec<bool>,
    /// Workers currently inside a handler (between inbox pop and effect
    /// dispatch). Terminal conditions require `active == 0` so a
    /// mid-handler worker's pending sends are never missed.
    active: u32,
    epoch: u64,
    events: u64,
    done: bool,
    /// Fatal condition raised by a worker (runaway guard, deadlock at
    /// quiescence, stall watchdog). Panicked on the main thread after
    /// join so the caller sees one clean message.
    error: Option<String>,
    net: Vec<NetStats>,
    /// Wall-us marks at each barrier completion (per-phase reporting).
    phase_marks: Vec<f64>,
    /// Injected-fault bookkeeping shared by every worker: one RNG stream,
    /// one crash ledger, so both runtimes share the [`fault`](super::fault)
    /// surface. Inert (no draws, no branches taken) when the plan is none.
    fault: FaultState,
    /// Envelopes held back by injected extra delay:
    /// `(release wall-us, dst, delivery)`. Counted as in-flight traffic —
    /// they hold barriers and quiescence open until released.
    delayed: Vec<(f64, usize, Delivery<M>)>,
    /// Wall-us of the most recent handler completion; the stall watchdog
    /// measures silence from here.
    last_event_us: f64,
}

impl<M> Shared<M> {
    /// Nothing in flight anywhere: no queued delivery, no mid-handler
    /// worker, no armed timer, no delayed envelope awaiting release. The
    /// threaded equivalent of the simulator's `messages_pending == 0`
    /// with an empty event heap.
    fn quiesced(&self) -> bool {
        self.active == 0
            && self.inboxes.iter().all(|q| q.is_empty())
            && self.timers.iter().all(|t| t.is_empty())
            && self.delayed.is_empty()
    }

    /// Snapshot the stuck system for a structured deadlock/stall
    /// diagnosis instead of a bare panic or an indefinite hang.
    fn stall_report(&self) -> StallReport {
        let is_ack = |d: &Delivery<M>| matches!(d, Delivery::Ack { .. });
        StallReport {
            waiting: self
                .waiting
                .iter()
                .enumerate()
                .filter(|(_, w)| **w)
                .map(|(i, _)| i)
                .collect(),
            missing: self
                .waiting
                .iter()
                .enumerate()
                .filter(|(i, w)| !**w && !self.fault.is_crashed(*i as LocalityId))
                .map(|(i, _)| i)
                .collect(),
            inbox_depths: self.inboxes.iter().map(|q| q.len()).collect(),
            pending_timers: self.timers.iter().map(|t| t.len()).collect(),
            inflight_acks: self
                .inboxes
                .iter()
                .map(|q| q.iter().filter(|d| is_ack(d)).count())
                .collect(),
            messages_pending: self.inboxes.iter().map(|q| q.len() as u64).sum::<u64>()
                + self.delayed.len() as u64,
            epoch: self.epoch,
        }
    }
}

/// Ensures a panicking worker (actor assertion, poisoned lock) releases
/// the others instead of leaving them parked on the condvar forever; the
/// scope join then propagates the original panic.
struct Bail<'a, M> {
    shared: &'a Mutex<Shared<M>>,
    cv: &'a Condvar,
}

impl<M> Drop for Bail<'_, M> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            if let Ok(mut g) = self.shared.lock() {
                g.done = true;
            }
            self.cv.notify_all();
        }
    }
}

/// The thread-per-locality runtime. See module docs.
pub struct ThreadedRuntime {
    cfg: SimConfig,
}

impl ThreadedRuntime {
    /// Create a runtime with the given configuration. Only
    /// `aggregate_sends`, `max_events`, `fault` (crash times and injected
    /// delays read as wall-us), and `stall_timeout_us` are consulted; the
    /// modeled interconnect fields are cost-model-only (see module docs).
    pub fn new(cfg: SimConfig) -> Self {
        ThreadedRuntime { cfg }
    }

    /// Run `actors` (one per locality, one worker thread each) to
    /// quiescence; returns the final actor states plus the report with
    /// real wall-clock timings.
    pub fn run<A>(&self, actors: Vec<A>) -> (Vec<A>, SimReport)
    where
        A: Actor + Send,
        A::Msg: Send,
    {
        let n = actors.len() as u32;
        assert!(n > 0, "need at least one locality");
        let run_start = Instant::now();

        let shared = Mutex::new(Shared {
            inboxes: (0..n).map(|_| VecDeque::from([Delivery::<A::Msg>::Start])).collect(),
            timers: vec![Vec::new(); n as usize],
            waiting: vec![false; n as usize],
            active: 0,
            epoch: 0,
            events: 0,
            done: false,
            error: None,
            net: vec![NetStats::default(); n as usize],
            phase_marks: Vec::new(),
            fault: FaultState::new(self.cfg.fault.clone(), n as usize),
            delayed: Vec::new(),
            last_event_us: 0.0,
        });
        let cv = Condvar::new();

        let (actors, busy): (Vec<A>, Vec<f64>) = std::thread::scope(|s| {
            let handles: Vec<_> = actors
                .into_iter()
                .enumerate()
                .map(|(l, mut actor)| {
                    let shared = &shared;
                    let cv = &cv;
                    let cfg = &self.cfg;
                    s.spawn(move || {
                        let _bail = Bail { shared, cv };
                        let busy = worker(l, n, run_start, cfg, shared, cv, &mut actor);
                        (actor, busy)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).unzip()
        });

        let g = shared.into_inner().unwrap();
        if let Some(e) = g.error {
            panic!("{e}");
        }

        let wall_us = run_start.elapsed().as_secs_f64() * 1e6;
        let mut total_net = NetStats::default();
        for st in &g.net {
            total_net.merge(st);
        }
        let mut report = SimReport::new(n);
        report.makespan_us = wall_us;
        report.busy_us = busy;
        report.barriers = g.epoch;
        report.events = g.events;
        report.net = total_net;
        report.per_locality_net = g.net;
        report.wall_us = wall_us;
        report.phase_wall_us = phase_segments(&g.phase_marks, wall_us);
        report.fault.injected_drops = g.fault.drops;
        report.fault.injected_dups = g.fault.dups;
        report.fault.injected_delays = g.fault.delays;
        report.fault.crashes = g.fault.crashes;
        (actors, report)
    }
}

fn elapsed_us(t0: Instant) -> f64 {
    t0.elapsed().as_secs_f64() * 1e6
}

/// One locality's worker loop: pop work, run the handler outside the
/// lock, dispatch effects under the lock, decide barriers/quiescence.
/// Returns the accumulated in-handler wall time (the locality's busy_us).
fn worker<A>(
    l: usize,
    n: u32,
    t0: Instant,
    cfg: &SimConfig,
    shared: &Mutex<Shared<A::Msg>>,
    cv: &Condvar,
    actor: &mut A,
) -> f64
where
    A: Actor,
{
    let mut busy_us = 0.0;
    // Fail-stop deadline for *this* locality, if the plan names it.
    let crash_at: Option<f64> = cfg
        .fault
        .crash
        .filter(|&(cl, _)| cl as usize == l)
        .map(|(_, t)| t);
    let mut g = shared.lock().unwrap();
    loop {
        if g.done {
            return busy_us;
        }

        // 0. Fail-stop: wall-clock crash deadline reached? The locality
        // vanishes — queued work, timers, and any barrier vote are
        // discarded, and this worker exits. Survivors exclude it from
        // barrier quorum and quiescence from here on.
        if let Some(at) = crash_at {
            if !g.fault.is_crashed(l as LocalityId) && elapsed_us(t0) >= at {
                g.fault.mark_crashed(l as LocalityId);
                g.inboxes[l].clear();
                g.timers[l].clear();
                g.waiting[l] = false;
                g.delayed.retain(|&(_, dst, _)| dst != l);
                cv.notify_all();
                return busy_us;
            }
        }

        // 0b. Release injected-delay envelopes whose hold has expired.
        // Any worker may promote; destinations that crashed meanwhile
        // lose the envelope on the wire.
        if !g.delayed.is_empty() {
            let now = elapsed_us(t0);
            let mut i = 0;
            let mut promoted = false;
            while i < g.delayed.len() {
                if g.delayed[i].0 <= now {
                    let (_, dst, d) = g.delayed.swap_remove(i);
                    if !g.fault.is_crashed(dst as LocalityId) {
                        g.inboxes[dst].push_back(d);
                    }
                    promoted = true;
                } else {
                    i += 1;
                }
            }
            if promoted {
                cv.notify_all();
            }
        }

        // 1. A due timer? (Timers fire on their owning worker.)
        let now = elapsed_us(t0);
        let due = g.timers[l].iter().position(|&at| at <= now);
        if let Some(i) = due {
            g.timers[l].swap_remove(i);
            g = dispatch(l, n, t0, cfg, shared, cv, actor, g, None, &mut busy_us, |a, ctx| {
                a.on_timer(ctx)
            });
            continue;
        }

        // 2. Queued delivery?
        if let Some(d) = g.inboxes[l].pop_front() {
            g = match d {
                Delivery::Start => dispatch(
                    l, n, t0, cfg, shared, cv, actor, g, None, &mut busy_us,
                    |a, ctx| a.on_start(ctx),
                ),
                Delivery::Envelope { from, items, acks } => dispatch(
                    l, n, t0, cfg, shared, cv, actor, g,
                    Some((from, acks)),
                    &mut busy_us,
                    move |a, ctx| {
                        for msg in items {
                            a.on_message(ctx, from, msg);
                        }
                    },
                ),
                Delivery::Ack { token, sent, delivered } => dispatch(
                    l, n, t0, cfg, shared, cv, actor, g, None, &mut busy_us,
                    move |a, ctx| a.on_ack(ctx, token, sent, delivered),
                ),
                Delivery::Barrier { epoch } => dispatch(
                    l, n, t0, cfg, shared, cv, actor, g, None, &mut busy_us,
                    move |a, ctx| a.on_barrier(ctx, epoch),
                ),
            };
            continue;
        }

        // 2b. Stall watchdog: the run is neither finished nor quiesced,
        // yet no handler has completed for the configured window.
        // Surface a structured report instead of hanging forever.
        if cfg.stall_timeout_us > 0.0 && !g.quiesced() {
            let now = elapsed_us(t0);
            if now - g.last_event_us >= cfg.stall_timeout_us {
                let report = g.stall_report();
                g.error.get_or_insert_with(|| report.to_string());
                g.done = true;
                cv.notify_all();
                return busy_us;
            }
        }

        // 3. Nothing runnable here — is the whole system terminal?
        // Crashed localities are outside the barrier quorum: they will
        // never vote, and holding the epoch for them would wedge every
        // survivor.
        if g.quiesced() {
            let live_waiting = g
                .waiting
                .iter()
                .enumerate()
                .any(|(i, w)| *w && !g.fault.is_crashed(i as LocalityId));
            let quorum = g
                .waiting
                .iter()
                .enumerate()
                .all(|(i, w)| *w || g.fault.is_crashed(i as LocalityId));
            if live_waiting && quorum {
                // Barrier completion: every live locality waiting +
                // network drained. Crashed localities get no fan-out.
                g.epoch += 1;
                let epoch = g.epoch;
                g.phase_marks.push(elapsed_us(t0));
                for d in 0..n as usize {
                    if g.fault.is_crashed(d as LocalityId) {
                        continue;
                    }
                    g.waiting[d] = false;
                    g.inboxes[d].push_back(Delivery::Barrier { epoch });
                }
                cv.notify_all();
                continue;
            }
            if g.waiting.iter().any(|w| *w) {
                // Partial barrier with nothing left to deliver: the same
                // deadlock the simulator reports. Recorded here,
                // panicked on the main thread after join.
                let report = g.stall_report();
                g.error.get_or_insert_with(|| report.to_string());
                g.done = true;
                cv.notify_all();
                return busy_us;
            }
            g.done = true;
            cv.notify_all();
            return busy_us;
        }

        // 4. Park until notified, or until the earliest of: our next
        // timer, the next delayed-envelope release, our crash deadline,
        // or the next stall-watchdog check.
        let mut next = g.timers[l].iter().cloned().fold(f64::INFINITY, f64::min);
        if let Some(at) = crash_at {
            if !g.fault.is_crashed(l as LocalityId) {
                next = next.min(at);
            }
        }
        next = next.min(g.delayed.iter().map(|d| d.0).fold(f64::INFINITY, f64::min));
        if cfg.stall_timeout_us > 0.0 {
            next = next.min(g.last_event_us + cfg.stall_timeout_us);
        }
        if next.is_finite() {
            let wait = (next - elapsed_us(t0)).max(0.0);
            let (g2, _) = cv
                .wait_timeout(g, Duration::from_micros(wait as u64 + 1))
                .unwrap();
            g = g2;
        } else {
            g = cv.wait(g).unwrap();
        }
    }
}

/// Run one handler outside the lock and apply its effects under it:
/// barrier flag, acks for the consumed envelope, outbox fan-out (with the
/// simulator's per-destination grouping), timer arming, event accounting.
#[allow(clippy::too_many_arguments)]
fn dispatch<'m, A, F>(
    l: usize,
    n: u32,
    t0: Instant,
    cfg: &SimConfig,
    shared: &'m Mutex<Shared<A::Msg>>,
    cv: &Condvar,
    actor: &mut A,
    mut g: std::sync::MutexGuard<'m, Shared<A::Msg>>,
    envelope_acks: Option<(LocalityId, AckReqs)>,
    busy_us: &mut f64,
    f: F,
) -> std::sync::MutexGuard<'m, Shared<A::Msg>>
where
    A: Actor,
    F: FnOnce(&mut A, &mut Ctx<A::Msg>),
{
    g.active += 1;
    let epoch = g.epoch;
    let was_waiting = g.waiting[l];
    drop(g);

    let now = elapsed_us(t0);
    let mut barrier_requested = was_waiting;
    let mut ctx = Ctx {
        locality: l as LocalityId,
        n_localities: n,
        now,
        epoch,
        explicit_charge_us: 0.0,
        barrier_requested: &mut barrier_requested,
        outbox: Vec::new(),
        timers: Vec::new(),
    };
    let wall = Instant::now();
    f(actor, &mut ctx);
    *busy_us += wall.elapsed().as_secs_f64() * 1e6;
    let outbox = std::mem::take(&mut ctx.outbox);
    let timers = std::mem::take(&mut ctx.timers);
    drop(ctx);

    let mut g = shared.lock().unwrap();
    g.waiting[l] = barrier_requested;
    g.events += 1;
    g.last_event_us = elapsed_us(t0);
    if g.events > cfg.max_events && g.error.is_none() {
        g.error = Some(format!(
            "threaded run exceeded max_events={} (runaway?)",
            cfg.max_events
        ));
        g.done = true;
    }
    // Ack the envelope we just consumed: real send-to-handler-start
    // latency, receiver-side queueing included (the A7 signal). A sender
    // that crashed since is past caring.
    if let Some((from, acks)) = envelope_acks {
        if !g.fault.is_crashed(from) {
            for (token, sent) in acks {
                g.inboxes[from as usize]
                    .push_back(Delivery::Ack { token, sent, delivered: now });
            }
        }
    }
    // Outbox fan-out. Same grouping as the simulator (envelope counts
    // agree); traced sends stamp the handler-start time. Self-sends skip
    // the network accounting, exactly like the simulator's local spawns.
    // Under an active fault plan, wire envelopes pass through the same
    // `fault_deliveries` seam the simulator uses (drop / duplicate /
    // extra delay); the fault-free path is untouched — no RNG draws, no
    // envelope splitting.
    let fault_on = g.fault.active();
    for (dst, items, acks) in group_outbox(outbox, cfg.aggregate_sends, now) {
        let du = dst as usize;
        if du == l {
            g.inboxes[du].push_back(Delivery::Envelope { from: l as LocalityId, items, acks });
            continue;
        }
        if g.fault.is_crashed(dst) {
            // Fail-stopped destination: the traffic (and any ack
            // requests riding it) vanishes on the wire.
            continue;
        }
        let deliveries = if fault_on {
            fault_deliveries(&mut g.fault, items, acks)
        } else {
            vec![(items, acks, 0.0)]
        };
        for (items, acks, extra) in deliveries {
            let n_items: usize = items.iter().map(|m| m.item_count()).sum();
            let payload_bytes: usize = items.iter().map(|m| m.wire_bytes()).sum();
            let st = &mut g.net[l];
            st.envelopes += 1;
            st.messages += n_items as u64;
            st.payload_bytes += payload_bytes as u64;
            let env = Delivery::Envelope { from: l as LocalityId, items, acks };
            if extra > 0.0 {
                g.delayed.push((now + extra, du, env));
            } else {
                g.inboxes[du].push_back(env);
            }
        }
    }
    for at in timers {
        g.timers[l].push(at);
    }
    g.active -= 1;
    cv.notify_all();
    g
}

#[cfg(test)]
mod tests {
    use super::super::sim::RuntimeKind;
    use super::*;

    fn threads_cfg() -> SimConfig {
        SimConfig { runtime: RuntimeKind::Threads, ..SimConfig::default() }
    }

    #[derive(Clone)]
    struct Ping(u32);
    impl Message for Ping {
        fn wire_bytes(&self) -> usize {
            4
        }
    }

    struct RingActor {
        hops_left: u32,
        received: u32,
    }
    impl Actor for RingActor {
        type Msg = Ping;
        fn on_start(&mut self, ctx: &mut Ctx<Ping>) {
            if ctx.locality() == 0 && self.hops_left > 0 {
                ctx.send(1 % ctx.n_localities(), Ping(self.hops_left));
            }
        }
        fn on_message(&mut self, ctx: &mut Ctx<Ping>, _from: LocalityId, msg: Ping) {
            self.received += 1;
            if msg.0 > 1 {
                let next = (ctx.locality() + 1) % ctx.n_localities();
                ctx.send(next, Ping(msg.0 - 1));
            }
        }
    }

    #[test]
    fn ring_of_pings_terminates_with_real_wall_clock() {
        let actors = (0..4).map(|_| RingActor { hops_left: 8, received: 0 }).collect();
        let (actors, report) = ThreadedRuntime::new(threads_cfg()).run(actors);
        let total: u32 = actors.iter().map(|a| a.received).sum();
        assert_eq!(total, 8);
        assert_eq!(report.net.messages, 8);
        assert_eq!(report.net.envelopes, 8);
        assert!(report.wall_us > 0.0, "a real run takes real time");
        assert_eq!(report.makespan_us, report.wall_us);
        assert_eq!(report.phase_wall_us.len(), 1, "no barriers: one phase");
    }

    struct BspActor {
        rounds: u64,
    }
    #[derive(Clone)]
    struct Nothing;
    impl Message for Nothing {
        fn wire_bytes(&self) -> usize {
            0
        }
    }
    impl Actor for BspActor {
        type Msg = Nothing;
        fn on_start(&mut self, ctx: &mut Ctx<Nothing>) {
            ctx.request_barrier();
        }
        fn on_message(&mut self, _: &mut Ctx<Nothing>, _: LocalityId, _: Nothing) {}
        fn on_barrier(&mut self, ctx: &mut Ctx<Nothing>, epoch: u64) {
            if epoch < self.rounds {
                ctx.request_barrier();
            }
        }
    }

    #[test]
    fn barriers_complete_and_phases_are_reported() {
        let actors = (0..3).map(|_| BspActor { rounds: 4 }).collect();
        let (_, report) = ThreadedRuntime::new(threads_cfg()).run(actors);
        assert_eq!(report.barriers, 4);
        assert_eq!(report.phase_wall_us.len(), 5, "4 barriers => 5 phases");
        let sum: f64 = report.phase_wall_us.iter().sum();
        assert!((sum - report.wall_us).abs() < 1e-6, "{sum} vs {}", report.wall_us);
    }

    #[test]
    fn messages_drain_before_barriers() {
        // A BSP round: messages sent before a barrier request must be
        // delivered before the barrier fires, however threads interleave.
        struct OneShot {
            got: u32,
        }
        impl Actor for OneShot {
            type Msg = Ping;
            fn on_start(&mut self, ctx: &mut Ctx<Ping>) {
                let next = (ctx.locality() + 1) % ctx.n_localities();
                ctx.send(next, Ping(1));
                ctx.request_barrier();
            }
            fn on_message(&mut self, _: &mut Ctx<Ping>, _: LocalityId, _: Ping) {
                self.got += 1;
            }
            fn on_barrier(&mut self, _: &mut Ctx<Ping>, _: u64) {
                assert_eq!(self.got, 1, "barrier fired before delivery");
            }
        }
        let actors = (0..3).map(|_| OneShot { got: 0 }).collect();
        let (actors, report) = ThreadedRuntime::new(threads_cfg()).run(actors);
        assert_eq!(report.barriers, 1);
        assert!(actors.iter().all(|a| a.got == 1));
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn partial_barrier_is_a_deadlock() {
        struct OnlyZeroWaits;
        impl Actor for OnlyZeroWaits {
            type Msg = Nothing;
            fn on_start(&mut self, ctx: &mut Ctx<Nothing>) {
                if ctx.locality() == 0 {
                    ctx.request_barrier();
                }
            }
            fn on_message(&mut self, _: &mut Ctx<Nothing>, _: LocalityId, _: Nothing) {}
        }
        ThreadedRuntime::new(threads_cfg()).run(vec![OnlyZeroWaits, OnlyZeroWaits]);
    }

    #[test]
    fn traced_sends_are_acked_with_real_latency() {
        struct Tracer {
            acks: Vec<(u64, SimTime, SimTime)>,
        }
        impl Actor for Tracer {
            type Msg = Ping;
            fn on_start(&mut self, ctx: &mut Ctx<Ping>) {
                if ctx.locality() == 0 {
                    ctx.send_traced(1, Ping(1), 7);
                    ctx.send_traced(1, Ping(2), 8);
                }
            }
            fn on_message(&mut self, _: &mut Ctx<Ping>, _: LocalityId, _: Ping) {}
            fn on_ack(&mut self, _: &mut Ctx<Ping>, token: u64, sent: SimTime, del: SimTime) {
                self.acks.push((token, sent, del));
            }
        }
        let actors = (0..2).map(|_| Tracer { acks: Vec::new() }).collect();
        let (actors, _) = ThreadedRuntime::new(threads_cfg()).run(actors);
        let acks = &actors[0].acks;
        assert_eq!(acks.len(), 2, "every traced send is acked");
        for &(_, sent, delivered) in acks {
            assert!(delivered >= sent, "latency cannot be negative");
        }
        assert!(actors[1].acks.is_empty());
    }

    #[test]
    fn timers_fire_and_hold_barriers() {
        struct Alarm {
            fired_at: Option<SimTime>,
            barrier_at: Option<SimTime>,
        }
        impl Actor for Alarm {
            type Msg = Nothing;
            fn on_start(&mut self, ctx: &mut Ctx<Nothing>) {
                if ctx.locality() == 0 {
                    ctx.set_timer(ctx.now() + 200.0);
                }
                ctx.request_barrier();
            }
            fn on_message(&mut self, _: &mut Ctx<Nothing>, _: LocalityId, _: Nothing) {}
            fn on_timer(&mut self, ctx: &mut Ctx<Nothing>) {
                self.fired_at = Some(ctx.now());
            }
            fn on_barrier(&mut self, ctx: &mut Ctx<Nothing>, _: u64) {
                self.barrier_at = Some(ctx.now());
            }
        }
        let actors = (0..2).map(|_| Alarm { fired_at: None, barrier_at: None }).collect();
        let (actors, report) = ThreadedRuntime::new(threads_cfg()).run(actors);
        let fired = actors[0].fired_at.expect("timer fired");
        assert_eq!(report.barriers, 1);
        for a in &actors {
            assert!(a.barrier_at.expect("barrier completed") >= fired, "barrier outran timer");
        }
    }

    #[test]
    fn self_sends_do_not_hit_the_network() {
        struct SelfSpawn {
            seen: u32,
        }
        impl Actor for SelfSpawn {
            type Msg = Ping;
            fn on_start(&mut self, ctx: &mut Ctx<Ping>) {
                ctx.send(ctx.locality(), Ping(3));
            }
            fn on_message(&mut self, ctx: &mut Ctx<Ping>, _: LocalityId, msg: Ping) {
                self.seen += 1;
                if msg.0 > 1 {
                    ctx.send(ctx.locality(), Ping(msg.0 - 1));
                }
            }
        }
        let (actors, report) =
            ThreadedRuntime::new(threads_cfg()).run(vec![SelfSpawn { seen: 0 }]);
        assert_eq!(actors[0].seen, 3);
        assert_eq!(report.net.messages, 0, "self-sends must not hit the network");
    }

    #[test]
    fn aggregate_sends_group_envelopes_like_the_simulator() {
        struct Fanout;
        impl Actor for Fanout {
            type Msg = Ping;
            fn on_start(&mut self, ctx: &mut Ctx<Ping>) {
                if ctx.locality() == 0 {
                    for i in 0..10 {
                        ctx.send(1, Ping(i));
                    }
                }
            }
            fn on_message(&mut self, _: &mut Ctx<Ping>, _: LocalityId, _: Ping) {}
        }
        let run = |aggregate| {
            let cfg = SimConfig { aggregate_sends: aggregate, ..threads_cfg() };
            ThreadedRuntime::new(cfg).run(vec![Fanout, Fanout]).1
        };
        let loose = run(false);
        let packed = run(true);
        assert_eq!(loose.net.messages, 10);
        assert_eq!(packed.net.messages, 10);
        assert_eq!(loose.net.envelopes, 10);
        assert_eq!(packed.net.envelopes, 1);
    }

    #[test]
    #[should_panic(expected = "max_events")]
    fn runaway_guard_trips() {
        struct Bouncer;
        impl Actor for Bouncer {
            type Msg = Ping;
            fn on_start(&mut self, ctx: &mut Ctx<Ping>) {
                if ctx.locality() == 0 {
                    ctx.send(1, Ping(0));
                }
            }
            fn on_message(&mut self, ctx: &mut Ctx<Ping>, from: LocalityId, msg: Ping) {
                ctx.send(from, msg); // ping-pong forever
            }
        }
        let cfg = SimConfig { max_events: 1000, ..threads_cfg() };
        ThreadedRuntime::new(cfg).run(vec![Bouncer, Bouncer]);
    }

    use super::super::fault::FaultPlan;

    fn fault_cfg(plan: FaultPlan) -> SimConfig {
        SimConfig { fault: plan, ..threads_cfg() }
    }

    #[test]
    fn fault_drop_loses_the_envelope_on_threads() {
        let plan = FaultPlan { drop_p: 1.0, seed: 11, ..FaultPlan::none() };
        let actors = (0..2).map(|_| RingActor { hops_left: 1, received: 0 }).collect();
        let (actors, report) = ThreadedRuntime::new(fault_cfg(plan)).run(actors);
        let total: u32 = actors.iter().map(|a| a.received).sum();
        assert_eq!(total, 0, "certain drop: the ping never arrives");
        assert_eq!(report.fault.injected_drops, 1);
    }

    #[test]
    fn fault_dup_delivers_twice_on_threads() {
        let plan = FaultPlan { dup_p: 1.0, seed: 7, ..FaultPlan::none() };
        let actors = (0..2).map(|_| RingActor { hops_left: 1, received: 0 }).collect();
        let (actors, report) = ThreadedRuntime::new(fault_cfg(plan)).run(actors);
        let total: u32 = actors.iter().map(|a| a.received).sum();
        assert_eq!(total, 2, "certain duplication: the ping arrives twice");
        assert_eq!(report.fault.injected_dups, 1);
        assert_eq!(report.net.envelopes, 2, "the duplicate is real traffic");
    }

    #[test]
    fn fault_delay_holds_then_releases_on_threads() {
        let plan = FaultPlan { delay_us: 5_000.0, seed: 5, ..FaultPlan::none() };
        let actors = (0..2).map(|_| RingActor { hops_left: 1, received: 0 }).collect();
        let (actors, report) = ThreadedRuntime::new(fault_cfg(plan)).run(actors);
        let total: u32 = actors.iter().map(|a| a.received).sum();
        assert_eq!(total, 1, "delayed, not lost");
        assert_eq!(report.fault.injected_delays, 1);
        assert!(report.wall_us >= 5_000.0, "the hold is real wall time: {}", report.wall_us);
    }

    #[test]
    fn wall_clock_crash_stops_the_locality_and_run_completes() {
        // An otherwise-endless ping-pong: only the fail-stop of locality 1
        // lets the run quiesce.
        struct Bouncer {
            got: u32,
        }
        impl Actor for Bouncer {
            type Msg = Ping;
            fn on_start(&mut self, ctx: &mut Ctx<Ping>) {
                if ctx.locality() == 0 {
                    ctx.send(1, Ping(0));
                }
            }
            fn on_message(&mut self, ctx: &mut Ctx<Ping>, from: LocalityId, msg: Ping) {
                self.got += 1;
                ctx.send(from, msg);
            }
        }
        let plan = FaultPlan { crash: Some((1, 10_000.0)), ..FaultPlan::none() };
        let actors = (0..2).map(|_| Bouncer { got: 0 }).collect();
        let (actors, report) = ThreadedRuntime::new(fault_cfg(plan)).run(actors);
        assert_eq!(report.fault.crashes, 1);
        assert!(actors[0].got > 0, "traffic flowed before the crash");
    }

    #[test]
    fn crash_excludes_locality_from_threaded_barrier_quorum() {
        // Locality 1 requests barriers forever and fail-stops at 10ms;
        // locality 0 keeps the BSP loop going until 25ms of wall clock.
        // Without quorum exclusion the first post-crash barrier would
        // wedge; with it, locality 0 finishes its rounds solo.
        struct TimedBsp {
            stop_at: f64,
        }
        impl Actor for TimedBsp {
            type Msg = Nothing;
            fn on_start(&mut self, ctx: &mut Ctx<Nothing>) {
                ctx.request_barrier();
            }
            fn on_message(&mut self, _: &mut Ctx<Nothing>, _: LocalityId, _: Nothing) {}
            fn on_barrier(&mut self, ctx: &mut Ctx<Nothing>, _: u64) {
                if ctx.now() < self.stop_at {
                    ctx.request_barrier();
                }
            }
        }
        let plan = FaultPlan { crash: Some((1, 10_000.0)), ..FaultPlan::none() };
        let actors = vec![
            TimedBsp { stop_at: 25_000.0 },
            TimedBsp { stop_at: f64::INFINITY },
        ];
        let (_, report) = ThreadedRuntime::new(fault_cfg(plan)).run(actors);
        assert_eq!(report.fault.crashes, 1);
        assert!(report.barriers > 0, "barriers completed before and after the crash");
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn stall_watchdog_reports_instead_of_hanging() {
        // Locality 0 arms a timer a minute out and everyone requests a
        // barrier: quiescence is held open, the barrier cannot complete,
        // and without the watchdog the run would sit there for a minute.
        struct FarTimer;
        impl Actor for FarTimer {
            type Msg = Nothing;
            fn on_start(&mut self, ctx: &mut Ctx<Nothing>) {
                if ctx.locality() == 0 {
                    ctx.set_timer(ctx.now() + 60_000_000.0);
                }
                ctx.request_barrier();
            }
            fn on_message(&mut self, _: &mut Ctx<Nothing>, _: LocalityId, _: Nothing) {}
            fn on_timer(&mut self, _: &mut Ctx<Nothing>) {}
        }
        let cfg = SimConfig { stall_timeout_us: 30_000.0, ..threads_cfg() };
        ThreadedRuntime::new(cfg).run(vec![FarTimer, FarTimer]);
    }
}
