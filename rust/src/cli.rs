//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `nwgraph-hpx <subcommand> [--flag value]... [--switch]...
//! [key=value overrides]...`. Flags starting with `--` take a value unless
//! registered as boolean switches; bare `key=value` tokens become config
//! overrides passed to [`crate::config::Config::load`].

use std::collections::BTreeMap;

use crate::Result;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First positional token (subcommand).
    pub command: String,
    /// `--flag value` pairs (switches map to "true").
    pub flags: BTreeMap<String, String>,
    /// `key=value` config overrides, in order.
    pub overrides: Vec<String>,
}

/// Boolean switches that take no value.
const SWITCHES: &[&str] = &["help", "aggregate", "quiet", "validate", "json", "large"];

impl Args {
    /// Parse from raw tokens (without argv[0]).
    pub fn parse(tokens: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = tokens.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') && !first.contains('=') {
                args.command = it.next().unwrap().clone();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if SWITCHES.contains(&name) {
                    args.flags.insert(name.to_string(), "true".to_string());
                } else {
                    let val = it
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("flag --{name} needs a value"))?;
                    args.flags.insert(name.to_string(), val.clone());
                }
            } else if tok.contains('=') {
                args.overrides.push(tok.clone());
            } else {
                anyhow::bail!("unexpected positional argument `{tok}`");
            }
        }
        Ok(args)
    }

    /// Parse from the process environment.
    pub fn from_env() -> Result<Args> {
        let tokens: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&tokens)
    }

    /// Flag lookup.
    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// Boolean switch lookup.
    pub fn switch(&self, name: &str) -> bool {
        self.flags.get(name).map(|v| v == "true").unwrap_or(false)
    }

    /// Typed flag with default.
    pub fn flag_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("flag --{name}={v}: {e}")),
        }
    }
}

/// Top-level usage text.
pub const USAGE: &str = "\
nwgraph-hpx — distributed graph algorithms on an AMT runtime (paper repro)

USAGE:
    nwgraph-hpx <COMMAND> [--flag value]... [key=value]...

COMMANDS:
    bfs         run one distributed BFS (--engine async|bsp|diropt)
    pagerank    run one distributed PageRank (--engine async|async-naive|bsp|kernel)
    sssp        run one distributed SSSP (--engine delta|async|bsp); reports
                relaxation counters (total vs useful); every engine is
                partition-generic, vertex cuts included
    cc          run one distributed connected-components pass
                (--engine bsp|async)
    serve       answer a generated s->t query stream (distance / path /
                rank) instead of one-shot analytics: landmark-oracle
                precompute, hot-source LRU cache, and batched multi-source
                SSSP waves through the aggregator; prints hits, waves,
                qps, and p50/p99 wall-clock latency; scheme-generic
                (vertex cuts included); needs an undirected generator
                (symmetric metric)
    mutate      apply a seeded edge-update batch (inserts + deletes) to the
                distributed graph through the aggregator scatter path, then
                re-converge --algo sssp|bfs|cc|pagerank incrementally from
                the previous fixpoint (deletion dependency taint + frontier
                re-seeding; PageRank warm-restarts from its previous ranks
                on BSP) and print the cost next to a full recompute;
                batch shape comes from mutate_frac/mutate_inserts/mutate_seed
    fig1        regenerate Figure 1 (BFS speedup sweep, HPX vs Boost/BSP)
    fig2        regenerate Figure 2 (PageRank sweep, HPX naive/opt vs Boost/BSP)
    ablations   run the DESIGN.md ablation suite (A1 aggregation, A2 chunking,
                A4 amt::aggregate flush policies, A5 delta-stepping
                delta x flush-policy sweep, A6 partition schemes x algorithms,
                A7 adaptive coalescing: static-adaptive vs latency vs time
                windows x {block, vertex_cut} with observed-latency columns,
                A8 query serving: oracle x cache x batch over {sim, threads}
                with hits/waves/qps/latency columns,
                A9 memory-limit scale sweep: streamed kron10..16 x
                {plain, compressed} storage x {block, vertex_cut} with
                bytes/edge, peak builder bytes, build time, and MTEPS
                columns — --large extends it to kron18,
                A10 incremental re-convergence: update-batch size x
                {block, vertex_cut} x {sim, threads} with applied/tainted/
                reseeded counters and incremental-vs-full relaxation,
                envelope, and makespan columns,
                A11 fault injection: {none, drop+dup, drop+dup+crash} x
                reliability x {bfs-async, sssp-delta, pagerank-bsp} over
                {sim, threads}, every cell oracle-validated, with
                drops/retransmits/dedup/crashes/restores/checkpoint
                columns);
                --json additionally writes machine-readable tables to
                bench_out/*.json (--out-dir overrides the directory);
                --only a4,a7,a8,a9,a10,a11 runs a prefix-matched subset
    info        print graph statistics for the configured generator
    help        show this message

CONFIG OVERRIDES (key=value):
    scale, degree, generator (urand|urand-directed|kron), seed,
    localities (comma list), alpha, iterations, root, reps, aggregate,
    flush_policy (unbatched|naive|items:N|bytes:N|adaptive|latency|time:US|manual
                  — adaptive derives a static break-even from the net model;
                  latency self-tunes per destination on observed delivery
                  latency; time:US flushes when the oldest buffered item has
                  waited US microseconds, time:0 == unbatched;
                  items:0/bytes:0 are rejected),
    sssp_delta (bucket width; 0 = auto w/d heuristic, inf = Bellman-Ford),
    partition (block|edge_balanced|hash|vertex_cut),
    storage (plain|compressed — shard adjacency encoding; compressed packs
             each sorted row as delta-varint bytes, decoded through a
             reusable scratch buffer on the hot path),
    ingest (materialize|stream — stream builds shards in one pass from the
            generator's edge stream and never materializes the whole-graph
            CSR; serve requires materialize),
    runtime (sim|threads — discrete-event simulator with the modeled
             interconnect, or one OS thread per locality with real queueing;
             both run the same engines and report wall-clock columns),
    serve_queries, serve_landmarks, serve_cache (0 disables),
    serve_batch (>= 1), serve_oracle (true|false),
    serve_deadline_us (per-window latency budget in wall-clock us; past it
                       uncovered queries degrade to flagged landmark
                       bounds instead of waving; 0 = no deadline),
    mutate_frac (update-batch size as a fraction of the edge count, in [0,1]),
    mutate_inserts (insert share of the batch, in [0,1]; rest are deletes),
    mutate_seed (batch RNG seed; 0 derives from seed),
    fault_drop, fault_dup (per-envelope probabilities in [0,1]),
    fault_delay_us (extra per-envelope delivery delay bound),
    fault_crash (L@T: locality L fail-stops at time T us; recovery restores
                 it from its last checkpoint and re-converges warm),
    fault_slow (L@F: locality L's compute charges scale by F >= 1; sim only),
    fault_seed (decision-stream seed),
    reliability (none|acked — acked turns on sequence-numbered envelopes,
                 receiver dedup, and ack-driven retransmit; none keeps the
                 historical zero-overhead fast path),
    checkpoint_every (engine progress ticks between snapshots; 0 =
                      checkpoint only when a crash is planned),
    stall_timeout_us (threads-runtime deadlock watchdog; 0 disables),
    taint_cap (deletion-taint fraction above which incremental reconverge
               falls back to full recompute, in [0,1]; 0 never falls back),
    net.latency_us, net.bandwidth_gbps, net.send_cpu_us, net.recv_cpu_us,
    net.per_item_cpu_us, net.overhead_bytes, artifact_dir

FLAGS:
    --config <file>    key=value config file (overrides applied after)
    --engine <name>    algorithm engine (see per-command lists above)
    --algo <name>      algorithm for `mutate` (sssp|bfs|cc|pagerank; default sssp)
    --runtime <name>   execution substrate, sim|threads (same as runtime=)
    --out <file>       write the result table as CSV
    --out-dir <dir>    output directory for `ablations --json` (default bench_out)
    --json             also write ablation tables as JSON (ablations only)
    --only <list>      comma list of ablation stems to run, prefix-matched
                       (e.g. --only a4,a7,a8,a9,a10,a11; ablations only)
    --large            extend the A9 scale sweep to kron18 (ablations only)
    --validate         validate results against the sequential oracle
";

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_command_flags_overrides() {
        let a = Args::parse(&toks("fig1 --engine async scale=12 net.latency_us=3")).unwrap();
        assert_eq!(a.command, "fig1");
        assert_eq!(a.flag("engine"), Some("async"));
        assert_eq!(a.overrides, vec!["scale=12", "net.latency_us=3"]);
    }

    #[test]
    fn switches_take_no_value() {
        let a = Args::parse(&toks("bfs --validate --engine bsp")).unwrap();
        assert!(a.switch("validate"));
        assert_eq!(a.flag("engine"), Some("bsp"));
    }

    #[test]
    fn json_is_a_switch_and_out_dir_takes_a_value() {
        let a = Args::parse(&toks("ablations --json --out-dir results scale=8")).unwrap();
        assert!(a.switch("json"));
        assert_eq!(a.flag("out-dir"), Some("results"));
        assert_eq!(a.overrides, vec!["scale=8"]);
    }

    #[test]
    fn large_is_a_switch() {
        let a = Args::parse(&toks("ablations --large --only a9")).unwrap();
        assert!(a.switch("large"));
        assert_eq!(a.flag("only"), Some("a9"));
    }

    #[test]
    fn missing_value_is_an_error() {
        assert!(Args::parse(&toks("bfs --engine")).is_err());
    }

    #[test]
    fn unexpected_positional_is_an_error() {
        assert!(Args::parse(&toks("bfs extra")).is_err());
    }

    #[test]
    fn typed_flag_default() {
        let a = Args::parse(&toks("bfs")).unwrap();
        assert_eq!(a.flag_or("p", 4u32).unwrap(), 4);
    }
}
