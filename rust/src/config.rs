//! Experiment configuration: key=value files + CLI overrides.
//!
//! No serde offline, so the format is deliberately simple: one `key =
//! value` per line, `#` comments. Every knob has a default matching the
//! paper's setup (urand graphs, alpha = 0.85, locality sweep 1..32).

use std::collections::BTreeMap;
use std::path::Path;

use crate::amt::{FaultPlan, FlushPolicy, NetConfig, Reliability, RuntimeKind};
use crate::graph::{PartitionKind, StorageKind};
use crate::Result;

/// How the distributed graph is built (config key `ingest`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum IngestMode {
    /// Build the whole-graph [`Csr`](crate::graph::Csr) on the leader,
    /// then shard it — the classic path, and required when a sequential
    /// oracle validates the run.
    #[default]
    Materialize,
    /// One-pass streaming ingestion ([`graph::stream`](crate::graph::stream)):
    /// shards are built straight from the edge stream and the global
    /// graph is never materialized.
    Stream,
}

impl IngestMode {
    /// Parse the config spelling (`materialize` | `stream`).
    pub fn parse(s: &str) -> Option<IngestMode> {
        match s {
            "materialize" | "materialized" => Some(IngestMode::Materialize),
            "stream" | "streamed" => Some(IngestMode::Stream),
            _ => None,
        }
    }

    /// Config spelling of this mode.
    pub fn name(&self) -> &'static str {
        match self {
            IngestMode::Materialize => "materialize",
            IngestMode::Stream => "stream",
        }
    }
}

/// Full experiment configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Graph scale: n = 2^scale (GAP `urandN` naming).
    pub scale: u32,
    /// Average degree of the generated graph.
    pub degree: usize,
    /// Generator: "urand", "urand-directed", or "kron".
    pub generator: String,
    /// PRNG seed.
    pub seed: u64,
    /// Locality counts to sweep.
    pub localities: Vec<u32>,
    /// PageRank damping factor.
    pub alpha: f32,
    /// PageRank iterations.
    pub iterations: u32,
    /// BFS root vertex.
    pub root: u32,
    /// Repetitions per data point.
    pub reps: u32,
    /// Interconnect model.
    pub net: NetConfig,
    /// Aggregate same-destination sends per handler (optimized variant).
    pub aggregate: bool,
    /// Flush policy for the `amt::aggregate` combiners in the asynchronous
    /// engines (`unbatched`, `items:N`, `bytes:N`, `adaptive`, `latency`,
    /// `time:US`, `manual`).
    pub flush_policy: FlushPolicy,
    /// Delta-stepping SSSP bucket width Δ. `0` (the default) auto-tunes via
    /// [`sssp::auto_delta`](crate::algorithms::sssp::auto_delta) (mean
    /// weight / mean degree); `inf` is accepted (≡ Bellman-Ford).
    pub sssp_delta: f32,
    /// Vertex/edge partition scheme
    /// (`block|edge_balanced|hash|vertex_cut`).
    pub partition: PartitionKind,
    /// Shard adjacency storage (`plain|compressed`).
    pub storage: StorageKind,
    /// Graph build path (`materialize|stream`).
    pub ingest: IngestMode,
    /// Execution substrate: the discrete-event simulator (`sim`, default)
    /// or one OS thread per locality with real wall-clock (`threads`).
    pub runtime: RuntimeKind,
    /// Artifact directory for the kernel path.
    pub artifact_dir: String,
    /// Serve mode: queries in the generated stream.
    pub serve_queries: usize,
    /// Serve mode: landmarks precomputed for the distance oracle.
    pub serve_landmarks: usize,
    /// Serve mode: hot-source LRU cache capacity in trees (`0` disables).
    pub serve_cache: usize,
    /// Serve mode: multi-source wave width (must be `>= 1`).
    pub serve_batch: usize,
    /// Serve mode: master switch for the landmark oracle.
    pub serve_oracle: bool,
    /// Serve mode: per-window deadline in host wall-clock µs (`0` = no
    /// deadline; see `serve::ServeParams::deadline_us`).
    pub serve_deadline_us: f64,
    /// Mutate mode: update-batch size as a fraction of the graph's edge
    /// pairs (`0` = empty batch).
    pub mutate_frac: f64,
    /// Mutate mode: share of the batch that is inserts (rest deletes).
    pub mutate_inserts: f64,
    /// Mutate mode: batch-generator seed (`0` = derive from `seed`).
    pub mutate_seed: u64,
    /// Fault-injection plan (keys `fault_drop`, `fault_dup`,
    /// `fault_delay_us`, `fault_crash`, `fault_slow`, `fault_seed`).
    /// Defaults to [`FaultPlan::none`]: the injector is compiled out of
    /// the hot path and envelope traces are bit-identical to a
    /// fault-free build.
    pub fault: FaultPlan,
    /// Message-delivery contract (`none` | `acked`). `acked` turns on
    /// sequence-numbered envelopes, receiver dedup, and ack-driven
    /// retransmit in every aggregator.
    pub reliability: Reliability,
    /// Checkpoint cadence in engine progress ticks (`0` = only when a
    /// crash is planned, at the default cadence).
    pub checkpoint_every: u64,
    /// Threads-runtime stall watchdog: barrier wait time before a
    /// [`StallReport`](crate::amt::metrics::StallReport) is raised
    /// (`0` = watchdog disabled).
    pub stall_timeout_us: f64,
    /// Incremental-update taint cap: when a deletion taints more than
    /// this fraction of vertices, fall back to full recompute (`0`
    /// disables the fallback).
    pub taint_cap: f64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            scale: 14,
            degree: 8,
            generator: "urand".into(),
            seed: 42,
            localities: vec![1, 2, 4, 8, 16, 32],
            alpha: 0.85,
            iterations: 20,
            root: 0,
            reps: 3,
            net: NetConfig::default(),
            aggregate: false,
            flush_policy: FlushPolicy::Adaptive,
            sssp_delta: 0.0,
            partition: PartitionKind::Block,
            storage: StorageKind::Plain,
            ingest: IngestMode::Materialize,
            runtime: RuntimeKind::Sim,
            artifact_dir: "artifacts".into(),
            serve_queries: 1000,
            serve_landmarks: 8,
            serve_cache: 32,
            serve_batch: 16,
            serve_oracle: true,
            serve_deadline_us: 0.0,
            mutate_frac: 0.01,
            mutate_inserts: 0.5,
            mutate_seed: 0,
            fault: FaultPlan::none(),
            reliability: Reliability::None,
            checkpoint_every: 0,
            stall_timeout_us: 0.0,
            taint_cap: 0.5,
        }
    }
}

impl Config {
    /// Parse a config file, then apply `key=value` overrides in order.
    pub fn load(path: Option<&Path>, overrides: &[String]) -> Result<Config> {
        let mut kv = BTreeMap::new();
        if let Some(p) = path {
            let text = std::fs::read_to_string(p)?;
            parse_kv(&text, &mut kv)?;
        }
        for ov in overrides {
            let (k, v) = ov
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("override `{ov}` is not key=value"))?;
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        Config::from_kv(&kv)
    }

    /// Build from a key/value map (unknown keys are an error — typo guard).
    pub fn from_kv(kv: &BTreeMap<String, String>) -> Result<Config> {
        let mut c = Config::default();
        for (k, v) in kv {
            match k.as_str() {
                "scale" => c.scale = v.parse()?,
                "degree" => c.degree = v.parse()?,
                "generator" => c.generator = v.clone(),
                "seed" => c.seed = v.parse()?,
                "localities" => {
                    c.localities = v
                        .split(',')
                        .map(|s| s.trim().parse::<u32>())
                        .collect::<std::result::Result<_, _>>()?;
                }
                "alpha" => c.alpha = v.parse()?,
                "iterations" => c.iterations = v.parse()?,
                "root" => c.root = v.parse()?,
                "reps" => c.reps = v.parse()?,
                "aggregate" => c.aggregate = v.parse()?,
                "flush_policy" => {
                    c.flush_policy = FlushPolicy::parse(v)
                        .map_err(|e| anyhow::anyhow!("bad flush_policy: {e}"))?;
                }
                "sssp_delta" => {
                    let d: f32 = v.parse()?;
                    anyhow::ensure!(
                        d >= 0.0 && !d.is_nan(),
                        "sssp_delta must be >= 0 (0 = auto) or inf, got `{v}`"
                    );
                    c.sssp_delta = d;
                }
                "partition" => {
                    c.partition = PartitionKind::parse(v).ok_or_else(|| {
                        anyhow::anyhow!(
                            "bad partition `{v}` (want block|edge_balanced|hash|vertex_cut)"
                        )
                    })?;
                }
                "storage" => {
                    c.storage = StorageKind::parse(v).ok_or_else(|| {
                        anyhow::anyhow!("bad storage `{v}` (want plain|compressed)")
                    })?;
                }
                "ingest" => {
                    c.ingest = IngestMode::parse(v).ok_or_else(|| {
                        anyhow::anyhow!("bad ingest `{v}` (want materialize|stream)")
                    })?;
                }
                "runtime" => {
                    c.runtime = RuntimeKind::parse(v)
                        .map_err(|e| anyhow::anyhow!("bad runtime: {e}"))?;
                }
                "artifact_dir" => c.artifact_dir = v.clone(),
                "serve_queries" => c.serve_queries = v.parse()?,
                "serve_landmarks" => c.serve_landmarks = v.parse()?,
                "serve_cache" => c.serve_cache = v.parse()?,
                "serve_batch" => {
                    let b: usize = v.parse()?;
                    anyhow::ensure!(b >= 1, "serve_batch must be >= 1, got `{v}`");
                    c.serve_batch = b;
                }
                "serve_oracle" => c.serve_oracle = v.parse()?,
                "serve_deadline_us" => {
                    let d: f64 = v.parse()?;
                    anyhow::ensure!(
                        d >= 0.0 && !d.is_nan(),
                        "serve_deadline_us must be >= 0 (0 = none), got `{v}`"
                    );
                    c.serve_deadline_us = d;
                }
                "mutate_frac" => {
                    let f: f64 = v.parse()?;
                    anyhow::ensure!(
                        (0.0..=1.0).contains(&f),
                        "mutate_frac must be in [0, 1], got `{v}`"
                    );
                    c.mutate_frac = f;
                }
                "mutate_inserts" => {
                    let f: f64 = v.parse()?;
                    anyhow::ensure!(
                        (0.0..=1.0).contains(&f),
                        "mutate_inserts must be in [0, 1], got `{v}`"
                    );
                    c.mutate_inserts = f;
                }
                "mutate_seed" => c.mutate_seed = v.parse()?,
                "fault_drop" => {
                    let p: f64 = v.parse()?;
                    anyhow::ensure!(
                        (0.0..=1.0).contains(&p),
                        "fault_drop must be in [0, 1], got `{v}`"
                    );
                    c.fault.drop_p = p;
                }
                "fault_dup" => {
                    let p: f64 = v.parse()?;
                    anyhow::ensure!(
                        (0.0..=1.0).contains(&p),
                        "fault_dup must be in [0, 1], got `{v}`"
                    );
                    c.fault.dup_p = p;
                }
                "fault_delay_us" => {
                    let d: f64 = v.parse()?;
                    anyhow::ensure!(
                        d >= 0.0 && !d.is_nan(),
                        "fault_delay_us must be >= 0, got `{v}`"
                    );
                    c.fault.delay_us = d;
                }
                "fault_crash" => {
                    c.fault.crash = Some(
                        FaultPlan::parse_crash(v)
                            .map_err(|e| anyhow::anyhow!("bad fault_crash: {e}"))?,
                    );
                }
                "fault_slow" => {
                    c.fault.slow = Some(
                        FaultPlan::parse_slow(v)
                            .map_err(|e| anyhow::anyhow!("bad fault_slow: {e}"))?,
                    );
                }
                "fault_seed" => c.fault.seed = v.parse()?,
                "reliability" => {
                    c.reliability = Reliability::parse(v)
                        .map_err(|e| anyhow::anyhow!("bad reliability: {e}"))?;
                }
                "checkpoint_every" => c.checkpoint_every = v.parse()?,
                "stall_timeout_us" => {
                    let t: f64 = v.parse()?;
                    anyhow::ensure!(
                        t >= 0.0 && !t.is_nan(),
                        "stall_timeout_us must be >= 0 (0 = no watchdog), got `{v}`"
                    );
                    c.stall_timeout_us = t;
                }
                "taint_cap" => {
                    let f: f64 = v.parse()?;
                    anyhow::ensure!(
                        (0.0..=1.0).contains(&f),
                        "taint_cap must be in [0, 1] (0 = never fall back), got `{v}`"
                    );
                    c.taint_cap = f;
                }
                "net.latency_us" => c.net.latency_us = v.parse()?,
                "net.bandwidth_gbps" => {
                    c.net.bandwidth_bytes_per_us = v.parse::<f64>()? * 1000.0
                }
                "net.send_cpu_us" => c.net.send_cpu_us = v.parse()?,
                "net.recv_cpu_us" => c.net.recv_cpu_us = v.parse()?,
                "net.per_item_cpu_us" => c.net.per_item_cpu_us = v.parse()?,
                "net.overhead_bytes" => c.net.overhead_bytes = v.parse()?,
                _ => anyhow::bail!("unknown config key `{k}`"),
            }
        }
        Ok(c)
    }

    /// Build the configured graph.
    pub fn build_graph(&self) -> Result<crate::graph::Csr> {
        use crate::graph::generators as gen;
        Ok(match self.generator.as_str() {
            "urand" => gen::urand(self.scale, self.degree, self.seed),
            "urand-directed" => gen::urand_directed(self.scale, self.degree, self.seed),
            "kron" => gen::kron(self.scale, self.degree, self.seed),
            other => anyhow::bail!("unknown generator `{other}`"),
        })
    }

    /// The update-batch generator seed: `mutate_seed`, or derived from
    /// the graph seed when left at `0` so `seed=` alone moves everything.
    pub fn effective_mutate_seed(&self) -> u64 {
        if self.mutate_seed == 0 { self.seed.wrapping_add(3) } else { self.mutate_seed }
    }

    /// Graph name in GAP style (`urand14`, `kron16`, ...).
    pub fn graph_name(&self) -> String {
        let base = match self.generator.as_str() {
            "urand-directed" => "urand",
            g => g,
        };
        format!("{base}{}", self.scale)
    }
}

fn parse_kv(text: &str, kv: &mut BTreeMap<String, String>) -> Result<()> {
    for (no, line) in text.lines().enumerate() {
        let s = line.trim();
        if s.is_empty() || s.starts_with('#') {
            continue;
        }
        let (k, v) = s
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("config line {}: expected key = value", no + 1))?;
        kv.insert(k.trim().to_string(), v.trim().to_string());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_like() {
        let c = Config::default();
        assert_eq!(c.alpha, 0.85);
        assert_eq!(c.localities, vec![1, 2, 4, 8, 16, 32]);
    }

    #[test]
    fn file_plus_overrides() {
        let mut kv = BTreeMap::new();
        parse_kv("# comment\nscale = 10\nlocalities = 1,2,4\n", &mut kv).unwrap();
        kv.insert("degree".into(), "16".into());
        let c = Config::from_kv(&kv).unwrap();
        assert_eq!(c.scale, 10);
        assert_eq!(c.degree, 16);
        assert_eq!(c.localities, vec![1, 2, 4]);
    }

    #[test]
    fn unknown_key_is_an_error() {
        let mut kv = BTreeMap::new();
        kv.insert("scle".into(), "10".into());
        assert!(Config::from_kv(&kv).is_err());
    }

    #[test]
    fn flush_policy_parses_and_rejects() {
        let mut kv = BTreeMap::new();
        kv.insert("flush_policy".into(), "items:256".into());
        let c = Config::from_kv(&kv).unwrap();
        assert_eq!(c.flush_policy, FlushPolicy::Items(256));
        kv.insert("flush_policy".into(), "latency".into());
        assert_eq!(Config::from_kv(&kv).unwrap().flush_policy, FlushPolicy::LatencyAdaptive);
        kv.insert("flush_policy".into(), "time:25".into());
        assert_eq!(Config::from_kv(&kv).unwrap().flush_policy, FlushPolicy::TimeWindow(25));
        kv.insert("flush_policy".into(), "warp".into());
        assert!(Config::from_kv(&kv).is_err());
        kv.insert("flush_policy".into(), "items:0".into());
        let err = Config::from_kv(&kv).unwrap_err().to_string();
        assert!(err.contains("items:0"), "{err}");
    }

    #[test]
    fn sssp_delta_parses_and_rejects() {
        let mut kv = BTreeMap::new();
        kv.insert("sssp_delta".into(), "0.5".into());
        let c = Config::from_kv(&kv).unwrap();
        assert_eq!(c.sssp_delta, 0.5);
        kv.insert("sssp_delta".into(), "inf".into());
        assert!(Config::from_kv(&kv).unwrap().sssp_delta.is_infinite());
        kv.insert("sssp_delta".into(), "-1".into());
        assert!(Config::from_kv(&kv).is_err());
        kv.insert("sssp_delta".into(), "NaN".into());
        assert!(Config::from_kv(&kv).is_err());
        assert_eq!(Config::default().sssp_delta, 0.0, "default is auto");
    }

    #[test]
    fn partition_parses_and_rejects() {
        let mut kv = BTreeMap::new();
        kv.insert("partition".into(), "vertex_cut".into());
        let c = Config::from_kv(&kv).unwrap();
        assert_eq!(c.partition, PartitionKind::VertexCut);
        kv.insert("partition".into(), "hash".into());
        assert_eq!(Config::from_kv(&kv).unwrap().partition, PartitionKind::Hash);
        kv.insert("partition".into(), "diagonal".into());
        assert!(Config::from_kv(&kv).is_err());
        assert_eq!(Config::default().partition, PartitionKind::Block);
    }

    #[test]
    fn storage_and_ingest_parse_and_reject() {
        let mut kv = BTreeMap::new();
        kv.insert("storage".into(), "compressed".into());
        kv.insert("ingest".into(), "stream".into());
        let c = Config::from_kv(&kv).unwrap();
        assert_eq!(c.storage, StorageKind::Compressed);
        assert_eq!(c.ingest, IngestMode::Stream);
        kv.insert("storage".into(), "varint".into());
        assert_eq!(Config::from_kv(&kv).unwrap().storage, StorageKind::Compressed);
        kv.insert("storage".into(), "zip".into());
        let err = Config::from_kv(&kv).unwrap_err().to_string();
        assert!(err.contains("plain|compressed"), "{err}");
        kv.insert("storage".into(), "plain".into());
        kv.insert("ingest".into(), "mmap".into());
        let err = Config::from_kv(&kv).unwrap_err().to_string();
        assert!(err.contains("materialize|stream"), "{err}");
        let d = Config::default();
        assert_eq!(d.storage, StorageKind::Plain);
        assert_eq!(d.ingest, IngestMode::Materialize);
        assert_eq!(IngestMode::parse("materialized"), Some(IngestMode::Materialize));
        assert_eq!(IngestMode::Stream.name(), "stream");
    }

    #[test]
    fn runtime_parses_and_rejects() {
        let mut kv = BTreeMap::new();
        kv.insert("runtime".into(), "threads".into());
        let c = Config::from_kv(&kv).unwrap();
        assert_eq!(c.runtime, RuntimeKind::Threads);
        kv.insert("runtime".into(), "sim".into());
        assert_eq!(Config::from_kv(&kv).unwrap().runtime, RuntimeKind::Sim);
        kv.insert("runtime".into(), "fibers".into());
        let err = Config::from_kv(&kv).unwrap_err().to_string();
        assert!(err.contains("fibers"), "{err}");
        assert_eq!(Config::default().runtime, RuntimeKind::Sim, "sim is the default");
    }

    #[test]
    fn serve_keys_parse_and_reject() {
        let mut kv = BTreeMap::new();
        kv.insert("serve_queries".into(), "250".into());
        kv.insert("serve_landmarks".into(), "4".into());
        kv.insert("serve_cache".into(), "0".into());
        kv.insert("serve_batch".into(), "8".into());
        kv.insert("serve_oracle".into(), "false".into());
        let c = Config::from_kv(&kv).unwrap();
        assert_eq!(c.serve_queries, 250);
        assert_eq!(c.serve_landmarks, 4);
        assert_eq!(c.serve_cache, 0);
        assert_eq!(c.serve_batch, 8);
        assert!(!c.serve_oracle);
        kv.insert("serve_batch".into(), "0".into());
        let err = Config::from_kv(&kv).unwrap_err().to_string();
        assert!(err.contains("serve_batch"), "{err}");
        let d = Config::default();
        assert_eq!(
            (d.serve_queries, d.serve_landmarks, d.serve_cache, d.serve_batch, d.serve_oracle),
            (1000, 8, 32, 16, true)
        );
    }

    #[test]
    fn mutate_keys_parse_and_reject() {
        let mut kv = BTreeMap::new();
        kv.insert("mutate_frac".into(), "0.05".into());
        kv.insert("mutate_inserts".into(), "0.25".into());
        kv.insert("mutate_seed".into(), "99".into());
        let c = Config::from_kv(&kv).unwrap();
        assert_eq!(c.mutate_frac, 0.05);
        assert_eq!(c.mutate_inserts, 0.25);
        assert_eq!(c.effective_mutate_seed(), 99);
        kv.insert("mutate_frac".into(), "1.5".into());
        let err = Config::from_kv(&kv).unwrap_err().to_string();
        assert!(err.contains("mutate_frac"), "{err}");
        kv.insert("mutate_frac".into(), "0.1".into());
        kv.insert("mutate_inserts".into(), "-0.2".into());
        let err = Config::from_kv(&kv).unwrap_err().to_string();
        assert!(err.contains("mutate_inserts"), "{err}");
        let d = Config::default();
        assert_eq!((d.mutate_frac, d.mutate_inserts, d.mutate_seed), (0.01, 0.5, 0));
        assert_eq!(d.effective_mutate_seed(), d.seed + 3, "0 derives from seed");
    }

    #[test]
    fn fault_keys_parse_and_reject() {
        let mut kv = BTreeMap::new();
        kv.insert("fault_drop".into(), "0.05".into());
        kv.insert("fault_dup".into(), "0.02".into());
        kv.insert("fault_delay_us".into(), "12.5".into());
        kv.insert("fault_crash".into(), "1@800".into());
        kv.insert("fault_slow".into(), "2@3.5".into());
        kv.insert("fault_seed".into(), "77".into());
        kv.insert("reliability".into(), "acked".into());
        kv.insert("checkpoint_every".into(), "32".into());
        kv.insert("stall_timeout_us".into(), "5000".into());
        kv.insert("taint_cap".into(), "0.25".into());
        let c = Config::from_kv(&kv).unwrap();
        assert_eq!(c.fault.drop_p, 0.05);
        assert_eq!(c.fault.dup_p, 0.02);
        assert_eq!(c.fault.delay_us, 12.5);
        assert_eq!(c.fault.crash, Some((1, 800.0)));
        assert_eq!(c.fault.slow, Some((2, 3.5)));
        assert_eq!(c.fault.seed, 77);
        assert!(!c.fault.is_none());
        assert_eq!(c.reliability, Reliability::Acked);
        assert_eq!(c.checkpoint_every, 32);
        assert_eq!(c.stall_timeout_us, 5000.0);
        assert_eq!(c.taint_cap, 0.25);

        kv.insert("fault_drop".into(), "1.5".into());
        let err = Config::from_kv(&kv).unwrap_err().to_string();
        assert!(err.contains("fault_drop"), "{err}");
        kv.insert("fault_drop".into(), "0".into());
        kv.insert("fault_crash".into(), "oops".into());
        let err = Config::from_kv(&kv).unwrap_err().to_string();
        assert!(err.contains("fault_crash"), "{err}");
        kv.insert("fault_crash".into(), "0@100".into());
        kv.insert("reliability".into(), "tcp".into());
        let err = Config::from_kv(&kv).unwrap_err().to_string();
        assert!(err.contains("reliability"), "{err}");
        kv.insert("reliability".into(), "none".into());
        kv.insert("taint_cap".into(), "2".into());
        let err = Config::from_kv(&kv).unwrap_err().to_string();
        assert!(err.contains("taint_cap"), "{err}");

        let d = Config::default();
        assert!(d.fault.is_none(), "defaults are fault-free");
        assert_eq!(d.reliability, Reliability::None);
        assert_eq!((d.checkpoint_every, d.stall_timeout_us, d.taint_cap), (0, 0.0, 0.5));
        assert_eq!(d.serve_deadline_us, 0.0);
    }

    #[test]
    fn serve_deadline_parses_and_rejects() {
        let mut kv = BTreeMap::new();
        kv.insert("serve_deadline_us".into(), "2500".into());
        assert_eq!(Config::from_kv(&kv).unwrap().serve_deadline_us, 2500.0);
        kv.insert("serve_deadline_us".into(), "-1".into());
        let err = Config::from_kv(&kv).unwrap_err().to_string();
        assert!(err.contains("serve_deadline_us"), "{err}");
    }

    #[test]
    fn net_keys_parse() {
        let mut kv = BTreeMap::new();
        kv.insert("net.latency_us".into(), "5.5".into());
        kv.insert("net.bandwidth_gbps".into(), "100".into());
        let c = Config::from_kv(&kv).unwrap();
        assert_eq!(c.net.latency_us, 5.5);
        assert_eq!(c.net.bandwidth_bytes_per_us, 100_000.0);
    }

    #[test]
    fn graph_name_follows_gap() {
        let mut c = Config::default();
        c.scale = 25;
        assert_eq!(c.graph_name(), "urand25");
        c.generator = "kron".into();
        c.scale = 16;
        assert_eq!(c.graph_name(), "kron16");
    }
}
