//! Experiment definitions: one function per paper artifact (DESIGN.md §2).
//!
//! Every experiment builds the configured graph, sweeps locality counts,
//! runs each engine `reps` times (keeping the fastest repetition, GAP
//! convention), and reports *modeled* time: per-locality measured compute
//! charged into the discrete-event clock plus the interconnect model.
//! Speedups are normalized to the measured wall time of the fastest
//! sequential implementation, exactly like the paper's Figure 1/2 y-axis.

use std::time::Instant;

use crate::algorithms::{bfs, pagerank, pagerank::PrParams};
use crate::amt::{FlushPolicy, RuntimeKind, SimConfig, SimReport};
use crate::config::Config;
use crate::graph::{Csr, DistGraph, PartitionKind};
use crate::Result;

use super::report::{fmt_us, Table};

/// One measured data point.
#[derive(Debug, Clone)]
pub struct Point {
    /// Engine label ("HPX", "Boost", ...).
    pub engine: String,
    /// Locality count.
    pub p: u32,
    /// Best modeled makespan over reps, us.
    pub makespan_us: f64,
    /// Speedup vs the sequential baseline.
    pub speedup: f64,
    /// Report of the best repetition.
    pub report: SimReport,
}

fn sim_cfg(cfg: &Config, aggregate: bool) -> SimConfig {
    SimConfig {
        net: cfg.net.clone(),
        aggregate_sends: aggregate,
        runtime: cfg.runtime,
        ..SimConfig::default()
    }
}

/// The HPX runtime configuration: per-handler aggregation plus
/// `hpx::plugins::parcel::coalescing` with a small flush window (a
/// cost-model feature; the threaded runtime delivers eagerly instead).
fn hpx_cfg(cfg: &Config) -> SimConfig {
    SimConfig {
        net: cfg.net.clone(),
        aggregate_sends: true,
        coalesce_window_us: 5.0,
        runtime: cfg.runtime,
        ..SimConfig::default()
    }
}

/// Measure the sequential BFS wall time (min over reps), us.
pub fn sequential_bfs_us(g: &Csr, root: u32, reps: u32) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        let parents = bfs::sequential::bfs(g, root);
        std::hint::black_box(&parents);
        best = best.min(t0.elapsed().as_secs_f64() * 1e6);
    }
    best
}

/// Measure the sequential PageRank wall time (min over reps), us.
pub fn sequential_pr_us(g: &Csr, params: PrParams, reps: u32) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        let r = pagerank::sequential::pagerank(g, params);
        std::hint::black_box(&r);
        best = best.min(t0.elapsed().as_secs_f64() * 1e6);
    }
    best
}

/// Figure 1: distributed BFS, HPX (async) vs Boost (BSP level-sync).
pub fn fig1_bfs(cfg: &Config) -> Result<(Table, Vec<Point>)> {
    let g = cfg.build_graph()?;
    let seq_us = sequential_bfs_us(&g, cfg.root, cfg.reps);
    let mut points = Vec::new();
    let mut table = Table::new(
        format!(
            "Figure 1 — distributed BFS on {} (n={}, m={}): speedup vs fastest sequential",
            cfg.graph_name(),
            g.n(),
            g.m()
        ),
        &["nodes", "HPX (async)", "Boost (BSP)", "HPX time", "Boost time", "HPX msgs",
          "Boost msgs", "Boost barriers"],
    );
    for &p in &cfg.localities {
        let dist = DistGraph::build_with(&g, cfg.partition.build(&g, p));
        let mut best: [Option<(f64, SimReport)>; 2] = [None, None];
        for _ in 0..cfg.reps.max(1) {
            // The paper's Figure 1 HPX arm is fine-grained (no app-level
            // combiners); coalescing happens in the runtime's parcelport,
            // which hpx_cfg models. Keep the app level Unbatched so this
            // figure measures what the paper measured.
            let a = bfs::run_async_with(
                &dist,
                cfg.root,
                FlushPolicy::Unbatched,
                hpx_cfg(cfg),
            );
            let b = bfs::run_bsp(&dist, cfg.root, sim_cfg(cfg, false));
            for (slot, res) in [(0, a), (1, b)] {
                let m = res.report.makespan_us;
                if best[slot].as_ref().map(|(t, _)| m < *t).unwrap_or(true) {
                    best[slot] = Some((m, res.report));
                }
            }
        }
        let (at, ar) = best[0].take().unwrap();
        let (bt, br) = best[1].take().unwrap();
        table.row(vec![
            p.to_string(),
            format!("{:.2}x", seq_us / at),
            format!("{:.2}x", seq_us / bt),
            fmt_us(at),
            fmt_us(bt),
            ar.net.messages.to_string(),
            br.net.messages.to_string(),
            br.barriers.to_string(),
        ]);
        points.push(Point {
            engine: "HPX".into(),
            p,
            makespan_us: at,
            speedup: seq_us / at,
            report: ar,
        });
        points.push(Point {
            engine: "Boost".into(),
            p,
            makespan_us: bt,
            speedup: seq_us / bt,
            report: br,
        });
    }
    Ok((table, points))
}

/// Figure 2: distributed PageRank — HPX naive, HPX optimized, Boost (BSP).
pub fn fig2_pagerank(cfg: &Config) -> Result<(Table, Vec<Point>)> {
    let g = cfg.build_graph()?;
    let params = PrParams { alpha: cfg.alpha, iterations: cfg.iterations };
    let seq_us = sequential_pr_us(&g, params, cfg.reps);
    let mut points = Vec::new();
    let mut table = Table::new(
        format!(
            "Figure 2 — distributed PageRank on {} (n={}, m={}, {} iters): \
             speedup vs fastest sequential",
            cfg.graph_name(),
            g.n(),
            g.m(),
            cfg.iterations
        ),
        &["nodes", "HPX naive", "HPX (opt)", "Boost (BSP)", "naive time", "opt time",
          "Boost time", "naive msgs", "opt envs", "Boost envs"],
    );
    let engines: [(&str, Box<dyn Fn(&DistGraph) -> pagerank::PrResult>); 3] = [
        (
            "HPX-naive",
            Box::new({
                let sc = sim_cfg(cfg, false);
                move |d| {
                    pagerank::run_async(d, params, FlushPolicy::Unbatched, sc.clone())
                }
            }),
        ),
        (
            "HPX-opt",
            Box::new({
                let sc = sim_cfg(cfg, false);
                move |d| {
                    // Chunked combiner flushes, each shipped eagerly as its
                    // own parcel (no handler-level re-merge): the overlap
                    // knob that got the paper's prototype close to Boost.
                    pagerank::run_async(d, params, FlushPolicy::Items(1024), sc.clone())
                }
            }),
        ),
        (
            "Boost",
            Box::new({
                let sc = sim_cfg(cfg, false);
                move |d| pagerank::run_bsp(d, params, sc.clone())
            }),
        ),
    ];
    for &p in &cfg.localities {
        let dist = DistGraph::build_with(&g, cfg.partition.build(&g, p));
        let mut best: Vec<Option<(f64, SimReport)>> = vec![None; engines.len()];
        for _ in 0..cfg.reps.max(1) {
            for (i, (_, run)) in engines.iter().enumerate() {
                let res = run(&dist);
                let m = res.report.makespan_us;
                if best[i].as_ref().map(|(t, _)| m < *t).unwrap_or(true) {
                    best[i] = Some((m, res.report));
                }
            }
        }
        let taken: Vec<(f64, SimReport)> = best.into_iter().map(|b| b.unwrap()).collect();
        table.row(vec![
            p.to_string(),
            format!("{:.2}x", seq_us / taken[0].0),
            format!("{:.2}x", seq_us / taken[1].0),
            format!("{:.2}x", seq_us / taken[2].0),
            fmt_us(taken[0].0),
            fmt_us(taken[1].0),
            fmt_us(taken[2].0),
            taken[0].1.net.messages.to_string(),
            taken[1].1.net.envelopes.to_string(),
            taken[2].1.net.envelopes.to_string(),
        ]);
        for ((name, _), (t, r)) in engines.iter().zip(taken) {
            points.push(Point {
                engine: name.to_string(),
                p,
                makespan_us: t,
                speedup: seq_us / t,
                report: r,
            });
        }
    }
    Ok((table, points))
}

/// Ablation A1: message aggregation in asynchronous BFS.
pub fn ablation_aggregation(cfg: &Config) -> Result<Table> {
    let g = cfg.build_graph()?;
    let mut table = Table::new(
        format!("Ablation A1 — async BFS send aggregation on {}", cfg.graph_name()),
        &["nodes", "no-agg time", "agg time", "no-agg envs", "agg envs", "agg factor",
          "agg wall"],
    );
    for &p in &cfg.localities {
        let dist = DistGraph::build_with(&g, cfg.partition.build(&g, p));
        let mut best = [f64::INFINITY; 2];
        let mut reps_report: [Option<SimReport>; 2] = [None, None];
        for _ in 0..cfg.reps.max(1) {
            for (i, agg) in [(0, false), (1, true)] {
                // App-level combiners stay Unbatched in both arms: A1
                // isolates the engine's handler-level send aggregation.
                let r = bfs::run_async_with(
                    &dist,
                    cfg.root,
                    FlushPolicy::Unbatched,
                    sim_cfg(cfg, agg),
                );
                if r.report.makespan_us < best[i] {
                    best[i] = r.report.makespan_us;
                    reps_report[i] = Some(r.report);
                }
            }
        }
        let (r0, r1) = (reps_report[0].take().unwrap(), reps_report[1].take().unwrap());
        table.row(vec![
            p.to_string(),
            fmt_us(best[0]),
            fmt_us(best[1]),
            r0.net.envelopes.to_string(),
            r1.net.envelopes.to_string(),
            format!("{:.1}", r1.net.aggregation_factor()),
            fmt_us(r1.wall_us),
        ]);
    }
    Ok(table)
}

/// The flush-policy grid every aggregation sweep uses.
pub fn flush_policy_grid() -> Vec<(&'static str, FlushPolicy)> {
    vec![
        ("unbatched", FlushPolicy::Unbatched),
        ("items:64", FlushPolicy::Items(64)),
        ("items:1024", FlushPolicy::Items(1024)),
        ("bytes:4096", FlushPolicy::Bytes(4096)),
        ("adaptive", FlushPolicy::Adaptive),
        ("latency", FlushPolicy::LatencyAdaptive),
        ("time:5", FlushPolicy::TimeWindow(5)),
        ("manual", FlushPolicy::Manual),
    ]
}

/// Ablation A4: `amt::aggregate` flush policies on asynchronous PageRank —
/// the naive-vs-aggregated axis as one measurable sweep. Reports envelope
/// counts, the combiner fold factor, modeled time, and L∞ error vs the
/// sequential oracle at the largest locality count ≤ 8 (the paper's
/// mid-scale point; aggregation effects saturate beyond it).
pub fn ablation_flush_policy(cfg: &Config) -> Result<Table> {
    let g = cfg.build_graph()?;
    let params = PrParams { alpha: cfg.alpha, iterations: cfg.iterations };
    let want = pagerank::sequential::pagerank(&g, params);
    let p = cfg.localities.iter().cloned().filter(|&x| x <= 8).max().unwrap_or(8);
    let dist = DistGraph::build_with(&g, cfg.partition.build(&g, p));
    let mut table = Table::new(
        format!(
            "Ablation A4 — async PageRank flush policy on {} ({} localities)",
            cfg.graph_name(),
            p
        ),
        &["policy", "best time", "wall", "envelopes", "wire msgs", "fold factor",
          "Linf vs seq"],
    );
    for (name, policy) in flush_policy_grid() {
        let mut best: Option<SimReport> = None;
        let mut diff = 0.0f32;
        for _ in 0..cfg.reps.max(1) {
            let r = pagerank::run_async(&dist, params, policy, sim_cfg(cfg, false));
            diff = pagerank::max_abs_diff(&r.ranks, &want);
            if best.as_ref().map(|b| r.report.makespan_us < b.makespan_us).unwrap_or(true) {
                best = Some(r.report);
            }
        }
        let b = best.unwrap();
        table.row(vec![
            name.to_string(),
            fmt_us(b.makespan_us),
            fmt_us(b.wall_us),
            b.net.envelopes.to_string(),
            b.net.messages.to_string(),
            format!("{:.1}", b.agg.fold_factor()),
            format!("{diff:.2e}"),
        ]);
    }
    Ok(table)
}

/// Ablation A2: intra-locality executor chunking policies on the PageRank
/// update loop (`adaptive_core_chunk_size`, paper §6).
pub fn ablation_adaptive_chunk(cfg: &Config) -> Result<Table> {
    use crate::amt::executor::{ChunkPolicy, Executor};
    use std::sync::Arc;

    let g = cfg.build_graph()?;
    let params = PrParams { alpha: cfg.alpha, iterations: cfg.iterations };
    let p = *cfg.localities.iter().find(|&&x| x >= 2).unwrap_or(&2);
    let dist = DistGraph::build_with(&g, cfg.partition.build(&g, p));
    let policies: [(&str, ChunkPolicy); 5] = [
        ("sequential", ChunkPolicy::Sequential),
        ("static-256", ChunkPolicy::Static { chunk: 256 }),
        ("static-4096", ChunkPolicy::Static { chunk: 4096 }),
        ("dynamic-256", ChunkPolicy::Dynamic { chunk: 256 }),
        ("adaptive", ChunkPolicy::Adaptive),
    ];
    let mut table = Table::new(
        format!(
            "Ablation A2 — executor chunking on PageRank update ({}, {} localities)",
            cfg.graph_name(),
            p
        ),
        &["policy", "best time", "wall", "mean busy", "imbalance"],
    );
    for (name, policy) in policies {
        let ex = Arc::new(Executor::new(0));
        let mut best: Option<SimReport> = None;
        for _ in 0..cfg.reps.max(1) {
            let r = pagerank::run_bsp_with_executor(
                &dist,
                params,
                sim_cfg(cfg, false),
                if matches!(policy, ChunkPolicy::Sequential) { None } else { Some(ex.clone()) },
                policy,
            );
            if best.as_ref().map(|b| r.report.makespan_us < b.makespan_us).unwrap_or(true) {
                best = Some(r.report);
            }
        }
        let b = best.unwrap();
        table.row(vec![
            name.to_string(),
            fmt_us(b.makespan_us),
            fmt_us(b.wall_us),
            fmt_us(b.mean_busy_us()),
            format!("{:.2}", b.load_imbalance()),
        ]);
    }
    Ok(table)
}

/// Extension benches (§6 coverage): SSSP / CC / triangle across localities.
pub fn extensions(cfg: &Config) -> Result<Table> {
    use crate::algorithms::{cc, sssp, triangle};
    use crate::graph::generators;

    let g = cfg.build_graph()?;
    let gw = generators::with_random_weights(&g, 1.0, 10.0, cfg.seed + 1);
    let delta = if cfg.sssp_delta > 0.0 { cfg.sssp_delta } else { sssp::auto_delta(&gw) };
    anyhow::ensure!(
        cfg.partition != PartitionKind::VertexCut,
        "the extensions sweep includes triangle counting, which needs a mirror-free \
         partition; set partition=block|edge_balanced|hash"
    );
    let mut table = Table::new(
        format!("Extensions — SSSP / CC / triangles on {}", cfg.graph_name()),
        &["nodes", "sssp-async", "sssp-bsp", "sssp-delta", "cc", "triangles"],
    );
    for &p in &cfg.localities {
        let dist = DistGraph::build_with(&g, cfg.partition.build(&g, p));
        // SSSP engines read weights from the shards, so they get their own
        // DistGraph built from the weighted graph.
        let distw = DistGraph::build_with(&gw, cfg.partition.build(&gw, p));
        // Async label-correcting floods fine-grained relaxations; run it
        // under the HPX parcel-coalescing config like the async BFS.
        let s_async = sssp::run_async(&gw, &distw, cfg.root, hpx_cfg(cfg));
        let s_bsp = sssp::run_bsp(&gw, &distw, cfg.root, sim_cfg(cfg, false));
        let s_delta = sssp::run_delta_with(
            &gw,
            &distw,
            cfg.root,
            delta,
            cfg.flush_policy,
            sim_cfg(cfg, false),
        );
        let c = cc::run(&dist, sim_cfg(cfg, false));
        let t = triangle::run(&dist, sim_cfg(cfg, false));
        table.row(vec![
            p.to_string(),
            fmt_us(s_async.report.makespan_us),
            fmt_us(s_bsp.report.makespan_us),
            fmt_us(s_delta.report.makespan_us),
            fmt_us(c.report.makespan_us),
            fmt_us(t.report.makespan_us),
        ]);
    }
    Ok(table)
}

/// Ablation A5: delta-stepping SSSP — Δ sweep × flush policy, with the
/// asynchronous label-correcting and BSP Bellman-Ford engines as reference
/// rows. Δ = ∞ is Bellman-Ford (one bucket, round-synchronous); a tiny Δ
/// approaches Dijkstra's ordering (one distance class per bucket). Reports
/// the [`WorkStats`](crate::amt::WorkStats) relaxation counters so the
/// work-efficiency axis — ordered buckets vs. chaotic label-correcting —
/// is measured directly, plus L∞ error vs the Dijkstra oracle.
pub fn ablation_delta_stepping(cfg: &Config) -> Result<Table> {
    use crate::algorithms::sssp;
    use crate::graph::generators;

    let g = cfg.build_graph()?;
    let gw = generators::with_random_weights(&g, 1.0, 10.0, cfg.seed + 1);
    let p = cfg.localities.iter().cloned().filter(|&x| x <= 8).max().unwrap_or(8);
    let dist = DistGraph::build_with(&gw, cfg.partition.build(&gw, p));
    let want = sssp::dijkstra(&gw, cfg.root);
    let auto = if cfg.sssp_delta > 0.0 { cfg.sssp_delta } else { sssp::auto_delta(&gw) };
    let deltas: Vec<(String, f32)> = vec![
        (format!("{:.3} (Dijkstra-like)", auto / 8.0), auto / 8.0),
        (format!("{auto:.3} (auto)"), auto),
        (format!("{:.3}", auto * 8.0), auto * 8.0),
        ("inf (Bellman-Ford)".into(), f32::INFINITY),
    ];
    let policies = [
        ("unbatched", FlushPolicy::Unbatched),
        ("adaptive", FlushPolicy::Adaptive),
        ("manual", FlushPolicy::Manual),
    ];
    let mut table = Table::new(
        format!(
            "Ablation A5 — delta-stepping SSSP: delta x flush policy on {} ({} localities)",
            cfg.graph_name(),
            p
        ),
        &["engine", "delta", "policy", "best time", "wall", "envelopes", "relax", "useful",
          "efficiency", "Linf vs dijkstra"],
    );
    let linf = |dist: &[f32]| {
        dist.iter()
            .zip(&want)
            .map(|(a, b)| {
                if a.is_infinite() && b.is_infinite() {
                    0.0
                } else {
                    (a - b).abs()
                }
            })
            .fold(0.0f32, f32::max)
    };
    let mut push = |engine: &str, dname: &str, pname: &str, best: &SimReport, err: f32| {
        table.row(vec![
            engine.to_string(),
            dname.to_string(),
            pname.to_string(),
            fmt_us(best.makespan_us),
            fmt_us(best.wall_us),
            best.agg.envelopes.to_string(),
            best.work.relaxations.to_string(),
            best.work.useful_relaxations.to_string(),
            format!("{:.2}", best.work.efficiency()),
            format!("{err:.2e}"),
        ]);
    };
    for (dname, dval) in &deltas {
        for (pname, policy) in policies {
            let mut best: Option<SimReport> = None;
            let mut err = 0.0f32;
            for _ in 0..cfg.reps.max(1) {
                let r = sssp::run_delta_with(
                    &gw,
                    &dist,
                    cfg.root,
                    *dval,
                    policy,
                    sim_cfg(cfg, false),
                );
                if best.as_ref().map(|b| r.report.makespan_us < b.makespan_us).unwrap_or(true) {
                    err = linf(&r.dist);
                    best = Some(r.report);
                }
            }
            push("delta", dname, pname, &best.unwrap(), err);
        }
    }
    // Reference rows: the unordered engines this ablation is judged against.
    let r = sssp::run_async(&gw, &dist, cfg.root, sim_cfg(cfg, false));
    push("async", "-", "adaptive", &r.report, linf(&r.dist));
    let r = sssp::run_bsp(&gw, &dist, cfg.root, sim_cfg(cfg, false));
    push("bsp", "-", "manual", &r.report, linf(&r.dist));
    Ok(table)
}

/// Ablation A6: partition scheme × algorithm. Runs every
/// [`PartitionKind`] against one engine per algorithm family — async BFS,
/// async PageRank, BSP CC, BSP SSSP, and delta SSSP (all scheme-generic
/// since the engine redesign, delta included) — at the largest locality
/// count ≤ 8, validating each result against its sequential oracle and
/// reporting modeled time, envelope counts, and the partition quality
/// columns (vertex/edge imbalance, replication factor). This is the
/// experiment the partition tentpole exists for: on skewed inputs the
/// vertex cut trades replication traffic for the edge balance the 1-D
/// block layout cannot reach — and the `sssp-delta × vertex_cut` row is
/// the combination the engine redesign un-gated.
pub fn ablation_partition_schemes(cfg: &Config) -> Result<Table> {
    use crate::algorithms::{cc, sssp};
    use crate::graph::generators;

    let g = cfg.build_graph()?;
    let gw = generators::with_random_weights(&g, 1.0, 10.0, cfg.seed + 1);
    let delta = if cfg.sssp_delta > 0.0 { cfg.sssp_delta } else { sssp::auto_delta(&gw) };
    let p = cfg.localities.iter().cloned().filter(|&x| x <= 8).max().unwrap_or(8);
    let params = PrParams { alpha: cfg.alpha, iterations: cfg.iterations };
    let pr_want = pagerank::sequential::pagerank(&g, params);
    let bfs_want = bfs::sequential::distances(&g, cfg.root);
    let cc_want = crate::algorithms::cc::union_find(&g);
    let sssp_want = sssp::dijkstra(&gw, cfg.root);
    let mut table = Table::new(
        format!(
            "Ablation A6 — partition scheme x algorithm on {} ({} localities)",
            cfg.graph_name(),
            p
        ),
        &["scheme", "algorithm", "best time", "wall", "envelopes", "v-imb", "e-imb",
          "repl"],
    );
    for kind in PartitionKind::all() {
        let dist = DistGraph::build_with(&g, kind.build(&g, p));
        let distw = DistGraph::build_with(&gw, kind.build(&gw, p));
        let mut rows: Vec<(&str, Option<SimReport>)> = Vec::new();
        for _ in 0..cfg.reps.max(1) {
            let r = bfs::run_async_with(
                &dist,
                cfg.root,
                cfg.flush_policy,
                sim_cfg(cfg, false),
            );
            let lv = bfs::tree_levels(cfg.root, &r.parents);
            anyhow::ensure!(lv == bfs_want, "A6: BFS levels diverge under {}", kind.name());
            keep_best(&mut rows, "bfs-async", r.report);

            let r =
                pagerank::run_async(&dist, params, cfg.flush_policy, sim_cfg(cfg, false));
            let diff = pagerank::max_abs_diff(&r.ranks, &pr_want);
            anyhow::ensure!(diff < 1e-3, "A6: PageRank diverges under {} ({diff})", kind.name());
            keep_best(&mut rows, "pagerank-async", r.report);

            let r = cc::run(&dist, sim_cfg(cfg, false));
            anyhow::ensure!(r.labels == cc_want, "A6: CC labels diverge under {}", kind.name());
            keep_best(&mut rows, "cc-bsp", r.report);

            let sssp_ok = |dist: &[f32]| {
                dist.iter().zip(&sssp_want).all(|(a, b)| {
                    (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-3
                })
            };
            let r = sssp::run_bsp(&gw, &distw, cfg.root, sim_cfg(cfg, false));
            anyhow::ensure!(sssp_ok(&r.dist), "A6: SSSP distances diverge under {}", kind.name());
            keep_best(&mut rows, "sssp-bsp", r.report);

            // The row the engine redesign un-gated: the ordered bucket
            // schedule under every scheme, vertex cut included.
            let r = sssp::run_delta_with(
                &gw,
                &distw,
                cfg.root,
                delta,
                cfg.flush_policy,
                sim_cfg(cfg, false),
            );
            anyhow::ensure!(
                sssp_ok(&r.dist),
                "A6: delta SSSP distances diverge under {}",
                kind.name()
            );
            keep_best(&mut rows, "sssp-delta", r.report);
        }
        for (algo, report) in rows {
            let r = report.unwrap();
            table.row(vec![
                kind.name().to_string(),
                algo.to_string(),
                fmt_us(r.makespan_us),
                fmt_us(r.wall_us),
                r.net.envelopes.to_string(),
                format!("{:.2}", r.partition.vertex_imbalance),
                format!("{:.2}", r.partition.edge_imbalance),
                format!("{:.2}", r.partition.replication_factor),
            ]);
        }
    }
    Ok(table)
}

/// Ablation A7: adaptive coalescing. The tentpole experiment for the
/// latency-observing flush layer: static break-even (`adaptive`) vs the
/// self-tuning `latency` policy vs `time:US` windows, swept over
/// `{sim, threads}` × `{block, vertex_cut}` ×
/// `{bfs-async, pagerank-async, sssp-delta}` at the largest locality
/// count ≤ 8, every run validated against its sequential oracle. The
/// threads rows are the real-queueing validation of the latency-adaptive
/// policy: there the observed latencies are actual inter-thread delivery
/// delays, not the cost model. Reports envelope counts, the combiner fold factor,
/// and the *observed* per-envelope delivery latency split by destination
/// slot space (master-bound vs mirror-bound — the fan-in asymmetry that
/// motivates per-space estimators under vertex cuts), straight from
/// `SimReport.agg_master` / `agg_mirror` with no side channels.
pub fn ablation_adaptive_coalescing(cfg: &Config) -> Result<Table> {
    use crate::algorithms::sssp;
    use crate::graph::generators;

    let g = cfg.build_graph()?;
    let gw = generators::with_random_weights(&g, 1.0, 10.0, cfg.seed + 1);
    let p = cfg.localities.iter().cloned().filter(|&x| x <= 8).max().unwrap_or(8);
    let params = PrParams { alpha: cfg.alpha, iterations: cfg.iterations };
    let delta = if cfg.sssp_delta > 0.0 { cfg.sssp_delta } else { sssp::auto_delta(&gw) };
    let pr_want = pagerank::sequential::pagerank(&g, params);
    let bfs_want = bfs::sequential::distances(&g, cfg.root);
    let sssp_want = sssp::dijkstra(&gw, cfg.root);
    let policies: [(&str, FlushPolicy); 4] = [
        ("adaptive", FlushPolicy::Adaptive),
        ("latency", FlushPolicy::LatencyAdaptive),
        ("time:5", FlushPolicy::TimeWindow(5)),
        ("time:50", FlushPolicy::TimeWindow(50)),
    ];
    let mut table = Table::new(
        format!(
            "Ablation A7 — adaptive coalescing (policy x scheme x algorithm) on {} \
             ({} localities)",
            cfg.graph_name(),
            p
        ),
        &["runtime", "scheme", "algorithm", "policy", "best time", "wall", "envelopes",
          "fold factor", "master-lat-us", "mirror-lat-us"],
    );
    for kind in [PartitionKind::Block, PartitionKind::VertexCut] {
        let dist = DistGraph::build_with(&g, kind.build(&g, p));
        let distw = DistGraph::build_with(&gw, kind.build(&gw, p));
        // Both substrates, whatever the session default: the sim rows give
        // the modeled economics, the threads rows validate the
        // latency-adaptive policy against *real* queueing (observed
        // latencies are actual inter-thread delays there) and fill the
        // wall column with true end-to-end time.
        for rt in [RuntimeKind::Sim, RuntimeKind::Threads] {
            let scfg = SimConfig { runtime: rt, ..sim_cfg(cfg, false) };
            for (pname, policy) in policies {
                let mut rows: Vec<(&str, Option<SimReport>)> = Vec::new();
                for _ in 0..cfg.reps.max(1) {
                    let r = bfs::run_async_with(&dist, cfg.root, policy, scfg.clone());
                    let lv = bfs::tree_levels(cfg.root, &r.parents);
                    anyhow::ensure!(
                        lv == bfs_want,
                        "A7: BFS levels diverge under {} / {} / {pname}",
                        rt.name(),
                        kind.name()
                    );
                    keep_best(&mut rows, "bfs-async", r.report);

                    let r = pagerank::run_async(&dist, params, policy, scfg.clone());
                    let diff = pagerank::max_abs_diff(&r.ranks, &pr_want);
                    anyhow::ensure!(
                        diff < 1e-3,
                        "A7: PageRank diverges under {} / {} / {pname} ({diff})",
                        rt.name(),
                        kind.name()
                    );
                    keep_best(&mut rows, "pagerank-async", r.report);

                    let r = sssp::run_delta_with(
                        &gw,
                        &distw,
                        cfg.root,
                        delta,
                        policy,
                        scfg.clone(),
                    );
                    let ok = r.dist.iter().zip(&sssp_want).all(|(a, b)| {
                        (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-3
                    });
                    anyhow::ensure!(
                        ok,
                        "A7: delta SSSP distances diverge under {} / {} / {pname}",
                        rt.name(),
                        kind.name()
                    );
                    keep_best(&mut rows, "sssp-delta", r.report);
                }
                for (algo, report) in rows {
                    let r = report.unwrap();
                    table.row(vec![
                        rt.name().to_string(),
                        kind.name().to_string(),
                        algo.to_string(),
                        pname.to_string(),
                        fmt_us(r.makespan_us),
                        fmt_us(r.wall_us),
                        r.net.envelopes.to_string(),
                        format!("{:.1}", r.agg.fold_factor()),
                        format!("{:.2}", r.agg_master.mean_obs_latency_us()),
                        format!("{:.2}", r.agg_mirror.mean_obs_latency_us()),
                    ]);
                }
            }
        }
    }
    Ok(table)
}

/// Ablation A8: query-serving throughput. Sweeps the three serving
/// amortizations — landmark oracle {on, off}, hot-source LRU cache
/// {0, configured}, wave width {1, configured} — over `{sim, threads}` at
/// the largest locality count ≤ 8, answering the same generated stream
/// each time and validating every answer set against the sequential
/// Dijkstra oracle (the covered-vs-uncovered parity property: toggling
/// the oracle or cache may only move hits and waves, never answers).
/// Reports the [`QueryStats`](crate::amt::QueryStats) columns: hits,
/// waves, qps, and the real wall-clock latency distribution.
pub fn ablation_query_serving(cfg: &Config) -> Result<Table> {
    use crate::serve;
    use crate::graph::generators;

    anyhow::ensure!(
        cfg.generator != "urand-directed",
        "A8 serves a symmetric metric; generator `urand-directed` is unsupported"
    );
    let g = cfg.build_graph()?;
    let gw = generators::with_symmetric_random_weights(&g, 1.0, 10.0, cfg.seed + 1);
    let p = cfg.localities.iter().cloned().filter(|&x| x <= 8).max().unwrap_or(8);
    let dist = DistGraph::build_with(&gw, cfg.partition.build(&gw, p));
    // The full serve_queries default would dominate the ablation suite's
    // runtime; 256 queries are plenty to separate the knobs.
    let queries = cfg.serve_queries.min(256);
    let mut table = Table::new(
        format!(
            "Ablation A8 — query serving (oracle x cache x batch) on {} ({} localities, \
             {queries} queries)",
            cfg.graph_name(),
            p
        ),
        &["runtime", "oracle", "cache", "batch", "queries", "oracle-hits", "cache-hits",
          "waves", "qps", "p50-us", "p99-us", "wall"],
    );
    for rt in [RuntimeKind::Sim, RuntimeKind::Threads] {
        let scfg = SimConfig { runtime: rt, ..sim_cfg(cfg, cfg.aggregate) };
        for (oracle, cache, batch) in [
            (true, cfg.serve_cache, cfg.serve_batch),
            (false, cfg.serve_cache, cfg.serve_batch),
            (true, 0, cfg.serve_batch),
            (true, cfg.serve_cache, 1),
        ] {
            let params = serve::ServeParams {
                queries,
                landmarks: cfg.serve_landmarks,
                cache,
                batch,
                oracle,
                deadline_us: cfg.serve_deadline_us,
                seed: cfg.seed + 2,
            };
            let res = serve::run(&gw, &dist, &params, cfg.flush_policy, scfg.clone());
            serve::validate(&gw, &res.queries, &res.answers).map_err(|e| {
                anyhow::anyhow!(
                    "A8: answers diverge under {} oracle={oracle} cache={cache} \
                     batch={batch}: {e}",
                    rt.name()
                )
            })?;
            let q = res.report.query;
            table.row(vec![
                rt.name().to_string(),
                oracle.to_string(),
                cache.to_string(),
                batch.to_string(),
                q.queries.to_string(),
                q.oracle_hits.to_string(),
                q.cache_hits.to_string(),
                q.waves.to_string(),
                format!("{:.0}", q.qps),
                format!("{:.1}", q.p50_us),
                format!("{:.1}", q.p99_us),
                fmt_us(res.report.wall_us),
            ]);
        }
    }
    Ok(table)
}

/// Ablation A9: memory-limit scale sweep. Streams kron graphs from
/// `scale = 10` through `16` (`18` behind the CLI's `--large`) straight
/// into shards — the whole-graph CSR is never materialized — under
/// `{plain, compressed}` storage × `{block, vertex_cut}` partitioning,
/// and reports the [`MemStats`](crate::amt::metrics::MemStats) axis
/// (bytes/edge, per-locality peak builder bytes, build time) next to
/// bfs-async / pagerank-bsp / sssp-delta throughput in MTEPS. Every cell
/// runs under both storages and the answers are compared before the rows
/// are emitted: compression may only change bytes, never results.
pub fn ablation_scale_sweep(cfg: &Config, large: bool) -> Result<Table> {
    let scales: &[u32] = if large { &[10, 12, 14, 16, 18] } else { &[10, 12, 14, 16] };
    scale_sweep_over(cfg, scales)
}

/// [`ablation_scale_sweep`] over an explicit scale list (unit tests and
/// benches shrink it to stay fast).
pub fn scale_sweep_over(cfg: &Config, scales: &[u32]) -> Result<Table> {
    use crate::algorithms::sssp;
    use crate::graph::stream::{self, EdgeSource, WeightSpec};
    use crate::graph::StorageKind;

    let p = cfg.localities.iter().cloned().filter(|&x| x <= 8).max().unwrap_or(8);
    let params = PrParams { alpha: cfg.alpha, iterations: cfg.iterations };
    let mut table = Table::new(
        format!(
            "Ablation A9 — memory-limit scale sweep: streamed kron x storage x scheme \
             ({} localities, degree {})",
            p, cfg.degree
        ),
        &["scale", "scheme", "storage", "n", "m", "bytes/edge", "peak-MB", "build-ms",
          "bfs-MTEPS", "pr-MTEPS", "sssp-MTEPS"],
    );
    for &scale in scales {
        let src = EdgeSource::kron(scale, cfg.degree, cfg.seed);
        for kind in [PartitionKind::Block, PartitionKind::VertexCut] {
            // Parity gate: answers from the second (compressed) pass must
            // equal the first (plain) pass bit-for-bit — the deterministic
            // engines see identical logical rows either way.
            let mut baseline: Option<(Vec<i64>, Vec<f32>, Vec<f32>)> = None;
            for storage in [StorageKind::Plain, StorageKind::Compressed] {
                let dist = stream::build_streamed(&src, kind, p, storage, None)?;
                let mem = dist.mem_stats();
                let m = dist.m();
                let b =
                    bfs::run_async_with(&dist, cfg.root, cfg.flush_policy, sim_cfg(cfg, false));
                let pr = pagerank::run_bsp(&dist, params, sim_cfg(cfg, false));
                // SSSP reads weights from the shards: an identically
                // partitioned weighted build (pair-keyed weights, so the
                // draw is stream-order independent).
                let spec = WeightSpec { lo: 1.0, hi: 10.0, seed: cfg.seed + 1 };
                let distw = stream::build_streamed(&src, kind, p, storage, Some(spec))?;
                let delta = if cfg.sssp_delta > 0.0 {
                    cfg.sssp_delta
                } else {
                    sssp::auto_delta_dist(&distw)
                };
                let s = sssp::run_delta_dist_with(
                    &distw,
                    cfg.root,
                    delta,
                    cfg.flush_policy,
                    sim_cfg(cfg, false),
                );
                match &baseline {
                    None => {
                        baseline = Some((b.parents.clone(), pr.ranks.clone(), s.dist.clone()))
                    }
                    Some((bp, pp, sp)) => {
                        anyhow::ensure!(
                            &b.parents == bp,
                            "A9: BFS parents differ plain vs compressed at kron{scale}/{}",
                            kind.name()
                        );
                        anyhow::ensure!(
                            pr.ranks.iter().zip(pp).all(|(a, w)| (a - w).abs() < 1e-6),
                            "A9: PageRank ranks differ plain vs compressed at kron{scale}/{}",
                            kind.name()
                        );
                        anyhow::ensure!(
                            s.dist.iter().zip(sp).all(|(a, w)| {
                                (a.is_infinite() && w.is_infinite()) || (a - w).abs() < 1e-6
                            }),
                            "A9: SSSP distances differ plain vs compressed at kron{scale}/{}",
                            kind.name()
                        );
                    }
                }
                let mteps =
                    |us: f64| if us > 0.0 { format!("{:.2}", m as f64 / us) } else { "-".into() };
                table.row(vec![
                    format!("kron{scale}"),
                    kind.name().to_string(),
                    mem.storage.to_string(),
                    dist.n().to_string(),
                    m.to_string(),
                    format!("{:.2}", mem.bytes_per_edge),
                    format!("{:.1}", mem.peak_builder_bytes as f64 / 1e6),
                    format!("{:.1}", mem.build_ms),
                    mteps(b.report.makespan_us),
                    mteps(pr.report.makespan_us),
                    mteps(s.report.makespan_us),
                ]);
            }
        }
    }
    Ok(table)
}

/// Ablation A10: incremental re-convergence vs full recompute on a
/// dynamic graph. Generates a seeded edge-update batch (half inserts,
/// half deletes) at three sizes — 0.1%, 1%, and 10% of the edge count —
/// applies it through [`DistGraph::apply_updates`]'s scatter path, and
/// re-converges SSSP from the previous fixpoint
/// ([`rerun_incremental`](crate::engine::rerun_incremental)) next to a
/// from-scratch run on a fresh build of the updated graph, under
/// `{block, vertex_cut}` × `{sim, threads}`. Every cell validates both
/// answer sets against the Dijkstra oracle on the updated graph and
/// cross-checks the shard-side applied count; under the deterministic
/// `sim` substrate, batches ≤ 1% must beat the full recompute on *both*
/// relaxations and envelopes — the dynamic-graph claim this table pins
/// (threads rows re-validate answers under real queueing but skip the
/// strict-win gate: arrival order perturbs label-correcting work counts).
pub fn ablation_incremental(cfg: &Config) -> Result<Table> {
    use crate::algorithms::sssp;
    use crate::engine::{run_async, rerun_incremental, Reconverge};
    use crate::graph::{generators, mutation};

    let g = cfg.build_graph()?;
    let gw = generators::with_random_weights(&g, 1.0, 10.0, cfg.seed + 1);
    let p = cfg.localities.iter().cloned().filter(|&x| x <= 8).max().unwrap_or(8);
    let symmetric = cfg.generator != "urand-directed";
    let mut table = Table::new(
        format!(
            "Ablation A10 — incremental re-convergence vs full recompute (SSSP on {}, \
             {} localities)",
            cfg.graph_name(),
            p
        ),
        &["runtime", "scheme", "frac", "applied", "retracted", "tainted", "reseeded",
          "inc-relax", "full-relax", "inc-envs", "full-envs", "inc-time", "full-time"],
    );
    for (i, frac) in [0.001f64, 0.01, 0.1].into_iter().enumerate() {
        let batch = mutation::generate_batch(
            &gw,
            frac,
            0.5,
            cfg.effective_mutate_seed() + i as u64,
            symmetric,
        );
        let (g2w, applied, _) = mutation::apply_to_csr(&gw, &batch);
        let want = sssp::dijkstra(&g2w, cfg.root);
        let check = |label: String, got: &[f32]| -> Result<()> {
            for (v, (a, b)) in got.iter().zip(&want).enumerate() {
                anyhow::ensure!(
                    (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-3,
                    "A10: {label} diverges from the oracle at vertex {v} (frac {frac})"
                );
            }
            Ok(())
        };
        for kind in [PartitionKind::Block, PartitionKind::VertexCut] {
            for rt in [RuntimeKind::Sim, RuntimeKind::Threads] {
                let scfg = SimConfig { runtime: rt, ..sim_cfg(cfg, false) };
                let mut dist = DistGraph::build_with(&gw, kind.build(&gw, p));
                let prog = sssp::SsspProgram { source: cfg.root };
                let base = run_async(prog.clone(), &dist, cfg.flush_policy, scfg.clone());
                let inc = rerun_incremental(
                    prog.clone(),
                    &mut dist,
                    &base.states,
                    &batch,
                    Reconverge::Async(cfg.flush_policy),
                    scfg.clone(),
                );
                let full = run_async(
                    prog,
                    &DistGraph::build_with(&g2w, kind.build(&g2w, p)),
                    cfg.flush_policy,
                    scfg,
                );
                check(format!("incremental {}/{}", rt.name(), kind.name()), &inc.states)?;
                check(format!("full {}/{}", rt.name(), kind.name()), &full.states)?;
                let u = &inc.report.update;
                anyhow::ensure!(
                    u.applied == applied,
                    "A10: shard-side applied {} != oracle {} at frac {frac} on {}",
                    u.applied,
                    applied,
                    kind.name()
                );
                if matches!(rt, RuntimeKind::Sim) && frac <= 0.01 {
                    anyhow::ensure!(
                        u.reconverge_relaxations < full.report.work.relaxations
                            && u.reconverge_envelopes < full.report.net.envelopes,
                        "A10: incremental must strictly beat the full recompute at \
                         frac {frac} on {} (relax {} vs {}, envs {} vs {})",
                        kind.name(),
                        u.reconverge_relaxations,
                        full.report.work.relaxations,
                        u.reconverge_envelopes,
                        full.report.net.envelopes,
                    );
                }
                table.row(vec![
                    rt.name().to_string(),
                    kind.name().to_string(),
                    format!("{}%", frac * 100.0),
                    u.applied.to_string(),
                    u.retracted.to_string(),
                    u.tainted.to_string(),
                    u.reseeded.to_string(),
                    u.reconverge_relaxations.to_string(),
                    full.report.work.relaxations.to_string(),
                    u.reconverge_envelopes.to_string(),
                    full.report.net.envelopes.to_string(),
                    fmt_us(inc.report.makespan_us),
                    fmt_us(full.report.makespan_us),
                ]);
            }
        }
    }
    Ok(table)
}

/// Ablation A11: fault injection × reliability. Sweeps three fault
/// schemes — none (the parity baseline), drop/dup/delay under
/// `reliability=acked`, and drop/dup plus a mid-run fail-stop crash with
/// checkpoint/restart recovery — over `{sim, threads}` ×
/// `{bfs-async, sssp-delta, pagerank-bsp}` at the largest locality count
/// ≤ 8. Every cell validates its answers against the sequential oracle:
/// the robustness claim this table pins is that injected faults cost
/// retransmits, dedups, and recovery time but never correctness. The
/// crash time is calibrated per cell from the fault-free baseline (half
/// its makespan on `sim`, half its wall time on `threads`) so the
/// fail-stop lands mid-run. On the deterministic `sim` substrate the
/// faulty rows must show nonzero injected drops and retransmits, and the
/// crash rows nonzero crashes and restores — injection and recovery
/// actually happened, the run did not just luck into a quiet schedule.
pub fn ablation_fault_injection(cfg: &Config) -> Result<Table> {
    use crate::algorithms::sssp;
    use crate::amt::{FaultPlan, Reliability};
    use crate::graph::generators;

    let g = cfg.build_graph()?;
    let gw = generators::with_random_weights(&g, 1.0, 10.0, cfg.seed + 1);
    let p = cfg.localities.iter().cloned().filter(|&x| (2..=8).contains(&x)).max().unwrap_or(4);
    let params = PrParams { alpha: cfg.alpha, iterations: cfg.iterations };
    let delta = if cfg.sssp_delta > 0.0 { cfg.sssp_delta } else { sssp::auto_delta(&gw) };
    let bfs_want = bfs::sequential::distances(&g, cfg.root);
    let pr_want = pagerank::sequential::pagerank(&g, params);
    let sssp_want = sssp::dijkstra(&gw, cfg.root);
    let dist = DistGraph::build_with(&g, cfg.partition.build(&g, p));
    let distw = DistGraph::build_with(&gw, cfg.partition.build(&gw, p));
    let chaos = FaultPlan {
        drop_p: 0.05,
        dup_p: 0.05,
        delay_us: 5.0,
        crash: None,
        slow: None,
        seed: cfg.seed.wrapping_mul(31).wrapping_add(7),
    };

    let mut table = Table::new(
        format!(
            "Ablation A11 — fault injection x reliability on {} ({} localities)",
            cfg.graph_name(),
            p
        ),
        &["runtime", "algorithm", "faults", "reliability", "time", "wall", "drops", "dups",
          "retransmits", "dedup", "crashes", "restores", "ckpts", "recovery-wall"],
    );
    // Totals over the deterministic sim rows; asserted nonzero below.
    let (mut sim_drops, mut sim_retransmits, mut sim_crashes, mut sim_restores) =
        (0u64, 0u64, 0u64, 0u64);
    for rt in [RuntimeKind::Sim, RuntimeKind::Threads] {
        for algo in ["bfs-async", "sssp-delta", "pagerank-bsp"] {
            let mut baseline_us = 0.0f64;
            for (fname, fault, reliability) in [
                ("none", FaultPlan::none(), Reliability::None),
                ("drop+dup", chaos.clone(), Reliability::Acked),
                ("drop+dup+crash", chaos.clone(), Reliability::Acked),
            ] {
                let mut fault = fault;
                if fname == "drop+dup+crash" {
                    // Fail-stop the last locality halfway through the
                    // fault-free baseline (simulated time on sim,
                    // wall-clock on threads).
                    fault.crash = Some((p - 1, (baseline_us * 0.5).max(1.0)));
                }
                let scfg = SimConfig {
                    runtime: rt,
                    fault,
                    reliability,
                    ..sim_cfg(cfg, false)
                };
                let report = match algo {
                    "bfs-async" => {
                        let r = bfs::run_async_with(&dist, cfg.root, cfg.flush_policy, scfg);
                        let lv = bfs::tree_levels(cfg.root, &r.parents);
                        anyhow::ensure!(
                            lv == bfs_want,
                            "A11: BFS levels diverge under {} / {fname}",
                            rt.name()
                        );
                        r.report
                    }
                    "sssp-delta" => {
                        let r = sssp::run_delta_with(
                            &gw,
                            &distw,
                            cfg.root,
                            delta,
                            cfg.flush_policy,
                            scfg,
                        );
                        let ok = r.dist.iter().zip(&sssp_want).all(|(a, b)| {
                            (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-3
                        });
                        anyhow::ensure!(
                            ok,
                            "A11: delta SSSP diverges under {} / {fname}",
                            rt.name()
                        );
                        r.report
                    }
                    "pagerank-bsp" => {
                        let r = pagerank::run_bsp(&dist, params, scfg);
                        let diff = pagerank::max_abs_diff(&r.ranks, &pr_want);
                        anyhow::ensure!(
                            diff < 1e-3,
                            "A11: PageRank diverges under {} / {fname} ({diff})",
                            rt.name()
                        );
                        r.report
                    }
                    _ => unreachable!(),
                };
                if fname == "none" {
                    baseline_us = if rt == RuntimeKind::Sim {
                        report.makespan_us
                    } else {
                        report.wall_us
                    };
                    anyhow::ensure!(
                        report.fault.is_quiet(),
                        "A11: fault counters moved on the fault-free baseline ({} / {algo})",
                        rt.name()
                    );
                }
                let f = &report.fault;
                if rt == RuntimeKind::Sim {
                    sim_drops += f.injected_drops;
                    sim_retransmits += f.retransmits;
                    sim_crashes += f.crashes;
                    sim_restores += f.restores;
                }
                table.row(vec![
                    rt.name().to_string(),
                    algo.to_string(),
                    fname.to_string(),
                    if reliability.is_acked() { "acked" } else { "none" }.to_string(),
                    fmt_us(report.makespan_us),
                    fmt_us(report.wall_us),
                    f.injected_drops.to_string(),
                    f.injected_dups.to_string(),
                    f.retransmits.to_string(),
                    f.dedup_hits.to_string(),
                    f.crashes.to_string(),
                    f.restores.to_string(),
                    f.checkpoints.to_string(),
                    fmt_us(f.recovery_wall_us),
                ]);
            }
        }
    }
    anyhow::ensure!(
        sim_drops > 0 && sim_retransmits > 0,
        "A11: the sim chaos rows injected no drops ({sim_drops}) or never \
         retransmitted ({sim_retransmits}) — the fault plan is not reaching the wire"
    );
    anyhow::ensure!(
        sim_crashes > 0 && sim_restores > 0,
        "A11: the sim crash rows never crashed ({sim_crashes}) or never restored \
         ({sim_restores}) — the fail-stop is not landing mid-run"
    );
    Ok(table)
}

/// Keep the fastest repetition per labelled row of an A6 sweep.
fn keep_best(
    rows: &mut Vec<(&'static str, Option<SimReport>)>,
    algo: &'static str,
    report: SimReport,
) {
    match rows.iter_mut().find(|(a, _)| *a == algo) {
        Some((_, slot)) => {
            if slot.as_ref().map(|b| report.makespan_us < b.makespan_us).unwrap_or(true) {
                *slot = Some(report);
            }
        }
        None => rows.push((algo, Some(report))),
    }
}
