//! Experiment coordinator: the leader-side driver tying together graph
//! construction, partitioning, the simulated runtime, and result reporting.
//!
//! The CLI (`main.rs`) and the bench binaries (`rust/benches/`) both call
//! into this module, so a paper figure is regenerated identically whether
//! run interactively (`nwgraph-hpx fig1`) or via `cargo bench`.

pub mod experiment;
pub mod report;

use crate::algorithms::{bfs, pagerank, pagerank::PrParams};
use crate::amt::{FlushPolicy, SimConfig};
use crate::config::Config;
use crate::graph::{Csr, DistGraph};
use crate::Result;

pub use experiment::Point;
pub use report::Table;

/// Which engine executes a single-run command.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Asynchronous HPX-style.
    Async,
    /// Naive asynchronous (PageRank only).
    AsyncNaive,
    /// BSP / distributed-BGL baseline.
    Bsp,
    /// Delta-stepping with distributed bucket coordination (SSSP only).
    Delta,
    /// Direction-optimizing BFS.
    DirOpt,
    /// Kernel-offloaded (PageRank only; needs artifacts).
    Kernel,
}

impl Engine {
    /// Parse an `--engine` flag value.
    pub fn parse(s: &str) -> Result<Engine> {
        Ok(match s {
            "async" => Engine::Async,
            "async-naive" => Engine::AsyncNaive,
            "bsp" | "boost" => Engine::Bsp,
            "delta" | "delta-stepping" => Engine::Delta,
            "diropt" => Engine::DirOpt,
            "kernel" => Engine::Kernel,
            other => anyhow::bail!("unknown engine `{other}`"),
        })
    }
}

/// Build the configured partition scheme and shard `g` over `p`
/// localities; rejects scheme/engine combinations that cannot work.
fn build_dist(cfg: &Config, g: &Csr, p: u32, needs_whole_rows: bool) -> Result<DistGraph> {
    let dist = DistGraph::build_with(g, cfg.partition.build(g, p));
    if needs_whole_rows && dist.has_mirrors() {
        anyhow::bail!(
            "partition `{}` produces mirror rows, which this engine cannot expand; \
             use block|edge_balanced|hash",
            cfg.partition.name()
        );
    }
    Ok(dist)
}

/// Run a single distributed BFS with the chosen engine; optionally
/// validates against the sequential oracle.
pub fn run_bfs(cfg: &Config, p: u32, engine: Engine, validate: bool) -> Result<bfs::BfsResult> {
    let g = cfg.build_graph()?;
    let dist = build_dist(cfg, &g, p, engine == Engine::DirOpt)?;
    let sim = SimConfig {
        net: cfg.net.clone(),
        aggregate_sends: cfg.aggregate,
        ..SimConfig::default()
    };
    let res = match engine {
        Engine::Async => bfs::async_hpx::run_with_policy(&dist, cfg.root, cfg.flush_policy, sim),
        Engine::Bsp => bfs::level_sync::run(&dist, cfg.root, sim),
        Engine::DirOpt => bfs::direction_opt::run(&dist, cfg.root, sim),
        other => anyhow::bail!("engine {other:?} does not implement BFS"),
    };
    if validate {
        bfs::validate_parents(&g, cfg.root, &res.parents)
            .map_err(|e| anyhow::anyhow!("BFS validation failed: {e}"))?;
    }
    Ok(res)
}

/// Run a single distributed PageRank with the chosen engine; optionally
/// validates against the sequential oracle.
pub fn run_pagerank(
    cfg: &Config,
    p: u32,
    engine: Engine,
    validate: bool,
) -> Result<pagerank::PrResult> {
    let g = cfg.build_graph()?;
    let dist = build_dist(cfg, &g, p, engine == Engine::Kernel)?;
    let params = PrParams { alpha: cfg.alpha, iterations: cfg.iterations };
    let sim = SimConfig {
        net: cfg.net.clone(),
        aggregate_sends: cfg.aggregate,
        ..SimConfig::default()
    };
    let res = match engine {
        Engine::Async => pagerank::async_hpx::run(&dist, params, cfg.flush_policy, sim),
        Engine::AsyncNaive => {
            pagerank::async_hpx::run(&dist, params, FlushPolicy::Unbatched, sim)
        }
        Engine::Bsp => pagerank::bsp::run(&dist, params, sim),
        Engine::Kernel => {
            let engine = std::sync::Arc::new(std::sync::Mutex::new(
                crate::runtime::Engine::load(&cfg.artifact_dir)?,
            ));
            pagerank::kernel::run(&dist, params, sim, engine)?
        }
        other => anyhow::bail!("engine {other:?} does not implement PageRank"),
    };
    if validate {
        let want = pagerank::sequential::pagerank(&g, params);
        let diff = pagerank::max_abs_diff(&res.ranks, &want);
        anyhow::ensure!(diff < 1e-4, "PageRank validation failed: max |diff| = {diff}");
    }
    Ok(res)
}

/// Run a single distributed SSSP with the chosen engine; optionally
/// validates against the Dijkstra oracle. Config graphs are unweighted, so
/// GAP-style uniform random weights in `[1, 10)` are attached (seeded by
/// `cfg.seed + 1`, like the extensions bench).
pub fn run_sssp(
    cfg: &Config,
    p: u32,
    engine: Engine,
    validate: bool,
) -> Result<crate::algorithms::sssp::SsspResult> {
    use crate::algorithms::sssp;
    use crate::graph::generators;

    let g = cfg.build_graph()?;
    let gw = generators::with_random_weights(&g, 1.0, 10.0, cfg.seed + 1);
    let dist = build_dist(cfg, &gw, p, engine == Engine::Delta)?;
    let sim = SimConfig {
        net: cfg.net.clone(),
        aggregate_sends: cfg.aggregate,
        ..SimConfig::default()
    };
    let res = match engine {
        Engine::Async => sssp::run_async_with(&gw, &dist, cfg.root, cfg.flush_policy, sim),
        Engine::Bsp => sssp::run_bsp(&gw, &dist, cfg.root, sim),
        Engine::Delta => {
            // auto_delta scans every edge weight; only pay for it here.
            let delta =
                if cfg.sssp_delta > 0.0 { cfg.sssp_delta } else { sssp::auto_delta(&gw) };
            sssp::delta::run_with(&gw, &dist, cfg.root, delta, cfg.flush_policy, sim)
        }
        other => anyhow::bail!("engine {other:?} does not implement SSSP"),
    };
    if validate {
        let want = sssp::dijkstra(&gw, cfg.root);
        for (v, (got, exp)) in res.dist.iter().zip(&want).enumerate() {
            let ok = (got.is_infinite() && exp.is_infinite()) || (got - exp).abs() < 1e-3;
            anyhow::ensure!(ok, "SSSP validation failed at vertex {v}: {got} vs {exp}");
        }
    }
    Ok(res)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> Config {
        let mut c = Config::default();
        c.scale = 6;
        c.degree = 4;
        c.iterations = 8;
        c.reps = 1;
        c
    }

    #[test]
    fn engine_parse() {
        assert_eq!(Engine::parse("async").unwrap(), Engine::Async);
        assert_eq!(Engine::parse("boost").unwrap(), Engine::Bsp);
        assert_eq!(Engine::parse("delta").unwrap(), Engine::Delta);
        assert_eq!(Engine::parse("delta-stepping").unwrap(), Engine::Delta);
        assert!(Engine::parse("warp").is_err());
    }

    #[test]
    fn run_bfs_all_engines_validate() {
        let cfg = tiny_cfg();
        for e in [Engine::Async, Engine::Bsp, Engine::DirOpt] {
            run_bfs(&cfg, 3, e, true).unwrap();
        }
    }

    #[test]
    fn run_pagerank_scalar_engines_validate() {
        let mut cfg = tiny_cfg();
        cfg.generator = "urand-directed".into();
        for e in [Engine::Async, Engine::AsyncNaive, Engine::Bsp] {
            run_pagerank(&cfg, 3, e, true).unwrap();
        }
    }

    #[test]
    fn bfs_engine_rejects_kernel() {
        let cfg = tiny_cfg();
        assert!(run_bfs(&cfg, 2, Engine::Kernel, false).is_err());
    }

    #[test]
    fn run_sssp_all_engines_validate() {
        let cfg = tiny_cfg();
        for e in [Engine::Async, Engine::Bsp, Engine::Delta] {
            let res = run_sssp(&cfg, 3, e, true).unwrap();
            assert!(res.report.work.relaxations > 0, "{e:?} counted no relaxations");
        }
    }

    #[test]
    fn run_commands_work_under_every_partition_scheme() {
        use crate::graph::PartitionKind;
        for kind in PartitionKind::all() {
            let mut cfg = tiny_cfg();
            cfg.partition = kind;
            run_bfs(&cfg, 4, Engine::Async, true).unwrap();
            cfg.generator = "urand-directed".into();
            run_pagerank(&cfg, 4, Engine::Bsp, true).unwrap();
            cfg.generator = "urand".into();
            run_sssp(&cfg, 4, Engine::Bsp, true).unwrap();
        }
    }

    #[test]
    fn whole_row_engines_reject_vertex_cut() {
        use crate::graph::PartitionKind;
        let mut cfg = tiny_cfg();
        cfg.generator = "kron".into(); // skewed -> the cut really mirrors
        cfg.partition = PartitionKind::VertexCut;
        assert!(run_bfs(&cfg, 4, Engine::DirOpt, false).is_err());
        assert!(run_sssp(&cfg, 4, Engine::Delta, false).is_err());
    }

    #[test]
    fn run_sssp_honors_explicit_delta() {
        let mut cfg = tiny_cfg();
        cfg.sssp_delta = f32::INFINITY;
        run_sssp(&cfg, 3, Engine::Delta, true).unwrap();
        cfg.sssp_delta = 0.25;
        run_sssp(&cfg, 3, Engine::Delta, true).unwrap();
    }

    #[test]
    fn sssp_engine_rejects_diropt() {
        let cfg = tiny_cfg();
        assert!(run_sssp(&cfg, 2, Engine::DirOpt, false).is_err());
    }
}
