//! Experiment coordinator: the leader-side driver tying together graph
//! construction, partitioning, the simulated runtime, and result
//! reporting. Single-run commands dispatch `program × engine × partition
//! scheme` through the [`engine`](crate::engine) API; unsupported
//! combinations are rejected up front with
//! [`engine::require_mirror_free`](crate::engine::require_mirror_free)'s
//! uniform error.
//!
//! The CLI (`main.rs`) and the bench binaries (`rust/benches/`) both call
//! into this module, so a paper figure is regenerated identically whether
//! run interactively (`nwgraph-hpx fig1`) or via `cargo bench`.

pub mod experiment;
pub mod report;

use crate::algorithms::{bfs, cc, pagerank, pagerank::PrParams};
use crate::amt::{FlushPolicy, SimConfig};
use crate::config::{Config, IngestMode};
use crate::engine::require_mirror_free;
use crate::graph::{stream, Csr, DistGraph};
use crate::Result;

pub use experiment::Point;
pub use report::Table;

/// Which engine executes a single-run command.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Asynchronous HPX-style (generic async engine).
    Async,
    /// Naive asynchronous (PageRank only: `FlushPolicy::Unbatched`).
    AsyncNaive,
    /// BSP / distributed-BGL baseline (generic BSP engine).
    Bsp,
    /// Ordered bucket schedule (SSSP only; scheme-generic since the
    /// engine redesign — vertex cuts included).
    Delta,
    /// Direction-optimizing BFS (specialized; mirror-free schemes only).
    DirOpt,
    /// Kernel-offloaded (PageRank only; needs artifacts and a contiguous
    /// mirror-free scheme).
    Kernel,
    /// Query-serving front-end (`serve` command): landmark oracle +
    /// hot-source cache + batched multi-source SSSP waves on the generic
    /// async engine. Scheme-generic, vertex cuts included.
    Serve,
}

impl Engine {
    /// Parse an `--engine` flag value.
    pub fn parse(s: &str) -> Result<Engine> {
        Ok(match s {
            "async" => Engine::Async,
            "async-naive" => Engine::AsyncNaive,
            "bsp" | "boost" => Engine::Bsp,
            "delta" | "delta-stepping" => Engine::Delta,
            "diropt" => Engine::DirOpt,
            "kernel" => Engine::Kernel,
            "serve" => Engine::Serve,
            other => anyhow::bail!("unknown engine `{other}`"),
        })
    }
}

/// Build the configured partition scheme and shard `g` over `p`
/// localities, with the configured shard storage.
fn build_dist(cfg: &Config, g: &Csr, p: u32) -> DistGraph {
    DistGraph::build_with_storage(g, cfg.partition.build(g, p), cfg.storage)
}

/// Build the distributed graph straight from the configured generator's
/// edge stream (`ingest = stream`): the whole-graph [`Csr`] is never
/// materialized on this path.
fn build_dist_streamed(
    cfg: &Config,
    p: u32,
    weights: Option<stream::WeightSpec>,
) -> Result<DistGraph> {
    let src = stream::EdgeSource::from_generator(&cfg.generator, cfg.scale, cfg.degree, cfg.seed)?;
    stream::build_streamed(&src, cfg.partition, p, cfg.storage, weights)
}

/// Dispatch on [`Config::ingest`] for the unweighted commands: the
/// distributed graph, plus the whole-graph [`Csr`] only when an oracle
/// will need it (always materialized on the classic path; on the
/// streaming path only when `validate` asks for it, at test scale).
fn build_for_run(cfg: &Config, p: u32, validate: bool) -> Result<(Option<Csr>, DistGraph)> {
    match cfg.ingest {
        IngestMode::Materialize => {
            let g = cfg.build_graph()?;
            let dist = build_dist(cfg, &g, p);
            Ok((Some(g), dist))
        }
        IngestMode::Stream => {
            let dist = build_dist_streamed(cfg, p, None)?;
            let g = if validate { Some(cfg.build_graph()?) } else { None };
            Ok((g, dist))
        }
    }
}

fn sim(cfg: &Config) -> SimConfig {
    SimConfig {
        net: cfg.net.clone(),
        aggregate_sends: cfg.aggregate,
        runtime: cfg.runtime,
        fault: cfg.fault.clone(),
        reliability: cfg.reliability,
        checkpoint_every: cfg.checkpoint_every,
        stall_timeout_us: cfg.stall_timeout_us,
        taint_cap: cfg.taint_cap,
        ..SimConfig::default()
    }
}

/// Run a single distributed BFS with the chosen engine; optionally
/// validates against the sequential oracle.
pub fn run_bfs(cfg: &Config, p: u32, engine: Engine, validate: bool) -> Result<bfs::BfsResult> {
    let (g, dist) = build_for_run(cfg, p, validate)?;
    let res = match engine {
        Engine::Async => bfs::run_async_with(&dist, cfg.root, cfg.flush_policy, sim(cfg)),
        Engine::Bsp => bfs::run_bsp(&dist, cfg.root, sim(cfg)),
        Engine::DirOpt => {
            require_mirror_free(&dist, "direction-optimizing BFS")?;
            bfs::direction_opt::run(&dist, cfg.root, sim(cfg))
        }
        other => anyhow::bail!("engine {other:?} does not implement BFS"),
    };
    if let Some(g) = g.filter(|_| validate) {
        bfs::validate_parents(&g, cfg.root, &res.parents)
            .map_err(|e| anyhow::anyhow!("BFS validation failed: {e}"))?;
    }
    Ok(res)
}

/// Run a single distributed PageRank with the chosen engine; optionally
/// validates against the sequential oracle.
pub fn run_pagerank(
    cfg: &Config,
    p: u32,
    engine: Engine,
    validate: bool,
) -> Result<pagerank::PrResult> {
    let (g, dist) = build_for_run(cfg, p, validate)?;
    let params = PrParams { alpha: cfg.alpha, iterations: cfg.iterations };
    let res = match engine {
        Engine::Async => pagerank::run_async(&dist, params, cfg.flush_policy, sim(cfg)),
        Engine::AsyncNaive => {
            pagerank::run_async(&dist, params, FlushPolicy::Unbatched, sim(cfg))
        }
        Engine::Bsp => pagerank::run_bsp(&dist, params, sim(cfg)),
        Engine::Kernel => {
            require_mirror_free(&dist, "kernel-offloaded PageRank")?;
            let engine = std::sync::Arc::new(std::sync::Mutex::new(
                crate::runtime::Engine::load(&cfg.artifact_dir)?,
            ));
            pagerank::kernel::run(&dist, params, sim(cfg), engine)?
        }
        other => anyhow::bail!("engine {other:?} does not implement PageRank"),
    };
    if let Some(g) = g.filter(|_| validate) {
        let want = pagerank::sequential::pagerank(&g, params);
        let diff = pagerank::max_abs_diff(&res.ranks, &want);
        anyhow::ensure!(diff < 1e-4, "PageRank validation failed: max |diff| = {diff}");
    }
    Ok(res)
}

/// Run a single distributed SSSP with the chosen engine; optionally
/// validates against the Dijkstra oracle. Config graphs are unweighted, so
/// GAP-style uniform random weights in `[1, 10)` are attached (seeded by
/// `cfg.seed + 1`, like the extensions bench). Under `ingest = stream`
/// the weights are pair-keyed ([`stream::WeightSpec`]) so the one-pass
/// build draws the same weight for an edge regardless of stream order,
/// and the engines run straight from the shards (`run_*_dist`).
pub fn run_sssp(
    cfg: &Config,
    p: u32,
    engine: Engine,
    validate: bool,
) -> Result<crate::algorithms::sssp::SsspResult> {
    use crate::algorithms::sssp;
    use crate::graph::generators;

    let (gw, dist) = match cfg.ingest {
        IngestMode::Materialize => {
            let g = cfg.build_graph()?;
            let gw = generators::with_random_weights(&g, 1.0, 10.0, cfg.seed + 1);
            let dist = build_dist(cfg, &gw, p);
            (Some(gw), dist)
        }
        IngestMode::Stream => {
            let spec = stream::WeightSpec { lo: 1.0, hi: 10.0, seed: cfg.seed + 1 };
            let dist = build_dist_streamed(cfg, p, Some(spec))?;
            let gw = if validate {
                let g = cfg.build_graph()?;
                Some(generators::with_symmetric_random_weights(&g, 1.0, 10.0, cfg.seed + 1))
            } else {
                None
            };
            (gw, dist)
        }
    };
    let res = match engine {
        Engine::Async => sssp::run_async_dist_with(&dist, cfg.root, cfg.flush_policy, sim(cfg)),
        Engine::Bsp => sssp::run_bsp_dist(&dist, cfg.root, sim(cfg)),
        Engine::Delta => {
            // auto_delta scans every edge weight; only pay for it here.
            let delta =
                if cfg.sssp_delta > 0.0 { cfg.sssp_delta } else { sssp::auto_delta_dist(&dist) };
            sssp::run_delta_dist_with(&dist, cfg.root, delta, cfg.flush_policy, sim(cfg))
        }
        other => anyhow::bail!("engine {other:?} does not implement SSSP"),
    };
    if let Some(gw) = gw.filter(|_| validate) {
        sssp::check_graph_matches(&gw, &dist);
        let want = sssp::dijkstra(&gw, cfg.root);
        for (v, (got, exp)) in res.dist.iter().zip(&want).enumerate() {
            let ok = (got.is_infinite() && exp.is_infinite()) || (got - exp).abs() < 1e-3;
            anyhow::ensure!(ok, "SSSP validation failed at vertex {v}: {got} vs {exp}");
        }
    }
    Ok(res)
}

/// Run a single distributed connected-components pass with the chosen
/// engine; optionally validates against the union-find oracle.
pub fn run_cc(cfg: &Config, p: u32, engine: Engine, validate: bool) -> Result<cc::CcResult> {
    let (g, dist) = build_for_run(cfg, p, validate)?;
    let res = match engine {
        Engine::Async => cc::run_async(&dist, cfg.flush_policy, sim(cfg)),
        Engine::Bsp => cc::run(&dist, sim(cfg)),
        other => anyhow::bail!("engine {other:?} does not implement CC"),
    };
    if let Some(g) = g.filter(|_| validate) {
        let want = cc::union_find(&g);
        anyhow::ensure!(res.labels == want, "CC validation failed: labels diverge");
    }
    Ok(res)
}

/// Run the query-serving front-end: precompute the landmark oracle, then
/// answer the generated `s → t` stream via cache hits, oracle hits, and
/// batched multi-source SSSP waves. Waves run on the generic mirror-aware
/// async engine, so every partition scheme is supported — serve never
/// calls [`require_mirror_free`]. The oracle's triangle bounds need a
/// symmetric metric, so the (undirected) config graph gets pair-keyed
/// symmetric weights and the directed generator is rejected up front.
pub fn run_serve(
    cfg: &Config,
    p: u32,
    engine: Engine,
    validate: bool,
) -> Result<crate::serve::ServeResult> {
    use crate::graph::generators;
    use crate::serve;

    anyhow::ensure!(
        matches!(engine, Engine::Serve | Engine::Async),
        "engine {engine:?} does not implement serve (waves always run on the async engine)"
    );
    anyhow::ensure!(
        cfg.generator != "urand-directed",
        "serve needs a symmetric metric; generator `urand-directed` is unsupported \
         (use urand or kron)"
    );
    anyhow::ensure!(
        cfg.ingest == IngestMode::Materialize,
        "serve requires `ingest = materialize`: the landmark oracle and path \
         recovery precompute against the whole-graph Csr"
    );
    let g = cfg.build_graph()?;
    let gw = generators::with_symmetric_random_weights(&g, 1.0, 10.0, cfg.seed + 1);
    let dist = build_dist(cfg, &gw, p);
    let params = serve::ServeParams {
        queries: cfg.serve_queries,
        landmarks: cfg.serve_landmarks,
        cache: cfg.serve_cache,
        batch: cfg.serve_batch,
        oracle: cfg.serve_oracle,
        deadline_us: cfg.serve_deadline_us,
        seed: cfg.seed + 2,
    };
    let res = serve::run(&gw, &dist, &params, cfg.flush_policy, sim(cfg));
    if validate {
        serve::validate(&gw, &res.queries, &res.answers)
            .map_err(|e| anyhow::anyhow!("serve validation failed: {e}"))?;
    }
    Ok(res)
}

/// Outcome of a `mutate` command run: one incremental re-convergence
/// after a generated [`UpdateBatch`](crate::graph::UpdateBatch), plus a
/// from-scratch recompute on the updated graph for the side-by-side cost
/// comparison. The incremental report carries the batch/routing/taint
/// counters in [`SimReport::update`](crate::amt::SimReport).
#[derive(Debug)]
pub struct MutateResult {
    /// Which algorithm re-converged (`sssp` | `bfs` | `cc` | `pagerank`).
    pub algo: &'static str,
    /// Report of the incremental run (update stats stamped).
    pub report: crate::amt::SimReport,
    /// Report of the full recompute on a fresh build of the updated graph.
    pub full: crate::amt::SimReport,
}

/// Run the dynamic-graph command: converge `algo` on the configured
/// graph, apply a seeded edge-update batch (`mutate_frac`,
/// `mutate_inserts`, `mutate_seed`) through the distributed scatter path,
/// re-converge incrementally from the previous fixpoint, and recompute
/// from scratch for comparison. Monotone programs ride the async engine
/// ([`Reconverge::Async`](crate::engine::Reconverge)); PageRank restarts
/// its fixed-iteration schedule on BSP from the previous rank vector.
/// With `validate`, every answer is checked against the sequential oracle
/// on the *updated* graph, and the shard-side applied count is always
/// cross-checked against the oracle's.
pub fn run_mutate(cfg: &Config, p: u32, algo: &str, validate: bool) -> Result<MutateResult> {
    use crate::algorithms::sssp;
    use crate::engine::{rerun_incremental, run_async, run_bsp, Reconverge};
    use crate::graph::{generators, mutation};

    anyhow::ensure!(
        cfg.ingest == IngestMode::Materialize,
        "mutate requires `ingest = materialize`: batch generation and the \
         full-recompute comparison need the whole-graph Csr"
    );
    // Undirected generators carry every edge in both directions; the batch
    // generator must mutate both or the graph silently loses symmetry.
    let symmetric = cfg.generator != "urand-directed";
    let seed = cfg.effective_mutate_seed();
    let make_batch = |g: &Csr| {
        mutation::generate_batch(g, cfg.mutate_frac, cfg.mutate_inserts, seed, symmetric)
    };
    let check_applied = |report: &crate::amt::SimReport, oracle: u64| -> Result<()> {
        anyhow::ensure!(
            report.update.applied == oracle,
            "mutate: shard-side applied count {} diverges from the oracle's {}",
            report.update.applied,
            oracle
        );
        Ok(())
    };

    match algo {
        "sssp" => {
            let g = cfg.build_graph()?;
            let gw = generators::with_random_weights(&g, 1.0, 10.0, cfg.seed + 1);
            let mut dist = build_dist(cfg, &gw, p);
            let prog = sssp::SsspProgram { source: cfg.root };
            let base = run_async(prog.clone(), &dist, cfg.flush_policy, sim(cfg));
            let batch = make_batch(&gw);
            let (g2, applied, _) = mutation::apply_to_csr(&gw, &batch);
            let run = rerun_incremental(
                prog.clone(),
                &mut dist,
                &base.states,
                &batch,
                Reconverge::Async(cfg.flush_policy),
                sim(cfg),
            );
            check_applied(&run.report, applied)?;
            let full = run_async(prog, &build_dist(cfg, &g2, p), cfg.flush_policy, sim(cfg));
            if validate {
                let want = sssp::dijkstra(&g2, cfg.root);
                for (v, (got, exp)) in run.states.iter().zip(&want).enumerate() {
                    let ok =
                        (got.is_infinite() && exp.is_infinite()) || (got - exp).abs() < 1e-3;
                    anyhow::ensure!(ok, "mutate sssp validation failed at {v}: {got} vs {exp}");
                }
            }
            Ok(MutateResult { algo: "sssp", report: run.report, full: full.report })
        }
        "bfs" => {
            let g = cfg.build_graph()?;
            let mut dist = build_dist(cfg, &g, p);
            let prog = bfs::BfsProgram { root: cfg.root };
            let base = run_async(prog.clone(), &dist, cfg.flush_policy, sim(cfg));
            let batch = make_batch(&g);
            let (g2, applied, _) = mutation::apply_to_csr(&g, &batch);
            let run = rerun_incremental(
                prog.clone(),
                &mut dist,
                &base.states,
                &batch,
                Reconverge::Async(cfg.flush_policy),
                sim(cfg),
            );
            check_applied(&run.report, applied)?;
            let full = run_async(prog, &build_dist(cfg, &g2, p), cfg.flush_policy, sim(cfg));
            if validate {
                let parents: Vec<i64> = run.states.iter().map(|s| s.parent).collect();
                bfs::validate_parents(&g2, cfg.root, &parents)
                    .map_err(|e| anyhow::anyhow!("mutate bfs validation failed: {e}"))?;
            }
            Ok(MutateResult { algo: "bfs", report: run.report, full: full.report })
        }
        "cc" => {
            let g = cfg.build_graph()?;
            let mut dist = build_dist(cfg, &g, p);
            let base = run_async(cc::CcProgram, &dist, cfg.flush_policy, sim(cfg));
            let batch = make_batch(&g);
            let (g2, applied, _) = mutation::apply_to_csr(&g, &batch);
            let run = rerun_incremental(
                cc::CcProgram,
                &mut dist,
                &base.states,
                &batch,
                Reconverge::Async(cfg.flush_policy),
                sim(cfg),
            );
            check_applied(&run.report, applied)?;
            let full =
                run_async(cc::CcProgram, &build_dist(cfg, &g2, p), cfg.flush_policy, sim(cfg));
            if validate {
                let want = cc::union_find(&g2);
                anyhow::ensure!(run.states == want, "mutate cc validation failed: labels diverge");
            }
            Ok(MutateResult { algo: "cc", report: run.report, full: full.report })
        }
        "pagerank" => {
            let g = cfg.build_graph()?;
            let mut dist = build_dist(cfg, &g, p);
            let params = PrParams { alpha: cfg.alpha, iterations: cfg.iterations };
            let prog = pagerank::PrProgram { params, n: g.n() };
            let base = run_bsp(prog.clone(), &dist, sim(cfg));
            let batch = make_batch(&g);
            let (g2, applied, _) = mutation::apply_to_csr(&g, &batch);
            let run = rerun_incremental(
                prog.clone(),
                &mut dist,
                &base.states,
                &batch,
                Reconverge::Bsp,
                sim(cfg),
            );
            check_applied(&run.report, applied)?;
            let full = run_bsp(prog, &build_dist(cfg, &g2, p), sim(cfg));
            if validate {
                // The oracle restarts its power iteration from the same
                // previous ranks, so both sides run `iterations` warm steps.
                let prev: Vec<f32> = base.states.iter().map(|s| s.rank).collect();
                let got: Vec<f32> = run.states.iter().map(|s| s.rank).collect();
                let want = pagerank::sequential::pagerank_warm(&g2, params, &prev);
                let diff = pagerank::max_abs_diff(&got, &want);
                anyhow::ensure!(
                    diff < 1e-4,
                    "mutate pagerank validation failed: max |diff| = {diff}"
                );
            }
            Ok(MutateResult { algo: "pagerank", report: run.report, full: full.report })
        }
        other => anyhow::bail!("mutate does not know algorithm `{other}` (sssp|bfs|cc|pagerank)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> Config {
        let mut c = Config::default();
        c.scale = 6;
        c.degree = 4;
        c.iterations = 8;
        c.reps = 1;
        c
    }

    #[test]
    fn engine_parse() {
        assert_eq!(Engine::parse("async").unwrap(), Engine::Async);
        assert_eq!(Engine::parse("boost").unwrap(), Engine::Bsp);
        assert_eq!(Engine::parse("delta").unwrap(), Engine::Delta);
        assert_eq!(Engine::parse("delta-stepping").unwrap(), Engine::Delta);
        assert_eq!(Engine::parse("serve").unwrap(), Engine::Serve);
        assert!(Engine::parse("warp").is_err());
    }

    #[test]
    fn run_bfs_all_engines_validate() {
        let cfg = tiny_cfg();
        for e in [Engine::Async, Engine::Bsp, Engine::DirOpt] {
            run_bfs(&cfg, 3, e, true).unwrap();
        }
    }

    #[test]
    fn run_pagerank_scalar_engines_validate() {
        let mut cfg = tiny_cfg();
        cfg.generator = "urand-directed".into();
        for e in [Engine::Async, Engine::AsyncNaive, Engine::Bsp] {
            run_pagerank(&cfg, 3, e, true).unwrap();
        }
    }

    #[test]
    fn run_cc_both_engines_validate() {
        let cfg = tiny_cfg();
        for e in [Engine::Async, Engine::Bsp] {
            run_cc(&cfg, 3, e, true).unwrap();
        }
    }

    #[test]
    fn bfs_engine_rejects_kernel() {
        let cfg = tiny_cfg();
        assert!(run_bfs(&cfg, 2, Engine::Kernel, false).is_err());
    }

    #[test]
    fn run_sssp_all_engines_validate() {
        let cfg = tiny_cfg();
        for e in [Engine::Async, Engine::Bsp, Engine::Delta] {
            let res = run_sssp(&cfg, 3, e, true).unwrap();
            assert!(res.report.work.relaxations > 0, "{e:?} counted no relaxations");
        }
    }

    #[test]
    fn run_commands_work_under_every_partition_scheme() {
        use crate::graph::PartitionKind;
        for kind in PartitionKind::all() {
            let mut cfg = tiny_cfg();
            cfg.partition = kind;
            run_bfs(&cfg, 4, Engine::Async, true).unwrap();
            run_cc(&cfg, 4, Engine::Bsp, true).unwrap();
            cfg.generator = "urand-directed".into();
            run_pagerank(&cfg, 4, Engine::Bsp, true).unwrap();
            cfg.generator = "urand".into();
            run_sssp(&cfg, 4, Engine::Bsp, true).unwrap();
            // Previously gated: the delta engine is scheme-generic now.
            run_sssp(&cfg, 4, Engine::Delta, true).unwrap();
        }
    }

    #[test]
    fn whole_row_engines_reject_vertex_cut_uniformly() {
        use crate::graph::PartitionKind;
        let mut cfg = tiny_cfg();
        cfg.generator = "kron".into(); // skewed -> the cut really mirrors
        cfg.partition = PartitionKind::VertexCut;
        let err = run_bfs(&cfg, 4, Engine::DirOpt, false).unwrap_err().to_string();
        assert!(err.contains("direction-optimizing BFS"), "{err}");
        assert!(err.contains("vertex_cut"), "{err}");
        assert!(err.contains("mirror-free"), "{err}");
    }

    fn serve_cfg() -> Config {
        let mut c = tiny_cfg();
        c.serve_queries = 32;
        c.serve_landmarks = 3;
        c.serve_cache = 8;
        c.serve_batch = 4;
        c
    }

    #[test]
    fn run_serve_validates_under_every_partition_scheme() {
        use crate::graph::PartitionKind;
        for kind in PartitionKind::all() {
            let mut cfg = serve_cfg();
            cfg.partition = kind;
            let res = run_serve(&cfg, 4, Engine::Serve, true).unwrap();
            let q = res.report.query;
            assert_eq!(q.queries, 32, "{kind:?}");
            assert!(q.oracle_hits + q.cache_hits > 0, "{kind:?}: {q:?}");
            assert!(q.waves < q.queries, "{kind:?}: {q:?}");
            // Timing-free invariants only; strict latency pins live behind
            // NWGRAPH_STRICT_TIMING=1 (see tests/serve_props.rs).
            assert!(q.qps >= 0.0 && q.p99_us >= q.p50_us, "{kind:?}: {q:?}");
        }
    }

    #[test]
    fn serve_rejects_directed_generator_and_wrong_engine() {
        let mut cfg = serve_cfg();
        let err = run_serve(&cfg, 2, Engine::Bsp, false).unwrap_err().to_string();
        assert!(err.contains("does not implement serve"), "{err}");
        cfg.generator = "urand-directed".into();
        let err = run_serve(&cfg, 2, Engine::Serve, false).unwrap_err().to_string();
        assert!(err.contains("symmetric"), "{err}");
    }

    #[test]
    fn run_commands_validate_under_compressed_storage_and_streaming() {
        use crate::graph::{PartitionKind, StorageKind};
        for kind in [PartitionKind::Block, PartitionKind::VertexCut] {
            for ingest in [IngestMode::Materialize, IngestMode::Stream] {
                let mut cfg = tiny_cfg();
                cfg.generator = "kron".into();
                cfg.partition = kind;
                cfg.storage = StorageKind::Compressed;
                cfg.ingest = ingest;
                run_bfs(&cfg, 4, Engine::Async, true).unwrap();
                run_cc(&cfg, 4, Engine::Bsp, true).unwrap();
                run_pagerank(&cfg, 4, Engine::Bsp, true).unwrap();
                run_sssp(&cfg, 4, Engine::Delta, true).unwrap();
            }
        }
    }

    #[test]
    fn streamed_runs_report_mem_stats() {
        let mut cfg = tiny_cfg();
        cfg.generator = "kron".into();
        cfg.ingest = IngestMode::Stream;
        cfg.storage = crate::graph::StorageKind::Compressed;
        let res = run_bfs(&cfg, 4, Engine::Async, false).unwrap();
        let mem = &res.report.mem;
        assert_eq!(mem.storage, "compressed");
        assert!(mem.total_shard_bytes > 0 && mem.bytes_per_edge > 0.0, "{mem:?}");
        assert!(mem.peak_builder_bytes > 0, "{mem:?}");
    }

    #[test]
    fn serve_rejects_streaming_ingest() {
        let mut cfg = serve_cfg();
        cfg.ingest = IngestMode::Stream;
        let err = run_serve(&cfg, 2, Engine::Serve, false).unwrap_err().to_string();
        assert!(err.contains("materialize"), "{err}");
    }

    #[test]
    fn run_sssp_honors_explicit_delta() {
        let mut cfg = tiny_cfg();
        cfg.sssp_delta = f32::INFINITY;
        run_sssp(&cfg, 3, Engine::Delta, true).unwrap();
        cfg.sssp_delta = 0.25;
        run_sssp(&cfg, 3, Engine::Delta, true).unwrap();
    }

    #[test]
    fn sssp_engine_rejects_diropt() {
        let cfg = tiny_cfg();
        assert!(run_sssp(&cfg, 2, Engine::DirOpt, false).is_err());
    }

    #[test]
    fn run_mutate_validates_every_algorithm() {
        let mut cfg = tiny_cfg();
        cfg.mutate_frac = 0.05;
        for algo in ["sssp", "bfs", "cc", "pagerank"] {
            let res = run_mutate(&cfg, 3, algo, true).unwrap();
            assert_eq!(res.algo, algo);
            let u = &res.report.update;
            assert!(u.batch_edges > 0, "{algo}: empty generated batch");
            assert!(u.applied + u.retracted > 0, "{algo}: batch was all no-ops");
            assert!(res.full.work.relaxations > 0, "{algo}: full recompute did nothing");
        }
    }

    #[test]
    fn run_mutate_works_under_vertex_cut_and_compressed_storage() {
        use crate::graph::{PartitionKind, StorageKind};
        let mut cfg = tiny_cfg();
        cfg.generator = "kron".into();
        cfg.partition = PartitionKind::VertexCut;
        cfg.storage = StorageKind::Compressed;
        cfg.mutate_frac = 0.05;
        run_mutate(&cfg, 4, "sssp", true).unwrap();
        run_mutate(&cfg, 4, "cc", true).unwrap();
    }

    #[test]
    fn ablation_incremental_validates_and_beats_full_recompute() {
        // kron9@8 mirrors the A10 bench shape at test scale: the strict
        // incremental-vs-full gate inside the ablation is the assertion.
        let mut cfg = tiny_cfg();
        cfg.generator = "kron".into();
        cfg.scale = 9;
        cfg.degree = 8;
        cfg.localities = vec![8];
        let table = experiment::ablation_incremental(&cfg).unwrap();
        // 3 fractions x {block, vertex_cut} x {sim, threads}.
        assert_eq!(table.rows.len(), 12);
    }

    #[test]
    fn ablation_fault_injection_validates_and_recovers() {
        // The assertions live inside the ablation: every cell must match
        // its sequential oracle, the sim chaos rows must show injected
        // drops + retransmits, and the sim crash rows crashes + restores.
        let mut cfg = tiny_cfg();
        cfg.generator = "kron".into();
        cfg.scale = 8;
        cfg.degree = 8;
        cfg.localities = vec![4];
        cfg.iterations = 8;
        let table = experiment::ablation_fault_injection(&cfg).unwrap();
        // 2 runtimes x 3 algorithms x 3 fault schemes.
        assert_eq!(table.rows.len(), 18);
    }

    #[test]
    fn run_mutate_rejects_streaming_and_unknown_algo() {
        let mut cfg = tiny_cfg();
        let err = run_mutate(&cfg, 2, "warp", false).unwrap_err().to_string();
        assert!(err.contains("does not know algorithm"), "{err}");
        cfg.ingest = IngestMode::Stream;
        let err = run_mutate(&cfg, 2, "sssp", false).unwrap_err().to_string();
        assert!(err.contains("materialize"), "{err}");
    }
}
