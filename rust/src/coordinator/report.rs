//! Result tables: aligned console output + CSV export.

use crate::Result;

/// A rectangular result table.
#[derive(Debug, Clone)]
pub struct Table {
    /// Table caption (e.g. "Figure 1: urand16").
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Data rows.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New empty table.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row.
    pub fn row(&mut self, cells: Vec<String>) {
        debug_assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    /// Render as an aligned console table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    /// Write CSV to a file.
    pub fn write_csv(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }

    /// Render as machine-readable JSON: `{"title", "headers", "rows"}`
    /// with rows as arrays of objects keyed by header, cell values emitted
    /// as JSON numbers when they parse as one (no serde offline, so the
    /// encoder is hand-rolled with full string escaping).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"title\": {},\n", json_string(&self.title)));
        out.push_str("  \"headers\": [");
        for (i, h) in self.headers.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str(&json_string(h));
        }
        out.push_str("],\n  \"rows\": [\n");
        for (r, row) in self.rows.iter().enumerate() {
            out.push_str("    {");
            for (i, (h, c)) in self.headers.iter().zip(row).enumerate() {
                if i > 0 {
                    out.push_str(", ");
                }
                out.push_str(&format!("{}: {}", json_string(h), json_value(c)));
            }
            out.push_str(if r + 1 < self.rows.len() { "},\n" } else { "}\n" });
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write JSON to a file, creating parent directories as needed.
    pub fn write_json(&self, path: &str) -> Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json())?;
        Ok(())
    }
}

/// JSON-escape a string (quotes, backslashes, control characters).
fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Emit a cell as a bare JSON number when it is one (perf trackers diff
/// these files; `"12"` vs `12` matters), otherwise as an escaped string.
fn json_value(cell: &str) -> String {
    match cell.parse::<f64>() {
        Ok(x) if x.is_finite() => cell.to_string(),
        _ => json_string(cell),
    }
}

/// Format microseconds human-readably (us / ms / s).
pub fn fmt_us(us: f64) -> String {
    if us < 1_000.0 {
        format!("{us:.1}us")
    } else if us < 1_000_000.0 {
        format!("{:.2}ms", us / 1_000.0)
    } else {
        format!("{:.3}s", us / 1_000_000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("demo", &["a", "long_header"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["100".into(), "x".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("long_header"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new("demo", &["x", "y"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "x,y\n1,2\n");
    }

    #[test]
    fn json_escapes_and_types_cells() {
        let mut t = Table::new("demo \"quoted\"", &["policy", "envs", "time"]);
        t.row(vec!["items:64".into(), "120".into(), "1.25ms".into()]);
        let j = t.to_json();
        assert!(j.contains("\"title\": \"demo \\\"quoted\\\"\""), "{j}");
        // Numeric cells are bare numbers; others stay strings.
        assert!(j.contains("\"envs\": 120"), "{j}");
        assert!(j.contains("\"time\": \"1.25ms\""), "{j}");
        assert!(j.contains("\"policy\": \"items:64\""), "{j}");
        // Sanity: balanced braces/brackets.
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn write_json_creates_parent_dirs() {
        let mut t = Table::new("demo", &["x"]);
        t.row(vec!["1".into()]);
        let dir = std::env::temp_dir().join(format!("nwgraph_json_{}", std::process::id()));
        let path = dir.join("nested").join("out.json");
        t.write_json(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"x\": 1"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fmt_us_scales() {
        assert_eq!(fmt_us(500.0), "500.0us");
        assert_eq!(fmt_us(2_500.0), "2.50ms");
        assert_eq!(fmt_us(3_000_000.0), "3.000s");
    }
}
