//! Result tables: aligned console output + CSV export.

use crate::Result;

/// A rectangular result table.
#[derive(Debug, Clone)]
pub struct Table {
    /// Table caption (e.g. "Figure 1: urand16").
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Data rows.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New empty table.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row.
    pub fn row(&mut self, cells: Vec<String>) {
        debug_assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    /// Render as an aligned console table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    /// Write CSV to a file.
    pub fn write_csv(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }
}

/// Format microseconds human-readably (us / ms / s).
pub fn fmt_us(us: f64) -> String {
    if us < 1_000.0 {
        format!("{us:.1}us")
    } else if us < 1_000_000.0 {
        format!("{:.2}ms", us / 1_000.0)
    } else {
        format!("{:.3}s", us / 1_000_000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("demo", &["a", "long_header"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["100".into(), "x".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("long_header"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new("demo", &["x", "y"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "x,y\n1,2\n");
    }

    #[test]
    fn fmt_us_scales() {
        assert_eq!(fmt_us(500.0), "500.0us");
        assert_eq!(fmt_us(2_500.0), "2.50ms");
        assert_eq!(fmt_us(3_000_000.0), "3.000s");
    }
}
