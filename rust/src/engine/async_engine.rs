//! `AsyncEngine` — the asynchronous HPX-style execution loop, once.
//!
//! [`Mode::Converge`] programs run a label-correcting wavefront over the
//! whole local row space (owned *and* ghost rows): messages queue on a
//! priority heap ([`VertexProgram::priority`] order — the per-locality
//! Dijkstra-wavefront trick that keeps unordered label-correcting from
//! re-expanding whole subtrees), a winning application at an owned row
//! scatters the row's signal to its mirrors, a winning application at a
//! ghost row forwards it to the master, and every handler ends with a
//! combiner drain so network quiescence — the engine's exact termination —
//! can never strand buffered traffic. There are **no global barriers**.
//!
//! [`Mode::Iterate`] programs (rank-style) emit every owned row's signal
//! per superstep, apply master-bound messages *on arrival* (communication
//! overlaps the contribution phase — the paper's §4.2 contrast against
//! BSP), expand mirror installs inside the receiving handler so replicated
//! traffic lands in the same superstep, and advance state at the
//! per-iteration barrier.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::sync::Arc;

use crate::amt::aggregate::{Aggregator, FlushPolicy, SlotSpace};
use crate::amt::sim::{Actor, Ctx, LocalityId, SimConfig, SimTime};
use crate::amt::{SimReport, WorkStats};
use crate::graph::{DistGraph, Shard};

use super::checkpoint::Checkpoint;
use super::incremental::{recovery_converge, recovery_iterate};
use super::program::{Mode, VertexProgram};
use super::{
    absorb_recovery, finish, init_states, recovered_states, seed_checkpoint, ship, untag_token,
    EngineMsg, ProgramRun, SPACE_MASTER, SPACE_MIRROR,
};

/// Pending wavefront entry: apply `msg` to `row` when popped. Min-ordered
/// by (priority bits, insertion seq) — deterministic without requiring an
/// order on `Msg` itself.
struct HeapEntry<M> {
    prio: u32,
    seq: u64,
    row: u32,
    msg: M,
}

impl<M> PartialEq for HeapEntry<M> {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl<M> Eq for HeapEntry<M> {}
impl<M> PartialOrd for HeapEntry<M> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<M> Ord for HeapEntry<M> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; reverse for smallest-priority-first.
        other.prio.cmp(&self.prio).then_with(|| other.seq.cmp(&self.seq))
    }
}

struct AsyncActor<P: VertexProgram> {
    prog: Arc<P>,
    shard: Arc<Shard>,
    mode: Mode,
    state: Vec<P::State>,
    /// Master-bound combiner (ghost-row improvements / remote emissions).
    agg: Aggregator<P::Msg>,
    /// Mirror-bound combiner (owned-row signals; idle under 1-D schemes).
    mirror_agg: Aggregator<P::Msg>,
    heap: BinaryHeap<HeapEntry<P::Msg>>,
    seq: u64,
    iter: u32,
    deltas: Vec<f32>,
    work: WorkStats,
    /// The policy is a non-zero `TimeWindow`: handler boundaries poll the
    /// combiners instead of draining them, and a runtime timer is kept
    /// armed at the earliest flush deadline so buffered traffic can never
    /// outlive quiescence (or a superstep barrier).
    windowed: bool,
    /// The combiners need a clock at handler boundaries: time-window
    /// flushes and/or `reliability=acked` retransmit deadlines. Implied
    /// by `windowed`; also true for reliable runs under drain policies.
    clocked: bool,
    /// Earliest outstanding timer deadline (None = no timer armed).
    timer_at: Option<SimTime>,
    /// Crash/restart snapshot store; `None` when neither a crash is
    /// planned nor `checkpoint_every` set (zero overhead).
    ckpt: Option<Checkpoint<P::State>>,
}

impl<P: VertexProgram> AsyncActor<P> {
    fn push(&mut self, row: usize, msg: P::Msg) {
        let prio = self.prog.priority(&msg);
        debug_assert!(prio >= 0.0, "priorities must be non-negative");
        self.heap.push(HeapEntry { prio: prio.to_bits(), seq: self.seq, row: row as u32, msg });
        self.seq += 1;
    }

    /// Queue proposals for `row`'s locally homed edges at its current
    /// state (Converge: the ghost caches double as the send-dedup that
    /// keeps the correcting flood finite).
    fn expand_converge(&mut self, row: usize) {
        let sig = self.prog.signal(&self.state[row]);
        let u = self.shard.global_of(row);
        let shard = Arc::clone(&self.shard);
        for (t, w) in shard.row_edges(row) {
            self.work.relaxations += 1;
            let m = self.prog.along_edge(u, &sig, w);
            if self.prog.beats(&m, &self.state[t as usize]) {
                self.push(t as usize, m);
            }
        }
    }

    /// Emit `row`'s signal along its locally homed edges (Iterate: local
    /// targets apply on the spot, remote targets fold into the
    /// master-bound combiner and ship by policy).
    fn expand_iterate(&mut self, ctx: &mut Ctx<EngineMsg<P::Msg>>, row: usize) {
        let n_owned = self.shard.n_local();
        let sig = self.prog.signal(&self.state[row]);
        let u = self.shard.global_of(row);
        let shard = Arc::clone(&self.shard);
        for (t, w) in shard.row_edges(row) {
            self.work.relaxations += 1;
            let m = self.prog.along_edge(u, &sig, w);
            let t = t as usize;
            if t < n_owned {
                // Iterate applies are unconditional accumulations, not
                // improvements; useful_relaxations stays a Converge metric
                // so work efficiency compares across engines.
                let _ = self.prog.apply(&mut self.state[t], m);
            } else {
                let gi = t - n_owned;
                let dst = shard.ghost_owner[gi];
                let b = self.agg.accumulate(dst, shard.ghost_master_index[gi], m, ctx.now());
                if let Some(b) = b {
                    ship(ctx, dst, b, SPACE_MASTER, EngineMsg::ToMaster);
                }
            }
        }
    }

    /// Drain the wavefront heap: apply pending messages in priority order,
    /// route winning applications (mirror scatter from masters, master
    /// forward from ghosts), and expand improved rows.
    fn relax(&mut self, ctx: &mut Ctx<EngineMsg<P::Msg>>) {
        let n_owned = self.shard.n_local();
        let shard = Arc::clone(&self.shard);
        while let Some(e) = self.heap.pop() {
            let row = e.row as usize;
            if !self.prog.beats(&e.msg, &self.state[row]) {
                continue; // stale: a better value already landed
            }
            self.prog.apply(&mut self.state[row], e.msg);
            let sig = self.prog.signal(&self.state[row]);
            if row < n_owned {
                self.work.useful_relaxations += 1;
                for &(dst, gi) in shard.mirrors(row) {
                    if let Some(b) = self.mirror_agg.accumulate(dst, gi, sig.clone(), ctx.now()) {
                        ship(ctx, dst, b, SPACE_MIRROR, EngineMsg::ToMirror);
                    }
                }
            } else {
                let gi = row - n_owned;
                let dst = shard.ghost_owner[gi];
                let b = self.agg.accumulate(dst, shard.ghost_master_index[gi], sig, ctx.now());
                if let Some(b) = b {
                    ship(ctx, dst, b, SPACE_MASTER, EngineMsg::ToMaster);
                }
            }
            self.expand_converge(row);
        }
    }

    /// Ship everything the policies left buffered (unconditional flush).
    fn drain(&mut self, ctx: &mut Ctx<EngineMsg<P::Msg>>) {
        for (dst, b) in self.agg.drain() {
            ship(ctx, dst, b, SPACE_MASTER, EngineMsg::ToMaster);
        }
        for (dst, b) in self.mirror_agg.drain() {
            ship(ctx, dst, b, SPACE_MIRROR, EngineMsg::ToMirror);
        }
    }

    /// End-of-handler flush point. Non-windowed policies drain everything
    /// (the pre-existing contract: quiescence can never strand traffic).
    /// Under a time window the combiners are only *polled* — expired
    /// destinations ship, the rest keep buffering across handlers — and a
    /// runtime timer is kept armed at the earliest remaining deadline,
    /// which holds quiescence/barriers open until the window flushes.
    /// Reliable runs poll even under drain policies: `poll` is also where
    /// overdue unacked envelopes retransmit, and the armed timer is what
    /// keeps the run alive (not quiesced) until every ack lands or the
    /// retransmit layer gives a destination up.
    fn flush_boundary(&mut self, ctx: &mut Ctx<EngineMsg<P::Msg>>) {
        if !self.windowed {
            self.drain(ctx);
        }
        if self.clocked {
            let now = ctx.now();
            for (dst, b) in self.agg.poll(now) {
                ship(ctx, dst, b, SPACE_MASTER, EngineMsg::ToMaster);
            }
            for (dst, b) in self.mirror_agg.poll(now) {
                ship(ctx, dst, b, SPACE_MIRROR, EngineMsg::ToMirror);
            }
            self.arm_timer(ctx);
        }
    }

    /// Converge checkpoint cadence: one handled event. (Iterate snapshots
    /// at barriers instead — see [`Actor::on_barrier`].)
    fn ckpt_tick(&mut self) {
        let n_owned = self.shard.n_local();
        if let Some(c) = &mut self.ckpt {
            let cursors = self.agg.seq_cursors();
            c.tick(&self.state[..n_owned], 0, cursors);
        }
    }

    /// Keep a timer armed at the earliest pending flush deadline.
    fn arm_timer(&mut self, ctx: &mut Ctx<EngineMsg<P::Msg>>) {
        let next = match (self.agg.next_deadline(), self.mirror_agg.next_deadline()) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        if let Some(t) = next {
            let t = t.max(ctx.now());
            // Redundant later timers are harmless no-op polls; only re-arm
            // when this deadline is earlier than the armed one.
            if self.timer_at.is_none_or(|cur| t < cur) {
                ctx.set_timer(t);
                self.timer_at = Some(t);
            }
        }
    }

    /// One Iterate superstep: every owned row scatters to its mirrors and
    /// emits along its locally homed edges, then the phase drains — a
    /// superstep boundary is a hard flush point under every policy, time
    /// windows included — and waits at the iteration barrier.
    fn iteration_phase(&mut self, ctx: &mut Ctx<EngineMsg<P::Msg>>) {
        let n_owned = self.shard.n_local();
        let shard = Arc::clone(&self.shard);
        for u in 0..n_owned {
            let sig = self.prog.signal(&self.state[u]);
            for &(dst, gi) in shard.mirrors(u) {
                if let Some(b) = self.mirror_agg.accumulate(dst, gi, sig.clone(), ctx.now()) {
                    ship(ctx, dst, b, SPACE_MIRROR, EngineMsg::ToMirror);
                }
            }
            self.expand_iterate(ctx, u);
        }
        self.drain(ctx);
        ctx.request_barrier();
    }
}

impl<P: VertexProgram> Actor for AsyncActor<P> {
    type Msg = EngineMsg<P::Msg>;

    fn on_start(&mut self, ctx: &mut Ctx<Self::Msg>) {
        match self.mode {
            Mode::Converge => {
                for row in 0..self.shard.n_rows() {
                    if let Some(m) = self.prog.seed(self.shard.global_of(row)) {
                        let _ = self.prog.apply(&mut self.state[row], m);
                        self.expand_converge(row);
                    }
                }
                self.relax(ctx);
                self.flush_boundary(ctx);
            }
            Mode::Iterate(n) if n > 0 => self.iteration_phase(ctx),
            Mode::Iterate(_) => {}
        }
    }

    fn on_message(&mut self, ctx: &mut Ctx<Self::Msg>, from: LocalityId, msg: Self::Msg) {
        let n_owned = self.shard.n_local();
        match (msg, self.mode) {
            (EngineMsg::ToMaster(b), Mode::Converge) => {
                // A retransmit the original beat here is a duplicate:
                // reject by sequence, but still run the flush boundary so
                // the retransmit timer stays armed.
                if !self.agg.admit(from, b.seq()) {
                    self.agg.recycle(b.into_items());
                    self.flush_boundary(ctx);
                    return;
                }
                let mut items = b.into_items();
                for (idx, m) in items.drain(..) {
                    self.push(idx as usize, m);
                }
                self.agg.recycle(items);
                self.relax(ctx);
                self.flush_boundary(ctx);
                self.ckpt_tick();
            }
            (EngineMsg::ToMirror(b), Mode::Converge) => {
                if !self.mirror_agg.admit(from, b.seq()) {
                    self.mirror_agg.recycle(b.into_items());
                    self.flush_boundary(ctx);
                    return;
                }
                // The value came *from* the master: install it directly
                // (no echo back) and expand the locally homed edges.
                let mut items = b.into_items();
                for (gi, m) in items.drain(..) {
                    let row = n_owned + gi as usize;
                    if self.prog.apply_mirror(&mut self.state[row], m) {
                        self.expand_converge(row);
                    }
                }
                self.mirror_agg.recycle(items);
                self.relax(ctx);
                self.flush_boundary(ctx);
                self.ckpt_tick();
            }
            (EngineMsg::ToMaster(b), Mode::Iterate(_)) => {
                if !self.agg.admit(from, b.seq()) {
                    self.agg.recycle(b.into_items());
                    return;
                }
                // Applied on arrival — overlap, not at-barrier batching.
                // Iterate folds are *not* idempotent (rank contributions
                // sum), which is exactly why the dedup window above is
                // load-bearing under faults.
                let mut items = b.into_items();
                for (idx, m) in items.drain(..) {
                    let _ = self.prog.apply(&mut self.state[idx as usize], m);
                }
                self.agg.recycle(items);
            }
            (EngineMsg::ToMirror(b), Mode::Iterate(_)) => {
                if !self.mirror_agg.admit(from, b.seq()) {
                    self.mirror_agg.recycle(b.into_items());
                    return;
                }
                // Expand our share of the mirrored rows now; the resulting
                // master-bound traffic must land inside this superstep —
                // directly, or via the armed window timer the iteration
                // barrier waits out.
                let mut items = b.into_items();
                for (gi, m) in items.drain(..) {
                    let row = n_owned + gi as usize;
                    if self.prog.apply_mirror(&mut self.state[row], m) {
                        self.expand_iterate(ctx, row);
                    }
                }
                self.mirror_agg.recycle(items);
                if self.clocked {
                    self.flush_boundary(ctx);
                } else {
                    for (dst, b) in self.agg.drain() {
                        ship(ctx, dst, b, SPACE_MASTER, EngineMsg::ToMaster);
                    }
                }
            }
            _ => unreachable!("control message on the async engine"),
        }
    }

    fn on_ack(
        &mut self,
        _ctx: &mut Ctx<Self::Msg>,
        token: u64,
        sent: SimTime,
        delivered: SimTime,
    ) {
        let (tok, space) = untag_token(token);
        match space {
            SPACE_MASTER => self.agg.observe_ack(tok, sent, delivered),
            SPACE_MIRROR => self.mirror_agg.observe_ack(tok, sent, delivered),
            _ => unreachable!("heavy-space ack on the async engine"),
        }
    }

    fn on_timer(&mut self, ctx: &mut Ctx<Self::Msg>) {
        self.timer_at = None;
        self.flush_boundary(ctx);
    }

    fn on_barrier(&mut self, ctx: &mut Ctx<Self::Msg>, _epoch: u64) {
        if let Mode::Iterate(n) = self.mode {
            let mut delta = 0.0f32;
            for u in 0..self.shard.n_local() {
                delta += self.prog.step_update(&mut self.state[u]);
            }
            self.deltas.push(delta);
            self.iter += 1;
            if let Some(c) = &mut self.ckpt {
                // Iterate state is not monotone: keep the superstep
                // history so recovery can roll every locality back to
                // the crashed locality's epoch.
                let cursors = self.agg.seq_cursors();
                c.epoch_mark(&self.state[..self.shard.n_local()], u64::from(self.iter), cursors);
            }
            if self.iter < n {
                self.iteration_phase(ctx);
            }
        }
    }
}

/// One engine execution, no recovery: build the actors, run them on the
/// configured substrate, merge per-actor accounting. Split out of
/// [`run_async`] so the crash-recovery re-run can reuse it without
/// recursing (the recovery program is a `Warm<P>` wrapper — a recursive
/// driver would monomorphize forever).
fn run_async_core<P: VertexProgram>(
    prog: &Arc<P>,
    dist: &DistGraph,
    policy: FlushPolicy,
    cfg: &SimConfig,
) -> (Vec<AsyncActor<P>>, SimReport) {
    let info = prog.info();
    let reliable = cfg.reliability.is_acked();
    let actors: Vec<AsyncActor<P>> = dist
        .shards
        .iter()
        .map(|s| {
            let state = init_states(&**prog, s);
            let ckpt = seed_checkpoint(cfg, info.mode, s.n_local(), &state);
            AsyncActor {
                prog: Arc::clone(prog),
                shard: Arc::new(s.clone()),
                mode: info.mode,
                state,
                agg: Aggregator::new(
                    dist.owned_counts(),
                    s.locality,
                    SlotSpace::Master,
                    policy,
                    &cfg.net,
                    info.item_bytes,
                    P::combine,
                )
                .with_reliability(reliable),
                mirror_agg: Aggregator::new(
                    dist.ghost_counts(),
                    s.locality,
                    SlotSpace::Mirror,
                    policy,
                    &cfg.net,
                    info.item_bytes,
                    P::combine,
                )
                .with_reliability(reliable),
                heap: BinaryHeap::new(),
                seq: 0,
                iter: 0,
                deltas: Vec::new(),
                work: WorkStats::default(),
                windowed: policy.time_window_us().is_some(),
                clocked: policy.time_window_us().is_some() || reliable,
                timer_at: None,
                ckpt,
            }
        })
        .collect();
    let (actors, mut report) = crate::amt::run_actors(cfg, actors);
    for a in &actors {
        report.agg.merge(a.agg.stats());
        report.agg.merge(a.mirror_agg.stats());
        report.agg_master.merge(a.agg.stats());
        report.agg_mirror.merge(a.mirror_agg.stats());
        report.work.merge(&a.work);
        for (rtx, dedup, gu) in [a.agg.reliability_stats(), a.mirror_agg.reliability_stats()] {
            report.fault.retransmits += rtx;
            report.fault.dedup_hits += dedup;
            report.fault.give_ups += gu;
        }
        if let Some(c) = &a.ckpt {
            report.fault.checkpoints += c.taken();
        }
    }
    report.partition = dist.partition_stats();
    report.mem = dist.mem_stats();
    (actors, report)
}

/// Run `prog` on the asynchronous engine over `dist` with the given
/// combiner flush policy. When the configured fault plan fail-stops a
/// locality mid-run, the engine restores it from its last checkpoint
/// and re-runs warm to the exact answer (see
/// [`checkpoint`](super::checkpoint) for the per-mode recovery story).
pub fn run_async<P: VertexProgram>(
    prog: P,
    dist: &DistGraph,
    policy: FlushPolicy,
    cfg: SimConfig,
) -> ProgramRun<P::State> {
    let prog = Arc::new(prog);
    let (actors, mut report) = run_async_core(&prog, dist, policy, &cfg);
    if let Some((crash_l, _)) = cfg.fault.crash {
        if report.fault.crashes > 0 {
            let mut rcfg = cfg.clone();
            rcfg.fault.crash = None; // the restarted locality does not re-crash
            let parts = || actors.iter().map(|a| (&*a.shard, &a.state[..], a.ckpt.as_ref()));
            match prog.info().mode {
                Mode::Converge => {
                    let recovered = recovered_states(dist, parts(), crash_l, None);
                    let warm = Arc::new(recovery_converge(&prog, recovered));
                    let (ractors, rreport) = run_async_core(&warm, dist, policy, &rcfg);
                    absorb_recovery(&mut report, &rreport);
                    return finish(
                        dist,
                        ractors.iter().map(|a| (&*a.shard, &a.state[..], &a.deltas[..])),
                        report,
                    );
                }
                Mode::Iterate(n) => {
                    // Roll every locality back to the crashed locality's
                    // last completed superstep and replay the tail.
                    let e = actors
                        .iter()
                        .find(|a| a.shard.locality == crash_l)
                        .and_then(|a| a.ckpt.as_ref())
                        .and_then(|c| c.latest())
                        .map_or(0, |s| s.epoch);
                    let recovered = recovered_states(dist, parts(), crash_l, Some(e));
                    let remaining = n.saturating_sub(e as u32);
                    let warm = Arc::new(recovery_iterate(&prog, recovered, remaining));
                    let (ractors, rreport) = run_async_core(&warm, dist, policy, &rcfg);
                    absorb_recovery(&mut report, &rreport);
                    let mut run = finish(
                        dist,
                        ractors.iter().map(|a| (&*a.shard, &a.state[..], &a.deltas[..])),
                        report,
                    );
                    // Supersteps before the rollback epoch happened once,
                    // in the primary run: splice their deltas in front.
                    let mut head = vec![0.0f32; e as usize];
                    for a in &actors {
                        for (i, d) in a.deltas.iter().take(e as usize).enumerate() {
                            head[i] += d;
                        }
                    }
                    head.extend(run.deltas.iter().copied());
                    run.deltas = head;
                    return run;
                }
            }
        }
    }
    finish(
        dist,
        actors.iter().map(|a| (&*a.shard, &a.state[..], &a.deltas[..])),
        report,
    )
}
