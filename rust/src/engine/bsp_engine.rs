//! `BspEngine` — the bulk-synchronous (PBGL/Boost-style) execution loop,
//! once.
//!
//! [`Mode::Converge`] programs run active-set supersteps: every active row
//! emits along its locally homed edges, remote proposals fold into
//! Manual-policy combiners drained once per round (maximal batching — one
//! envelope per destination pair per superstep), and termination is an
//! activity-count reduction at locality 0 (**two global barriers per
//! superstep**: work+count, then verdict — the synchronization cost the
//! asynchronous engine eliminates). Activity accounting is conservative:
//! local improvements, remote proposals, and mirror-scatter batches all
//! count, and improvements applied *at* the barrier carry
//! `pending_activity` into the next round's count so termination can never
//! outrun in-flight scatter.
//!
//! [`Mode::Iterate`] programs run their fixed superstep count with strict
//! BSP semantics: master-bound messages buffer in an inbox and apply at
//! the barrier (no overlap), one barrier per superstep, no control
//! traffic. Mirror installs expand inside the receiving handler — the
//! runtime's barrier waits for network quiescence, so the replicated
//! cascade lands in the same superstep.
//!
//! Mirror handling (vertex cuts): an active owned row scatters its signal
//! to its mirrors when it expands; the receiving mirror installs the value
//! and re-activates its row for the next round (Converge) or expands it
//! immediately (Iterate). 1-D schemes never touch these paths.

use std::sync::Arc;

use crate::amt::aggregate::{Aggregator, FlushPolicy, SlotSpace};
use crate::amt::executor::{ChunkPolicy, Executor};
use crate::amt::sim::{Actor, Ctx, LocalityId, SimConfig, SimTime};
use crate::amt::{SimReport, WorkStats};
use crate::graph::{DistGraph, Shard};

use super::checkpoint::Checkpoint;
use super::incremental::{recovery_converge, recovery_iterate};
use super::program::{Mode, VertexProgram};
use super::{
    absorb_recovery, finish, init_states, recovered_states, seed_checkpoint, ship, untag_token,
    EngineMsg, ProgramRun, SPACE_MASTER, SPACE_MIRROR,
};

#[derive(PartialEq)]
enum Phase {
    AfterWork,
    AwaitDecision,
}

struct BspActor<P: VertexProgram> {
    prog: Arc<P>,
    shard: Arc<Shard>,
    mode: Mode,
    state: Vec<P::State>,
    /// Next-round active rows (local row space: owned and mirror rows).
    active: Vec<u32>,
    in_active: Vec<bool>,
    inbox: Vec<(u32, P::Msg)>,
    counts_seen: u32,
    counts_sum: u64,
    /// Activity earned at the barrier (inbox improvements whose expansion
    /// ships next round), folded into the next Count.
    pending_activity: u64,
    continue_flag: bool,
    phase: Phase,
    /// Master-bound superstep combiner (Manual: drained once per round).
    agg: Aggregator<P::Msg>,
    /// Mirror-bound superstep combiner (Manual).
    mirror_agg: Aggregator<P::Msg>,
    iter: u32,
    deltas: Vec<f32>,
    /// Optional intra-locality executor for the Iterate update loop.
    executor: Option<Arc<Executor>>,
    chunk_policy: ChunkPolicy,
    work: WorkStats,
    /// `reliability=acked`: poll the combiners for retransmit deadlines
    /// at flush points and keep a timer armed (a pending timer holds the
    /// superstep barrier open until every ack lands or a destination is
    /// given up).
    reliable: bool,
    /// A crash is planned this run, so partial termination votes are
    /// expected (the quorum excludes the failed locality).
    crash_armed: bool,
    /// Earliest outstanding timer deadline (None = no timer armed).
    timer_at: Option<SimTime>,
    /// Crash/restart snapshot store (see [`seed_checkpoint`]).
    ckpt: Option<Checkpoint<P::State>>,
}

impl<P: VertexProgram> BspActor<P> {
    fn activate(&mut self, row: usize) {
        if !self.in_active[row] {
            self.in_active[row] = true;
            self.active.push(row as u32);
        }
    }

    /// Apply a master-bound proposal to an owned row; on improvement,
    /// activate it and earn one unit of activity.
    fn apply_owned(&mut self, row: usize, m: P::Msg) -> bool {
        if !self.prog.beats(&m, &self.state[row]) {
            return false;
        }
        self.prog.apply(&mut self.state[row], m);
        self.work.useful_relaxations += 1;
        self.activate(row);
        true
    }

    /// One Converge superstep: expand the active set, drain the combiners,
    /// report activity, and wait at the barrier.
    fn work_round(&mut self, ctx: &mut Ctx<EngineMsg<P::Msg>>) {
        let n_owned = self.shard.n_local();
        let mut activity = self.pending_activity;
        self.pending_activity = 0;
        let active = std::mem::take(&mut self.active);
        let shard = Arc::clone(&self.shard);
        for &row in &active {
            let row = row as usize;
            // Clear the flag at processing time, not round start: a row
            // improved by an earlier row of the same round has not been
            // expanded yet and will read the improved value below, so
            // re-activating it for the next round would be redundant work
            // (and would break the delta engine's Δ=∞ schedule parity —
            // its buckets keep a row queued until it is processed).
            self.in_active[row] = false;
            let sig = self.prog.signal(&self.state[row]);
            let u = shard.global_of(row);
            if row < n_owned {
                for &(dst, gi) in shard.mirrors(row) {
                    // Manual policy: accumulate never auto-flushes.
                    let flushed = self.mirror_agg.accumulate(dst, gi, sig.clone(), ctx.now());
                    debug_assert!(flushed.is_none());
                }
            }
            for (t, w) in shard.row_edges(row) {
                self.work.relaxations += 1;
                let m = self.prog.along_edge(u, &sig, w);
                let t = t as usize;
                if t < n_owned {
                    if self.apply_owned(t, m) {
                        activity += 1;
                    }
                } else {
                    let gi = t - n_owned;
                    let flushed = self.agg.accumulate(
                        shard.ghost_owner[gi],
                        shard.ghost_master_index[gi],
                        m,
                        ctx.now(),
                    );
                    debug_assert!(flushed.is_none());
                    activity += 1;
                }
            }
        }
        for (dst, b) in self.agg.drain() {
            ship(ctx, dst, b, SPACE_MASTER, EngineMsg::ToMaster);
        }
        for (dst, b) in self.mirror_agg.drain() {
            ship(ctx, dst, b, SPACE_MIRROR, EngineMsg::ToMirror);
            // The scatter guarantees the next superstep runs; the mirror's
            // cascade is expanded and counted there.
            activity += 1;
        }
        self.poll_reliable(ctx);
        ctx.send(0, EngineMsg::Count(activity));
        self.phase = Phase::AfterWork;
        ctx.request_barrier();
    }

    /// Reliable-delivery flush point: retransmit overdue unacked
    /// envelopes and keep a timer armed at the earliest deadline. No-op
    /// under `reliability=none` (exact envelope parity).
    fn poll_reliable(&mut self, ctx: &mut Ctx<EngineMsg<P::Msg>>) {
        if !self.reliable {
            return;
        }
        let now = ctx.now();
        for (dst, b) in self.agg.poll(now) {
            ship(ctx, dst, b, SPACE_MASTER, EngineMsg::ToMaster);
        }
        for (dst, b) in self.mirror_agg.poll(now) {
            ship(ctx, dst, b, SPACE_MIRROR, EngineMsg::ToMirror);
        }
        let next = match (self.agg.next_deadline(), self.mirror_agg.next_deadline()) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        if let Some(t) = next {
            let t = t.max(now);
            if self.timer_at.is_none_or(|cur| t < cur) {
                ctx.set_timer(t);
                self.timer_at = Some(t);
            }
        }
    }

    /// Converge checkpoint cadence: one completed superstep.
    fn ckpt_tick(&mut self) {
        let n_owned = self.shard.n_local();
        if let Some(c) = &mut self.ckpt {
            let cursors = self.agg.seq_cursors();
            c.tick(&self.state[..n_owned], 0, cursors);
        }
    }

    /// One Iterate superstep: every owned row scatters to its mirrors and
    /// emits along its locally homed edges; strict BSP, so remote
    /// applications wait for the barrier.
    fn iterate_round(&mut self, ctx: &mut Ctx<EngineMsg<P::Msg>>) {
        let n_owned = self.shard.n_local();
        let shard = Arc::clone(&self.shard);
        for u in 0..n_owned {
            let sig = self.prog.signal(&self.state[u]);
            for &(dst, gi) in shard.mirrors(u) {
                let flushed = self.mirror_agg.accumulate(dst, gi, sig.clone(), ctx.now());
                debug_assert!(flushed.is_none());
            }
            self.emit_row(u, &sig, ctx.now());
        }
        for (dst, b) in self.mirror_agg.drain() {
            ship(ctx, dst, b, SPACE_MIRROR, EngineMsg::ToMirror);
        }
        for (dst, b) in self.agg.drain() {
            ship(ctx, dst, b, SPACE_MASTER, EngineMsg::ToMaster);
        }
        self.poll_reliable(ctx);
        ctx.request_barrier();
    }

    /// Emit one row's signal along its locally homed edges (Iterate: local
    /// targets apply now, remote targets fold into the Manual combiner).
    fn emit_row(&mut self, row: usize, sig: &P::Msg, now: SimTime) {
        let n_owned = self.shard.n_local();
        let u = self.shard.global_of(row);
        let shard = Arc::clone(&self.shard);
        for (t, w) in shard.row_edges(row) {
            self.work.relaxations += 1;
            let m = self.prog.along_edge(u, sig, w);
            let t = t as usize;
            if t < n_owned {
                let _ = self.prog.apply(&mut self.state[t], m);
            } else {
                let gi = t - n_owned;
                let flushed = self.agg.accumulate(
                    shard.ghost_owner[gi],
                    shard.ghost_master_index[gi],
                    m,
                    now,
                );
                debug_assert!(flushed.is_none());
            }
        }
    }

    /// Iterate-mode end-of-superstep update over the owned rows, serial or
    /// through the intra-locality executor (the `adaptive_core_chunk_size`
    /// ablation hooks in here).
    fn step_all(&mut self) -> f32 {
        let n_owned = self.shard.n_local();
        if let Some(ex) = self.executor.clone() {
            use std::sync::atomic::{AtomicU64, Ordering};
            let acc = AtomicU64::new(0f64.to_bits());
            let ptr = SendPtr(self.state.as_mut_ptr());
            let ptr = &ptr;
            let prog = &*self.prog;
            ex.parallel_for(n_owned, self.chunk_policy, |r| {
                let mut local = 0.0f64;
                for v in r {
                    // SAFETY: ranges from parallel_for are disjoint.
                    let s = unsafe { &mut *ptr.get().add(v) };
                    local += prog.step_update(s) as f64;
                }
                // fetch_add for f64 via CAS loop.
                let mut cur = acc.load(Ordering::Relaxed);
                loop {
                    let next = (f64::from_bits(cur) + local).to_bits();
                    match acc.compare_exchange(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                        Ok(_) => break,
                        Err(c) => cur = c,
                    }
                }
            });
            f64::from_bits(acc.load(std::sync::atomic::Ordering::Relaxed)) as f32
        } else {
            let mut d = 0.0f32;
            for v in 0..n_owned {
                d += self.prog.step_update(&mut self.state[v]);
            }
            d
        }
    }
}

struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}
impl<T> SendPtr<T> {
    fn get(&self) -> *mut T {
        self.0
    }
}

impl<P: VertexProgram> Actor for BspActor<P> {
    type Msg = EngineMsg<P::Msg>;

    fn on_start(&mut self, ctx: &mut Ctx<Self::Msg>) {
        match self.mode {
            Mode::Converge => {
                for row in 0..self.shard.n_rows() {
                    if let Some(m) = self.prog.seed(self.shard.global_of(row)) {
                        let _ = self.prog.apply(&mut self.state[row], m);
                        self.activate(row);
                    }
                }
                self.work_round(ctx);
            }
            Mode::Iterate(n) if n > 0 => self.iterate_round(ctx),
            Mode::Iterate(_) => {}
        }
    }

    fn on_message(&mut self, ctx: &mut Ctx<Self::Msg>, from: LocalityId, msg: Self::Msg) {
        let n_owned = self.shard.n_local();
        match msg {
            EngineMsg::ToMaster(b) => {
                // Reject retransmit duplicates by sequence: BSP inboxes
                // apply unconditionally at the barrier, so a duplicated
                // batch would double-fold (fatal for Iterate sums).
                if !self.agg.admit(from, b.seq()) {
                    self.agg.recycle(b.into_items());
                    return;
                }
                let mut items = b.into_items();
                self.inbox.append(&mut items);
                self.agg.recycle(items);
            }
            EngineMsg::ToMirror(b) => match self.mode {
                Mode::Converge => {
                    if !self.mirror_agg.admit(from, b.seq()) {
                        self.mirror_agg.recycle(b.into_items());
                        return;
                    }
                    // Install and re-activate: the mirror's share of the
                    // row expands next superstep (the sender counted the
                    // scatter, so that superstep is guaranteed to run).
                    let mut items = b.into_items();
                    for (gi, m) in items.drain(..) {
                        let row = n_owned + gi as usize;
                        if self.prog.apply_mirror(&mut self.state[row], m) {
                            self.activate(row);
                        }
                    }
                    self.mirror_agg.recycle(items);
                }
                Mode::Iterate(_) => {
                    if !self.mirror_agg.admit(from, b.seq()) {
                        self.mirror_agg.recycle(b.into_items());
                        return;
                    }
                    // Expand inside the handler so the replicated traffic
                    // lands in this superstep (the barrier waits for
                    // network quiescence).
                    let mut items = b.into_items();
                    for (gi, m) in items.drain(..) {
                        let row = n_owned + gi as usize;
                        if self.prog.apply_mirror(&mut self.state[row], m) {
                            let sig = self.prog.signal(&self.state[row]);
                            self.emit_row(row, &sig, ctx.now());
                        }
                    }
                    self.mirror_agg.recycle(items);
                    for (dst, b) in self.agg.drain() {
                        ship(ctx, dst, b, SPACE_MASTER, EngineMsg::ToMaster);
                    }
                    self.poll_reliable(ctx);
                }
            },
            EngineMsg::Count(c) => {
                self.counts_seen += 1;
                self.counts_sum += c;
            }
            EngineMsg::Continue(go) => self.continue_flag = go,
            _ => unreachable!("delta control message on the BSP engine"),
        }
    }

    fn on_barrier(&mut self, ctx: &mut Ctx<Self::Msg>, _epoch: u64) {
        match self.mode {
            Mode::Converge => match self.phase {
                Phase::AfterWork => {
                    let inbox = std::mem::take(&mut self.inbox);
                    for (idx, m) in inbox {
                        if self.apply_owned(idx as usize, m) {
                            // Expansion ships with the next round's drain;
                            // keep the run alive until it lands.
                            self.pending_activity += 1;
                        }
                    }
                    self.ckpt_tick();
                    if ctx.locality() == 0 {
                        // A crashed locality's vote never arrives (the
                        // runtime's barrier quorum excludes it), so the
                        // exact-count invariant only holds fault-free.
                        debug_assert!(
                            self.crash_armed || self.counts_seen == ctx.n_localities(),
                            "missing termination votes without a crash"
                        );
                        let go = self.counts_sum > 0;
                        self.counts_sum = 0;
                        self.counts_seen = 0;
                        for l in 0..ctx.n_localities() {
                            ctx.send(l, EngineMsg::Continue(go));
                        }
                    }
                    self.phase = Phase::AwaitDecision;
                    ctx.request_barrier();
                }
                Phase::AwaitDecision => {
                    // Uniform verdict: every activation was backed by a
                    // counted activity, so `go` is true whenever anyone
                    // still holds active rows or pending scatter.
                    if self.continue_flag {
                        self.work_round(ctx);
                    }
                }
            },
            Mode::Iterate(n) => {
                let inbox = std::mem::take(&mut self.inbox);
                for (idx, m) in inbox {
                    let _ = self.prog.apply(&mut self.state[idx as usize], m);
                }
                let delta = self.step_all();
                self.deltas.push(delta);
                self.iter += 1;
                if let Some(c) = &mut self.ckpt {
                    let cursors = self.agg.seq_cursors();
                    c.epoch_mark(&self.state[..self.shard.n_local()], u64::from(self.iter), cursors);
                }
                if self.iter < n {
                    self.iterate_round(ctx);
                }
            }
        }
    }

    fn on_ack(
        &mut self,
        _ctx: &mut Ctx<Self::Msg>,
        token: u64,
        sent: SimTime,
        delivered: SimTime,
    ) {
        let (tok, space) = untag_token(token);
        match space {
            SPACE_MASTER => self.agg.observe_ack(tok, sent, delivered),
            SPACE_MIRROR => self.mirror_agg.observe_ack(tok, sent, delivered),
            _ => unreachable!("heavy-space ack on the BSP engine"),
        }
    }

    fn on_timer(&mut self, ctx: &mut Ctx<Self::Msg>) {
        self.timer_at = None;
        self.poll_reliable(ctx);
    }
}

/// Run `prog` on the BSP engine over `dist` (serial update loop).
pub fn run_bsp<P: VertexProgram>(
    prog: P,
    dist: &DistGraph,
    cfg: SimConfig,
) -> ProgramRun<P::State> {
    run_bsp_with_executor(prog, dist, cfg, None, ChunkPolicy::Sequential)
}

/// One BSP execution, no recovery (see
/// [`run_async_core`](super::async_engine)'s note on why recovery cannot
/// recurse through the public driver).
fn run_bsp_core<P: VertexProgram>(
    prog: &Arc<P>,
    dist: &DistGraph,
    cfg: &SimConfig,
    executor: &Option<Arc<Executor>>,
    chunk_policy: ChunkPolicy,
) -> (Vec<BspActor<P>>, SimReport) {
    let info = prog.info();
    let reliable = cfg.reliability.is_acked();
    let actors: Vec<BspActor<P>> = dist
        .shards
        .iter()
        .map(|s| {
            let state = init_states(&**prog, s);
            let ckpt = seed_checkpoint(cfg, info.mode, s.n_local(), &state);
            BspActor {
                prog: Arc::clone(prog),
                shard: Arc::new(s.clone()),
                mode: info.mode,
                state,
                active: Vec::new(),
                in_active: vec![false; s.n_rows()],
                inbox: Vec::new(),
                counts_seen: 0,
                counts_sum: 0,
                pending_activity: 0,
                continue_flag: false,
                phase: Phase::AfterWork,
                agg: Aggregator::new(
                    dist.owned_counts(),
                    s.locality,
                    SlotSpace::Master,
                    FlushPolicy::Manual,
                    &cfg.net,
                    info.item_bytes,
                    P::combine,
                )
                .with_reliability(reliable),
                mirror_agg: Aggregator::new(
                    dist.ghost_counts(),
                    s.locality,
                    SlotSpace::Mirror,
                    FlushPolicy::Manual,
                    &cfg.net,
                    info.item_bytes,
                    P::combine,
                )
                .with_reliability(reliable),
                iter: 0,
                deltas: Vec::new(),
                executor: executor.clone(),
                chunk_policy,
                work: WorkStats::default(),
                reliable,
                crash_armed: cfg.fault.crash.is_some(),
                timer_at: None,
                ckpt,
            }
        })
        .collect();
    let (actors, mut report) = crate::amt::run_actors(cfg, actors);
    for a in &actors {
        report.agg.merge(a.agg.stats());
        report.agg.merge(a.mirror_agg.stats());
        report.agg_master.merge(a.agg.stats());
        report.agg_mirror.merge(a.mirror_agg.stats());
        report.work.merge(&a.work);
        for (rtx, dedup, gu) in [a.agg.reliability_stats(), a.mirror_agg.reliability_stats()] {
            report.fault.retransmits += rtx;
            report.fault.dedup_hits += dedup;
            report.fault.give_ups += gu;
        }
        if let Some(c) = &a.ckpt {
            report.fault.checkpoints += c.taken();
        }
    }
    report.partition = dist.partition_stats();
    report.mem = dist.mem_stats();
    (actors, report)
}

/// Run `prog` on the BSP engine with an intra-locality executor for the
/// Iterate-mode update loop. When the configured fault plan fail-stops a
/// locality mid-run, the engine restores it from its last checkpoint and
/// re-runs warm (see [`checkpoint`](super::checkpoint)).
pub fn run_bsp_with_executor<P: VertexProgram>(
    prog: P,
    dist: &DistGraph,
    cfg: SimConfig,
    executor: Option<Arc<Executor>>,
    chunk_policy: ChunkPolicy,
) -> ProgramRun<P::State> {
    let prog = Arc::new(prog);
    let (actors, mut report) = run_bsp_core(&prog, dist, &cfg, &executor, chunk_policy);
    if let Some((crash_l, _)) = cfg.fault.crash {
        if report.fault.crashes > 0 {
            let mut rcfg = cfg.clone();
            rcfg.fault.crash = None; // the restarted locality does not re-crash
            let parts = || actors.iter().map(|a| (&*a.shard, &a.state[..], a.ckpt.as_ref()));
            match prog.info().mode {
                Mode::Converge => {
                    let recovered = recovered_states(dist, parts(), crash_l, None);
                    let warm = Arc::new(recovery_converge(&prog, recovered));
                    let (ractors, rreport) =
                        run_bsp_core(&warm, dist, &rcfg, &executor, chunk_policy);
                    absorb_recovery(&mut report, &rreport);
                    return finish(
                        dist,
                        ractors.iter().map(|a| (&*a.shard, &a.state[..], &a.deltas[..])),
                        report,
                    );
                }
                Mode::Iterate(n) => {
                    let e = actors
                        .iter()
                        .find(|a| a.shard.locality == crash_l)
                        .and_then(|a| a.ckpt.as_ref())
                        .and_then(|c| c.latest())
                        .map_or(0, |s| s.epoch);
                    let recovered = recovered_states(dist, parts(), crash_l, Some(e));
                    let remaining = n.saturating_sub(e as u32);
                    let warm = Arc::new(recovery_iterate(&prog, recovered, remaining));
                    let (ractors, rreport) =
                        run_bsp_core(&warm, dist, &rcfg, &executor, chunk_policy);
                    absorb_recovery(&mut report, &rreport);
                    let mut run = finish(
                        dist,
                        ractors.iter().map(|a| (&*a.shard, &a.state[..], &a.deltas[..])),
                        report,
                    );
                    let mut head = vec![0.0f32; e as usize];
                    for a in &actors {
                        for (i, d) in a.deltas.iter().take(e as usize).enumerate() {
                            head[i] += d;
                        }
                    }
                    head.extend(run.deltas.iter().copied());
                    run.deltas = head;
                    return run;
                }
            }
        }
    }
    finish(
        dist,
        actors.iter().map(|a| (&*a.shard, &a.state[..], &a.deltas[..])),
        report,
    )
}
