//! Per-locality checkpointing for crash/restart recovery.
//!
//! Each engine actor owns one [`Checkpoint`] when a crash is planned (or
//! `checkpoint_every` is set) and snapshots its **owned** rows so a
//! fail-stopped locality can be restored without recomputing the world:
//!
//! * **[`Mode::Converge`](super::Mode) engines** snapshot on an
//!   event-count cadence ([`Checkpoint::tick`]): every `checkpoint_every`
//!   handled events the latest consistent owned-row vector replaces the
//!   previous snapshot (plus one seed snapshot at `on_start`, so a
//!   crash before the first cadence tick still restores to the initial
//!   states). Label-correcting programs are monotone, so *any* achieved
//!   state vector is a valid restart point — re-seeding the frontier
//!   from it re-floods forward to the exact fixpoint.
//! * **[`Mode::Iterate`](super::Mode) engines** snapshot at superstep
//!   boundaries ([`Checkpoint::epoch_mark`]) and keep the history:
//!   value-iteration state is *not* monotone, so recovery rolls every
//!   locality back to the crashed locality's last epoch and replays the
//!   remaining supersteps ([`Checkpoint::at_or_before`]).
//!
//! Cadences are event/epoch-driven on purpose: a periodic *timer* would
//! hold the runtime's quiescence detection open forever (a pending timer
//! is in-flight work), so a timer-based checkpointer could never let a
//! run terminate.
//!
//! Snapshots also record the reliable-delivery sequence cursors
//! ([`Aggregator::seq_cursors`](crate::amt::Aggregator::seq_cursors)) —
//! forensic state for the recovery report; the restarted run re-opens
//! fresh sequence spaces rather than resuming old ones, since its peers'
//! receive windows are rebuilt along with it.

/// Snapshot cadence used when a crash is planned but `checkpoint_every`
/// was left at 0 (events between Converge snapshots).
pub const DEFAULT_CHECKPOINT_EVERY: u64 = 64;

/// One captured restart point.
#[derive(Debug, Clone)]
pub struct Snapshot<S> {
    /// Owned-row states at capture, in shard row order.
    pub states: Vec<S>,
    /// Barrier epoch (Iterate: superstep boundary) at capture.
    pub epoch: u64,
    /// Reliable-delivery `next_seq` cursors at capture (empty when
    /// `reliability=none`); forensic, not replayed.
    pub seq_cursors: Vec<u64>,
}

/// Per-locality snapshot store. See the module docs for the two cadences.
#[derive(Debug, Clone)]
pub struct Checkpoint<S> {
    every: u64,
    ticks: u64,
    taken: u64,
    /// Converge: the single most recent snapshot.
    latest: Option<Snapshot<S>>,
    /// Iterate: one snapshot per marked epoch, ascending.
    history: Vec<Snapshot<S>>,
}

impl<S: Clone> Checkpoint<S> {
    /// A store snapshotting every `every` handled events (Converge
    /// cadence); `every == 0` selects [`DEFAULT_CHECKPOINT_EVERY`].
    pub fn new(every: u64) -> Self {
        Checkpoint {
            every: if every == 0 { DEFAULT_CHECKPOINT_EVERY } else { every },
            ticks: 0,
            taken: 0,
            latest: None,
            history: Vec::new(),
        }
    }

    /// Seed the store with the initial states (call from `on_start`), so
    /// a crash before the first cadence tick still has a restart point.
    pub fn seed(&mut self, states: &[S], seq_cursors: Vec<u64>) {
        self.taken += 1;
        self.latest = Some(Snapshot { states: states.to_vec(), epoch: 0, seq_cursors });
    }

    /// Converge cadence: count one handled event; when the cadence fires,
    /// capture `states` as the new latest snapshot. Returns whether a
    /// snapshot was taken (callers only build `states`' cursor vector
    /// lazily if they need to — pass it every time, it is cheap).
    pub fn tick(&mut self, states: &[S], epoch: u64, seq_cursors: Vec<u64>) -> bool {
        self.ticks += 1;
        if self.ticks < self.every {
            return false;
        }
        self.ticks = 0;
        self.taken += 1;
        self.latest = Some(Snapshot { states: states.to_vec(), epoch, seq_cursors });
        true
    }

    /// Iterate cadence: capture a superstep boundary into the history.
    pub fn epoch_mark(&mut self, states: &[S], epoch: u64, seq_cursors: Vec<u64>) {
        self.taken += 1;
        self.history.push(Snapshot { states: states.to_vec(), epoch, seq_cursors });
    }

    /// Most recent snapshot (Converge restart point).
    pub fn latest(&self) -> Option<&Snapshot<S>> {
        self.latest.as_ref().or(self.history.last())
    }

    /// Latest history snapshot at or before `epoch` (Iterate rollback
    /// point: every locality rolls to the *crashed* locality's epoch).
    pub fn at_or_before(&self, epoch: u64) -> Option<&Snapshot<S>> {
        self.history.iter().rev().find(|s| s.epoch <= epoch)
    }

    /// Snapshots captured so far (reported as
    /// [`FaultStats::checkpoints`](crate::amt::FaultStats)).
    pub fn taken(&self) -> u64 {
        self.taken
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converge_cadence_keeps_the_latest() {
        let mut cp: Checkpoint<u32> = Checkpoint::new(3);
        cp.seed(&[9, 9], Vec::new());
        assert_eq!(cp.latest().unwrap().states, vec![9, 9]);
        assert!(!cp.tick(&[1, 1], 0, Vec::new()));
        assert!(!cp.tick(&[2, 2], 0, Vec::new()));
        assert!(cp.tick(&[3, 3], 0, Vec::new()), "cadence fires on the 3rd event");
        assert_eq!(cp.latest().unwrap().states, vec![3, 3]);
        assert!(!cp.tick(&[4, 4], 1, Vec::new()), "counter reset");
        assert_eq!(cp.taken(), 2);
    }

    #[test]
    fn zero_cadence_selects_the_default() {
        let mut cp: Checkpoint<u32> = Checkpoint::new(0);
        for i in 0..DEFAULT_CHECKPOINT_EVERY - 1 {
            assert!(!cp.tick(&[i as u32], 0, Vec::new()));
        }
        assert!(cp.tick(&[7], 0, Vec::new()));
    }

    #[test]
    fn iterate_history_rolls_back_to_an_epoch() {
        let mut cp: Checkpoint<f32> = Checkpoint::new(1);
        cp.epoch_mark(&[0.0], 0, Vec::new());
        cp.epoch_mark(&[1.0], 1, Vec::new());
        cp.epoch_mark(&[2.0], 2, Vec::new());
        assert_eq!(cp.at_or_before(1).unwrap().states, vec![1.0]);
        assert_eq!(cp.at_or_before(5).unwrap().states, vec![2.0]);
        assert_eq!(cp.at_or_before(2).unwrap().epoch, 2);
        assert_eq!(cp.latest().unwrap().epoch, 2, "history feeds latest() too");
        assert_eq!(cp.taken(), 3);
    }

    #[test]
    fn seq_cursors_ride_along() {
        let mut cp: Checkpoint<u32> = Checkpoint::new(1);
        assert!(cp.tick(&[1], 0, vec![4, 0, 9]));
        assert_eq!(cp.latest().unwrap().seq_cursors, vec![4, 0, 9]);
    }
}
