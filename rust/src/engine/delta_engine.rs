//! `DeltaEngine` — the ordered bucket schedule (generalized from
//! delta-stepping SSSP), once, and now mirror-aware.
//!
//! # Schedule
//!
//! Messages carry a non-negative [`VertexProgram::priority`]; owned rows
//! queue in per-locality buckets keyed by `floor(priority / Δ)`. Edges are
//! split at build time into **light** (`w <= Δ`) and **heavy** (`w > Δ`)
//! sets over the whole local row space (owned *and* mirror rows). Buckets
//! are processed in order: bucket `k` drains through light edges to a
//! fixpoint (re-insertions into `k` are re-processed round-synchronously),
//! then the settled rows relax their heavy edges exactly once. `Δ = ∞`
//! degenerates to the BSP engine's relaxing rounds (identical active
//! sets, relaxation totals, and combiner envelope counts; barriers equal
//! up to the terminal handshake); `Δ → 0` approaches priority-ordered
//! (Dijkstra-like) scheduling.
//!
//! # Distributed current-bucket barrier
//!
//! One phase round is **work → vote → decide**: localities drain the
//! current bucket (light) or settled set (heavy), then — at a barrier, so
//! the network has drained and every in-flight relaxation and mirror
//! cascade has been applied — broadcast `(current bucket non-empty?, min
//! non-empty bucket)` all-to-all, and at the next barrier fold the P votes
//! with the same pure function to reach an identical verdict with no
//! coordinator round-trip.
//!
//! # Mirrors (vertex cuts)
//!
//! Previously this schedule was gated to mirror-free partitions; the
//! ROADMAP risk was that a mirror expansion could re-populate the current
//! bucket *after* the vote. The engine closes that race by construction:
//! masters scatter their signal to mirrors when a row is *processed*
//! (settled) in a light round, mirrors install and relax their share of
//! the **light** edges inside the receiving handler, and the settled set's
//! heavy phase sends an explicit heavy-expand signal (`ToMirrorHeavy`)
//! so mirrors relax their heavy share too.
//! All cascades ride ordinary messages, and votes are cast at barriers —
//! which complete only at network quiescence — so every re-population is
//! visible before any locality votes on emptiness.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::amt::aggregate::{Aggregator, FlushPolicy, SlotSpace};
use crate::amt::sim::{Actor, Ctx, LocalityId, SimConfig, SimTime};
use crate::amt::{SimReport, WorkStats};
use crate::graph::{DistGraph, Shard};

use super::checkpoint::Checkpoint;
use super::incremental::recovery_converge;
use super::program::{Mode, VertexProgram};
use super::{
    absorb_recovery, finish, init_states, recovered_states, seed_checkpoint, ship, untag_token,
    EngineMsg, ProgramRun, SPACE_HEAVY, SPACE_MASTER, SPACE_MIRROR,
};

/// `in_bucket` sentinel: the row is not queued in any bucket.
const NOT_QUEUED: u64 = u64::MAX;

/// Bucket index of a (finite, non-negative) priority.
fn bucket_of(p: f32, delta: f32) -> u64 {
    if delta.is_infinite() {
        return 0;
    }
    // f32 -> u64 casts saturate; clamp below the NOT_QUEUED sentinel.
    ((p / delta) as u64).min(NOT_QUEUED - 1)
}

/// Light/heavy edge separation over one shard's local rows (owned and
/// mirror rows), done once at engine setup. Targets are dense local rows,
/// so relaxation needs no owner arithmetic at all.
struct SplitEdges {
    light_offsets: Vec<usize>,
    light_targets: Vec<u32>,
    light_weights: Vec<f32>,
    heavy_offsets: Vec<usize>,
    heavy_targets: Vec<u32>,
    heavy_weights: Vec<f32>,
}

impl SplitEdges {
    fn build(shard: &Shard, delta: f32) -> Self {
        let mut s = SplitEdges {
            light_offsets: vec![0],
            light_targets: Vec::new(),
            light_weights: Vec::new(),
            heavy_offsets: vec![0],
            heavy_targets: Vec::new(),
            heavy_weights: Vec::new(),
        };
        for row in 0..shard.n_rows() {
            for (t, w) in shard.row_edges(row) {
                if w <= delta {
                    s.light_targets.push(t);
                    s.light_weights.push(w);
                } else {
                    s.heavy_targets.push(t);
                    s.heavy_weights.push(w);
                }
            }
            s.light_offsets.push(s.light_targets.len());
            s.heavy_offsets.push(s.heavy_targets.len());
        }
        s
    }
}

/// Which edge class the next work round relaxes.
enum LightHeavy {
    Light,
    Heavy,
}

/// Barrier-protocol step (work → vote → decide).
enum Step {
    AwaitVote,
    AwaitDecision,
}

struct DeltaActor<P: VertexProgram> {
    prog: Arc<P>,
    shard: Arc<Shard>,
    edges: SplitEdges,
    delta: f32,
    /// Per-row state: owned rows authoritative, ghost rows install slots.
    state: Vec<P::State>,
    /// Bucket index → queued owned rows. Sparse (`BTreeMap`) so tiny Δ
    /// cannot blow up memory; entries may go stale when a row moves
    /// buckets (`in_bucket` is the source of truth).
    buckets: BTreeMap<u64, Vec<u32>>,
    /// Owned row → bucket it is queued in ([`NOT_QUEUED`] = none).
    in_bucket: Vec<u64>,
    /// Rows settled during the current bucket's light phase, awaiting
    /// their one heavy relaxation.
    req: Vec<u32>,
    in_req: Vec<bool>,
    /// Globally agreed current bucket.
    current: u64,
    phase: LightHeavy,
    step: Step,
    votes_nonempty: bool,
    votes_min: Option<u64>,
    votes_seen: u32,
    /// Master-bound relaxation combiner (policy-driven).
    agg: Aggregator<P::Msg>,
    /// Mirror-bound settle-signal combiner (light phase).
    mirror_agg: Aggregator<P::Msg>,
    /// Mirror-bound heavy-expand combiner (heavy phase).
    heavy_agg: Aggregator<P::Msg>,
    work: WorkStats,
    /// Non-zero `TimeWindow` policy: mid-round handler boundaries poll
    /// instead of draining (the pre-vote `work_round` drain stays
    /// unconditional), with a timer armed at the earliest deadline so the
    /// vote barrier waits buffered relaxations out.
    windowed: bool,
    /// The combiners need a clock at flush points: time windows and/or
    /// `reliability=acked` retransmit deadlines (implied by `windowed`).
    clocked: bool,
    /// A crash is planned this run, so partial vote rounds are expected.
    crash_armed: bool,
    /// Earliest outstanding timer deadline (None = no timer armed).
    timer_at: Option<SimTime>,
    /// Crash/restart snapshot store (see [`seed_checkpoint`]).
    ckpt: Option<Checkpoint<P::State>>,
}

impl<P: VertexProgram> DeltaActor<P> {
    /// Route one relaxation proposal: owned targets apply eagerly and move
    /// buckets; ghost targets fold into the master-bound combiner.
    fn relax_target(&mut self, ctx: &mut Ctx<EngineMsg<P::Msg>>, t: usize, m: P::Msg) {
        let n_owned = self.shard.n_local();
        if t < n_owned {
            if self.prog.beats(&m, &self.state[t]) {
                let b = bucket_of(self.prog.priority(&m), self.delta);
                self.prog.apply(&mut self.state[t], m);
                self.work.useful_relaxations += 1;
                if self.in_bucket[t] != b {
                    self.in_bucket[t] = b;
                    self.buckets.entry(b).or_default().push(t as u32);
                }
            }
        } else {
            let gi = t - n_owned;
            let dst = self.shard.ghost_owner[gi];
            let idx = self.shard.ghost_master_index[gi];
            if let Some(batch) = self.agg.accumulate(dst, idx, m, ctx.now()) {
                ship(ctx, dst, batch, SPACE_MASTER, EngineMsg::ToMaster);
            }
        }
    }

    /// Relax one edge class of `row` at signal `sig`.
    fn relax_edges(
        &mut self,
        ctx: &mut Ctx<EngineMsg<P::Msg>>,
        row: usize,
        sig: &P::Msg,
        heavy: bool,
    ) {
        let u = self.shard.global_of(row);
        let range = if heavy {
            self.edges.heavy_offsets[row]..self.edges.heavy_offsets[row + 1]
        } else {
            self.edges.light_offsets[row]..self.edges.light_offsets[row + 1]
        };
        for k in range {
            let (t, w) = if heavy {
                (self.edges.heavy_targets[k], self.edges.heavy_weights[k])
            } else {
                (self.edges.light_targets[k], self.edges.light_weights[k])
            };
            self.work.relaxations += 1;
            let m = self.prog.along_edge(u, sig, w);
            self.relax_target(ctx, t as usize, m);
        }
    }

    /// One light round: settle the current bucket's members into `req`,
    /// scatter their signals to mirrors, and relax their light edges.
    /// Re-insertions into the current bucket are processed next round
    /// (round-synchronous, so `Δ = ∞` reproduces the BSP schedule).
    fn light_round(&mut self, ctx: &mut Ctx<EngineMsg<P::Msg>>) {
        let members = self.buckets.remove(&self.current).unwrap_or_default();
        let shard = Arc::clone(&self.shard);
        for &lv32 in &members {
            let lv = lv32 as usize;
            if self.in_bucket[lv] != self.current {
                continue; // stale entry: the row moved buckets
            }
            self.in_bucket[lv] = NOT_QUEUED;
            if !self.in_req[lv] {
                self.in_req[lv] = true;
                self.req.push(lv32);
            }
            let sig = self.prog.signal(&self.state[lv]);
            for &(dst, gi) in shard.mirrors(lv) {
                if let Some(b) = self.mirror_agg.accumulate(dst, gi, sig.clone(), ctx.now()) {
                    ship(ctx, dst, b, SPACE_MIRROR, EngineMsg::ToMirror);
                }
            }
            self.relax_edges(ctx, lv, &sig, false);
        }
    }

    /// The heavy round: relax the heavy edges of everything settled in the
    /// current bucket, exactly once, at their final signals — and ask
    /// their mirrors to do the same for the remotely homed heavy edges.
    fn heavy_round(&mut self, ctx: &mut Ctx<EngineMsg<P::Msg>>) {
        let req = std::mem::take(&mut self.req);
        let shard = Arc::clone(&self.shard);
        for &lv32 in &req {
            let lv = lv32 as usize;
            self.in_req[lv] = false;
            let sig = self.prog.signal(&self.state[lv]);
            for &(dst, gi) in shard.mirrors(lv) {
                if let Some(b) = self.heavy_agg.accumulate(dst, gi, sig.clone(), ctx.now()) {
                    ship(ctx, dst, b, SPACE_HEAVY, EngineMsg::ToMirrorHeavy);
                }
            }
            self.relax_edges(ctx, lv, &sig, true);
        }
    }

    fn work_round(&mut self, ctx: &mut Ctx<EngineMsg<P::Msg>>) {
        match self.phase {
            LightHeavy::Light => self.light_round(ctx),
            LightHeavy::Heavy => self.heavy_round(ctx),
        }
        // Unconditional drain before the vote barrier, under every policy
        // (time windows included): votes must see settled local state.
        self.drain(ctx);
        if self.clocked {
            self.poll_clocked(ctx);
        }
        self.step = Step::AwaitVote;
        ctx.request_barrier();
    }

    fn drain(&mut self, ctx: &mut Ctx<EngineMsg<P::Msg>>) {
        for (dst, b) in self.agg.drain() {
            ship(ctx, dst, b, SPACE_MASTER, EngineMsg::ToMaster);
        }
        for (dst, b) in self.mirror_agg.drain() {
            ship(ctx, dst, b, SPACE_MIRROR, EngineMsg::ToMirror);
        }
        for (dst, b) in self.heavy_agg.drain() {
            ship(ctx, dst, b, SPACE_HEAVY, EngineMsg::ToMirrorHeavy);
        }
    }

    /// Mid-round handler flush point: drain everything (the pre-existing
    /// contract), or — under a time window — poll for expired destinations
    /// only and keep a timer armed at the earliest remaining deadline.
    /// Timers count as in-flight work, so the vote barrier cannot complete
    /// until every windowed buffer has shipped and been applied: every
    /// locality still votes on complete post-round state. Reliable runs
    /// poll under drain policies too — `poll` is where overdue unacked
    /// envelopes retransmit.
    fn flush_boundary(&mut self, ctx: &mut Ctx<EngineMsg<P::Msg>>) {
        if !self.windowed {
            self.drain(ctx);
        }
        if self.clocked {
            self.poll_clocked(ctx);
        }
    }

    /// Poll all three combiners (window flushes + retransmits) and keep a
    /// timer armed at the earliest remaining deadline.
    fn poll_clocked(&mut self, ctx: &mut Ctx<EngineMsg<P::Msg>>) {
        let now = ctx.now();
        for (dst, b) in self.agg.poll(now) {
            ship(ctx, dst, b, SPACE_MASTER, EngineMsg::ToMaster);
        }
        for (dst, b) in self.mirror_agg.poll(now) {
            ship(ctx, dst, b, SPACE_MIRROR, EngineMsg::ToMirror);
        }
        for (dst, b) in self.heavy_agg.poll(now) {
            ship(ctx, dst, b, SPACE_HEAVY, EngineMsg::ToMirrorHeavy);
        }
        let next = [
            self.agg.next_deadline(),
            self.mirror_agg.next_deadline(),
            self.heavy_agg.next_deadline(),
        ]
        .into_iter()
        .flatten()
        .min_by(|a, b| a.total_cmp(b));
        if let Some(t) = next {
            let t = t.max(now);
            if self.timer_at.is_none_or(|cur| t < cur) {
                ctx.set_timer(t);
                self.timer_at = Some(t);
            }
        }
    }

    /// Converge checkpoint cadence: one completed vote round.
    fn ckpt_tick(&mut self) {
        let n_owned = self.shard.n_local();
        if let Some(c) = &mut self.ckpt {
            let cursors = self.agg.seq_cursors();
            c.tick(&self.state[..n_owned], 0, cursors);
        }
    }
}

impl<P: VertexProgram> Actor for DeltaActor<P> {
    type Msg = EngineMsg<P::Msg>;

    fn on_start(&mut self, ctx: &mut Ctx<Self::Msg>) {
        for lv in 0..self.shard.n_local() {
            if let Some(m) = self.prog.seed(self.shard.global_id(lv)) {
                let b = bucket_of(self.prog.priority(&m), self.delta);
                let _ = self.prog.apply(&mut self.state[lv], m);
                self.in_bucket[lv] = b;
                self.buckets.entry(b).or_default().push(lv as u32);
            }
        }
        self.work_round(ctx);
    }

    fn on_message(&mut self, ctx: &mut Ctx<Self::Msg>, from: LocalityId, msg: Self::Msg) {
        let n_owned = self.shard.n_local();
        match msg {
            // Relaxations apply eagerly: by the time the vote barrier
            // fires the network has drained, so every locality votes on
            // the complete post-round state.
            EngineMsg::ToMaster(b) => {
                if !self.agg.admit(from, b.seq()) {
                    self.agg.recycle(b.into_items());
                    self.flush_boundary(ctx);
                    return;
                }
                let mut items = b.into_items();
                for (lv, m) in items.drain(..) {
                    let lv = lv as usize;
                    if self.prog.beats(&m, &self.state[lv]) {
                        let bk = bucket_of(self.prog.priority(&m), self.delta);
                        self.prog.apply(&mut self.state[lv], m);
                        self.work.useful_relaxations += 1;
                        if self.in_bucket[lv] != bk {
                            self.in_bucket[lv] = bk;
                            self.buckets.entry(bk).or_default().push(lv as u32);
                        }
                    }
                }
                self.agg.recycle(items);
            }
            // A master settled in the current light phase: install its
            // signal and relax our share of the light edges now. The
            // cascade completes before the vote barrier (quiescence, which
            // also waits out any armed window timer).
            EngineMsg::ToMirror(b) => {
                if !self.mirror_agg.admit(from, b.seq()) {
                    self.mirror_agg.recycle(b.into_items());
                    self.flush_boundary(ctx);
                    return;
                }
                let mut items = b.into_items();
                for (gi, m) in items.drain(..) {
                    let row = n_owned + gi as usize;
                    if self.prog.apply_mirror(&mut self.state[row], m) {
                        let sig = self.prog.signal(&self.state[row]);
                        self.relax_edges(ctx, row, &sig, false);
                    }
                }
                self.mirror_agg.recycle(items);
                self.flush_boundary(ctx);
            }
            // Heavy expansion on the master's behalf: exactly once per
            // settlement, at the settled signal. Duplicates are rejected
            // by sequence — a replayed heavy expansion would relax twice.
            EngineMsg::ToMirrorHeavy(b) => {
                if !self.heavy_agg.admit(from, b.seq()) {
                    self.heavy_agg.recycle(b.into_items());
                    self.flush_boundary(ctx);
                    return;
                }
                let mut items = b.into_items();
                for (gi, m) in items.drain(..) {
                    let row = n_owned + gi as usize;
                    let _ = self.prog.apply_mirror(&mut self.state[row], m);
                    let sig = self.prog.signal(&self.state[row]);
                    self.relax_edges(ctx, row, &sig, true);
                }
                self.heavy_agg.recycle(items);
                self.flush_boundary(ctx);
            }
            EngineMsg::Status { nonempty_current, min_bucket } => {
                self.votes_seen += 1;
                self.votes_nonempty |= nonempty_current;
                self.votes_min = match (self.votes_min, min_bucket) {
                    (Some(a), Some(b)) => Some(a.min(b)),
                    (a, b) => a.or(b),
                };
            }
            _ => unreachable!("BSP control message on the delta engine"),
        }
    }

    fn on_ack(
        &mut self,
        _ctx: &mut Ctx<Self::Msg>,
        token: u64,
        sent: SimTime,
        delivered: SimTime,
    ) {
        let (tok, space) = untag_token(token);
        match space {
            SPACE_MASTER => self.agg.observe_ack(tok, sent, delivered),
            SPACE_MIRROR => self.mirror_agg.observe_ack(tok, sent, delivered),
            SPACE_HEAVY => self.heavy_agg.observe_ack(tok, sent, delivered),
            _ => unreachable!("unknown ack space"),
        }
    }

    fn on_timer(&mut self, ctx: &mut Ctx<Self::Msg>) {
        self.timer_at = None;
        self.flush_boundary(ctx);
    }

    fn on_barrier(&mut self, ctx: &mut Ctx<Self::Msg>, _epoch: u64) {
        match self.step {
            Step::AwaitVote => {
                self.ckpt_tick();
                // Drop stale bucket entries so emptiness votes are exact.
                let in_bucket = &self.in_bucket;
                self.buckets.retain(|&b, v| {
                    v.retain(|&lv| in_bucket[lv as usize] == b);
                    !v.is_empty()
                });
                let status = EngineMsg::Status {
                    nonempty_current: self.buckets.contains_key(&self.current),
                    min_bucket: self.buckets.keys().next().copied(),
                };
                for l in 0..ctx.n_localities() {
                    ctx.send(l, status.clone());
                }
                self.step = Step::AwaitDecision;
                ctx.request_barrier();
            }
            Step::AwaitDecision => {
                // All P votes are in; every locality folds them with the
                // same pure function and reaches the identical verdict.
                // (A crashed locality's vote never arrives; survivors
                // still agree because they fold the same subset.)
                debug_assert!(
                    self.crash_armed || self.votes_seen == ctx.n_localities(),
                    "missing bucket votes without a crash"
                );
                let nonempty = self.votes_nonempty;
                let min_b = self.votes_min;
                self.votes_seen = 0;
                self.votes_nonempty = false;
                self.votes_min = None;
                match self.phase {
                    LightHeavy::Light if nonempty => self.work_round(ctx),
                    LightHeavy::Light => {
                        self.phase = LightHeavy::Heavy;
                        self.work_round(ctx);
                    }
                    LightHeavy::Heavy => match min_b {
                        Some(k) => {
                            self.current = k;
                            self.phase = LightHeavy::Light;
                            self.work_round(ctx);
                        }
                        // Every bucket everywhere is empty and the network
                        // is quiet: no one requests another barrier and
                        // the run terminates at quiescence.
                        None => {}
                    },
                }
            }
        }
    }
}

/// One bucket-schedule execution, no recovery (see
/// [`run_async_core`](super::async_engine)'s note on why recovery cannot
/// recurse through the public driver).
fn run_delta_core<P: VertexProgram>(
    prog: &Arc<P>,
    dist: &DistGraph,
    delta: f32,
    policy: FlushPolicy,
    cfg: &SimConfig,
) -> (Vec<DeltaActor<P>>, SimReport) {
    let info = prog.info();
    let reliable = cfg.reliability.is_acked();
    let actors: Vec<DeltaActor<P>> = dist
        .shards
        .iter()
        .map(|s| {
            let state = init_states(&**prog, s);
            let ckpt = seed_checkpoint(cfg, info.mode, s.n_local(), &state);
            DeltaActor {
                prog: Arc::clone(prog),
                edges: SplitEdges::build(s, delta),
                shard: Arc::new(s.clone()),
                delta,
                state,
                buckets: BTreeMap::new(),
                in_bucket: vec![NOT_QUEUED; s.n_local()],
                req: Vec::new(),
                in_req: vec![false; s.n_local()],
                current: 0,
                phase: LightHeavy::Light,
                step: Step::AwaitVote,
                votes_nonempty: false,
                votes_min: None,
                votes_seen: 0,
                agg: Aggregator::new(
                    dist.owned_counts(),
                    s.locality,
                    SlotSpace::Master,
                    policy,
                    &cfg.net,
                    info.item_bytes,
                    P::combine,
                )
                .with_reliability(reliable),
                mirror_agg: Aggregator::new(
                    dist.ghost_counts(),
                    s.locality,
                    SlotSpace::Mirror,
                    policy,
                    &cfg.net,
                    info.item_bytes,
                    P::combine,
                )
                .with_reliability(reliable),
                heavy_agg: Aggregator::new(
                    dist.ghost_counts(),
                    s.locality,
                    SlotSpace::Mirror,
                    policy,
                    &cfg.net,
                    info.item_bytes,
                    P::combine,
                )
                .with_reliability(reliable),
                work: WorkStats::default(),
                windowed: policy.time_window_us().is_some(),
                clocked: policy.time_window_us().is_some() || reliable,
                crash_armed: cfg.fault.crash.is_some(),
                timer_at: None,
                ckpt,
            }
        })
        .collect();
    let (actors, mut report) = crate::amt::run_actors(cfg, actors);
    for a in &actors {
        report.agg.merge(a.agg.stats());
        report.agg.merge(a.mirror_agg.stats());
        report.agg.merge(a.heavy_agg.stats());
        report.agg_master.merge(a.agg.stats());
        report.agg_mirror.merge(a.mirror_agg.stats());
        report.agg_mirror.merge(a.heavy_agg.stats());
        report.work.merge(&a.work);
        for (rtx, dedup, gu) in [
            a.agg.reliability_stats(),
            a.mirror_agg.reliability_stats(),
            a.heavy_agg.reliability_stats(),
        ] {
            report.fault.retransmits += rtx;
            report.fault.dedup_hits += dedup;
            report.fault.give_ups += gu;
        }
        if let Some(c) = &a.ckpt {
            report.fault.checkpoints += c.taken();
        }
    }
    report.partition = dist.partition_stats();
    report.mem = dist.mem_stats();
    (actors, report)
}

/// Run `prog` on the ordered bucket engine over `dist` with bucket width
/// `delta` (must be positive; `f32::INFINITY` ≡ one bucket ≡ the BSP
/// schedule). Requires [`ProgramInfo::ordered`](super::ProgramInfo);
/// supports every partition scheme, including vertex cuts. When the
/// configured fault plan fail-stops a locality mid-run, the engine
/// restores it from its last checkpoint and re-runs warm (see
/// [`checkpoint`](super::checkpoint)).
pub fn run_delta<P: VertexProgram>(
    prog: P,
    dist: &DistGraph,
    delta: f32,
    policy: FlushPolicy,
    cfg: SimConfig,
) -> ProgramRun<P::State> {
    let info = prog.info();
    assert!(delta > 0.0, "delta must be positive (f32::INFINITY = one bucket), got {delta}");
    assert!(
        info.ordered && info.mode == Mode::Converge,
        "program `{}` is not bucket-orderable; use the async or BSP engine",
        info.name
    );
    let prog = Arc::new(prog);
    let (actors, mut report) = run_delta_core(&prog, dist, delta, policy, &cfg);
    static NO_DELTAS: [f32; 0] = [];
    if let Some((crash_l, _)) = cfg.fault.crash {
        if report.fault.crashes > 0 {
            let mut rcfg = cfg.clone();
            rcfg.fault.crash = None; // the restarted locality does not re-crash
            let recovered = recovered_states(
                dist,
                actors.iter().map(|a| (&*a.shard, &a.state[..], a.ckpt.as_ref())),
                crash_l,
                None,
            );
            let warm = Arc::new(recovery_converge(&prog, recovered));
            let (ractors, rreport) = run_delta_core(&warm, dist, delta, policy, &rcfg);
            absorb_recovery(&mut report, &rreport);
            return finish(
                dist,
                ractors.iter().map(|a| (&*a.shard, &a.state[..], &NO_DELTAS[..])),
                report,
            );
        }
    }
    finish(
        dist,
        actors.iter().map(|a| (&*a.shard, &a.state[..], &NO_DELTAS[..])),
        report,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_of_is_monotone_and_saturates() {
        assert_eq!(bucket_of(0.0, 0.5), 0);
        assert_eq!(bucket_of(0.49, 0.5), 0);
        assert_eq!(bucket_of(0.5, 0.5), 1);
        assert_eq!(bucket_of(7.3, 0.5), 14);
        assert_eq!(bucket_of(123.0, f32::INFINITY), 0);
        // Saturating cast stays clear of the NOT_QUEUED sentinel.
        assert_eq!(bucket_of(f32::MAX, 1e-30), NOT_QUEUED - 1);
    }
}
