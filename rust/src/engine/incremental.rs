//! Incremental re-convergence after an [`UpdateBatch`]: re-run a
//! [`VertexProgram`] over the mutated graph from its previous fixpoint
//! instead of from scratch.
//!
//! The contract comes in two halves, split by [`Mode`]:
//!
//! * **[`Mode::Converge`]** (BFS / SSSP / CC): the previous states are a
//!   fixpoint of a *monotone* label-correcting fold, so after a mutation
//!   they remain **achievable upper bounds** everywhere except where a
//!   deletion broke a justification chain. [`plan_taint`] finds that
//!   broken region on the *pre-update* graph: seed-taint the head of
//!   every effectively deleted edge the program says it
//!   [`depends_on_edge`] through, then propagate the taint along
//!   dependency edges to closure. Tainted rows restart from the cold
//!   [`VertexProgram::init`] value; everything else keeps its old state
//!   via the [`Warm`] wrapper. Re-seeding then restarts the wavefront
//!   from exactly three places — the program's original seeds inside the
//!   taint region, untainted rows with a *post-update* edge into the
//!   region (the taint frontier), and the sources of inserted edges —
//!   and the ordinary engine flood does the rest. An empty batch plans
//!   zero seeds and the engine terminates with zero relaxations.
//! * **[`Mode::Iterate`]** (PageRank): there is no taint; the previous
//!   ranks are simply a better starting vector than uniform. Every row
//!   re-warms through [`VertexProgram::rewarm`] (which refreshes
//!   degree-derived fields like `inv_deg`) and the engine runs its
//!   normal fixed superstep count from there.
//!
//! No engine changes are needed: the engines already apply seeds
//! unconditionally and expand the seeded row, so [`Warm`] expresses
//! everything through the existing [`VertexProgram`] surface.
//!
//! One escape hatch: when the deletion taint swallows more than
//! [`SimConfig::taint_cap`] of the graph (hub deletions), the warm
//! re-flood would redo essentially all the work *plus* the taint-closure
//! walk, so [`rerun_incremental`] falls back to a cold from-scratch run
//! on the mutated graph and reports it in
//! [`UpdateStats::fallbacks`](crate::amt::UpdateStats).

use std::sync::Arc;

use crate::amt::{FlushPolicy, SimConfig, UpdateStats};
use crate::graph::mutation::{UpdateBatch, UpdateOp};
use crate::graph::{DistGraph, VertexId};

use super::{Mode, ProgramInfo, ProgramRun, VertexProgram};

/// Which engine carries the re-convergence run.
#[derive(Debug, Clone, Copy)]
pub enum Reconverge {
    /// Asynchronous label-correcting wavefront ([`run_async`](super::run_async)).
    Async(FlushPolicy),
    /// Bulk-synchronous supersteps ([`run_bsp`](super::run_bsp)) — the
    /// only choice for [`Mode::Iterate`] programs.
    Bsp,
    /// Ordered bucket schedule ([`run_delta`](super::run_delta)).
    Delta {
        /// Bucket width.
        delta: f32,
        /// Flush policy for the light-phase combiners.
        policy: FlushPolicy,
    },
}

impl Reconverge {
    /// The flush policy the update batch itself is routed under (BSP
    /// drains at phase end, matching its engine idiom).
    fn route_policy(&self) -> FlushPolicy {
        match *self {
            Reconverge::Async(p) | Reconverge::Delta { policy: p, .. } => p,
            Reconverge::Bsp => FlushPolicy::Manual,
        }
    }
}

/// A [`VertexProgram`] wrapper that restarts `inner` from a previous
/// run's states: untainted rows re-initialize to their old value
/// (through [`VertexProgram::rewarm`]), tainted rows fall back to the
/// cold `init`, and seeding is replaced by the re-convergence plan's
/// reseed table. Everything else delegates.
///
/// The engines reuse this wrapper for crash recovery (see
/// [`recovery_converge`] / [`recovery_iterate`]): a restarted run is
/// just a warm re-run whose "previous states" are the survivors' live
/// rows plus the crashed locality's last checkpoint.
pub(crate) struct Warm<P: VertexProgram> {
    pub(crate) inner: Arc<P>,
    /// Previous state per global vertex; `None` = tainted (cold restart).
    pub(crate) prev: Vec<Option<P::State>>,
    /// Reseed message per global vertex; `None` = starts inactive.
    pub(crate) reseed: Vec<Option<P::Msg>>,
    /// [`Mode::Iterate`] override: run this many supersteps instead of
    /// the program's full count (crash recovery replays only the tail
    /// after the rollback epoch). `None` delegates to `inner`.
    pub(crate) iterations: Option<u32>,
}

impl<P: VertexProgram> VertexProgram for Warm<P> {
    type State = P::State;
    type Msg = P::Msg;

    fn info(&self) -> ProgramInfo {
        let mut info = self.inner.info();
        if let Some(n) = self.iterations {
            debug_assert!(matches!(info.mode, Mode::Iterate(_)));
            info.mode = Mode::Iterate(n);
        }
        info
    }

    fn init(&self, v: VertexId, out_degree: u32) -> P::State {
        match &self.prev[v as usize] {
            Some(s) => self.inner.rewarm(s, v, out_degree),
            None => self.inner.init(v, out_degree),
        }
    }

    fn seed(&self, v: VertexId) -> Option<P::Msg> {
        self.reseed[v as usize].clone()
    }

    fn combine(acc: &mut P::Msg, new: P::Msg) {
        P::combine(acc, new);
    }

    fn beats(&self, msg: &P::Msg, state: &P::State) -> bool {
        self.inner.beats(msg, state)
    }

    fn apply(&self, state: &mut P::State, msg: P::Msg) -> bool {
        self.inner.apply(state, msg)
    }

    fn signal(&self, state: &P::State) -> P::Msg {
        self.inner.signal(state)
    }

    fn along_edge(&self, u: VertexId, sig: &P::Msg, w: f32) -> P::Msg {
        self.inner.along_edge(u, sig, w)
    }

    fn priority(&self, msg: &P::Msg) -> f32 {
        self.inner.priority(msg)
    }

    fn apply_mirror(&self, state: &mut P::State, msg: P::Msg) -> bool {
        self.inner.apply_mirror(state, msg)
    }

    fn step_update(&self, state: &mut P::State) -> f32 {
        self.inner.step_update(state)
    }
}

/// Build the [`Warm`] wrapper that restarts a crashed
/// [`Mode::Converge`] run from recovered global states (survivors'
/// live rows + the crashed locality's last checkpoint). Every row keeps
/// its recovered value; the frontier is re-seeded from the program's
/// original seeds plus every row that still has a value to offer —
/// monotone re-flooding from an achievable state vector reaches the
/// exact fixpoint, and the re-flood prunes itself wherever neighbors
/// already hold the folded answer.
pub(crate) fn recovery_converge<P: VertexProgram>(
    prog: &Arc<P>,
    recovered: Vec<P::State>,
) -> Warm<P> {
    let reseed = recovered
        .iter()
        .enumerate()
        .map(|(v, s)| {
            prog.seed(v as VertexId)
                .or_else(|| prog.can_emit(s).then(|| prog.signal(s)))
        })
        .collect();
    Warm {
        inner: Arc::clone(prog),
        prev: recovered.into_iter().map(Some).collect(),
        reseed,
        iterations: None,
    }
}

/// Build the [`Warm`] wrapper that restarts a crashed
/// [`Mode::Iterate`] run: every locality rolled back to the crashed
/// locality's epoch, replaying only the `remaining` supersteps.
pub(crate) fn recovery_iterate<P: VertexProgram>(
    prog: &Arc<P>,
    recovered: Vec<P::State>,
    remaining: u32,
) -> Warm<P> {
    let n = recovered.len();
    Warm {
        inner: Arc::clone(prog),
        prev: recovered.into_iter().map(Some).collect(),
        reseed: vec![None; n],
        iterations: Some(remaining),
    }
}

/// Visit every (pre- or post-update) out-edge of global vertex `x`,
/// wherever its row is homed, as `(target global id, weight)`.
fn for_each_out_edge(dist: &DistGraph, x: VertexId, mut f: impl FnMut(VertexId, f32)) {
    for s in &dist.shards {
        if let Some(row) = s.row_of(x) {
            for (t, w) in s.row_edges(row) {
                f(s.global_of(t as usize), w);
            }
        }
    }
}

/// Deletion invalidation on the *pre-update* graph: taint the head of
/// every effective delete whose old states depended on the edge, then
/// close the taint under [`VertexProgram::depends_on_edge`] along the old
/// out-edges. Returns the taint bitmap (all-false when nothing fires).
fn plan_taint<P: VertexProgram>(
    prog: &P,
    dist: &DistGraph,
    prev: &[P::State],
    batch: &UpdateBatch,
) -> Vec<bool> {
    let mut tainted = vec![false; dist.n()];
    let mut work: Vec<VertexId> = Vec::new();
    for op in &batch.ops {
        if op.op != UpdateOp::Delete || tainted[op.dst as usize] {
            continue;
        }
        // An ineffective delete (absent edge) finds no edge and taints
        // nothing; duplicates are settled by the tainted check above.
        let (u, v) = (op.src, op.dst);
        let mut hit = false;
        for_each_out_edge(dist, u, |t, w| {
            if t == v && prog.depends_on_edge(&prev[u as usize], &prev[v as usize], w) {
                hit = true;
            }
        });
        if hit {
            tainted[v as usize] = true;
            work.push(v);
        }
    }
    while let Some(x) = work.pop() {
        for_each_out_edge(dist, x, |y, w| {
            if !tainted[y as usize]
                && prog.depends_on_edge(&prev[x as usize], &prev[y as usize], w)
            {
                tainted[y as usize] = true;
                work.push(y);
            }
        });
    }
    tainted
}

/// Apply `batch` to `dist` and re-run `prog` incrementally from `prev`
/// (the previous run's converged states, in global vertex order).
///
/// The returned run's states equal what a from-scratch run on the
/// updated graph produces — exactly for `Converge` programs, and for
/// `Iterate` programs up to the usual fixed-iteration tolerance against
/// a warm-started oracle. [`SimReport::update`](crate::amt::SimReport)
/// carries the batch/routing counters from
/// [`DistGraph::apply_updates`] plus the re-convergence cost
/// (relaxations, envelopes, makespan) for the incremental-vs-full
/// comparison the A10 ablation makes.
pub fn rerun_incremental<P: VertexProgram>(
    prog: P,
    dist: &mut DistGraph,
    prev: &[P::State],
    batch: &UpdateBatch,
    how: Reconverge,
    cfg: SimConfig,
) -> ProgramRun<P::State> {
    assert_eq!(prev.len(), dist.n(), "previous states must cover every vertex");
    let converge = prog.info().mode == Mode::Converge;

    // Phase 1 (pre-update graph): deletion dependency taint.
    let tainted = if converge {
        plan_taint(&prog, dist, prev, batch)
    } else {
        vec![false; dist.n()]
    };

    // Phase 2: mutate the shards, costing the scatter-routing.
    let mut stats = dist.apply_updates(batch, how.route_policy(), &cfg.net);

    // Phase 3 (post-update graph): warm states + reseeds.
    let warm: Vec<Option<P::State>> = prev
        .iter()
        .zip(&tainted)
        .map(|(s, &t)| (!t).then(|| s.clone()))
        .collect();
    let mut reseed: Vec<Option<P::Msg>> = vec![None; dist.n()];
    if converge {
        // (a) The program's own seeds inside the taint region.
        for (v, &t) in tainted.iter().enumerate() {
            if t {
                reseed[v] = prog.seed(v as VertexId);
            }
        }
        // (b) The taint frontier: untainted rows with a post-update edge
        // into the region re-offer their (still valid) value.
        for s in &dist.shards {
            for row in 0..s.n_rows() {
                let u = s.global_of(row) as usize;
                if tainted[u] || !prog.can_emit(&prev[u]) {
                    continue;
                }
                for t in s.row_locals(row) {
                    if tainted[s.global_of(t as usize) as usize] {
                        reseed[u] = Some(prog.signal(&prev[u]));
                        break;
                    }
                }
            }
        }
        // (c) Sources of inserted edges push their value across the new
        // edge (tainted sources already restart cold and re-flood).
        for op in &batch.ops {
            let u = op.src as usize;
            if op.op == UpdateOp::Insert && !tainted[u] && prog.can_emit(&prev[u]) {
                reseed[u] = Some(prog.signal(&prev[u]));
            }
        }
    }
    stats.tainted = tainted.iter().filter(|&&t| t).count() as u64;
    stats.reseeded = reseed.iter().filter(|r| r.is_some()).count() as u64;

    // Phase 4: the ordinary engine flood, warm-started — unless the
    // taint swallowed most of the graph. Past `taint_cap` (fraction of
    // vertices), re-flooding the invalidated region costs as much as
    // recomputing from scratch while still paying the taint-closure
    // walk, so fall back to a cold run on the (already mutated) graph.
    let fallback = converge
        && cfg.taint_cap > 0.0
        && stats.tainted as f64 > cfg.taint_cap * dist.n() as f64;
    let mut run = if fallback {
        stats.fallbacks = 1;
        match how {
            Reconverge::Async(policy) => super::run_async(prog, dist, policy, cfg),
            Reconverge::Bsp => super::run_bsp(prog, dist, cfg),
            Reconverge::Delta { delta, policy } => {
                super::run_delta(prog, dist, delta, policy, cfg)
            }
        }
    } else {
        let warm_prog = Warm { inner: Arc::new(prog), prev: warm, reseed, iterations: None };
        match how {
            Reconverge::Async(policy) => super::run_async(warm_prog, dist, policy, cfg),
            Reconverge::Bsp => super::run_bsp(warm_prog, dist, cfg),
            Reconverge::Delta { delta, policy } => {
                super::run_delta(warm_prog, dist, delta, policy, cfg)
            }
        }
    };
    stats.reconverge_relaxations = run.report.work.relaxations;
    stats.reconverge_envelopes = run.report.net.envelopes;
    stats.reconverge_makespan_us = run.report.makespan_us;
    stats.reconverge_wall_us = run.report.wall_us;
    run.report.update = stats;
    run
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{bfs, cc, sssp};
    use crate::amt::NetConfig;
    use crate::graph::{generators, mutation, PartitionKind};

    fn det() -> SimConfig {
        SimConfig::deterministic(NetConfig::default())
    }

    #[test]
    fn empty_batch_reconverges_for_free() {
        let g = generators::with_random_weights(&generators::kron(7, 4, 5), 1.0, 10.0, 6);
        let mut d = crate::graph::DistGraph::block(&g, 4);
        let base = super::super::run_async(
            sssp::SsspProgram { source: 0 },
            &d,
            FlushPolicy::Adaptive,
            det(),
        );
        let run = rerun_incremental(
            sssp::SsspProgram { source: 0 },
            &mut d,
            &base.states,
            &UpdateBatch::new(),
            Reconverge::Async(FlushPolicy::Adaptive),
            det(),
        );
        assert_eq!(run.states, base.states);
        let u = run.report.update;
        assert_eq!(u.reconverge_relaxations, 0, "no seeds, no work");
        assert_eq!((u.tainted, u.reseeded, u.applied, u.retracted), (0, 0, 0, 0));
    }

    #[test]
    fn insert_only_batch_improves_without_taint() {
        // A pure-insert batch must never taint: inserts only add better
        // paths to a monotone program.
        let g = generators::with_random_weights(&generators::urand(7, 4, 9), 1.0, 10.0, 2);
        let mut d = crate::graph::DistGraph::block(&g, 4);
        let base = super::super::run_async(
            sssp::SsspProgram { source: 0 },
            &d,
            FlushPolicy::Adaptive,
            det(),
        );
        let batch = mutation::generate_batch(&g, 0.1, 1.0, 17, true);
        let (g2, _, _) = mutation::apply_to_csr(&g, &batch);
        let run = rerun_incremental(
            sssp::SsspProgram { source: 0 },
            &mut d,
            &base.states,
            &batch,
            Reconverge::Async(FlushPolicy::Adaptive),
            det(),
        );
        assert_eq!(run.report.update.tainted, 0);
        let want = sssp::dijkstra(&g2, 0);
        for (v, (&got, &exp)) in run.states.iter().zip(&want).enumerate() {
            assert!(
                (got.is_infinite() && exp.is_infinite()) || (got - exp).abs() < 1e-3,
                "v{v}: {got} vs {exp}"
            );
        }
    }

    #[test]
    fn deletion_taint_recovers_exact_answers() {
        // Delete-heavy batch across engines and schemes; answers must
        // equal the from-scratch oracle on the updated graph.
        let g = generators::with_random_weights(&generators::kron(7, 5, 31), 1.0, 10.0, 8);
        let batch = mutation::generate_batch(&g, 0.1, 0.0, 23, true);
        let (g2, _, retracted) = mutation::apply_to_csr(&g, &batch);
        assert!(retracted > 0);
        let want = sssp::dijkstra(&g2, 0);
        for kind in [PartitionKind::Block, PartitionKind::VertexCut] {
            let mut d = crate::graph::DistGraph::build_with(&g, kind.build(&g, 4));
            let base = super::super::run_async(
                sssp::SsspProgram { source: 0 },
                &d,
                FlushPolicy::Adaptive,
                det(),
            );
            let run = rerun_incremental(
                sssp::SsspProgram { source: 0 },
                &mut d,
                &base.states,
                &batch,
                Reconverge::Async(FlushPolicy::Adaptive),
                det(),
            );
            assert!(run.report.update.tainted > 0, "{kind:?}: deletes must taint");
            for (v, (&got, &exp)) in run.states.iter().zip(&want).enumerate() {
                assert!(
                    (got.is_infinite() && exp.is_infinite()) || (got - exp).abs() < 1e-3,
                    "{kind:?} v{v}: {got} vs {exp}"
                );
            }
        }
    }

    #[test]
    fn disconnecting_delete_unreaches_the_far_side() {
        // path 0-1-2-3-4-5: delete 2-3 (both directions); BFS from 0 must
        // report 3,4,5 unreached, CC must split the component.
        let g = generators::path(6);
        let mut batch = UpdateBatch::new();
        batch.delete(2, 3);
        batch.delete(3, 2);

        let mut d = crate::graph::DistGraph::block(&g, 3);
        let base =
            super::super::run_async(bfs::BfsProgram { root: 0 }, &d, FlushPolicy::Adaptive, det());
        let run = rerun_incremental(
            bfs::BfsProgram { root: 0 },
            &mut d,
            &base.states,
            &batch,
            Reconverge::Async(FlushPolicy::Adaptive),
            det(),
        );
        let levels: Vec<u32> = run.states.iter().map(|s| s.level).collect();
        assert_eq!(levels, vec![0, 1, 2, u32::MAX, u32::MAX, u32::MAX]);

        let mut d = crate::graph::DistGraph::block(&g, 3);
        let base = super::super::run_async(cc::CcProgram, &d, FlushPolicy::Adaptive, det());
        let run = rerun_incremental(
            cc::CcProgram,
            &mut d,
            &base.states,
            &batch,
            Reconverge::Async(FlushPolicy::Adaptive),
            det(),
        );
        assert_eq!(run.states, vec![0, 0, 0, 3, 3, 3]);
    }

    #[test]
    fn hub_delete_past_the_taint_cap_falls_back_to_full_recompute() {
        // Severing the path right behind the root taints (almost) the
        // whole graph — a warm re-flood would redo all the work *plus*
        // the taint walk, so the cap must route to a cold run; raising
        // the cap out of reach must keep the warm path. Both answers
        // must match the from-scratch oracle.
        let g = generators::path(24);
        let mut batch = UpdateBatch::new();
        batch.delete(1, 2);
        batch.delete(2, 1);
        let mut want = vec![u32::MAX; 24];
        (want[0], want[1]) = (0, 1);

        for (cap, expect_fallback) in [(0.5, 1u64), (1.0, 0u64)] {
            let mut d = crate::graph::DistGraph::block(&g, 4);
            let base = super::super::run_async(
                bfs::BfsProgram { root: 0 },
                &d,
                FlushPolicy::Adaptive,
                det(),
            );
            let mut cfg = det();
            cfg.taint_cap = cap;
            let run = rerun_incremental(
                bfs::BfsProgram { root: 0 },
                &mut d,
                &base.states,
                &batch,
                Reconverge::Async(FlushPolicy::Adaptive),
                cfg,
            );
            let u = run.report.update;
            assert_eq!(u.fallbacks, expect_fallback, "cap {cap}: tainted {}", u.tainted);
            assert_eq!(u.tainted, 22, "cap {cap}: everything behind the cut is tainted");
            let levels: Vec<u32> = run.states.iter().map(|s| s.level).collect();
            assert_eq!(levels, want, "cap {cap}");
        }
    }

    #[test]
    fn incremental_beats_full_recompute_on_small_batches() {
        let g = generators::with_random_weights(&generators::kron(9, 8, 3), 1.0, 10.0, 4);
        let batch = mutation::generate_batch(&g, 0.005, 0.5, 29, true);
        let (g2, _, _) = mutation::apply_to_csr(&g, &batch);
        let mut d = crate::graph::DistGraph::block(&g, 8);
        let base = super::super::run_async(
            sssp::SsspProgram { source: 0 },
            &d,
            FlushPolicy::Adaptive,
            det(),
        );
        let run = rerun_incremental(
            sssp::SsspProgram { source: 0 },
            &mut d,
            &base.states,
            &batch,
            Reconverge::Async(FlushPolicy::Adaptive),
            det(),
        );
        let full = super::super::run_async(
            sssp::SsspProgram { source: 0 },
            &crate::graph::DistGraph::block(&g2, 8),
            FlushPolicy::Adaptive,
            det(),
        );
        assert_eq!(run.states, full.states, "same fixpoint either way");
        let u = run.report.update;
        assert!(
            u.reconverge_relaxations < full.report.work.relaxations,
            "incremental {} vs full {}",
            u.reconverge_relaxations,
            full.report.work.relaxations
        );
    }
}
