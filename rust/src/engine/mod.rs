//! Generic execution engines for [`VertexProgram`]s.
//!
//! The execution loops of the distributed algorithms exist exactly once,
//! here, as three engines over the simulated AMT runtime:
//!
//! * **[`run_async`]** ([`async_engine`]) — asynchronous label-correcting
//!   wavefront over owned+ghost rows; remote traffic folds through the
//!   [`amt::aggregate`](crate::amt::aggregate) combiners under any
//!   [`FlushPolicy`](crate::amt::FlushPolicy); termination is network
//!   quiescence ([`Mode::Converge`]) or barrier-separated supersteps
//!   ([`Mode::Iterate`]).
//! * **[`run_bsp`]** ([`bsp_engine`]) — bulk-synchronous supersteps with
//!   Manual-policy combiner drains; `Converge` programs terminate through
//!   an activity-count reduction (two barriers per superstep), `Iterate`
//!   programs run their fixed count (one barrier per superstep).
//! * **[`run_delta`]** ([`delta_engine`]) — the ordered middle ground:
//!   bucketed priority scheduling (generalized from delta-stepping SSSP)
//!   with light/heavy edge splitting and a distributed current-bucket
//!   vote. Mirror-aware: masters scatter settle/heavy signals to mirror
//!   rows, so vertex-cut partitions are supported.
//!
//! The engines own *all* distribution machinery — mirror-table routing,
//! ghost-slot aggregation, activation/termination accounting,
//! [`WorkStats`](crate::amt::WorkStats) counting, and
//! [`SimReport`](crate::amt::SimReport) stamping. A program contributes
//! only the ~10 pure hooks of [`VertexProgram`]; see
//! [`program`] and `ARCHITECTURE.md`.
//!
//! [`incremental`] layers dynamic graphs on top: after a
//! [`DistGraph::apply_updates`](crate::graph::DistGraph::apply_updates)
//! batch, [`rerun_incremental`] warm-starts any program on any of the
//! three engines from its previous fixpoint, re-seeding only the
//! invalidated region instead of recomputing from scratch.

pub mod async_engine;
pub mod bsp_engine;
pub mod checkpoint;
pub mod delta_engine;
pub mod incremental;
pub mod program;

pub use async_engine::run_async;
pub use bsp_engine::{run_bsp, run_bsp_with_executor};
pub use checkpoint::Checkpoint;
pub use delta_engine::run_delta;
pub use incremental::{rerun_incremental, Reconverge};
pub use program::{Mode, ProgramInfo, VertexProgram};

use crate::amt::aggregate::Batch;
use crate::amt::sim::{Ctx, Message, SimConfig};
use crate::amt::{LocalityId, SimReport};
use crate::graph::{DistGraph, Shard};

use checkpoint::Checkpoint;

/// Outcome of one engine run, before the algorithm driver projects its
/// result type out of the per-vertex states.
#[derive(Debug)]
pub struct ProgramRun<S> {
    /// Final owned-row states in global vertex order.
    pub states: Vec<S>,
    /// Per-superstep global convergence deltas ([`Mode::Iterate`] only).
    pub deltas: Vec<f32>,
    /// Runtime report (aggregation, work, and partition stats stamped).
    pub report: SimReport,
}

/// Uniform coordinator-facing rejection for `algorithm × partition`
/// combinations that need whole vertex rows at the owner. The explicitly
/// specialized engines (direction-optimizing BFS, kernel PageRank,
/// triangle counting) cannot expand mirror rows; everything running on the
/// generic engines is scheme-generic and never calls this.
pub fn require_mirror_free(dist: &DistGraph, algo: &str) -> crate::Result<()> {
    if dist.has_mirrors() {
        anyhow::bail!(
            "`{algo}` does not support the `{}` partition: it needs whole vertex rows at \
             the owner and this scheme splits rows across mirror localities; use a \
             mirror-free partition (block|edge_balanced|hash) or a scheme-generic engine",
            dist.partition.name()
        );
    }
    Ok(())
}

/// Engine wire format: combiner batches toward masters or mirrors plus the
/// small control messages of the BSP/delta termination protocols. Unused
/// variants are dead code for a given engine, not traffic.
#[derive(Debug, Clone)]
pub(crate) enum EngineMsg<M> {
    /// `(destination master index, folded value)` toward a vertex's owner.
    ToMaster(Batch<M>),
    /// `(ghost slot, master's signal)` toward a vertex's mirror.
    ToMirror(Batch<M>),
    /// Delta heavy phase: `(ghost slot, settled signal)` — the mirror
    /// relaxes its share of the heavy edges at this value.
    ToMirrorHeavy(Batch<M>),
    /// Superstep activity count, reduced at locality 0 (BSP Converge).
    Count(u64),
    /// Locality 0's superstep verdict (BSP Converge).
    Continue(bool),
    /// One locality's bucket status, broadcast all-to-all (delta).
    Status {
        /// The current bucket still holds vertices here.
        nonempty_current: bool,
        /// Smallest non-empty bucket here (`None` = all empty).
        min_bucket: Option<u64>,
    },
}

impl<M> Message for EngineMsg<M> {
    fn wire_bytes(&self) -> usize {
        match self {
            EngineMsg::ToMaster(b) | EngineMsg::ToMirror(b) | EngineMsg::ToMirrorHeavy(b) => {
                b.wire_bytes()
            }
            EngineMsg::Count(_) => 8,
            EngineMsg::Continue(_) => 1,
            EngineMsg::Status { .. } => 16,
        }
    }

    fn item_count(&self) -> usize {
        match self {
            EngineMsg::ToMaster(b) | EngineMsg::ToMirror(b) | EngineMsg::ToMirrorHeavy(b) => {
                b.len()
            }
            _ => 1,
        }
    }

    /// Control traffic (termination votes, superstep verdicts, bucket
    /// status) is exempt from injected faults: the harness models a lossy
    /// *data* plane, while these few tiny messages stand in for HPX's
    /// reliable collectives. Losing one would wedge a protocol rather
    /// than corrupt an answer, which is a different (and uninteresting)
    /// failure mode — see ARCHITECTURE.md, "Fault model & recovery".
    fn fault_immune(&self) -> bool {
        matches!(
            self,
            EngineMsg::Count(_) | EngineMsg::Continue(_) | EngineMsg::Status { .. }
        )
    }
}

/// Trace-token tags: an engine holds several [`Aggregator`]s (master /
/// mirror / heavy), each minting its own token space, so the shipper tags
/// the top bits with which combiner emitted the envelope and
/// [`untag_token`] routes the ack back. See
/// [`Aggregator::observe_ack`](crate::amt::Aggregator::observe_ack).
pub(crate) const SPACE_MASTER: u64 = 0;
/// Mirror-scatter combiner tag (see [`SPACE_MASTER`]).
pub(crate) const SPACE_MIRROR: u64 = 1;
/// Delta heavy-expand combiner tag (see [`SPACE_MASTER`]).
pub(crate) const SPACE_HEAVY: u64 = 2;
const SPACE_SHIFT: u32 = 62;

/// Split a tagged ack token into `(combiner token, space tag)`.
pub(crate) fn untag_token(t: u64) -> (u64, u64) {
    (t & !(3u64 << SPACE_SHIFT), t >> SPACE_SHIFT)
}

/// Ship one combiner batch: traced envelopes (see
/// [`FlushPolicy::traced`](crate::amt::FlushPolicy::traced)) go out via
/// [`Ctx::send_traced`] with the space tag folded into the token so the
/// delivery ack can be routed back to the emitting aggregator; everything
/// else is a plain send.
pub(crate) fn ship<M>(
    ctx: &mut Ctx<EngineMsg<M>>,
    dst: crate::amt::LocalityId,
    b: Batch<M>,
    space: u64,
    wrap: fn(Batch<M>) -> EngineMsg<M>,
) {
    match b.token() {
        Some(t) => {
            debug_assert!(t < 1 << SPACE_SHIFT, "trace token overflow");
            ctx.send_traced(dst, wrap(b), t | (space << SPACE_SHIFT));
        }
        None => ctx.send(dst, wrap(b)),
    }
}

/// Build one actor's [`Checkpoint`] store when the run needs one (a
/// crash is planned, or an explicit `checkpoint_every` cadence is set),
/// pre-seeded with the initial owned rows so a crash at any time — even
/// before the first handler — has a restart point. `None` otherwise:
/// fault-free runs pay nothing.
pub(crate) fn seed_checkpoint<S: Clone>(
    cfg: &SimConfig,
    mode: Mode,
    n_owned: usize,
    states: &[S],
) -> Option<Checkpoint<S>> {
    if cfg.fault.crash.is_none() && cfg.checkpoint_every == 0 {
        return None;
    }
    let mut c = Checkpoint::new(cfg.checkpoint_every);
    match mode {
        Mode::Converge => c.seed(&states[..n_owned], Vec::new()),
        Mode::Iterate(_) => c.epoch_mark(&states[..n_owned], 0, Vec::new()),
    }
    Some(c)
}

/// Assemble the global restart state vector after a crash: the crashed
/// locality contributes its last snapshot, survivors contribute their
/// live owned rows (Converge — any achieved vector is a valid monotone
/// restart point) or their snapshot at the rollback epoch (Iterate —
/// every locality rolls back to `rollback_epoch`, the crashed
/// locality's last completed superstep).
pub(crate) fn recovered_states<'a, S: Clone + 'a>(
    dist: &DistGraph,
    parts: impl Iterator<Item = (&'a Shard, &'a [S], Option<&'a Checkpoint<S>>)>,
    crash_l: LocalityId,
    rollback_epoch: Option<u64>,
) -> Vec<S> {
    let mut global: Vec<Option<S>> = vec![None; dist.n()];
    for (shard, live, ckpt) in parts {
        let snapshot = if shard.locality == crash_l {
            Some(
                ckpt.expect("crash planned => checkpointing armed")
                    .latest()
                    .expect("checkpoint stores are pre-seeded"),
            )
        } else {
            rollback_epoch.map(|e| {
                ckpt.expect("crash planned => checkpointing armed")
                    .at_or_before(e)
                    .expect("epoch 0 is always marked")
            })
        };
        let owned: &[S] = match snapshot {
            Some(s) => &s.states[..],
            None => &live[..shard.n_local()],
        };
        for (i, &gid) in shard.owned_ids.iter().enumerate() {
            global[gid as usize] = Some(owned[i].clone());
        }
    }
    global
        .into_iter()
        .map(|s| s.expect("vertex not owned by any shard"))
        .collect()
}

/// Fold a post-crash recovery run's report into the primary run's:
/// additive costs accumulate (the user paid for both runs), the fault
/// block records the restore, and the recovery run's host wall-clock is
/// kept separately as [`FaultStats::recovery_wall_us`](crate::amt::FaultStats).
pub(crate) fn absorb_recovery(base: &mut SimReport, r: &SimReport) {
    base.makespan_us += r.makespan_us;
    base.wall_us += r.wall_us;
    base.events += r.events;
    base.barriers += r.barriers;
    for (b, x) in base.busy_us.iter_mut().zip(&r.busy_us) {
        *b += x;
    }
    base.net.merge(&r.net);
    for (b, x) in base.per_locality_net.iter_mut().zip(&r.per_locality_net) {
        b.merge(x);
    }
    base.agg.merge(&r.agg);
    base.agg_master.merge(&r.agg_master);
    base.agg_mirror.merge(&r.agg_mirror);
    base.work.merge(&r.work);
    base.fault.merge(&r.fault);
    base.phase_wall_us.extend(r.phase_wall_us.iter().copied());
    base.fault.restores += 1;
    base.fault.recovery_wall_us = r.wall_us;
}

/// Initial per-row states for one shard: owned rows get their global
/// out-degree, ghost rows get 0 (install-only slots).
pub(crate) fn init_states<P: VertexProgram>(prog: &P, shard: &Shard) -> Vec<P::State> {
    (0..shard.n_rows())
        .map(|row| {
            let deg = if row < shard.n_local() { shard.out_degree[row] } else { 0 };
            prog.init(shard.global_of(row), deg)
        })
        .collect()
}

/// Assemble the global result: scatter owned states into vertex order and
/// reduce the per-locality superstep deltas elementwise.
pub(crate) fn finish<'a, S: Clone + 'a>(
    dist: &DistGraph,
    parts: impl Iterator<Item = (&'a Shard, &'a [S], &'a [f32])>,
    report: SimReport,
) -> ProgramRun<S> {
    let mut states: Vec<Option<S>> = vec![None; dist.n()];
    let mut deltas: Vec<f32> = Vec::new();
    for (shard, st, dl) in parts {
        for (i, &gid) in shard.owned_ids.iter().enumerate() {
            states[gid as usize] = Some(st[i].clone());
        }
        if deltas.len() < dl.len() {
            deltas.resize(dl.len(), 0.0);
        }
        for (i, d) in dl.iter().enumerate() {
            deltas[i] += d;
        }
    }
    ProgramRun {
        states: states
            .into_iter()
            .map(|s| s.expect("vertex not owned by any shard"))
            .collect(),
        deltas,
        report,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, PartitionKind};

    #[test]
    fn require_mirror_free_names_algo_and_scheme() {
        let g = generators::kron(7, 6, 9);
        let vc = DistGraph::build_with(&g, PartitionKind::VertexCut.build(&g, 4));
        assert!(vc.has_mirrors(), "kron@4 vertex cut should mirror");
        let err = require_mirror_free(&vc, "triangle counting").unwrap_err().to_string();
        assert!(err.contains("triangle counting"), "{err}");
        assert!(err.contains("vertex_cut"), "{err}");
        assert!(err.contains("mirror-free"), "{err}");
        require_mirror_free(&DistGraph::block(&g, 4), "triangle counting").unwrap();
    }
}
