//! The [`VertexProgram`] abstraction: what an algorithm *is*, separated
//! from how an engine *runs* it.
//!
//! The "Anatomy of Large-Scale Distributed Graph Algorithms" line of work
//! argues that distributed graph algorithms should be studied as small
//! vertex programs behind a common abstract-machine API so the execution
//! policy (asynchronous label-correcting, bulk-synchronous supersteps,
//! ordered bucket schedules) can vary independently. This module is that
//! API: a program declares per-row [`VertexProgram::State`], a wire
//! [`VertexProgram::Msg`], and a handful of pure hooks; the three engines
//! in [`engine`](crate::engine) own everything else — mirror-table
//! routing, ghost-slot aggregation, activity/vote termination, work
//! counters, and [`SimReport`](crate::amt::SimReport) stamping.
//!
//! Two scheduling families are expressible through one trait:
//!
//! * **[`Mode::Converge`]** — monotone label-correcting programs (BFS
//!   levels, SSSP distances, CC labels): rows improve under an idempotent
//!   [`VertexProgram::combine`] fold until a global fixpoint; termination
//!   is quiescence (async), an activity vote (BSP), or bucket exhaustion
//!   (delta).
//! * **[`Mode::Iterate`]** — rank-style pull/push rounds (PageRank): every
//!   owned row emits each superstep, messages fold by sum, and
//!   [`VertexProgram::step_update`] advances the state at the barrier for
//!   a fixed iteration count.

use crate::graph::VertexId;

/// How an engine schedules a program.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Monotone label-correcting: run to the combine-fold fixpoint.
    Converge,
    /// Rank-style: exactly this many barrier-separated supersteps.
    Iterate(u32),
}

/// Program-declared capabilities, read once by the engines at setup.
#[derive(Debug, Clone, Copy)]
pub struct ProgramInfo {
    /// Short name used in errors and reports.
    pub name: &'static str,
    /// Scheduling family (see [`Mode`]).
    pub mode: Mode,
    /// The program reads edge weights ([`VertexProgram::along_edge`]'s
    /// `w`). Informational for callers (algorithm drivers validate their
    /// inputs, e.g. `sssp::check_graph_matches`) — the engines themselves
    /// run unweighted graphs as unit weights, which is the documented
    /// degeneration (SSSP == hop count).
    pub needs_weights: bool,
    /// [`VertexProgram::priority`] is a meaningful path metric, so the
    /// ordered bucket schedule ([`run_delta`](crate::engine::run_delta))
    /// applies.
    pub ordered: bool,
    /// Serialized wire size of one `(slot, Msg)` item.
    pub item_bytes: usize,
}

/// A distributed graph algorithm as a vertex program. See the module docs
/// for the engine/program contract; `ARCHITECTURE.md` documents it in
/// prose with the full support matrix.
///
/// Semantics the engines rely on:
///
/// * [`VertexProgram::combine`] must be associative, commutative, and
///   idempotent-safe for [`Mode::Converge`] (min-style) or a plain
///   commutative reduction for [`Mode::Iterate`] (sum-style), so
///   aggregation and message order never change results.
/// * [`VertexProgram::apply`] must be monotone under `Converge`: once
///   [`VertexProgram::beats`] is false for a message it stays false, which
///   is what makes the label-correcting flood finite.
/// * `beats`/`apply`/`signal`/`along_edge` are pure in everything but the
///   row state; engines may call them in any order consistent with message
///   delivery.
pub trait VertexProgram: Send + Sync + 'static {
    /// Per-row state. Owned rows are authoritative; ghost rows hold the
    /// cache/install slot the engines maintain for mirror routing.
    type State: Clone + Send + 'static;
    /// Wire value per destination slot; folded by [`VertexProgram::combine`].
    /// `Default` backs the aggregator's flat combiner storage (dense value
    /// arrays with generation-stamped occupancy — retired slots hold the
    /// default value, never read).
    type Msg: Clone + Send + Default + std::fmt::Debug + 'static;

    /// Capability declaration.
    fn info(&self) -> ProgramInfo;

    /// Initial state of the row for global vertex `v`. `out_degree` is the
    /// global out-degree for owned rows and `0` for ghost rows (whose
    /// state is install-only).
    fn init(&self, v: VertexId, out_degree: u32) -> Self::State;

    /// Message that seeds vertex `v` at start ([`Mode::Converge`] only);
    /// `None` = starts inactive. The async and BSP engines apply it to
    /// every local row of `v` (master and mirrors) and expand the row;
    /// the delta engine seeds master rows only — mirror activation flows
    /// through its settle-scatter protocol, which keeps bucket ordering
    /// intact when a seed lands in a later bucket.
    fn seed(&self, v: VertexId) -> Option<Self::Msg>;

    /// Aggregator fold hook (an associated fn so it can feed
    /// [`Aggregator`](crate::amt::Aggregator)'s function pointer).
    fn combine(acc: &mut Self::Msg, new: Self::Msg);

    /// Would `msg` strictly improve `state`? Pure pre-check the engines
    /// use to prune floods and decide activation.
    fn beats(&self, msg: &Self::Msg, state: &Self::State) -> bool;

    /// Fold `msg` into `state`; returns whether the state changed.
    fn apply(&self, state: &mut Self::State, msg: Self::Msg) -> bool;

    /// The row's current value as a wire message — what masters scatter to
    /// mirrors, what ghost rows forward to their master, and what a row
    /// emits per superstep under [`Mode::Iterate`].
    fn signal(&self, state: &Self::State) -> Self::Msg;

    /// Transform the emitting row's signal into the message carried along
    /// one out-edge (`u` = the emitting row's global id, `w` = the edge
    /// weight; `1.0` on unweighted graphs).
    fn along_edge(&self, u: VertexId, sig: &Self::Msg, w: f32) -> Self::Msg;

    /// Scheduling priority of a message (smaller = sooner). Orders the
    /// async wavefront heap and, when [`ProgramInfo::ordered`], the delta
    /// engine's buckets. Must be non-negative.
    fn priority(&self, _msg: &Self::Msg) -> f32 {
        0.0
    }

    /// Install a master→mirror sync message into a ghost row; returns
    /// whether the mirror's locally homed edges should expand now. The
    /// default is the monotone improvement check; rank-style programs
    /// override it to stash the per-superstep emission.
    fn apply_mirror(&self, state: &mut Self::State, msg: Self::Msg) -> bool {
        if self.beats(&msg, state) {
            self.apply(state, msg);
            true
        } else {
            false
        }
    }

    /// [`Mode::Iterate`] end-of-superstep state advance for one owned row;
    /// returns the row's contribution to the global convergence delta.
    fn step_update(&self, _state: &mut Self::State) -> f32 {
        0.0
    }

    // --- Incremental re-convergence hooks (dynamic graphs) ---------------
    //
    // Consumed by [`incremental`](crate::engine::incremental) when a
    // program re-runs over a mutated graph from its previous fixpoint.
    // Static runs never call them; the defaults are maximally
    // conservative, so programs that ignore dynamic graphs stay correct.

    /// Could `dst`'s converged value have been *derived through* the edge
    /// `src --w--> dst`? Drives deletion invalidation: when the edge goes
    /// away, every state whose justification chain may pass through it is
    /// tainted and recomputed from scratch. Must never return false for a
    /// real dependency (over-taint is only wasted work); the `true`
    /// default taints everything reachable from a deleted edge.
    fn depends_on_edge(&self, _src: &Self::State, _dst: &Self::State, _w: f32) -> bool {
        true
    }

    /// May a warm row with this state re-emit its [`VertexProgram::signal`]
    /// as a reseed? Guards frontier re-seeding: rows whose state encodes
    /// "unreached" (infinite distance, unvisited level) have no signal to
    /// offer — and BFS's `along_edge` would overflow on one.
    fn can_emit(&self, _state: &Self::State) -> bool {
        true
    }

    /// Rebuild a warm row's state from its previous converged value, given
    /// the vertex's *post-update* global out-degree. The default carries
    /// the old state over verbatim; degree-dependent programs (PageRank's
    /// `inv_deg`) override it to refresh derived fields.
    fn rewarm(&self, prev: &Self::State, _v: VertexId, _out_degree: u32) -> Self::State {
        prev.clone()
    }
}
