//! Fluent graph construction helpers.

use super::{Csr, EdgeList, VertexId};

/// Builder collecting edges before CSR finalization, with the usual
/// hygiene toggles applied at `build` time.
#[derive(Debug, Default)]
pub struct GraphBuilder {
    n: usize,
    edges: Vec<(VertexId, VertexId)>,
    weights: Vec<f32>,
    symmetrize: bool,
    dedup: bool,
    drop_self_loops: bool,
}

impl GraphBuilder {
    /// Builder over `n` vertices.
    pub fn new(n: usize) -> Self {
        GraphBuilder { n, ..Default::default() }
    }

    /// Add a directed edge.
    pub fn edge(mut self, u: VertexId, v: VertexId) -> Self {
        self.edges.push((u, v));
        self
    }

    /// Add a weighted directed edge.
    pub fn weighted_edge(mut self, u: VertexId, v: VertexId, w: f32) -> Self {
        self.weights.resize(self.edges.len(), 1.0);
        self.edges.push((u, v));
        self.weights.push(w);
        self
    }

    /// Add many edges.
    pub fn edges(mut self, it: impl IntoIterator<Item = (VertexId, VertexId)>) -> Self {
        self.edges.extend(it);
        self
    }

    /// Mirror every edge at build time.
    pub fn symmetrize(mut self) -> Self {
        self.symmetrize = true;
        self
    }

    /// Remove duplicates at build time.
    pub fn dedup(mut self) -> Self {
        self.dedup = true;
        self
    }

    /// Remove self loops at build time.
    pub fn drop_self_loops(mut self) -> Self {
        self.drop_self_loops = true;
        self
    }

    /// Finalize into CSR.
    pub fn build(self) -> Csr {
        let mut el = EdgeList { n: self.n, edges: self.edges, weights: self.weights };
        if !el.weights.is_empty() {
            el.weights.resize(el.edges.len(), 1.0);
        }
        if self.drop_self_loops {
            el.remove_self_loops();
        }
        if self.symmetrize {
            el.symmetrize();
        } else if self.dedup {
            el.dedup();
        }
        Csr::from_edge_list(&el)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_basic() {
        let g = GraphBuilder::new(3).edge(0, 1).edge(1, 2).build();
        assert_eq!(g.m(), 2);
        assert_eq!(g.neighbors(0), &[1]);
    }

    #[test]
    fn builder_symmetrize_dedup() {
        let g = GraphBuilder::new(3)
            .edges([(0, 1), (0, 1), (1, 1)])
            .drop_self_loops()
            .symmetrize()
            .build();
        assert_eq!(g.m(), 2);
        assert!(g.has_edge(1, 0));
    }

    #[test]
    fn builder_weighted() {
        let g = GraphBuilder::new(2).weighted_edge(0, 1, 4.5).build();
        assert!(g.is_weighted());
        assert_eq!(g.neighbors_weighted(0).next().unwrap(), (1, 4.5));
    }
}
