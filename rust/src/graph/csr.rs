//! Compressed-sparse-row adjacency — the NWGraph "range of ranges".

use super::{EdgeList, VertexId};

/// CSR adjacency. `neighbors(u)` is the inner range of NWGraph's
/// range-of-ranges model; algorithms iterate `for u in 0..n { for v in
/// g.neighbors(u) { .. } }` exactly like the paper's Listing 1.1.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    offsets: Vec<usize>,
    targets: Vec<VertexId>,
    weights: Option<Vec<f32>>,
}

impl Csr {
    /// Build from an edge list (sorts a copy; stable for duplicate edges).
    ///
    /// Weighted inputs must carry finite, non-negative weights: SSSP's
    /// min-fold combine hook
    /// ([`SsspProgram`](crate::algorithms::sssp::SsspProgram)) relies on
    /// `<` being a total order over every tentative distance, which holds
    /// exactly when weights (and therefore path sums) are NaN-free and
    /// non-negative. Checked here, at the single construction choke
    /// point, in debug builds.
    pub fn from_edge_list(el: &EdgeList) -> Self {
        debug_assert!(
            el.weights.iter().all(|w| w.is_finite() && *w >= 0.0),
            "edge weights must be finite and non-negative (SSSP min-folds \
             assume a NaN-free total order on distances)"
        );
        let n = el.n;
        // Counting sort with the offsets array doubling as the scatter
        // cursor: count into offsets[u+1], prefix-sum, scatter through
        // offsets[u] (each row's cursor ends exactly one slot ahead),
        // then shift the array back down — no cloned cursor array.
        let mut offsets = vec![0usize; n + 1];
        for &(u, _) in &el.edges {
            offsets[u as usize + 1] += 1;
        }
        for u in 0..n {
            offsets[u + 1] += offsets[u];
        }
        let mut targets = vec![0 as VertexId; el.edges.len()];
        let mut weights = el.is_weighted().then(|| vec![0.0f32; el.edges.len()]);
        for (i, &(u, v)) in el.edges.iter().enumerate() {
            let at = offsets[u as usize];
            targets[at] = v;
            if let Some(w) = weights.as_mut() {
                w[at] = el.weights[i];
            }
            offsets[u as usize] += 1;
        }
        for u in (1..=n).rev() {
            offsets[u] = offsets[u - 1];
        }
        offsets[0] = 0;
        // Sort each row for deterministic iteration + binary-searchable rows.
        let mut scratch: Vec<(VertexId, f32)> = Vec::new();
        for u in 0..n {
            let r = offsets[u]..offsets[u + 1];
            if let Some(w) = weights.as_mut() {
                scratch.clear();
                scratch.extend(
                    targets[r.clone()].iter().cloned().zip(w[r.clone()].iter().cloned()),
                );
                scratch.sort_by_key(|&(t, _)| t);
                for (k, &(t, wt)) in scratch.iter().enumerate() {
                    targets[r.start + k] = t;
                    w[r.start + k] = wt;
                }
            } else {
                targets[r].sort_unstable();
            }
        }
        Csr { offsets, targets, weights }
    }

    /// Vertex count.
    pub fn n(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Directed edge count.
    pub fn m(&self) -> usize {
        self.targets.len()
    }

    /// True when edges carry weights.
    pub fn is_weighted(&self) -> bool {
        self.weights.is_some()
    }

    /// Out-neighbors of `u` (sorted).
    pub fn neighbors(&self, u: VertexId) -> &[VertexId] {
        let u = u as usize;
        &self.targets[self.offsets[u]..self.offsets[u + 1]]
    }

    /// Out-neighbors of `u` with weights; unweighted graphs yield unit
    /// weights (SSSP on them degenerates to hop counts).
    pub fn neighbors_weighted(&self, u: VertexId) -> impl Iterator<Item = (VertexId, f32)> + '_ {
        let u = u as usize;
        let r = self.offsets[u]..self.offsets[u + 1];
        let w = self.weights.as_deref();
        self.targets[r.clone()]
            .iter()
            .cloned()
            .enumerate()
            .map(move |(k, t)| (t, w.map(|w| w[r.start + k]).unwrap_or(1.0)))
    }

    /// Out-degree of `u`.
    pub fn degree(&self, u: VertexId) -> usize {
        let u = u as usize;
        self.offsets[u + 1] - self.offsets[u]
    }

    /// Does the edge `u -> v` exist? (binary search on the sorted row)
    pub fn has_edge(&self, u: VertexId, v: VertexId) -> bool {
        self.neighbors(u).binary_search(&v).is_ok()
    }

    /// The transposed graph (in-neighbors become out-neighbors). PageRank
    /// pulls over the transpose; BFS parent checks use it in tests.
    pub fn transpose(&self) -> Csr {
        let mut el = EdgeList::new(self.n());
        if let Some(w) = &self.weights {
            el.weights = Vec::with_capacity(self.m());
            for u in 0..self.n() as VertexId {
                let r = self.offsets[u as usize]..self.offsets[u as usize + 1];
                for (k, &v) in self.targets[r.clone()].iter().enumerate() {
                    el.edges.push((v, u));
                    el.weights.push(w[r.start + k]);
                }
            }
        } else {
            for u in 0..self.n() as VertexId {
                for &v in self.neighbors(u) {
                    el.edges.push((v, u));
                }
            }
        }
        Csr::from_edge_list(&el)
    }

    /// Raw offsets (len n+1).
    pub fn offsets(&self) -> &[usize] {
        &self.offsets
    }

    /// Raw target array (len m).
    pub fn targets(&self) -> &[VertexId] {
        &self.targets
    }

    /// Raw weight array (len m), parallel to [`Csr::targets`]; `None` for
    /// unweighted graphs.
    pub fn weights(&self) -> Option<&[f32]> {
        self.weights.as_deref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Csr {
        // 0 -> {1,2}, 1 -> {3}, 2 -> {3}
        Csr::from_edge_list(&EdgeList::from_pairs(4, [(0, 2), (0, 1), (1, 3), (2, 3)]))
    }

    #[test]
    fn rows_are_sorted() {
        let g = diamond();
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(1), &[3]);
        assert_eq!(g.neighbors(3), &[] as &[VertexId]);
        assert_eq!(g.n(), 4);
        assert_eq!(g.m(), 4);
    }

    #[test]
    fn degree_and_has_edge() {
        let g = diamond();
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(3), 0);
        assert!(g.has_edge(0, 2));
        assert!(!g.has_edge(2, 0));
    }

    #[test]
    fn transpose_reverses_every_edge() {
        let g = diamond();
        let t = g.transpose();
        assert_eq!(t.neighbors(3), &[1, 2]);
        assert_eq!(t.neighbors(0), &[] as &[VertexId]);
        assert_eq!(t.m(), g.m());
        // double transpose is identity
        assert_eq!(t.transpose(), g);
    }

    #[test]
    fn weighted_roundtrip() {
        let mut el = EdgeList::new(3);
        el.push_weighted(0, 1, 1.5);
        el.push_weighted(0, 2, 2.5);
        el.push_weighted(1, 2, 3.5);
        let g = Csr::from_edge_list(&el);
        let w0: Vec<_> = g.neighbors_weighted(0).collect();
        assert_eq!(w0, vec![(1, 1.5), (2, 2.5)]);
        let t = g.transpose();
        let wt: Vec<_> = t.neighbors_weighted(2).collect();
        assert_eq!(wt, vec![(0, 2.5), (1, 3.5)]);
    }

    #[test]
    fn empty_graph() {
        let g = Csr::from_edge_list(&EdgeList::new(0));
        assert_eq!(g.n(), 0);
        assert_eq!(g.m(), 0);
    }

    #[test]
    fn plain_bytes_per_edge_is_pinned() {
        // Regression pin for the plain layout: 8 bytes per offset slot
        // (n + 1 of them) + 4 bytes per target. path(9) has n=9, m=16.
        use crate::graph::storage::AdjacencyStorage;
        let g = crate::graph::generators::path(9);
        assert_eq!((g.n(), g.m()), (9, 16));
        assert_eq!(g.heap_bytes(), 10 * 8 + 16 * 4);
        assert_eq!(g.heap_bytes() as f64 / g.m() as f64, 9.0);
        // Weighted adds a parallel 4-byte array.
        let gw = crate::graph::generators::with_random_weights(&g, 1.0, 2.0, 1);
        assert_eq!(gw.heap_bytes(), 10 * 8 + 16 * 4 + 16 * 4);
    }

    #[test]
    fn duplicate_weighted_edges_keep_input_order() {
        // The single-cursor build + stable row sort must keep duplicate
        // (u, v) entries in insertion order, like the cloned-cursor
        // implementation it replaced.
        let mut el = EdgeList::new(3);
        el.push_weighted(0, 2, 9.0);
        el.push_weighted(0, 1, 1.0);
        el.push_weighted(0, 2, 5.0);
        let g = Csr::from_edge_list(&el);
        let row: Vec<_> = g.neighbors_weighted(0).collect();
        assert_eq!(row, vec![(1, 1.0), (2, 9.0), (2, 5.0)]);
    }

    #[test]
    fn zero_weights_are_allowed() {
        let mut el = EdgeList::new(2);
        el.push_weighted(0, 1, 0.0);
        let g = Csr::from_edge_list(&el);
        assert_eq!(g.neighbors_weighted(0).next(), Some((1, 0.0)));
    }

    // debug_assert-backed guards only exist in debug builds; the release
    // CI job must not expect the panic.
    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "finite and non-negative")]
    fn nan_weight_is_rejected_at_build() {
        let mut el = EdgeList::new(2);
        el.push_weighted(0, 1, f32::NAN);
        let _ = Csr::from_edge_list(&el);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "finite and non-negative")]
    fn negative_weight_is_rejected_at_build() {
        let mut el = EdgeList::new(2);
        el.push_weighted(0, 1, -1.5);
        let _ = Csr::from_edge_list(&el);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "finite and non-negative")]
    fn infinite_weight_is_rejected_at_build() {
        let mut el = EdgeList::new(2);
        el.push_weighted(0, 1, f32::INFINITY);
        let _ = Csr::from_edge_list(&el);
    }
}
