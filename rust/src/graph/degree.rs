//! Degree utilities and distribution statistics.

use super::{Csr, VertexId};

/// Out-degree of every vertex.
pub fn out_degrees(g: &Csr) -> Vec<u32> {
    (0..g.n() as VertexId).map(|u| g.degree(u) as u32).collect()
}

/// In-degree of every vertex (one pass over the edges; no transpose).
pub fn in_degrees(g: &Csr) -> Vec<u32> {
    let mut d = vec![0u32; g.n()];
    for &v in g.targets() {
        d[v as usize] += 1;
    }
    d
}

/// Summary statistics of a degree vector.
#[derive(Debug, Clone, PartialEq)]
pub struct DegreeStats {
    /// Smallest degree.
    pub min: u32,
    /// Largest degree.
    pub max: u32,
    /// Mean degree.
    pub mean: f64,
    /// Median degree.
    pub median: u32,
}

/// Compute [`DegreeStats`].
pub fn degree_stats(degrees: &[u32]) -> DegreeStats {
    if degrees.is_empty() {
        return DegreeStats { min: 0, max: 0, mean: 0.0, median: 0 };
    }
    let mut sorted = degrees.to_vec();
    sorted.sort_unstable();
    DegreeStats {
        min: sorted[0],
        max: *sorted.last().unwrap(),
        mean: sorted.iter().map(|&d| d as f64).sum::<f64>() / sorted.len() as f64,
        median: sorted[sorted.len() / 2],
    }
}

/// log2-bucketed degree histogram: `hist[k]` counts vertices with degree in
/// `[2^k, 2^(k+1))`; `hist[0]` also counts degree 0..2.
pub fn degree_histogram(degrees: &[u32]) -> Vec<usize> {
    let mut hist = Vec::new();
    for &d in degrees {
        let bucket = if d <= 1 { 0 } else { (u32::BITS - d.leading_zeros() - 1) as usize };
        if hist.len() <= bucket {
            hist.resize(bucket + 1, 0);
        }
        hist[bucket] += 1;
    }
    hist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn in_degrees_match_transpose() {
        let g = generators::urand_directed(6, 4, 3);
        let t = g.transpose();
        let ind = in_degrees(&g);
        for u in 0..g.n() as VertexId {
            assert_eq!(ind[u as usize] as usize, t.degree(u));
        }
    }

    #[test]
    fn stats_on_star() {
        let g = generators::star(10);
        let s = degree_stats(&out_degrees(&g));
        assert_eq!(s.min, 1);
        assert_eq!(s.max, 9);
        assert!((s.mean - 18.0 / 10.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_buckets() {
        let h = degree_histogram(&[0, 1, 2, 3, 4, 8, 9]);
        // deg 0,1 -> bucket 0; 2,3 -> 1; 4 -> 2; 8,9 -> 3
        assert_eq!(h, vec![2, 2, 1, 2]);
    }

    #[test]
    fn empty_stats() {
        let s = degree_stats(&[]);
        assert_eq!(s.max, 0);
        assert_eq!(degree_histogram(&[]), Vec::<usize>::new());
    }
}
