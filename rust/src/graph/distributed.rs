//! Distributed graph shards: the per-locality slice of a partitioned graph.
//!
//! Each locality owns a contiguous vertex range (see
//! [`Partition1D`](super::Partition1D)) and holds
//!
//! * the **out-CSR** of its owned rows (targets are *global* ids — edges
//!   freely cross localities, exactly like NWGraph adjacency backed by an
//!   `hpx::partitioned_vector` segment), used by push-style traversal;
//! * the **in-CSR** (transposed rows), used by pull-style PageRank;
//! * on demand, a **masked-ELL** encoding of the in-adjacency
//!   ([`EllShard`]) with *virtual-row splitting* for the AOT kernel path —
//!   HLO needs static shapes, so rows wider than the kernel's `max_deg`
//!   are split across several virtual rows whose partial sums the caller
//!   re-accumulates (`row_map`).

use std::ops::Range;

use super::{Csr, Partition1D, VertexId};
use crate::amt::sim::LocalityId;

/// One locality's shard.
#[derive(Debug, Clone)]
pub struct Shard {
    /// Owning locality.
    pub locality: LocalityId,
    /// Owned global vertex range.
    pub range: Range<usize>,
    out_offsets: Vec<usize>,
    out_targets: Vec<VertexId>,
    in_offsets: Vec<usize>,
    in_targets: Vec<VertexId>,
    /// Global out-degree of each owned vertex (PageRank contributions
    /// divide by this).
    pub out_degree: Vec<u32>,
}

impl Shard {
    /// Number of owned vertices.
    pub fn n_local(&self) -> usize {
        self.range.end - self.range.start
    }

    /// Local row index of a global vertex (must be owned).
    pub fn local_index(&self, v: VertexId) -> usize {
        debug_assert!(self.range.contains(&(v as usize)));
        v as usize - self.range.start
    }

    /// Global id of a local row.
    pub fn global_id(&self, local: usize) -> VertexId {
        (self.range.start + local) as VertexId
    }

    /// Out-neighbors (global ids) of the owned vertex with local row `u`.
    pub fn out_neighbors(&self, u: usize) -> &[VertexId] {
        &self.out_targets[self.out_offsets[u]..self.out_offsets[u + 1]]
    }

    /// In-neighbors (global ids) of the owned vertex with local row `u`.
    pub fn in_neighbors(&self, u: usize) -> &[VertexId] {
        &self.in_targets[self.in_offsets[u]..self.in_offsets[u + 1]]
    }

    /// Owned out-edge count.
    pub fn m_out(&self) -> usize {
        self.out_targets.len()
    }

    /// Owned in-edge count.
    pub fn m_in(&self) -> usize {
        self.in_targets.len()
    }

    /// Encode the in-adjacency as masked ELL with virtual-row splitting.
    ///
    /// * `max_deg` — slot width (must match the AOT artifact);
    /// * `pad_rows_to` — pad the virtual row count up to this (artifact
    ///   row count); `0` means no padding.
    ///
    /// Returns `None` if the virtual rows exceed `pad_rows_to`.
    pub fn in_ell(&self, max_deg: usize, pad_rows_to: usize) -> Option<EllShard> {
        let n_local = self.n_local();
        let mut row_map: Vec<u32> = Vec::new();
        let mut cols: Vec<i32> = Vec::new();
        let mut mask: Vec<f32> = Vec::new();
        for u in 0..n_local {
            let nbrs = self.in_neighbors(u);
            let chunks = if nbrs.is_empty() { 1 } else { nbrs.len().div_ceil(max_deg) };
            for c in 0..chunks {
                row_map.push(u as u32);
                let chunk = &nbrs[c * max_deg..((c + 1) * max_deg).min(nbrs.len())];
                for &v in chunk {
                    cols.push(v as i32);
                    mask.push(1.0);
                }
                for _ in chunk.len()..max_deg {
                    cols.push(0);
                    mask.push(0.0);
                }
            }
        }
        let n_virtual = row_map.len();
        let n_rows_padded = if pad_rows_to == 0 { n_virtual } else { pad_rows_to };
        if n_virtual > n_rows_padded {
            return None;
        }
        for _ in n_virtual..n_rows_padded {
            row_map.push(u32::MAX);
            cols.extend(std::iter::repeat(0).take(max_deg));
            mask.extend(std::iter::repeat(0.0).take(max_deg));
        }
        Some(EllShard { n_local, n_virtual, max_deg, n_rows_padded, cols, mask, row_map })
    }
}

/// Masked-ELL in-adjacency for the kernel-offload path (layout contract
/// shared with `python/compile/model.py`).
#[derive(Debug, Clone)]
pub struct EllShard {
    /// Owned (real) rows.
    pub n_local: usize,
    /// Virtual rows before padding (>= n_local).
    pub n_virtual: usize,
    /// Slot width.
    pub max_deg: usize,
    /// Padded row count (artifact shape).
    pub n_rows_padded: usize,
    /// `n_rows_padded * max_deg` global column ids (padding -> 0).
    pub cols: Vec<i32>,
    /// `n_rows_padded * max_deg` slot validity (1.0 real, 0.0 padding).
    pub mask: Vec<f32>,
    /// Virtual row -> owned local row (`u32::MAX` for padding rows).
    pub row_map: Vec<u32>,
}

impl EllShard {
    /// Fold per-virtual-row values back into per-owned-row values
    /// (re-accumulating split rows).
    pub fn fold_rows(&self, virtual_vals: &[f32]) -> Vec<f32> {
        debug_assert_eq!(virtual_vals.len(), self.n_rows_padded);
        let mut out = vec![0.0f32; self.n_local];
        for (r, &owner) in self.row_map.iter().enumerate() {
            if owner != u32::MAX {
                out[owner as usize] += virtual_vals[r];
            }
        }
        out
    }
}

/// A graph partitioned into per-locality shards.
#[derive(Debug, Clone)]
pub struct DistGraph {
    /// The vertex partition.
    pub partition: Partition1D,
    /// One shard per locality.
    pub shards: Vec<Shard>,
    n: usize,
    m: usize,
}

impl DistGraph {
    /// Partition `g` according to `partition`.
    pub fn build(g: &Csr, partition: &Partition1D) -> Self {
        assert_eq!(g.n(), partition.n());
        let t = g.transpose();
        let shards = (0..partition.p())
            .map(|l| {
                let range = partition.range_of(l);
                let mut out_offsets = Vec::with_capacity(range.len() + 1);
                let mut out_targets = Vec::new();
                let mut in_offsets = Vec::with_capacity(range.len() + 1);
                let mut in_targets = Vec::new();
                let mut out_degree = Vec::with_capacity(range.len());
                out_offsets.push(0);
                in_offsets.push(0);
                for v in range.clone() {
                    let v = v as VertexId;
                    out_targets.extend_from_slice(g.neighbors(v));
                    out_offsets.push(out_targets.len());
                    in_targets.extend_from_slice(t.neighbors(v));
                    in_offsets.push(in_targets.len());
                    out_degree.push(g.degree(v) as u32);
                }
                Shard {
                    locality: l,
                    range,
                    out_offsets,
                    out_targets,
                    in_offsets,
                    in_targets,
                    out_degree,
                }
            })
            .collect();
        DistGraph { partition: partition.clone(), shards, n: g.n(), m: g.m() }
    }

    /// Convenience: block partition over `p` localities.
    pub fn block(g: &Csr, p: u32) -> Self {
        DistGraph::build(g, &Partition1D::block(g.n(), p))
    }

    /// Global vertex count.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Global directed edge count.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Locality count.
    pub fn p(&self) -> u32 {
        self.partition.p()
    }

    /// Owner of a global vertex (`vertex_locality_id` of Listing 1.2).
    pub fn owner(&self, v: VertexId) -> LocalityId {
        self.partition.owner(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn shards_cover_all_edges() {
        let g = generators::urand(8, 4, 2);
        let d = DistGraph::block(&g, 4);
        let out_total: usize = d.shards.iter().map(|s| s.m_out()).sum();
        let in_total: usize = d.shards.iter().map(|s| s.m_in()).sum();
        assert_eq!(out_total, g.m());
        assert_eq!(in_total, g.m());
    }

    #[test]
    fn shard_neighbors_match_global_graph() {
        let g = generators::kron(7, 4, 3);
        let d = DistGraph::block(&g, 3);
        for s in &d.shards {
            for u in 0..s.n_local() {
                let gu = s.global_id(u);
                assert_eq!(s.out_neighbors(u), g.neighbors(gu));
                assert_eq!(s.out_degree[u] as usize, g.degree(gu));
            }
        }
    }

    #[test]
    fn in_neighbors_are_the_transpose() {
        let g = generators::urand_directed(6, 4, 5);
        let d = DistGraph::block(&g, 2);
        let t = g.transpose();
        for s in &d.shards {
            for u in 0..s.n_local() {
                assert_eq!(s.in_neighbors(u), t.neighbors(s.global_id(u)));
            }
        }
    }

    #[test]
    fn ell_roundtrip_preserves_spmv() {
        let g = generators::urand_directed(6, 6, 7);
        let d = DistGraph::block(&g, 2);
        let n = g.n();
        let contrib: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin().abs()).collect();
        for s in &d.shards {
            let max_deg = 4; // force row splitting
            let ell = s.in_ell(max_deg, 0).unwrap();
            assert!(ell.n_virtual >= s.n_local());
            // Virtual SpMV then fold == direct in-neighbor sums.
            let mut virt = vec![0.0f32; ell.n_rows_padded];
            for r in 0..ell.n_rows_padded {
                for k in 0..max_deg {
                    let idx = r * max_deg + k;
                    virt[r] += contrib[ell.cols[idx] as usize] * ell.mask[idx];
                }
            }
            let folded = ell.fold_rows(&virt);
            for u in 0..s.n_local() {
                let want: f32 = s.in_neighbors(u).iter().map(|&v| contrib[v as usize]).sum();
                assert!((folded[u] - want).abs() < 1e-4, "row {u}: {} vs {want}", folded[u]);
            }
        }
    }

    #[test]
    fn ell_padding_rows_are_inert() {
        let g = generators::path(10);
        let d = DistGraph::block(&g, 2);
        let ell = d.shards[0].in_ell(8, 16).unwrap();
        assert_eq!(ell.n_rows_padded, 16);
        for r in ell.n_virtual..16 {
            assert_eq!(ell.row_map[r], u32::MAX);
            for k in 0..8 {
                assert_eq!(ell.mask[r * 8 + k], 0.0);
            }
        }
    }

    #[test]
    fn ell_rejects_overflow() {
        let g = generators::star(100);
        let d = DistGraph::block(&g, 1);
        // star center has degree 99; with max_deg 4 that's 25 virtual rows
        // for row 0 alone — padding to 8 rows must fail.
        assert!(d.shards[0].in_ell(4, 8).is_none());
    }
}
